//! End-to-end driver: the paper's full jet-classification experiment.
//!
//! Runs the complete SNAC-Pack pipeline — surrogate training on HLS
//! simulator labels, baseline training, NAC and SNAC-Pack global searches,
//! §4 selection, local search (IMP + QAT), synthesis — and regenerates
//! Tables 2–3 and Figures 1–4 into `results/`.
//!
//! ```bash
//! cargo run --release --example jet_classification           # ci preset
//! cargo run --release --example jet_classification -- paper  # full scale
//! ```
//!
//! This is the EXPERIMENTS.md reference run: the loss curves of every
//! trained candidate, the Pareto fronts, and the paper-vs-measured table
//! comparisons all come from here.

use anyhow::Result;
use snac_pack::config::Preset;
use snac_pack::coordinator::run_pipeline;
use snac_pack::runtime::Runtime;

fn main() -> Result<()> {
    let preset_name = std::env::args().nth(1).unwrap_or_else(|| "ci".to_string());
    let preset = Preset::by_name(&preset_name)?;
    let out = std::path::PathBuf::from("results");
    eprintln!(
        "[jet-classification] preset `{}`: {} trials × {} epochs, pop {}",
        preset.name, preset.search.trials, preset.search.epochs, preset.search.population
    );
    // ./artifacts when present, else whatever this build can load (real
    // AOT artifacts or the checked-in HLO fixtures executed by the
    // rust/xla interpreter)
    let art = snac_pack::runtime::resolve_artifact_dir(std::path::Path::new("artifacts"));
    let rt = Runtime::load(&art)?;
    let summary = run_pipeline(&rt, &preset, &out)?;

    println!("{}", summary.table2);
    println!("{}", summary.table3);
    println!("## Final models");
    for m in &summary.models {
        println!(
            "  {:<18} {} | search acc {:.4} → final test acc {:.4} | sparsity {:.2} | \
             {} LUT, {} DSP, {} BRAM, {} cc",
            m.name,
            m.genome.label(&snac_pack::nn::SearchSpace::table1()),
            m.search_accuracy,
            m.final_accuracy,
            m.sparsity,
            m.synth.lut,
            m.synth.dsp,
            m.synth.bram36,
            m.synth.latency_cc
        );
    }
    println!("\n## Stage timings");
    let mut total = 0.0;
    for (stage, secs) in &summary.timings {
        println!("  {stage:<32} {secs:>8.1}s");
        total += secs;
    }
    println!("  {:<32} {total:>8.1}s", "TOTAL");
    println!("\nreports: results/table2.md, table3.md, fig1..4.csv/.txt, trials_*.json");
    Ok(())
}
