//! Local-search demo: the paper's compression stage on the baseline model.
//!
//! Runs warm-up + iterative magnitude pruning with 8-bit QAT and prints the
//! sparsity/accuracy sweep plus the synthesised resources at each selected
//! deployment point — the data behind Table 3's "pruned to ~50 %, 8-bit"
//! rows.
//!
//! ```bash
//! cargo run --release --example local_search
//! ```

use anyhow::Result;
use snac_pack::compress::{local_search, synthesis_nnz, LocalSearchConfig};
use snac_pack::data::Dataset;
use snac_pack::hls::{synthesize, FpgaDevice, HlsConfig, NetworkSpec};
use snac_pack::nn::{SearchSpace, SupernetInputs};
use snac_pack::runtime::Runtime;
use snac_pack::trainer::Trainer;
use snac_pack::util::Rng;

fn main() -> Result<()> {
    // ./artifacts when present, else whatever this build can load (real
    // AOT artifacts or the checked-in HLO fixtures executed by the
    // rust/xla interpreter)
    let art = snac_pack::runtime::resolve_artifact_dir(std::path::Path::new("artifacts"));
    let rt = Runtime::load(&art)?;
    let ds = Dataset::generate(2560, 640, 640, 7);
    let space = SearchSpace::table1();
    let genome = space.baseline();
    let device = FpgaDevice::vu13p();
    let hls = HlsConfig::default();
    let trainer = Trainer::new(&rt, &ds);
    let cfg = LocalSearchConfig {
        warmup_epochs: 3,
        imp_iterations: 8,
        epochs_per_iteration: 2,
        ..Default::default()
    };
    println!(
        "local search on {}: {} warm-up epochs, {}×{}-epoch IMP @ {:.0}%/iter, {}-bit QAT\n",
        genome.label(&space),
        cfg.warmup_epochs,
        cfg.imp_iterations,
        cfg.epochs_per_iteration,
        cfg.prune_fraction * 100.0,
        cfg.bits
    );
    let t0 = std::time::Instant::now();
    let mut rng = Rng::new(5);
    let result = local_search(&trainer, &genome, &space, &cfg, &mut rng)?;

    println!("iter  sparsity  val-acc   val-loss");
    for rec in &result.history {
        let mark = if rec.iteration == result.selected { "  <- selected" } else { "" };
        println!(
            "{:>4}  {:>7.3}  {:>7.4}  {:>8.4}{mark}",
            rec.iteration, rec.sparsity, rec.val_accuracy, rec.val_loss
        );
    }

    let inputs = SupernetInputs::compile(&genome, &space);
    let nnz = synthesis_nnz(
        &result.model.params,
        &result.masks,
        &inputs,
        &genome,
        &space,
        cfg.bits,
    );
    let spec = NetworkSpec::from_genome_with_nnz(&genome, &space, cfg.bits, &nnz);
    let report = synthesize(&spec, &hls, &device);
    println!("\nper-layer surviving multipliers: {nnz:?}");
    println!(
        "synthesis @ selected point: {} DSP, {} LUT, {} FF, {} BRAM, {} cc ({} ns)",
        report.dsp,
        report.lut,
        report.ff,
        report.bram36,
        report.latency_cc,
        report.latency_ns()
    );
    println!("total {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
