//! Surrogate training demo: the rule4ml-style estimator in isolation.
//!
//! Trains the resource/latency surrogate on HLS-simulator labels and then
//! quantifies its held-out fidelity per target (the paper's §5 point:
//! estimation error shapes what the search finds).
//!
//! ```bash
//! cargo run --release --example surrogate_train
//! ```

use anyhow::Result;
use snac_pack::hls::{synthesize, FpgaDevice, HlsConfig, NetworkSpec};
use snac_pack::nn::SearchSpace;
use snac_pack::runtime::Runtime;
use snac_pack::surrogate::{train_surrogate, SurrogatePredictor, SurrogateTrainConfig};
use snac_pack::util::{OnlineStats, Rng};

fn main() -> Result<()> {
    // ./artifacts when present, else whatever this build can load (real
    // AOT artifacts or the checked-in HLO fixtures executed by the
    // rust/xla interpreter)
    let art = snac_pack::runtime::resolve_artifact_dir(std::path::Path::new("artifacts"));
    let rt = Runtime::load(&art)?;
    let space = SearchSpace::table1();
    let device = FpgaDevice::vu13p();
    let hls = HlsConfig::default();
    let cfg = SurrogateTrainConfig::default();
    println!(
        "training surrogate on {} simulator-labelled architectures, {} epochs…",
        cfg.dataset_size, cfg.epochs
    );
    let t0 = std::time::Instant::now();
    let (params, mse) = train_surrogate(&rt, &space, &cfg, &hls, &device)?;
    println!(
        "trained in {:.1}s; final MSE {mse:.5} (log1p space)",
        t0.elapsed().as_secs_f64()
    );

    // ---- held-out evaluation: fresh genomes the trainer never saw ----
    let sur = SurrogatePredictor::new(&rt, params);
    let mut rng = Rng::new(2077);
    let names = ["BRAM", "DSP", "FF", "LUT", "latency_cc", "II"];
    let mut stats: Vec<OnlineStats> = (0..6).map(|_| OnlineStats::new()).collect();
    let n = 200;
    for _ in 0..n {
        let g = space.sample(&mut rng);
        let bits = *rng.choose(&[4u32, 6, 8, 12]);
        let sparsity = rng.uniform() * 0.9;
        let est = sur.predict(&g, &space, bits, sparsity)?;
        let truth = synthesize(&NetworkSpec::from_genome(&g, &space, bits, sparsity), &hls, &device);
        let truths = [
            truth.bram36 as f64,
            truth.dsp as f64,
            truth.ff as f64,
            truth.lut as f64,
            truth.latency_cc as f64,
            truth.ii_cc as f64,
        ];
        let ests = [est.bram, est.dsp, est.ff, est.lut, est.latency_cc, est.ii_cc];
        for k in 0..6 {
            stats[k].push((ests[k] - truths[k]).abs() / (truths[k] + 1.0));
        }
    }
    println!("\nheld-out mean relative error over {n} fresh architectures:");
    for (name, s) in names.iter().zip(&stats) {
        println!(
            "  {name:<10} {:>6.1}%  (max {:>6.1}%)",
            s.mean() * 100.0,
            s.max() * 100.0
        );
    }
    println!("\n(rule4ml reports ~10-30% resource errors on real synthesis — the");
    println!(" surrogate is intentionally imperfect; SNAC-Pack searches on estimates.)");
    Ok(())
}
