//! Quickstart: the smallest end-to-end SNAC-Pack run.
//!
//! Loads the AOT artifacts, generates a tiny jet dataset, runs a miniature
//! NAC-objective global search, and prints the Pareto front.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! # run it twice with a cache snapshot: the second run retrains nothing
//! cargo run --release --example quickstart -- --cache-path /tmp/snac_cache.json
//! cargo run --release --example quickstart -- --cache-path /tmp/snac_cache.json
//! ```

use std::path::PathBuf;

use anyhow::Result;
use snac_pack::config::Preset;
use snac_pack::coordinator::{global_search, GlobalSearchConfig};
use snac_pack::data::Dataset;
use snac_pack::hls::FpgaDevice;
use snac_pack::nn::SearchSpace;
use snac_pack::objectives::{ObjectiveContext, ObjectiveKind};
use snac_pack::runtime::Runtime;

fn main() -> Result<()> {
    // sole optional flag: `--cache-path FILE` persists the evaluation
    // cache, so a second quickstart run reports pure cache hits
    let args: Vec<String> = std::env::args().collect();
    let cache_path: Option<PathBuf> = args
        .iter()
        .position(|a| a == "--cache-path")
        .and_then(|i| args.get(i + 1))
        .map(PathBuf::from);

    // ./artifacts when present, else whatever this build can load (real
    // AOT artifacts or the checked-in HLO fixtures executed by the
    // rust/xla interpreter)
    let art = snac_pack::runtime::resolve_artifact_dir(std::path::Path::new("artifacts"));
    let rt = Runtime::load(&art)?;
    println!("PJRT platform: {}", rt.platform());

    let preset = Preset::by_name("quickstart")?;
    let ds = Dataset::generate(
        preset.data.n_train,
        preset.data.n_val,
        preset.data.n_test,
        preset.data.seed,
    );
    let space = SearchSpace::table1();
    let device = FpgaDevice::vu13p();
    println!(
        "search space: {} architectures; dataset: {} train jets",
        space.architecture_count(),
        preset.data.n_train
    );

    let outcome = global_search(
        &rt,
        &ds,
        &space,
        GlobalSearchConfig {
            objectives: ObjectiveKind::nac_set(),
            ctx: ObjectiveContext {
                space: &space,
                device: &device,
                surrogate: None,
                bits: 8,
                sparsity: 0.5,
            },
            nsga2: preset.nsga2(),
            trials: preset.search.trials,
            epochs: preset.search.epochs,
            seed: preset.seed,
            workers: preset.search.workers,
            accuracy_threshold: 0.0,
            progress: Some(Box::new(|i, n, r| {
                println!("  trial {i:>2}/{n}: {:<28} acc={:.4}", r.label, r.accuracy);
            })),
            cache_path,
        },
    )?;

    println!("\nPareto front (accuracy vs BOPs):");
    for &i in &outcome.front {
        let r = &outcome.records[i];
        println!(
            "  {:<28} acc={:.4}  bops={:>8.0}",
            r.label, r.accuracy, r.bops
        );
    }
    println!(
        "\ncache: {} trained, {} cache hits, {} restored from snapshot",
        outcome.evaluations, outcome.cache_hits, outcome.cache_restored
    );
    println!(
        "{} trials in {:.1}s — see examples/jet_classification.rs for the full pipeline",
        outcome.records.len(),
        outcome.wall_seconds
    );
    Ok(())
}
