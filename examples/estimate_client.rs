//! Example client for the `snac-pack serve` estimation service.
//!
//! Start the service in one terminal, then point this client at it:
//!
//! ```bash
//! cargo run --release -- serve --preset quickstart --port 7878
//! cargo run --release --example estimate_client              # default addr
//! cargo run --release --example estimate_client -- 127.0.0.1:7878
//! ```
//!
//! The client checks `/healthz`, estimates a handful of sampled
//! architectures one at a time (`POST /estimate`), then re-estimates the
//! same set in one round trip (`POST /estimate/batch`) — demonstrating
//! that the batch endpoint and the micro-batched singles return the
//! identical numbers.

use anyhow::{Context, Result};
use snac_pack::nn::SearchSpace;
use snac_pack::serve::http;
use snac_pack::util::{Json, Rng};

fn main() -> Result<()> {
    let addr = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "127.0.0.1:7878".to_string());

    let (status, body) = http::request(&addr, "GET", "/healthz", None)
        .with_context(|| format!("is `snac-pack serve` running on {addr}?"))?;
    anyhow::ensure!(status == 200, "healthz returned {status}: {body}");
    let health = Json::parse(&body).map_err(anyhow::Error::msg)?;
    println!(
        "service ok: platform {}, device {}, {} memoised rows",
        health.get("platform").and_then(Json::as_str).unwrap_or("?"),
        health.get("device").and_then(Json::as_str).unwrap_or("?"),
        health.get("memo_rows").and_then(Json::as_f64).unwrap_or(0.0)
    );

    let space = SearchSpace::table1();
    let mut rng = Rng::new(2077);
    let genomes: Vec<_> = (0..5).map(|_| space.sample(&mut rng)).collect();

    println!("\nsingle estimates (8-bit, 50% sparse):");
    let mut singles = Vec::new();
    for g in &genomes {
        let req = Json::obj(vec![
            ("genome", g.to_json()),
            ("bits", Json::Num(8.0)),
            ("sparsity", Json::Num(0.5)),
        ]);
        let (status, body) = http::request(&addr, "POST", "/estimate", Some(&req.to_string()))?;
        anyhow::ensure!(status == 200, "estimate returned {status}: {body}");
        let est = Json::parse(&body).map_err(anyhow::Error::msg)?;
        let f = |k: &str| est.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
        println!(
            "  {:<24} LUT {:>9.0}  DSP {:>6.0}  latency {:>6.0}cc  avg res {:>6.2}%",
            g.label(&space),
            f("lut"),
            f("dsp"),
            f("latency_cc"),
            f("avg_resources")
        );
        singles.push(body);
    }

    let batch = Json::obj(vec![(
        "requests",
        Json::Arr(
            genomes
                .iter()
                .map(|g| {
                    Json::obj(vec![
                        ("genome", g.to_json()),
                        ("bits", Json::Num(8.0)),
                        ("sparsity", Json::Num(0.5)),
                    ])
                })
                .collect(),
        ),
    )]);
    let (status, body) =
        http::request(&addr, "POST", "/estimate/batch", Some(&batch.to_string()))?;
    anyhow::ensure!(status == 200, "batch returned {status}: {body}");
    let results = Json::parse(&body).map_err(anyhow::Error::msg)?;
    let results = results.get("results").context("no `results`")?.items().to_vec();
    anyhow::ensure!(results.len() == genomes.len(), "short batch response");
    for (single, batched) in singles.iter().zip(&results) {
        let single = Json::parse(single).map_err(anyhow::Error::msg)?;
        anyhow::ensure!(
            single == *batched,
            "batch and single estimates disagree: {single:?} vs {batched:?}"
        );
    }
    println!(
        "\nbatch of {} re-estimated in one round trip — identical to the singles ✓",
        genomes.len()
    );
    Ok(())
}
