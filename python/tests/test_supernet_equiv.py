"""L2 correctness: the padded supernet is EXACTLY the candidate MLP.

The entire reproduction hinges on one claim (DESIGN.md "Why a supernet?"):
evaluating the masked/gated supernet with a genome's masks equals
evaluating that genome's literal MLP. These tests build independent
per-architecture reference networks with sliced (unpadded) weights and
assert equivalence of logits and of training dynamics over the full
Table 1 hyperparameter grid (depth, widths, activation, BN on/off).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

L, P, I, O = M.NUM_LAYERS, M.PAD, M.IN_DIM, M.OUT_DIM

# Table 1 width choices per hidden layer
WIDTH_CHOICES = [
    [64, 120, 128], [32, 60, 64], [16, 32], [32, 64],
    [32, 64], [32, 64], [16, 32], [32, 44, 64],
]

genomes = st.fixed_dictionaries(
    {
        "n_layers": st.integers(4, 8),
        "width_idx": st.tuples(*[st.integers(0, len(c) - 1) for c in WIDTH_CHOICES]),
        "act": st.integers(0, 2),
        "bn": st.booleans(),
        "seed": st.integers(0, 2**31 - 1),
    }
)


def make_inputs(g):
    """Genome → supernet mask/gate inputs + the sliced width list."""
    widths = [WIDTH_CHOICES[i][g["width_idx"][i]] for i in range(L)]
    unit = np.zeros((L, P), np.float32)
    gates = np.zeros((L,), np.float32)
    for i in range(g["n_layers"]):
        unit[i, : widths[i]] = 1.0
        gates[i] = 1.0
    act = np.zeros((3,), np.float32)
    act[g["act"]] = 1.0
    return (
        jnp.asarray(unit),
        jnp.asarray(gates),
        jnp.asarray(act),
        widths[: g["n_layers"]],
    )


def make_params(rng):
    return {
        "w0": jnp.asarray(rng.randn(I, P).astype(np.float32) / np.sqrt(I)),
        "wh": jnp.asarray(rng.randn(L - 1, P, P).astype(np.float32) / np.sqrt(P)),
        "b": jnp.asarray(rng.randn(L, P).astype(np.float32) * 0.1),
        "gamma": jnp.asarray(1.0 + 0.1 * rng.randn(L, P).astype(np.float32)),
        "beta": jnp.asarray(0.1 * rng.randn(L, P).astype(np.float32)),
        "wo": jnp.asarray(rng.randn(P, O).astype(np.float32) / np.sqrt(P)),
        "bo": jnp.asarray(rng.randn(O).astype(np.float32) * 0.1),
    }


ACTS = [jax.nn.relu, jnp.tanh, jax.nn.sigmoid]


def literal_mlp(params, g, widths, x, bn):
    """Independent NumPy/jnp reference: the *sliced* candidate network."""
    act = ACTS[g["act"]]
    h = x
    prev = I
    for i, wdt in enumerate(widths):
        w = (params["w0"] if i == 0 else params["wh"][i - 1])[:prev, :wdt]
        bias = params["b"][i][:wdt]
        z = h @ w + bias[None, :]
        if bn:
            mean = z.mean(axis=0)
            var = ((z - mean) ** 2).mean(axis=0)
            zn = (z - mean) / jnp.sqrt(var + M.BN_EPS)
            z = params["gamma"][i][:wdt] * zn + params["beta"][i][:wdt]
        h = act(z)
        prev = wdt
    w = params["wo"][:prev, :]
    return h @ w + params["bo"][None, :]


def ones_masks():
    return (
        jnp.ones((I, P), jnp.float32),
        jnp.ones((L - 1, P, P), jnp.float32),
        jnp.ones((P, O), jnp.float32),
    )


@settings(deadline=None, max_examples=20)
@given(g=genomes)
def test_supernet_forward_equals_literal_mlp(g):
    rng = np.random.RandomState(g["seed"])
    params = make_params(rng)
    unit, gates, act_sel, widths = make_inputs(g)
    p0, ph, po = ones_masks()
    x = jnp.asarray(rng.randn(64, I).astype(np.float32))
    masks = {"unit": unit, "p0": p0, "ph": ph, "po": po}
    arch = {"gates": gates, "act_sel": act_sel}
    bn = 1.0 if g["bn"] else 0.0
    logits, _, _, _ = M.supernet_forward(
        params, masks, arch, bn, 0.0, 8.0, x, dropout=None
    )
    want = literal_mlp(params, g, widths, x, g["bn"])
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want), rtol=2e-4, atol=2e-4)


def _default_hp(t, lr=2e-3, l1=0.0, bn=1.0, drop=0.0, qat=0.0, bits=8.0, mom=0.1):
    b1, b2 = 0.9, 0.999
    return jnp.asarray(
        [bn, drop, qat, bits, lr, l1, b1, b2, 1e-8, b1**t, b2**t, float(t), mom],
        jnp.float32,
    )


def _init_state(rng):
    params = make_params(rng)
    p = [params[k] for k in M.PARAM_KEYS]
    zeros = [jnp.zeros_like(a) for a in p]
    return p, list(zeros), list(zeros)


def _toy_data(rng, n):
    w_true = rng.randn(I, O)
    x = rng.randn(n, I).astype(np.float32)
    y = (x @ w_true + 0.5 * rng.randn(n, O)).argmax(1)
    return x, np.eye(O, dtype=np.float32)[y]


@pytest.fixture(scope="module")
def jitted_train_step():
    return jax.jit(M.train_step)


def _run_training(jitted, hp_fn, steps=40, seed=0, prune=None):
    rng = np.random.RandomState(seed)
    p, m, v = _init_state(rng)
    g = {"n_layers": 4, "width_idx": (0, 0, 0, 0, 0, 0, 0, 0), "act": 0,
         "bn": True, "seed": seed}
    unit, gates, act_sel, _ = make_inputs(g)
    p0, ph, po = prune if prune is not None else ones_masks()
    x, y1h = _toy_data(rng, M.BATCH)
    rm = jnp.zeros((L, P), jnp.float32)
    rv = jnp.ones((L, P), jnp.float32)
    losses = []
    for t in range(1, steps + 1):
        out = jitted(
            *p, *m, *v, unit, p0, ph, po, gates, act_sel, hp_fn(t), rm, rv,
            jnp.asarray(x), jnp.asarray(y1h),
        )
        p, m, v = list(out[:7]), list(out[7:14]), list(out[14:21])
        losses.append(float(out[21]))
        rm, rv = out[23], out[24]
    return p, losses


def test_train_step_reduces_loss(jitted_train_step):
    _, losses = _run_training(jitted_train_step, lambda t: _default_hp(t))
    assert losses[-1] < 0.5 * losses[0]


def test_train_step_qat_reduces_loss(jitted_train_step):
    _, losses = _run_training(
        jitted_train_step, lambda t: _default_hp(t, qat=1.0, bits=8.0)
    )
    assert losses[-1] < 0.7 * losses[0]


def test_pruned_weights_stay_exactly_zero(jitted_train_step):
    rng = np.random.RandomState(7)
    p0 = (rng.rand(I, P) > 0.5).astype(np.float32)
    ph = (rng.rand(L - 1, P, P) > 0.5).astype(np.float32)
    po = (rng.rand(P, O) > 0.5).astype(np.float32)
    prune = (jnp.asarray(p0), jnp.asarray(ph), jnp.asarray(po))
    p, _ = _run_training(jitted_train_step, lambda t: _default_hp(t), prune=prune)
    assert (np.asarray(p[0])[p0 == 0] == 0.0).all()
    assert (np.asarray(p[1])[ph == 0] == 0.0).all()
    assert (np.asarray(p[5])[po == 0] == 0.0).all()


def test_l1_regularisation_shrinks_weights(jitted_train_step):
    p_plain, _ = _run_training(jitted_train_step, lambda t: _default_hp(t), steps=30)
    p_l1, _ = _run_training(
        jitted_train_step, lambda t: _default_hp(t, l1=1e-3), steps=30
    )
    assert np.abs(np.asarray(p_l1[0])).sum() < np.abs(np.asarray(p_plain[0])).sum()


def test_inactive_layer_weights_get_no_update(jitted_train_step):
    """Gated-off layers (depth < 8) must not train: their weights are
    untouched by the data path, and L1 is gated too."""
    rng = np.random.RandomState(3)
    p, m, v = _init_state(rng)
    g = {"n_layers": 4, "width_idx": (0,) * 8, "act": 0, "bn": False, "seed": 3}
    unit, gates, act_sel, _ = make_inputs(g)
    p0, ph, po = ones_masks()
    x, y1h = _toy_data(rng, M.BATCH)
    wh_before = np.asarray(p[1]).copy()
    out = jitted_train_step(
        *p, *m, *v, unit, p0, ph, po, gates, act_sel, _default_hp(1, l1=1e-4),
        jnp.zeros((L, P)), jnp.ones((L, P)),
        jnp.asarray(x), jnp.asarray(y1h),
    )
    wh_after = np.asarray(out[1])
    # layers 5..8 are gated off → rows 4..6 of wh (wh[i] serves layer i+1)
    np.testing.assert_array_equal(wh_after[4:], wh_before[4:])
    # layer 2 (wh[0]) is active → it must have moved
    assert np.abs(wh_after[0] - wh_before[0]).max() > 0


def test_eval_step_consistent_with_forward():
    rng = np.random.RandomState(11)
    params = make_params(rng)
    g = {"n_layers": 5, "width_idx": (1, 1, 1, 1, 1, 1, 1, 1), "act": 1,
         "bn": True, "seed": 11}
    unit, gates, act_sel, widths = make_inputs(g)
    p0, ph, po = ones_masks()
    x = np.zeros((M.EVAL_BATCH, I), np.float32)
    x[:256] = rng.randn(256, I)
    y = rng.randint(0, O, M.EVAL_BATCH)
    y1h = np.eye(O, dtype=np.float32)[y]
    run_mean = jnp.asarray(0.01 * rng.randn(L, P).astype(np.float32))
    run_var = jnp.asarray(1.0 + 0.1 * rng.rand(L, P).astype(np.float32))
    p = [params[k] for k in M.PARAM_KEYS]
    correct, loss, logits = jax.jit(M.eval_step)(
        *p, unit, p0, ph, po, gates, act_sel,
        jnp.asarray([1.0, 0.0, 8.0], jnp.float32), run_mean, run_var,
        jnp.asarray(x), jnp.asarray(y1h),
    )
    # independent recomputation with the running stats
    masks = {"unit": unit, "p0": p0, "ph": ph, "po": po}
    arch = {"gates": gates, "act_sel": act_sel}
    want, _, _, _ = M.supernet_forward(
        params, masks, arch, 1.0, 0.0, 8.0, jnp.asarray(x),
        bn_stats=(run_mean, run_var),
    )
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want), rtol=1e-5, atol=1e-5)
    acc = (np.asarray(want).argmax(1) == y).sum()
    assert float(correct) == pytest.approx(acc)


def test_dropout_zero_rate_is_identity():
    rng = np.random.RandomState(5)
    params = make_params(rng)
    g = {"n_layers": 4, "width_idx": (0,) * 8, "act": 0, "bn": False, "seed": 5}
    unit, gates, act_sel, widths = make_inputs(g)
    p0, ph, po = ones_masks()
    x = jnp.asarray(rng.randn(32, I).astype(np.float32))
    masks = {"unit": unit, "p0": p0, "ph": ph, "po": po}
    arch = {"gates": gates, "act_sel": act_sel}
    key = jax.random.PRNGKey(0)
    with_drop, _, _, _ = M.supernet_forward(
        params, masks, arch, 0.0, 0.0, 8.0, x, dropout=(jnp.float32(0.0), key)
    )
    without, _, _, _ = M.supernet_forward(
        params, masks, arch, 0.0, 0.0, 8.0, x, dropout=None
    )
    np.testing.assert_allclose(np.asarray(with_drop), np.asarray(without), rtol=1e-6)


def test_dropout_scales_expectation():
    rng = np.random.RandomState(6)
    params = make_params(rng)
    g = {"n_layers": 4, "width_idx": (2, 2, 1, 1, 0, 0, 0, 0), "act": 0,
         "bn": False, "seed": 6}
    unit, gates, act_sel, _ = make_inputs(g)
    p0, ph, po = ones_masks()
    x = jnp.asarray(rng.randn(M.BATCH, I).astype(np.float32))
    masks = {"unit": unit, "p0": p0, "ph": ph, "po": po}
    arch = {"gates": gates, "act_sel": act_sel}
    outs = []
    for s in range(30):
        o, _, _, _ = M.supernet_forward(
            params, masks, arch, 0.0, 0.0, 8.0, x,
            dropout=(jnp.float32(0.1), jax.random.PRNGKey(s)),
        )
        outs.append(np.asarray(o))
    mean_drop = np.mean(outs, axis=0)
    base, _, _, _ = M.supernet_forward(
        params, masks, arch, 0.0, 0.0, 8.0, x, dropout=None
    )
    # inverted dropout: E[output] ≈ deterministic output (loose tolerance —
    # nonlinearities break exact equality; this guards the 1/(1-p) scaling)
    corr = np.corrcoef(mean_drop.ravel(), np.asarray(base).ravel())[0, 1]
    assert corr > 0.98
