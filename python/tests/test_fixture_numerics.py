"""End-to-end numeric validation of the rust/xla HLO-text fixtures.

Unlike the other files in this directory, this needs **numpy only** (no
JAX): it re-implements, bit-faithfully, the Rust side's PRNG
(`rust/src/util/rng.rs`), jet generator + dataset
(`rust/src/data/{jets,dataset}.rs`) and training driver
(`rust/src/trainer/supernet.rs`), interprets the checked-in HLO fixtures
under `rust/xla/tests/fixtures/` with a small numpy HLO evaluator that
mirrors `rust/xla/src/interp.rs` semantics, and asserts the *same
thresholds* the Rust runtime-gated tests assert:

* `train_step`: 3 epochs on `Dataset::generate(1280, 256, 256, 11)` —
  loss falls, final epoch < 1.55;
* `eval_step`: test accuracy > 0.30 for the baseline genome;
* prune-20% + 1 resumed epoch keeps pruned `w0` coordinates exactly 0
  and accuracy > 0.30;
* `surrogate_predict`: zero weights → prediction == output bias (the
  linear-at-zero-weights property of runtime.rs);
* `surrogate_train`: Adam steps reduce the MSE loss;
* the micro local-search budget (warm-up 1 + 3 IMP epochs on the
  `quickstart` 640-row split) still beats chance at ~50 % sparsity.

Run directly (`python3 python/tests/test_fixture_numerics.py`) or via
pytest. If thresholds drift, regenerate fixtures with
`rust/xla/tests/fixtures/generate.py` and re-run this file first.
"""

import math
import os
import re
import sys

import numpy as np

FIXTURES = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "..", "rust", "xla", "tests", "fixtures"
)

MASK64 = (1 << 64) - 1

# ---------------------------------------------------------------------------
# rust/src/util/rng.rs — xoshiro256** + SplitMix64, bit-exact
# ---------------------------------------------------------------------------


def _splitmix64(state):
    state = (state + 0x9E3779B97F4A7C15) & MASK64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    return state, (z ^ (z >> 31)) & MASK64


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK64


class Rng:
    def __init__(self, seed):
        sm = seed & MASK64
        s = []
        for _ in range(4):
            sm, v = _splitmix64(sm)
            s.append(v)
        self.s = s
        self.spare = None

    def next_u64(self):
        s = self.s
        result = (_rotl((s[1] * 5) & MASK64, 7) * 9) & MASK64
        t = (s[1] << 17) & MASK64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def uniform(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n):
        return (self.next_u64() * n) >> 64

    def chance(self, p):
        return self.uniform() < p

    def normal(self):
        if self.spare is not None:
            z, self.spare = self.spare, None
            return z
        u1 = 1.0 - self.uniform()
        u2 = self.uniform()
        r = math.sqrt(-2.0 * math.log(u1))
        theta = 2.0 * math.pi * u2
        self.spare = r * math.sin(theta)
        return r * math.cos(theta)

    def normal_f32(self):
        return np.float32(self.normal())

    def fill_normal(self, n, sigma):
        sigma = np.float32(sigma)
        return np.array([self.normal_f32() * sigma for _ in range(n)], dtype=np.float32)

    def choose(self, items):
        return items[self.below(len(items))]

    def shuffle(self, items):
        for i in range(len(items) - 1, 0, -1):
            j = self.below(i + 1)
            items[i], items[j] = items[j], items[i]

    def permutation(self, n):
        idx = list(range(n))
        self.shuffle(idx)
        return idx


# ---------------------------------------------------------------------------
# rust/src/data/jets.rs + dataset.rs
# ---------------------------------------------------------------------------

N_CONST, IN_DIM, OUT_DIM = 8, 24, 5
PAD, L, BATCH, EVAL_BATCH, HP_LEN = 128, 8, 128, 512, 13
TAU = 2.0 * math.pi


def _two_body(mass, pt, rng):
    dr = 2.0 * mass / pt * (1.0 + 0.18 * rng.normal())
    axis = rng.uniform() * TAU
    z = 0.35 + 0.3 * rng.uniform()
    return [
        [dr * (1.0 - z) * math.cos(axis), dr * (1.0 - z) * math.sin(axis), z, 0.03],
        [-dr * z * math.cos(axis), -dr * z * math.sin(axis), 1.0 - z, 0.03],
    ]


def _prongs(cls, pt, rng):
    if cls == 0:
        return [[0.0, 0.0, 1.0, 0.04]]
    if cls == 1:
        return [[0.0, 0.0, 1.0, 0.10]]
    if cls == 2:
        return _two_body(80.4, pt, rng)
    if cls == 3:
        return _two_body(91.2, pt, rng)
    p = _two_body(80.4, pt, rng)
    dr_b = 2.0 * 172.8 / pt * (1.0 + 0.15 * rng.normal())
    axis = rng.uniform() * TAU
    for prong in p:
        prong[0] += 0.55 * dr_b * math.cos(axis)
        prong[1] += 0.55 * dr_b * math.sin(axis)
        prong[2] *= 0.65
    p.append([-0.45 * dr_b * math.cos(axis), -0.45 * dr_b * math.sin(axis), 0.35, 0.04])
    return p


def generate_jet(cls, rng, pt_range=(800.0, 1200.0), smear=0.025, soft_fraction=0.25):
    pt = pt_range[0] + (pt_range[1] - pt_range[0]) * rng.uniform()
    prongs = _prongs(cls, pt, rng)
    n_pieces = 14 if cls == 1 else (9 if cls == 0 else 12)
    consts = []
    for k in range(n_pieces):
        u = rng.uniform()
        prong = prongs[0]
        for p in prongs:
            if u < p[2]:
                prong = p
                break
            u -= p[2]
        if k < len(prongs):
            frac = 0.5 + 0.2 * rng.uniform()
        else:
            frac = -math.log(max(rng.uniform(), 1e-9)) * 0.08
        c_pt = pt * (1.0 - soft_fraction) * frac * prong[2]
        eta = prong[0] + prong[3] * rng.normal() + smear * rng.normal()
        phi = prong[1] + prong[3] * rng.normal() + smear * rng.normal()
        consts.append((c_pt, eta, phi))
    for _ in range(4):
        c_pt = pt * soft_fraction * (-math.log(max(rng.uniform(), 1e-9))) * 0.12
        consts.append((c_pt, 0.35 * rng.normal(), 0.35 * rng.normal()))
    consts.sort(key=lambda c: c[0], reverse=True)
    consts = consts[:N_CONST]
    total_pt = sum(c[0] for c in consts)
    out = np.zeros(IN_DIM, dtype=np.float32)
    for i, (c_pt, eta, phi) in enumerate(consts):
        out[i * 3] = np.float32(c_pt / total_pt)
        out[i * 3 + 1] = np.float32(eta)
        out[i * 3 + 2] = np.float32(phi)
    return out


class Dataset:
    def __init__(self, n_train, n_val, n_test, seed):
        rng = Rng(seed)
        total = n_train + n_val + n_test
        feats = np.zeros((total, IN_DIM), dtype=np.float32)
        labels = np.zeros(total, dtype=np.int64)
        for i in range(total):
            cls = i % OUT_DIM
            feats[i] = generate_jet(cls, rng)
            labels[i] = cls
        perm = rng.permutation(total)
        feats = feats[perm]
        labels = labels[perm]
        # standardise on the train split (f64 stats, applied in f32)
        tr = feats[:n_train].astype(np.float64)
        mean = tr.mean(axis=0).astype(np.float32)
        std = np.maximum(np.sqrt(tr.var(axis=0)).astype(np.float32), np.float32(1e-6))
        self.features = ((feats - mean) / std).astype(np.float32)
        self.labels = labels
        self.n_train, self.n_val, self.n_test = n_train, n_val, n_test

    def split(self, which):
        a = {"train": 0, "val": self.n_train, "test": self.n_train + self.n_val}[which]
        b = a + {"train": self.n_train, "val": self.n_val, "test": self.n_test}[which]
        return a, b

    def train_epoch(self, rng):
        n = self.n_train
        perm = rng.permutation(n)
        batches = []
        for b in range(n // BATCH):
            idx = perm[b * BATCH : (b + 1) * BATCH]
            x = self.features[idx]
            y = np.zeros((BATCH, OUT_DIM), dtype=np.float32)
            y[np.arange(BATCH), self.labels[idx]] = 1.0
            batches.append((x, y, BATCH))
        return batches

    def eval_tiles(self, which, tile):
        a, b = self.split(which)
        out = []
        i = a
        while i < b:
            rows = min(tile, b - i)
            x = np.zeros((tile, IN_DIM), dtype=np.float32)
            y = np.zeros((tile, OUT_DIM), dtype=np.float32)
            x[:rows] = self.features[i : i + rows]
            y[np.arange(rows), self.labels[i : i + rows]] = 1.0
            out.append((x, y, rows))
            i += rows
        return out


# ---------------------------------------------------------------------------
# numpy HLO interpreter (mirrors rust/xla/src/{parser,interp}.rs semantics)
# ---------------------------------------------------------------------------

INSTR_RE = re.compile(r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")


def _parse_shape(s, pos):
    while s[pos] == " ":
        pos += 1
    if s[pos] == "(":
        shapes = []
        pos += 1
        while s[pos] != ")":
            sh, pos = _parse_shape(s, pos)
            shapes.append(sh)
            while s[pos] in ", ":
                pos += 1
        return ("tuple", shapes), pos + 1
    m = re.match(r"(\w+)\[([\d,\s]*)\]", s[pos:])
    dtype = m.group(1)
    dims = tuple(int(d) for d in m.group(2).split(",") if d.strip())
    pos += m.end()
    if pos < len(s) and s[pos] == "{":  # layout — skip
        pos = s.index("}", pos) + 1
    return (dtype, dims), pos


def _split_top(s):
    parts, depth, start = [], 0, 0
    for i, c in enumerate(s):
        if c in "{[(":
            depth += 1
        elif c in "}])":
            depth -= 1
        elif c == "," and depth == 0:
            parts.append(s[start:i])
            start = i + 1
    parts.append(s[start:])
    return [p.strip() for p in parts if p.strip()]


def _int_list(v):
    return [int(t) for t in v.strip().strip("{}").split(",") if t.strip()]


class Computation:
    def __init__(self, name):
        self.name = name
        self.instrs = []  # (name, shape, opcode, operands, attrs, root)
        self.root = None


def parse_hlo(text):
    comps, current = {}, None
    entry = None
    for line in text.splitlines():
        line = line.rstrip()
        if not line or line.startswith("HloModule"):
            continue
        stripped = line.strip()
        if stripped == "}":
            current = None
            continue
        if current is None:
            name = stripped.split("(")[0].strip()
            is_entry = name.startswith("ENTRY")
            name = name.replace("ENTRY", "").strip().lstrip("%").split()[0]
            current = Computation(name)
            comps[name] = current
            if is_entry:
                entry = name
            continue
        m = INSTR_RE.match(line)
        root, name, rest = bool(m.group(1)), m.group(2), m.group(3)
        shape, pos = _parse_shape(rest, 0)
        rest = rest[pos:].strip()
        opcode = re.match(r"[\w\-]+", rest).group(0)
        rest = rest[len(opcode) :]
        # balanced-paren operand section
        depth, end = 0, 0
        for i, c in enumerate(rest):
            if c == "(":
                depth += 1
            elif c == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_raw = rest[1:end]
        attr_raw = rest[end + 1 :].lstrip(", ")
        attrs = {}
        for part in _split_top(attr_raw):
            k, _, v = part.partition("=")
            attrs[k.strip()] = v.strip()
        current.instrs.append((name, shape, opcode, operand_raw, attrs, root))
        if root:
            current.root = len(current.instrs) - 1
    return comps, entry


_F32 = np.float32


def _run_computation(comps, comp, args):
    env = {}
    result = None
    for name, shape, opcode, raw, attrs, root in comp.instrs:
        ops = [env[t.split()[-1].lstrip("%")] for t in _split_top(raw)] if opcode not in (
            "parameter",
            "constant",
        ) else []
        if opcode == "parameter":
            v = args[int(raw)]
        elif opcode == "constant":
            toks = [t for t in re.split(r"[{},\s]+", raw) if t]
            vals = [
                {"true": 1.0, "false": 0.0, "inf": np.inf, "-inf": -np.inf, "nan": np.nan}.get(
                    t, None
                )
                if not re.match(r"^[-+0-9.eE]+$", t)
                else float(t)
                for t in toks
            ]
            v = np.array(vals, dtype=_F32).reshape(shape[1])
        elif opcode in ("add", "subtract", "multiply", "divide", "maximum", "minimum", "power"):
            a, b = ops
            v = {
                "add": np.add,
                "subtract": np.subtract,
                "multiply": np.multiply,
                "divide": np.divide,
                "maximum": np.maximum,
                "minimum": np.minimum,
                "power": np.power,
            }[opcode](a, b).astype(_F32)
        elif opcode in ("negate", "abs", "exponential", "log", "sqrt", "rsqrt", "tanh"):
            (a,) = ops
            v = {
                "negate": lambda x: -x,
                "abs": np.abs,
                "exponential": np.exp,
                "log": np.log,
                "sqrt": np.sqrt,
                "rsqrt": lambda x: np.float32(1.0) / np.sqrt(x),
                "tanh": np.tanh,
            }[opcode](a).astype(_F32)
        elif opcode == "compare":
            a, b = ops
            v = {
                "EQ": np.equal, "NE": np.not_equal, "LT": np.less,
                "LE": np.less_equal, "GT": np.greater, "GE": np.greater_equal,
            }[attrs["direction"]](a, b)
        elif opcode == "select":
            p, t, f = ops
            v = np.where(p, t, f).astype(_F32)
        elif opcode == "convert":
            v = ops[0].astype(_F32)
        elif opcode == "broadcast":
            (a,) = ops
            out_dims = shape[1]
            dims = _int_list(attrs.get("dimensions", "{}"))
            if a.ndim == 0 or a.size == 1:
                v = np.broadcast_to(np.asarray(a, dtype=_F32).reshape(()), out_dims).astype(_F32)
            else:
                tmp = [1] * len(out_dims)
                for i, d in enumerate(dims):
                    tmp[d] = a.shape[i]
                v = np.broadcast_to(a.reshape(tmp), out_dims).astype(_F32)
        elif opcode == "reshape":
            v = ops[0].reshape(shape[1])
        elif opcode == "transpose":
            v = np.transpose(ops[0], _int_list(attrs["dimensions"]))
        elif opcode == "slice":
            spec = [
                tuple(int(x) for x in p.strip("[]").split(":"))
                for p in _split_top(attrs["slice"].strip("{}"))
            ]
            idx = tuple(
                slice(s[0], s[1], s[2] if len(s) == 3 else 1) for s in spec
            )
            v = ops[0][idx]
        elif opcode == "concatenate":
            v = np.concatenate(ops, axis=_int_list(attrs["dimensions"])[0])
        elif opcode == "dot":
            a, b = ops
            lc = _int_list(attrs.get("lhs_contracting_dims", "{}"))
            rc = _int_list(attrs.get("rhs_contracting_dims", "{}"))
            v = np.tensordot(a, b, axes=(lc, rc)).astype(_F32)
        elif opcode == "reduce":
            a, init = ops
            dims = tuple(_int_list(attrs["dimensions"]))
            region = comps[attrs["to_apply"].lstrip("%")]
            op = region.instrs[region.root][2]
            fn = {"add": np.sum, "maximum": np.max, "minimum": np.min, "multiply": np.prod}[op]
            v = fn(a, axis=dims).astype(_F32)
            if op == "add":
                v = (v + init).astype(_F32)
            # (max/min with -inf/+inf init: identity)
        elif opcode == "tuple":
            v = tuple(ops)
        elif opcode == "get-tuple-element":
            v = ops[0][int(attrs["index"])]
        else:
            raise ValueError(f"unsupported opcode {opcode}")
        # mirror the Rust evaluator's strictness: every non-tuple result
        # must match its declared shape exactly, and binary ops only accept
        # equal sizes or a scalar operand (numpy would silently broadcast)
        if opcode in ("add", "subtract", "multiply", "divide", "maximum", "minimum", "power"):
            a, b = ops
            assert (
                np.asarray(a).size == np.asarray(b).size
                or np.asarray(a).size == 1
                or np.asarray(b).size == 1
            ), f"%{name}: rust interpreter would reject operand sizes {np.asarray(a).shape} vs {np.asarray(b).shape}"
        if shape[0] != "tuple" and opcode != "parameter":
            declared = tuple(shape[1])
            got = tuple(np.asarray(v).shape)
            n_declared = int(np.prod(declared)) if declared else 1
            assert np.asarray(v).size == n_declared, (
                f"%{name}: declared {declared}, produced {got}"
            )
        env[name] = v
        if root:
            result = v
    return result if result is not None else env[comp.instrs[-1][0]]


class Executable:
    def __init__(self, path):
        with open(path) as f:
            self.comps, self.entry = parse_hlo(f.read())

    def run(self, args):
        args = [np.asarray(a, dtype=_F32) for a in args]
        return _run_computation(self.comps, self.comps[self.entry], args)


# ---------------------------------------------------------------------------
# trainer / genome ports
# ---------------------------------------------------------------------------


def baseline_inputs():
    widths = [64, 32, 32, 32]
    unit = np.zeros((L, PAD), dtype=np.float32)
    gates = np.zeros(L, dtype=np.float32)
    for i, w in enumerate(widths):
        unit[i, :w] = 1.0
        gates[i] = 1.0
    act_sel = np.array([1.0, 0.0, 0.0], dtype=np.float32)
    return dict(unit=unit, gates=gates, act_sel=act_sel, bn_gate=1.0, dropout=0.0,
                lr=0.001, l1=0.0, widths=widths, depth=4)


def init_model(rng):
    w0 = rng.fill_normal(24 * PAD, math.sqrt(2.0 / 24)).reshape(24, PAD)
    wh = rng.fill_normal((L - 1) * PAD * PAD, math.sqrt(2.0 / PAD)).reshape(L - 1, PAD, PAD)
    wo = rng.fill_normal(PAD * OUT_DIM, math.sqrt(2.0 / PAD)).reshape(PAD, OUT_DIM)
    z = lambda *s: np.zeros(s, dtype=np.float32)
    params = dict(w0=w0, wh=wh, b=z(L, PAD), gamma=np.ones((L, PAD), np.float32),
                  beta=z(L, PAD), wo=wo, bo=z(OUT_DIM))
    return dict(params=params,
                m={k: np.zeros_like(v) for k, v in params.items()},
                v={k: np.zeros_like(v) for k, v in params.items()},
                run_mean=z(L, PAD), run_var=np.ones((L, PAD), np.float32),
                steps=0, history=[])


def ones_masks():
    return dict(p0=np.ones((24, PAD), np.float32),
                ph=np.ones((L - 1, PAD, PAD), np.float32),
                po=np.ones((PAD, OUT_DIM), np.float32))


PARAM_ORDER = ["w0", "wh", "b", "gamma", "beta", "wo", "bo"]


def train(exe, ds, model, inputs, masks, epochs, rng, qat=False):
    hp = np.zeros(HP_LEN, dtype=np.float32)
    hp[0] = inputs["bn_gate"]
    hp[1] = inputs["dropout"]
    hp[2] = 1.0 if qat else 0.0
    hp[3] = 8.0
    hp[4] = inputs["lr"]
    hp[5] = inputs["l1"]
    hp[6], hp[7], hp[8] = 0.9, 0.999, 1e-8
    hp[12] = 0.1
    for _ in range(epochs):
        batches = ds.train_epoch(rng)
        loss_sum, correct_sum, rows = 0.0, 0.0, 0
        for x, y1h, nrows in batches:
            model["steps"] += 1
            t = model["steps"]
            hp[9] = np.float32(0.9) ** t
            hp[10] = np.float32(0.999) ** t
            hp[11] = float(model["steps"] % (1 << 24))
            p, m, v = model["params"], model["m"], model["v"]
            args = (
                [p[k] for k in PARAM_ORDER]
                + [m[k] for k in PARAM_ORDER]
                + [v[k] for k in PARAM_ORDER]
                + [inputs["unit"], masks["p0"], masks["ph"], masks["po"],
                   inputs["gates"], inputs["act_sel"], hp.copy(),
                   model["run_mean"], model["run_var"], x, y1h]
            )
            out = exe.run(args)
            for i, k in enumerate(PARAM_ORDER):
                p[k] = out[i].reshape(p[k].shape)
                m[k] = out[7 + i].reshape(m[k].shape)
                v[k] = out[14 + i].reshape(v[k].shape)
            loss_sum += float(out[21])
            correct_sum += float(out[22])
            model["run_mean"] = out[23].reshape(L, PAD)
            model["run_var"] = out[24].reshape(L, PAD)
            rows += nrows
        model["history"].append((loss_sum / max(len(batches), 1), correct_sum / max(rows, 1)))


def evaluate(exe, ds, model, inputs, masks, which, qat=False):
    ehp = np.array([inputs["bn_gate"], 1.0 if qat else 0.0, 8.0], dtype=np.float32)
    p = model["params"]
    correct, loss_sum, total = 0, 0.0, 0
    for x, y1h, rows in ds.eval_tiles(which, EVAL_BATCH):
        args = ([p[k] for k in PARAM_ORDER]
                + [inputs["unit"], masks["p0"], masks["ph"], masks["po"],
                   inputs["gates"], inputs["act_sel"], ehp,
                   model["run_mean"], model["run_var"], x, y1h])
        out = exe.run(args)
        logits = np.asarray(out[2], dtype=np.float64).reshape(EVAL_BATCH, OUT_DIM)
        for r in range(rows):
            row = logits[r]
            pred = int(np.argmax(row))
            label = int(np.argmax(y1h[r]))
            if pred == label:
                correct += 1
            mx = row.max()
            lse = mx + math.log(np.exp(row - mx).sum())
            loss_sum += lse - row[label]
        total += rows
    return correct / max(total, 1), loss_sum / max(total, 1)


def active_coords(inputs):
    """Global indices of active (tensor-order p0, ph, po) coordinates."""
    unit, depth = inputs["unit"], inputs["depth"]
    p0_len, ph_len = 24 * PAD, (L - 1) * PAD * PAD
    out = []
    for i in range(p0_len):
        if unit[0, i % PAD] != 0:
            out.append(i)
    for i in range(ph_len):
        layer = i // (PAD * PAD) + 1
        col = i % PAD
        row = (i // PAD) % PAD
        if layer < depth and unit[layer, col] != 0 and unit[layer - 1, row] != 0:
            out.append(p0_len + i)
    last = depth - 1
    for i in range(PAD * OUT_DIM):
        if unit[last, i // OUT_DIM] != 0:
            out.append(p0_len + ph_len + i)
    return np.array(out)


def prune_step(masks, params, inputs, fraction):
    p0_len, ph_len = 24 * PAD, (L - 1) * PAD * PAD
    flat_w = np.concatenate([params["w0"].ravel(), params["wh"].ravel(), params["wo"].ravel()])
    flat_m = np.concatenate([masks["p0"].ravel(), masks["ph"].ravel(), masks["po"].ravel()])
    act = active_coords(inputs)
    surv = act[flat_m[act] != 0]
    k = int(len(surv) * fraction)
    if k:
        order = np.argsort(np.abs(flat_w[surv]), kind="stable")
        flat_m[surv[order[:k]]] = 0.0
    masks["p0"] = flat_m[:p0_len].reshape(24, PAD)
    masks["ph"] = flat_m[p0_len : p0_len + ph_len].reshape(L - 1, PAD, PAD)
    masks["po"] = flat_m[p0_len + ph_len :].reshape(PAD, OUT_DIM)


# ---------------------------------------------------------------------------
# the actual checks
# ---------------------------------------------------------------------------


def test_surrogate_predict_linear_at_zero_weights():
    exe = Executable(os.path.join(FIXTURES, "surrogate_predict.hlo.txt"))
    z = np.zeros
    args = [z((72, 128)), z(128), z((128, 128)), z(128), z((128, 6)),
            np.array([1, 2, 3, 4, 5, 6], dtype=np.float32),
            np.full((256, 72), 0.5, dtype=np.float32)]
    (pred,) = exe.run(args)
    assert pred.shape == (256, 6)
    assert np.array_equal(pred, np.tile(np.arange(1, 7, dtype=np.float32), (256, 1)))
    print("surrogate_predict: linear at zero weights OK")


def test_surrogate_train_reduces_loss():
    exe = Executable(os.path.join(FIXTURES, "surrogate_train.hlo.txt"))
    rng = Rng(123)
    params = [
        rng.fill_normal(72 * 128, math.sqrt(2.0 / 72)).reshape(72, 128),
        np.zeros(128, np.float32),
        rng.fill_normal(128 * 128, math.sqrt(2.0 / 128)).reshape(128, 128),
        np.zeros(128, np.float32),
        rng.fill_normal(128 * 6, math.sqrt(2.0 / 128)).reshape(128, 6),
        np.zeros(6, np.float32),
    ]
    m = [np.zeros_like(p) for p in params]
    v = [np.zeros_like(p) for p in params]
    x = rng.fill_normal(256 * 72, 1.0).reshape(256, 72)
    # targets: a fixed random linear map of the features (learnable)
    w_true = rng.fill_normal(72 * 6, 0.3).reshape(72, 6)
    y = (x @ w_true).astype(np.float32)
    losses = []
    for t in range(1, 41):
        shp = np.array([1e-3, 0.9, 0.999, 1e-8,
                        np.float32(0.9) ** t, np.float32(0.999) ** t], dtype=np.float32)
        out = exe.run(params + m + v + [x, y, shp])
        params = [np.asarray(o) for o in out[0:6]]
        m = [np.asarray(o) for o in out[6:12]]
        v = [np.asarray(o) for o in out[12:18]]
        losses.append(float(out[18]))
    print(f"surrogate_train: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    assert losses[-1] < 0.5 * losses[0], (losses[0], losses[-1])


def test_train_eval_prune_resume_thresholds():
    train_exe = Executable(os.path.join(FIXTURES, "train_step.hlo.txt"))
    eval_exe = Executable(os.path.join(FIXTURES, "eval_step.hlo.txt"))
    ds = Dataset(1280, 256, 256, 11)
    inputs = baseline_inputs()
    masks = ones_masks()
    rng = Rng(0)
    model = init_model(rng)
    train(train_exe, ds, model, inputs, masks, 3, rng)
    losses = [h[0] for h in model["history"]]
    print(f"train_step: epoch losses {['%.4f' % l for l in losses]}")
    assert losses[-1] < losses[0], "loss should fall"
    assert losses[-1] < 1.55, losses[-1]
    acc, loss = evaluate(eval_exe, ds, model, inputs, masks, "test")
    print(f"eval_step: test acc {acc:.4f}, loss {loss:.4f}")
    assert acc > 0.30, acc
    assert loss < 1.6, loss

    prune_step(masks, model["params"], inputs, 0.2)
    train(train_exe, ds, model, inputs, masks, 1, rng, qat=True)
    w0, p0 = model["params"]["w0"], masks["p0"]
    assert np.all(w0[p0 == 0.0] == 0.0), "pruned coordinates must stay zero"
    acc_q, _ = evaluate(eval_exe, ds, model, inputs, masks, "test", qat=True)
    print(f"pruned+resumed: test acc {acc_q:.4f}")
    assert acc_q > 0.30, acc_q


def test_micro_local_search_budget_beats_chance():
    """The pipeline integration budget: quickstart data (640 train rows),
    warm-up 1 epoch + 3 IMP iterations x 1 epoch, deployment ~50 %."""
    train_exe = Executable(os.path.join(FIXTURES, "train_step.hlo.txt"))
    eval_exe = Executable(os.path.join(FIXTURES, "eval_step.hlo.txt"))
    ds = Dataset(640, 256, 256, 7)
    inputs = baseline_inputs()
    masks = ones_masks()
    rng = Rng(1 ^ 0x10CA1)
    model = init_model(rng)
    train(train_exe, ds, model, inputs, masks, 1, rng)  # warm-up
    sweep = []
    for it in range(3):
        prune_step(masks, model["params"], inputs, 0.2)
        train(train_exe, ds, model, inputs, masks, 1, rng, qat=True)
        acc, _ = evaluate(eval_exe, ds, model, inputs, masks, "val", qat=True)
        sweep.append(acc)
    acc, _ = evaluate(eval_exe, ds, model, inputs, masks, "test", qat=True)
    print(f"micro local search: val sweep {['%.4f' % a for a in sweep]}, test {acc:.4f}")
    assert acc > 0.2, acc


if __name__ == "__main__":
    test_surrogate_predict_linear_at_zero_weights()
    test_surrogate_train_reduces_loss()
    test_train_eval_prune_resume_thresholds()
    test_micro_local_search_budget_beats_chance()
    print("all fixture numerics OK")
    sys.exit(0)
