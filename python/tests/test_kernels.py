"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

Hypothesis sweeps shapes (including non-TILE_B-multiple batches, which
exercise the padded-row masking) and value distributions; every property
asserts allclose between the interpret-mode Pallas kernel and the oracle,
for the forward value AND all cotangents.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_dense as fd
from compile.kernels import ref

# interpret-mode pallas is slow; keep example counts moderate but useful.
COMMON = dict(deadline=None, max_examples=25)

dims = st.integers(min_value=1, max_value=160)
batches = st.integers(min_value=1, max_value=300)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


def _rand(rng, *shape):
    return jnp.asarray(rng.randn(*shape), jnp.float32)


# ---------------------------------------------------------------------- #
# masked_dense
# ---------------------------------------------------------------------- #


@settings(**COMMON)
@given(b=batches, ni=dims, no=dims, seed=seeds, keep=st.floats(0.0, 1.0))
def test_masked_dense_forward_matches_ref(b, ni, no, seed, keep):
    rng = np.random.RandomState(seed)
    x, w, bias = _rand(rng, b, ni), _rand(rng, ni, no), _rand(rng, no)
    mask = jnp.asarray((rng.rand(no) < keep).astype(np.float32))
    got = fd.masked_dense(x, w, bias, mask)
    want = ref.masked_dense_ref(x, w, bias, mask)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(**COMMON)
@given(b=batches, ni=dims, no=dims, seed=seeds)
def test_masked_dense_grads_match_ref(b, ni, no, seed):
    rng = np.random.RandomState(seed)
    x, w, bias = _rand(rng, b, ni), _rand(rng, ni, no), _rand(rng, no)
    mask = jnp.asarray((rng.rand(no) < 0.7).astype(np.float32))
    g = _rand(rng, b, no)

    def f(x, w, bias):
        return jnp.sum(fd.masked_dense(x, w, bias, mask) * g)

    dx, dw, db = jax.grad(f, (0, 1, 2))(x, w, bias)
    rx, rw, rb = ref.masked_dense_vjp_ref(x, w, bias, mask, g)
    np.testing.assert_allclose(dx, rx, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(dw, rw, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(db, rb, rtol=3e-4, atol=3e-4)


def test_masked_dense_masked_units_are_exactly_zero():
    rng = np.random.RandomState(0)
    x, w, bias = _rand(rng, 64, 32), _rand(rng, 32, 48), _rand(rng, 48)
    mask = np.ones(48, np.float32)
    mask[10:] = 0.0
    z = np.asarray(fd.masked_dense(x, w, bias, jnp.asarray(mask)))
    assert (z[:, 10:] == 0.0).all()


def test_masked_dense_mask_gets_no_gradient():
    rng = np.random.RandomState(1)
    x, w, bias = _rand(rng, 8, 4), _rand(rng, 4, 4), _rand(rng, 4)
    mask = jnp.ones((4,), jnp.float32)
    dm = jax.grad(lambda m: jnp.sum(fd.masked_dense(x, w, bias, m)))(mask)
    np.testing.assert_array_equal(np.asarray(dm), 0.0)


# ---------------------------------------------------------------------- #
# affine_act
# ---------------------------------------------------------------------- #


def _sel_strategy():
    # one-hot corners + arbitrary blends (the supernet always uses one-hots,
    # but the kernel contract is any convex weights).
    return st.one_of(
        st.sampled_from([(1.0, 0.0, 0.0), (0.0, 1.0, 0.0), (0.0, 0.0, 1.0)]),
        st.tuples(st.floats(0, 1), st.floats(0, 1), st.floats(0, 1)),
    )


@settings(**COMMON)
@given(b=batches, n=dims, seed=seeds, sel=_sel_strategy())
def test_affine_act_forward_matches_ref(b, n, seed, sel):
    rng = np.random.RandomState(seed)
    z = _rand(rng, b, n)
    sc = jnp.asarray(rng.rand(n).astype(np.float32) + 0.25)
    sh = _rand(rng, n)
    selv = jnp.asarray(sel, jnp.float32)
    got = fd.affine_act(z, sc, sh, selv)
    want = ref.affine_act_ref(z, sc, sh, selv)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(**COMMON)
@given(b=batches, n=dims, seed=seeds, sel=_sel_strategy())
def test_affine_act_grads_match_ref(b, n, seed, sel):
    rng = np.random.RandomState(seed)
    z = _rand(rng, b, n)
    sc = jnp.asarray(rng.rand(n).astype(np.float32) + 0.25)
    sh = _rand(rng, n)
    selv = jnp.asarray(sel, jnp.float32)
    g = _rand(rng, b, n)

    def f(z, sc, sh, selv):
        return jnp.sum(fd.affine_act(z, sc, sh, selv) * g)

    grads = jax.grad(f, (0, 1, 2, 3))(z, sc, sh, selv)
    refs = ref.affine_act_vjp_ref(z, sc, sh, selv, g)
    for got, want in zip(grads, refs):
        np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_affine_act_identity_affine_relu_is_relu():
    rng = np.random.RandomState(2)
    z = _rand(rng, 32, 16)
    a = fd.affine_act(
        z, jnp.ones((16,)), jnp.zeros((16,)), jnp.asarray([1.0, 0.0, 0.0])
    )
    np.testing.assert_allclose(a, jax.nn.relu(z), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------- #
# fake_quant
# ---------------------------------------------------------------------- #


@settings(**COMMON)
@given(
    seed=seeds,
    bits=st.sampled_from([2.0, 4.0, 6.0, 8.0, 12.0, 16.0]),
    scale=st.floats(1e-3, 1e3),
)
def test_fake_quant_level_count_and_range(seed, bits, scale):
    rng = np.random.RandomState(seed)
    w = jnp.asarray(rng.randn(64, 64).astype(np.float32) * scale)
    q = np.asarray(fd.fake_quant(w, jnp.float32(bits)))
    levels = 2 ** (bits - 1) - 1
    # quantised values live on the uniform grid and within the clip range
    assert len(np.unique(q)) <= 2**bits
    assert np.abs(q).max() <= float(np.abs(np.asarray(w)).max()) * (1 + 1e-5) * (
        (levels + 1) / levels
    )
    np.testing.assert_allclose(q, ref.fake_quant_ref(w, jnp.float32(bits)), rtol=1e-6)


def test_fake_quant_ste_gradient_is_identity():
    w = jnp.asarray(np.linspace(-2, 2, 64, dtype=np.float32).reshape(8, 8))
    dw = jax.grad(lambda w: jnp.sum(fd.fake_quant(w, jnp.float32(8.0))))(w)
    np.testing.assert_array_equal(np.asarray(dw), 1.0)


def test_fake_quant_preserves_zero():
    w = jnp.zeros((16, 16), jnp.float32)
    w = w.at[0, 0].set(1.0)  # avoid degenerate all-zero scale
    q = np.asarray(fd.fake_quant(w, jnp.float32(8.0)))
    assert (q[1:] == 0.0).all()


def test_fake_quant_idempotent():
    rng = np.random.RandomState(3)
    w = jnp.asarray(rng.randn(32, 32), jnp.float32)
    q1 = fd.fake_quant(w, jnp.float32(8.0))
    q2 = fd.fake_quant(q1, jnp.float32(8.0))
    np.testing.assert_allclose(q1, q2, rtol=1e-5, atol=1e-7)
