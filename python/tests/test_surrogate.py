"""Surrogate (rule4ml-style) model: training dynamics + predict consistency."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M

F, H, OUTS, B = M.SUR_FEATS, M.SUR_HIDDEN, M.SUR_OUT, M.SUR_BATCH


def _init(rng):
    shapes = M.SUR_PARAM_SHAPES
    p = []
    for s in shapes:
        fan = s[0] if len(s) == 2 else 1
        p.append(jnp.asarray(rng.randn(*s).astype(np.float32) / np.sqrt(fan)))
    return p


def _shp(t, lr=1e-3):
    b1, b2 = 0.9, 0.999
    return jnp.asarray([lr, b1, b2, 1e-8, b1**t, b2**t], jnp.float32)


def test_surrogate_train_reduces_mse():
    rng = np.random.RandomState(0)
    p = _init(rng)
    m = [jnp.zeros_like(a) for a in p]
    v = [jnp.zeros_like(a) for a in p]
    # learnable synthetic mapping: targets = |linear(features)|
    w_true = rng.randn(F, OUTS).astype(np.float32) / np.sqrt(F)
    x = rng.randn(B, F).astype(np.float32)
    y = np.abs(x @ w_true)
    step = jax.jit(M.surrogate_train_step)
    losses = []
    for t in range(1, 60):
        out = step(*p, *m, *v, jnp.asarray(x), jnp.asarray(y), _shp(t))
        p, m, v = list(out[:6]), list(out[6:12]), list(out[12:18])
        losses.append(float(out[18]))
    assert losses[-1] < 0.3 * losses[0]


def test_surrogate_predict_matches_forward():
    rng = np.random.RandomState(1)
    p = _init(rng)
    x = jnp.asarray(rng.randn(B, F).astype(np.float32))
    (pred,) = jax.jit(M.surrogate_predict)(*p, x)
    want = M.surrogate_forward(tuple(p), x)
    np.testing.assert_allclose(np.asarray(pred), np.asarray(want), rtol=1e-5, atol=1e-6)
    assert pred.shape == (B, OUTS)


def test_surrogate_forward_is_deterministic():
    rng = np.random.RandomState(2)
    p = _init(rng)
    x = jnp.asarray(rng.randn(B, F).astype(np.float32))
    a = np.asarray(M.surrogate_forward(tuple(p), x))
    b = np.asarray(M.surrogate_forward(tuple(p), x))
    np.testing.assert_array_equal(a, b)
