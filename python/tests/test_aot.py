"""AOT pipeline: every artifact lowers to valid HLO text and the manifest
is a faithful ABI description (input counts/orders/shapes)."""

import json
import os

import pytest

from compile import aot
from compile import model as M

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.parametrize("name", list(aot.ARTIFACTS))
def test_artifact_lowers_to_hlo_text(name):
    text = aot.lower_artifact(name)
    assert text.startswith("HloModule"), "expected HLO text, got something else"
    assert "ENTRY" in text
    # the CPU path must not contain Mosaic custom-calls (interpret=True)
    assert "tpu_custom_call" not in text
    assert "mosaic" not in text.lower()


def test_manifest_input_counts_match_signatures():
    import inspect

    for name, spec in aot.ARTIFACTS.items():
        n_sig = len(inspect.signature(spec["fn"]).parameters)
        assert n_sig == len(spec["inputs"]), name


def test_manifest_constants_match_model():
    spec = aot.ARTIFACTS["train_step"]["inputs"]
    by_name = dict(spec)
    assert by_name["x"] == (M.BATCH, M.IN_DIM)
    assert by_name["y1h"] == (M.BATCH, M.OUT_DIM)
    assert by_name["hp"] == (M.HP_LEN,)
    assert by_name["wh"] == (M.NUM_LAYERS - 1, M.PAD, M.PAD)
    ev = dict(aot.ARTIFACTS["eval_step"]["inputs"])
    assert ev["x"] == (M.EVAL_BATCH, M.IN_DIM)
    assert ev["run_mean"] == (M.NUM_LAYERS, M.PAD)


def test_train_step_abi_param_adam_alignment():
    """params, m, v blocks must be three identically-shaped groups of 7."""
    inputs = aot.ARTIFACTS["train_step"]["inputs"]
    p, m, v = inputs[:7], inputs[7:14], inputs[14:21]
    for (pn, ps), (mn, ms), (vn, vs) in zip(p, m, v):
        assert ms == ps and vs == ps
        assert mn == "m_" + pn and vn == "v_" + pn


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_built_manifest_is_current():
    with open(os.path.join(ART_DIR, "manifest.json")) as f:
        man = json.load(f)
    assert man["constants"]["pad"] == M.PAD
    assert man["constants"]["batch"] == M.BATCH
    for name, spec in aot.ARTIFACTS.items():
        got = man["artifacts"][name]["inputs"]
        want = [{"name": n, "shape": list(s)} for n, s in spec["inputs"]]
        assert got == want, f"manifest drift for {name}: rebuild artifacts"
        assert os.path.exists(os.path.join(ART_DIR, man["artifacts"][name]["file"]))
