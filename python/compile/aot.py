"""AOT lowering: JAX graphs → HLO *text* artifacts + an ABI manifest.

Run once at build time (``make artifacts``); Python is never on the search
path. Each entry point in :mod:`compile.model` is jitted, lowered to
StableHLO, converted to an XlaComputation, and dumped as **HLO text**.

Text — NOT ``lowered.compile()``/``.serialize()`` — is the interchange
format on purpose: jax ≥ 0.5 serialises HloModuleProto with 64-bit
instruction ids, which the ``xla`` crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``). The HLO *text* parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

``manifest.json`` records, for every artifact, the ordered input and output
names/shapes — the ABI contract that ``rust/src/runtime/artifacts.rs``
validates at load time so a drifted Python build fails fast instead of
producing garbage numerics.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

F32 = jnp.float32


def _s(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


# (name, shape) per input, in ABI order. Shapes use the model constants so
# a constant change here automatically propagates to the manifest.
L, P, I, O = M.NUM_LAYERS, M.PAD, M.IN_DIM, M.OUT_DIM

SUPERNET_PARAMS = [
    ("w0", (I, P)), ("wh", (L - 1, P, P)), ("b", (L, P)),
    ("gamma", (L, P)), ("beta", (L, P)), ("wo", (P, O)), ("bo", (O,)),
]
SUPERNET_MASKS = [
    ("unit", (L, P)), ("p0", (I, P)), ("ph", (L - 1, P, P)), ("po", (P, O)),
]
SUPERNET_ARCH = [("gates", (L,)), ("act_sel", (3,))]

SUR_PARAMS = [
    ("sw1", M.SUR_PARAM_SHAPES[0]), ("sb1", M.SUR_PARAM_SHAPES[1]),
    ("sw2", M.SUR_PARAM_SHAPES[2]), ("sb2", M.SUR_PARAM_SHAPES[3]),
    ("sw3", M.SUR_PARAM_SHAPES[4]), ("sb3", M.SUR_PARAM_SHAPES[5]),
]


def _adam_triplet(params):
    out = list(params)
    out += [("m_" + n, s) for n, s in params]
    out += [("v_" + n, s) for n, s in params]
    return out


ARTIFACTS = {
    "train_step": {
        "fn": M.train_step,
        "inputs": _adam_triplet(SUPERNET_PARAMS)
        + SUPERNET_MASKS
        + SUPERNET_ARCH
        + [
            ("hp", (M.HP_LEN,)),
            ("run_mean", (L, P)), ("run_var", (L, P)),
            ("x", (M.BATCH, I)), ("y1h", (M.BATCH, O)),
        ],
        "outputs": [n for n, _ in SUPERNET_PARAMS]
        + ["m_" + n for n, _ in SUPERNET_PARAMS]
        + ["v_" + n for n, _ in SUPERNET_PARAMS]
        + ["loss", "correct", "run_mean", "run_var"],
    },
    "eval_step": {
        "fn": M.eval_step,
        "inputs": SUPERNET_PARAMS
        + SUPERNET_MASKS
        + SUPERNET_ARCH
        + [
            ("ehp", (M.EHP_LEN,)),
            ("run_mean", (L, P)), ("run_var", (L, P)),
            ("x", (M.EVAL_BATCH, I)), ("y1h", (M.EVAL_BATCH, O)),
        ],
        "outputs": ["correct", "loss", "logits"],
    },
    "surrogate_train": {
        "fn": M.surrogate_train_step,
        "inputs": _adam_triplet(SUR_PARAMS)
        + [
            ("x", (M.SUR_BATCH, M.SUR_FEATS)),
            ("y", (M.SUR_BATCH, M.SUR_OUT)),
            ("shp", (M.SHP_LEN,)),
        ],
        "outputs": [n for n, _ in SUR_PARAMS]
        + ["m_" + n for n, _ in SUR_PARAMS]
        + ["v_" + n for n, _ in SUR_PARAMS]
        + ["loss"],
    },
    "surrogate_predict": {
        "fn": M.surrogate_predict,
        "inputs": SUR_PARAMS + [("x", (M.SUR_BATCH, M.SUR_FEATS))],
        "outputs": ["pred"],
    },
}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for rust unwrap)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(name: str) -> str:
    spec = ARTIFACTS[name]
    args = [_s(*shape) for _, shape in spec["inputs"]]
    return to_hlo_text(jax.jit(spec["fn"]).lower(*args))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", help="subset of artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "abi_version": 1,
        "constants": {
            "pad": P, "num_layers": L, "in_dim": I, "out_dim": O,
            "batch": M.BATCH, "eval_batch": M.EVAL_BATCH,
            "hp_len": M.HP_LEN, "ehp_len": M.EHP_LEN, "bn_eps": M.BN_EPS,
            "sur_feats": M.SUR_FEATS, "sur_hidden": M.SUR_HIDDEN,
            "sur_out": M.SUR_OUT, "sur_batch": M.SUR_BATCH,
            "shp_len": M.SHP_LEN,
        },
        "artifacts": {},
    }
    names = args.only or list(ARTIFACTS)
    for name in names:
        spec = ARTIFACTS[name]
        text = lower_artifact(name)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"name": n, "shape": list(s)} for n, s in spec["inputs"]
            ],
            "outputs": spec["outputs"],
        }
        print(f"wrote {path} ({len(text)} chars, {len(spec['inputs'])} inputs)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
