"""Layer-2 JAX graphs: the SNAC-Pack supernet and the rule4ml-style surrogate.

The *supernet* covers the paper's entire Table 1 MLP search space in one
compiled graph. Eight padded dense layers (max width ``PAD``) are always
computed; a candidate architecture is expressed purely through runtime
inputs:

  * ``unit``  — per-layer {0,1} unit masks selecting the hidden width,
  * ``gates`` — per-layer {0,1} scalars; a gated-off layer passes its input
    through unchanged (variable depth 4–8),
  * ``act_sel`` — one-hot over {ReLU, tanh, sigmoid},
  * ``hp``   — packed hyperparameter scalars (BN gate, dropout rate, QAT
    gate + bit-width, Adam schedule, L1 strength, RNG seed),
  * ``p0/ph/po`` — elementwise pruning masks (local-search IMP).

This makes every candidate a *data* change, so the Rust coordinator drives
the full NSGA-II search against ONE AOT-compiled HLO artifact with no
Python anywhere on the search path. Equivalence with literal per-candidate
MLPs is asserted by ``python/tests/test_supernet_equiv.py``.

All tensor compute flows through the Layer-1 Pallas kernels
(:mod:`compile.kernels.fused_dense`) in both directions.

Input/output orders here are the ABI contract with ``rust/src/runtime/``;
``aot.py`` serialises them into ``artifacts/manifest.json`` which the Rust
side validates at load time.
"""

import jax
import jax.numpy as jnp

from .kernels.fused_dense import affine_act, fake_quant, masked_dense

# ---------------------------------------------------------------------------
# Shape constants (the ABI; mirrored in rust/src/nn/space.rs and checked via
# artifacts/manifest.json).
# ---------------------------------------------------------------------------

PAD = 128          # padded hidden width (max of Table 1: layer 1 ∈ {64,120,128})
NUM_LAYERS = 8     # max depth of Table 1
IN_DIM = 24        # 8 constituents × (pT, η, φ) — hls4ml LHC jet MLP input
OUT_DIM = 5        # q / g / W / Z / t
BATCH = 128        # paper: "All training is performed with a batch size of 128"
EVAL_BATCH = 512   # evaluation tile; Rust pads the tail batch

# hp vector layout (train_step)
HP_BN_GATE = 0      # 1.0 → BatchNorm on
HP_DROPOUT = 1      # dropout rate ∈ {0, 0.05, 0.1}
HP_QAT_GATE = 2     # 1.0 → fake-quant weights
HP_BITS = 3         # QAT bit-width (e.g. 8)
HP_LR = 4           # Adam learning rate
HP_L1 = 5           # L1 regularisation strength
HP_BETA1 = 6        # Adam β1
HP_BETA2 = 7        # Adam β2
HP_EPS = 8          # Adam ε
HP_BETA1_POW = 9    # β1^t (bias correction, computed by the Rust trainer)
HP_BETA2_POW = 10   # β2^t
HP_SEED = 11        # dropout PRNG seed (integer-valued f32, < 2^24)
HP_BN_MOM = 12      # BN running-stat EMA momentum (weight of the new batch)
HP_LEN = 13

# hp vector layout (eval)
EHP_BN_GATE = 0
EHP_QAT_GATE = 1
EHP_BITS = 2
EHP_LEN = 3

BN_EPS = 1e-3      # matches Keras/hls4ml BatchNorm default epsilon scale

# Surrogate (rule4ml-style) shapes
SUR_FEATS = 72     # 8 layers × 8 per-layer features + 8 global features
SUR_HIDDEN = 128
SUR_OUT = 6        # BRAM, DSP, FF, LUT, latency-cycles, II  (rule4ml's targets)
SUR_BATCH = 256

# surrogate hp layout
SHP_LR = 0
SHP_BETA1 = 1
SHP_BETA2 = 2
SHP_EPS = 3
SHP_BETA1_POW = 4
SHP_BETA2_POW = 5
SHP_LEN = 6


# ---------------------------------------------------------------------------
# Supernet forward
# ---------------------------------------------------------------------------


def _effective_weight(w, prune, qat_gate, bits):
    """Pruned + (gated) fake-quantised weight — the hls4ml-deployable value."""
    wp = w * prune
    return qat_gate * fake_quant(wp, bits) + (1.0 - qat_gate) * wp


def supernet_forward(params, masks, arch, bn_gate, qat_gate, bits,
                     x, *, bn_stats=None, dropout=None):
    """Run the padded supernet.

    Args:
      params: dict with ``w0 (IN,PAD)``, ``wh (L-1,PAD,PAD)``, ``b (L,PAD)``,
        ``gamma (L,PAD)``, ``beta (L,PAD)``, ``wo (PAD,OUT)``, ``bo (OUT,)``.
      masks: dict with ``unit (L,PAD)``, ``p0``, ``ph``, ``po`` prune masks.
      arch: dict with ``gates (L,)`` and ``act_sel (3,)``.
      bn_stats: ``None`` → training mode (batch statistics; also returned);
        ``(run_mean, run_var)`` → eval mode with running statistics.
      dropout: ``None`` or ``(rate, key)`` — training-mode dropout.

    Returns:
      ``(logits, l1_of_active_weights, batch_means, batch_vars)``.
    """
    gates = arch["gates"]
    act_sel = arch["act_sel"]
    unit = masks["unit"]
    h = x
    means, variances = [], []
    l1_acc = 0.0
    for i in range(NUM_LAYERS):
        w = params["w0"] if i == 0 else params["wh"][i - 1]
        prune = masks["p0"] if i == 0 else masks["ph"][i - 1]
        w_eff = _effective_weight(w, prune, qat_gate, bits)
        z = masked_dense(h, w_eff, params["b"][i], unit[i])
        if bn_stats is None:
            mean = jnp.sum(z, axis=0) / z.shape[0]
            var = jnp.sum(jnp.square(z - mean[None, :]), axis=0) / z.shape[0]
        else:
            mean = bn_stats[0][i]
            var = bn_stats[1][i]
        means.append(mean)
        variances.append(var)
        bn_scale = params["gamma"][i] * jax.lax.rsqrt(var + BN_EPS)
        bn_shift = params["beta"][i] - mean * bn_scale
        scale = bn_gate * bn_scale + (1.0 - bn_gate)
        shift = bn_gate * bn_shift
        a = affine_act(z, scale, shift, act_sel)
        # affine_act shifts masked-off units away from 0 (act(shift) ≠ 0);
        # re-mask so gated layers expose a clean sub-network.
        a = a * unit[i][None, :]
        if dropout is not None:
            rate, key = dropout
            u = jax.random.uniform(jax.random.fold_in(key, i), a.shape)
            # inverted dropout with a *runtime* rate; rate=0 → keep ≡ 1.
            a = a * (u >= rate).astype(a.dtype) / (1.0 - rate)
        if i == 0:
            # Layer 1 always exists (Table 1 depth ≥ 4); no pass-through is
            # possible here since h still has IN_DIM columns.
            h = a
        else:
            h = gates[i] * a + (1.0 - gates[i]) * h
        l1_acc = l1_acc + gates[i] * jnp.sum(jnp.abs(w_eff * unit[i][None, :]))
    wo_eff = _effective_weight(params["wo"], masks["po"], qat_gate, bits)
    logits = masked_dense(h, wo_eff, params["bo"], jnp.ones((OUT_DIM,), x.dtype))
    l1_acc = l1_acc + jnp.sum(jnp.abs(wo_eff))
    return logits, l1_acc, jnp.stack(means), jnp.stack(variances)


def _ce_and_correct(logits, y1h):
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.mean(jnp.sum(y1h * logp, axis=-1))
    correct = jnp.sum(
        (jnp.argmax(logits, axis=-1) == jnp.argmax(y1h, axis=-1)).astype(jnp.float32)
    )
    return ce, correct


# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------


def adam_update(params, grads, m, v, lr, beta1, beta2, eps, b1_pow, b2_pow):
    """One Adam step with external bias-correction powers (β^t from Rust)."""
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        mk = beta1 * m[k] + (1.0 - beta1) * g
        vk = beta2 * v[k] + (1.0 - beta2) * jnp.square(g)
        mhat = mk / (1.0 - b1_pow)
        vhat = vk / (1.0 - b2_pow)
        new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
        new_m[k] = mk
        new_v[k] = vk
    return new_p, new_m, new_v


# ---------------------------------------------------------------------------
# AOT entry points (exact input order = the Rust ABI; see aot.py manifest)
# ---------------------------------------------------------------------------

PARAM_KEYS = ("w0", "wh", "b", "gamma", "beta", "wo", "bo")


def _unpack(w0, wh, b, gamma, beta, wo, bo):
    return {
        "w0": w0, "wh": wh, "b": b,
        "gamma": gamma, "beta": beta, "wo": wo, "bo": bo,
    }


def train_step(
    w0, wh, b, gamma, beta, wo, bo,
    m_w0, m_wh, m_b, m_gamma, m_beta, m_wo, m_bo,
    v_w0, v_wh, v_b, v_gamma, v_beta, v_wo, v_bo,
    unit, p0, ph, po, gates, act_sel, hp, run_mean, run_var, x, y1h,
):
    """One fused training step: fwd + bwd + Adam + BN running-stat EMA.

    Returns (in order): the 7 updated params, 7 Adam m, 7 Adam v, then
    ``loss``, ``correct``, ``run_mean (L,PAD)``, ``run_var (L,PAD)``.

    The BN running statistics are updated *in-graph*
    (``new = (1−mom)·old + mom·batch``) — both because it removes a
    host-side loop from the hot path and because xla_extension 0.5.1's
    StableHLO→XLA converter mis-lowers outputs that are bare
    ``concatenate`` results used only by the return tuple (it replaces
    them with echo parameters); the EMA arithmetic keeps the outputs as
    real computations.
    """
    params = _unpack(w0, wh, b, gamma, beta, wo, bo)
    m = _unpack(m_w0, m_wh, m_b, m_gamma, m_beta, m_wo, m_bo)
    v = _unpack(v_w0, v_wh, v_b, v_gamma, v_beta, v_wo, v_bo)
    masks = {"unit": unit, "p0": p0, "ph": ph, "po": po}
    arch = {"gates": gates, "act_sel": act_sel}
    key = jax.random.PRNGKey(hp[HP_SEED].astype(jnp.uint32))

    def loss_fn(p):
        logits, l1, means, variances = supernet_forward(
            p, masks, arch, hp[HP_BN_GATE], hp[HP_QAT_GATE], hp[HP_BITS], x,
            dropout=(hp[HP_DROPOUT], key),
        )
        ce, correct = _ce_and_correct(logits, y1h)
        return ce + hp[HP_L1] * l1, (correct, means, variances)

    (loss, (correct, means, variances)), grads = jax.value_and_grad(
        loss_fn, has_aux=True
    )(params)
    new_p, new_m, new_v = adam_update(
        params, grads, m, v,
        hp[HP_LR], hp[HP_BETA1], hp[HP_BETA2], hp[HP_EPS],
        hp[HP_BETA1_POW], hp[HP_BETA2_POW],
    )
    # Keep pruned coordinates exactly zero (IMP invariant): Adam momentum
    # accumulated before a weight was pruned must not resurrect it.
    new_p["w0"] = new_p["w0"] * p0
    new_p["wh"] = new_p["wh"] * ph
    new_p["wo"] = new_p["wo"] * po
    mom = hp[HP_BN_MOM]
    new_run_mean = (1.0 - mom) * run_mean + mom * means
    new_run_var = (1.0 - mom) * run_var + mom * variances
    return tuple(new_p[k] for k in PARAM_KEYS) + tuple(
        new_m[k] for k in PARAM_KEYS
    ) + tuple(new_v[k] for k in PARAM_KEYS) + (
        loss, correct, new_run_mean, new_run_var,
    )


def eval_step(
    w0, wh, b, gamma, beta, wo, bo,
    unit, p0, ph, po, gates, act_sel, ehp, run_mean, run_var, x, y1h,
):
    """Eval-mode forward: running BN stats, no dropout.

    Returns ``(correct, loss, logits)``.
    """
    params = _unpack(w0, wh, b, gamma, beta, wo, bo)
    masks = {"unit": unit, "p0": p0, "ph": ph, "po": po}
    arch = {"gates": gates, "act_sel": act_sel}
    logits, _, _, _ = supernet_forward(
        params, masks, arch, ehp[EHP_BN_GATE], ehp[EHP_QAT_GATE], ehp[EHP_BITS],
        x, bn_stats=(run_mean, run_var),
    )
    ce, correct = _ce_and_correct(logits, y1h)
    return correct, ce, logits


# ---------------------------------------------------------------------------
# rule4ml-style surrogate: arch features → 6 resource/latency targets.
# Reuses the same Pallas kernels (masks = ones, act = ReLU one-hot).
# ---------------------------------------------------------------------------

SUR_PARAM_SHAPES = (
    (SUR_FEATS, SUR_HIDDEN), (SUR_HIDDEN,),
    (SUR_HIDDEN, SUR_HIDDEN), (SUR_HIDDEN,),
    (SUR_HIDDEN, SUR_OUT), (SUR_OUT,),
)


def surrogate_forward(sp, x):
    """Three-layer ReLU MLP through the Pallas kernels."""
    relu = jnp.asarray([1.0, 0.0, 0.0], x.dtype)
    ones_h = jnp.ones((SUR_HIDDEN,), x.dtype)
    one_sc = jnp.ones((SUR_HIDDEN,), x.dtype)
    zero_sh = jnp.zeros((SUR_HIDDEN,), x.dtype)
    h = masked_dense(x, sp[0], sp[1], ones_h)
    h = affine_act(h, one_sc, zero_sh, relu)
    h = masked_dense(h, sp[2], sp[3], ones_h)
    h = affine_act(h, one_sc, zero_sh, relu)
    return masked_dense(h, sp[4], sp[5], jnp.ones((SUR_OUT,), x.dtype))


def surrogate_train_step(
    w1, b1, w2, b2, w3, b3,
    m1, mb1, m2, mb2, m3, mb3,
    v1, vb1, v2, vb2, v3, vb3,
    x, y, shp,
):
    """One MSE + Adam step of the surrogate. Returns params, m, v, loss."""
    sp = (w1, b1, w2, b2, w3, b3)
    m = (m1, mb1, m2, mb2, m3, mb3)
    v = (v1, vb1, v2, vb2, v3, vb3)

    def loss_fn(sp):
        pred = surrogate_forward(sp, x)
        return jnp.mean(jnp.square(pred - y))

    loss, grads = jax.value_and_grad(loss_fn)(sp)
    lr, beta1, beta2 = shp[SHP_LR], shp[SHP_BETA1], shp[SHP_BETA2]
    eps, b1p, b2p = shp[SHP_EPS], shp[SHP_BETA1_POW], shp[SHP_BETA2_POW]
    out_p, out_m, out_v = [], [], []
    for p, g, mk, vk in zip(sp, grads, m, v):
        nm = beta1 * mk + (1.0 - beta1) * g
        nv = beta2 * vk + (1.0 - beta2) * jnp.square(g)
        out_p.append(p - lr * (nm / (1.0 - b1p)) / (jnp.sqrt(nv / (1.0 - b2p)) + eps))
        out_m.append(nm)
        out_v.append(nv)
    return tuple(out_p) + tuple(out_m) + tuple(out_v) + (loss,)


def surrogate_predict(w1, b1, w2, b2, w3, b3, x):
    """Surrogate inference: ``(SUR_BATCH, SUR_FEATS) → (SUR_BATCH, SUR_OUT)``."""
    return (surrogate_forward((w1, b1, w2, b2, w3, b3), x),)
