"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact pure-`jax.numpy`
counterpart here. The pytest suite (``python/tests/test_kernels.py``)
asserts ``assert_allclose`` between the Pallas implementation (run in
``interpret=True`` mode) and these oracles over hypothesis-generated shape
and value sweeps — this is the CORE correctness signal for Layer 1.

The reference functions are also used by ``test_supernet_equiv.py`` to
build an independent per-architecture MLP against which the masked
supernet is checked end-to-end.
"""

import jax
import jax.numpy as jnp

__all__ = [
    "masked_dense_ref",
    "masked_dense_vjp_ref",
    "affine_act_ref",
    "affine_act_vjp_ref",
    "fake_quant_ref",
]


def masked_dense_ref(x, w, b, mask):
    """``z = (x @ (w * mask)) + b * mask``.

    ``mask`` is a per-output-unit {0,1} vector of shape ``(n_out,)``. Masked
    (inactive) units produce exactly 0 so downstream layers see a clean
    sub-network of the padded supernet.
    """
    return (x @ (w * mask[None, :])) + (b * mask)[None, :]


def masked_dense_vjp_ref(x, w, b, mask, g):
    """Reference cotangents of :func:`masked_dense_ref`.

    Returns ``(dx, dw, db)``; the mask is non-differentiable.
    """
    gm = g * mask[None, :]
    dx = gm @ (w * mask[None, :]).T
    dw = (x.T @ gm) * mask[None, :]
    db = jnp.sum(gm, axis=0) * mask
    return dx, dw, db


def _act_blend(u, sel):
    """One-hot blend of {ReLU, tanh, sigmoid} — the Table 1 activation set."""
    return (
        sel[0] * jax.nn.relu(u)
        + sel[1] * jnp.tanh(u)
        + sel[2] * jax.nn.sigmoid(u)
    )


def affine_act_ref(z, scale, shift, sel):
    """``a = act_blend(z * scale + shift)``.

    ``scale``/``shift`` of shape ``(n_out,)`` fold in BatchNorm (or identity
    when BN is gated off); ``sel`` of shape ``(3,)`` is the activation
    one-hot (blendable, so activation choice is a *runtime* input of the
    AOT-compiled supernet).
    """
    u = z * scale[None, :] + shift[None, :]
    return _act_blend(u, sel)


def affine_act_vjp_ref(z, scale, shift, sel, g):
    """Reference cotangents ``(dz, dscale, dshift, dsel)``."""
    u = z * scale[None, :] + shift[None, :]
    sig = jax.nn.sigmoid(u)
    th = jnp.tanh(u)
    dadu = sel[0] * (u > 0).astype(u.dtype) + sel[1] * (1.0 - th * th) + sel[2] * sig * (1.0 - sig)
    gu = g * dadu
    dz = gu * scale[None, :]
    dscale = jnp.sum(gu * z, axis=0)
    dshift = jnp.sum(gu, axis=0)
    dsel = jnp.stack(
        [
            jnp.sum(g * jax.nn.relu(u)),
            jnp.sum(g * th),
            jnp.sum(g * sig),
        ]
    )
    return dz, dscale, dshift, dsel


def fake_quant_ref(w, bits):
    """Symmetric per-tensor fake quantisation (forward value only).

    ``bits`` is a *runtime* float scalar (QAT bit-width). Levels are
    ``2^(bits-1) - 1``; the scale is max-abs. Matches hls4ml's
    ``ap_fixed``-style symmetric weight quantisation closely enough for
    QAT-in-the-loop (see DESIGN.md substitution #1).
    """
    levels = jnp.exp2(bits - 1.0) - 1.0
    max_abs = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8)
    delta = max_abs / levels
    return jnp.clip(jnp.round(w / delta), -levels - 1.0, levels) * delta
