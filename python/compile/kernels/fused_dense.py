"""Layer-1 Pallas kernels: the supernet's compute hot-spot.

Two fused kernels cover >95 % of the supernet's FLOPs, in *both*
directions (forward and hand-written backward wired via
``jax.custom_vjp`` — ``pallas_call`` itself is not differentiable):

``masked_dense``
    ``z = (x @ (w ⊙ mask_col)) + b ⊙ mask``  — the padded-supernet dense
    layer. The unit mask zeroes inactive output units so every candidate
    architecture of the Table 1 space is a runtime input of ONE compiled
    graph (see DESIGN.md "Why a supernet?").

``affine_act``
    ``a = act_blend(z ⊙ scale + shift)`` — the folded BatchNorm affine +
    one-hot activation blend over {ReLU, tanh, sigmoid}.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): tiles are chosen so a
(TILE_B × PAD) activation block plus a (PAD × PAD) weight block fit VMEM
comfortably with double buffering, and the inner ``jnp.dot`` hits the MXU's
native 128×128 tile. On this image Pallas must run ``interpret=True`` (the
CPU PJRT plugin cannot execute Mosaic custom-calls); correctness is checked
against ``ref.py`` and real-TPU performance is estimated analytically in
EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch tile. The supernet trains with B=128; a single (128, PAD) block per
# grid step keeps the grid tiny (interpret-mode per-step overhead is large)
# while matching the MXU-native 128-row tile on real hardware.
TILE_B = 128

# Pallas must be interpreted on CPU PJRT — see module docstring.
INTERPRET = True


def _grid(batch):
    return (max(1, (batch + TILE_B - 1) // TILE_B),)


# --------------------------------------------------------------------------
# masked_dense: z = x @ (w * mask) + b * mask
# --------------------------------------------------------------------------


def _masked_dense_fwd_kernel(x_ref, w_ref, b_ref, m_ref, z_ref):
    """One batch tile: masked matmul + masked bias, f32 accumulate."""
    w = w_ref[...] * m_ref[...][None, :]
    acc = jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)
    z_ref[...] = acc + (b_ref[...] * m_ref[...])[None, :]


def _row_validity(batch, rows):
    """{0,1} column vector marking rows of this tile that are in-bounds.

    When ``batch % TILE_B != 0`` the trailing tile is padded; padded rows
    hold *uninitialised* data in interpret mode and must not contribute to
    the dw/db batch reductions.
    """
    row = pl.program_id(0) * TILE_B + jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0)
    return row < batch


def _zero_invalid(valid, a):
    """Zero rows outside the batch. ``where``, not multiply: padded rows are
    *uninitialised* and may be NaN, and ``NaN * 0 == NaN``."""
    return jnp.where(valid, a, 0.0)


def _masked_dense_bwd_kernel(batch, x_ref, w_ref, m_ref, g_ref, dx_ref, dw_ref, db_ref):
    """Backward tile: dx = ḡ@(w⊙m)ᵀ, dw += xᵀ@ḡ, db += Σḡ  (ḡ = g⊙m).

    dw/db are accumulated across the batch grid: the first grid step
    initialises, later steps add (grid iterations run sequentially over the
    batch dimension, so the accumulation is race-free).
    """
    valid = _row_validity(batch, g_ref.shape[0])
    gm = _zero_invalid(valid, g_ref[...]) * m_ref[...][None, :]
    wm = w_ref[...] * m_ref[...][None, :]
    dx_ref[...] = jnp.dot(gm, wm.T, preferred_element_type=jnp.float32)
    # gm is already zeroed on padded rows, so x's padded garbage is annihilated.
    dw_tile = jnp.dot(_zero_invalid(valid, x_ref[...]).T, gm, preferred_element_type=jnp.float32)
    db_tile = jnp.sum(gm, axis=0)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        dw_ref[...] = dw_tile
        db_ref[...] = db_tile

    @pl.when(pl.program_id(0) != 0)
    def _acc():
        dw_ref[...] += dw_tile
        db_ref[...] += db_tile


def _masked_dense_fwd_call(x, w, b, mask):
    batch, n_in = x.shape
    n_out = w.shape[1]
    return pl.pallas_call(
        _masked_dense_fwd_kernel,
        grid=_grid(batch),
        in_specs=[
            pl.BlockSpec((TILE_B, n_in), lambda i: (i, 0)),
            pl.BlockSpec((n_in, n_out), lambda i: (0, 0)),
            pl.BlockSpec((n_out,), lambda i: (0,)),
            pl.BlockSpec((n_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((TILE_B, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n_out), x.dtype),
        interpret=INTERPRET,
    )(x, w, b, mask)


def _masked_dense_bwd_call(x, w, mask, g):
    batch, n_in = x.shape
    n_out = w.shape[1]
    return pl.pallas_call(
        functools.partial(_masked_dense_bwd_kernel, batch),
        grid=_grid(batch),
        in_specs=[
            pl.BlockSpec((TILE_B, n_in), lambda i: (i, 0)),
            pl.BlockSpec((n_in, n_out), lambda i: (0, 0)),
            pl.BlockSpec((n_out,), lambda i: (0,)),
            pl.BlockSpec((TILE_B, n_out), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_B, n_in), lambda i: (i, 0)),
            pl.BlockSpec((n_in, n_out), lambda i: (0, 0)),
            pl.BlockSpec((n_out,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, n_in), x.dtype),
            jax.ShapeDtypeStruct((n_in, n_out), w.dtype),
            jax.ShapeDtypeStruct((n_out,), w.dtype),
        ],
        interpret=INTERPRET,
    )(x, w, mask, g)


@jax.custom_vjp
def masked_dense(x, w, b, mask):
    """Masked dense layer ``z = x @ (w ⊙ mask) + b ⊙ mask`` (Pallas fwd+bwd).

    Args:
      x: ``(batch, n_in)`` activations.
      w: ``(n_in, n_out)`` weights.
      b: ``(n_out,)`` bias.
      mask: ``(n_out,)`` {0,1} unit mask — non-differentiable.
    """
    return _masked_dense_fwd_call(x, w, b, mask)


def _masked_dense_vjp_fwd(x, w, b, mask):
    return _masked_dense_fwd_call(x, w, b, mask), (x, w, mask)


def _masked_dense_vjp_bwd(res, g):
    x, w, mask = res
    dx, dw, db = _masked_dense_bwd_call(x, w, mask, g)
    # db already includes the mask factor (ḡ = g⊙m); dw gets it column-wise.
    return dx, dw * mask[None, :], db, jnp.zeros_like(mask)


masked_dense.defvjp(_masked_dense_vjp_fwd, _masked_dense_vjp_bwd)


# --------------------------------------------------------------------------
# affine_act: a = blend(relu/tanh/sigmoid)(z * scale + shift)
# --------------------------------------------------------------------------


def _affine_act_fwd_kernel(z_ref, sc_ref, sh_ref, sel_ref, a_ref):
    u = z_ref[...] * sc_ref[...][None, :] + sh_ref[...][None, :]
    sel = sel_ref[...]
    a_ref[...] = (
        sel[0] * jnp.maximum(u, 0.0)
        + sel[1] * jnp.tanh(u)
        + sel[2] * jax.nn.sigmoid(u)
    )


def _affine_act_bwd_kernel(
    batch, z_ref, sc_ref, sh_ref, sel_ref, g_ref, dz_ref, dsc_ref, dsh_ref, dsel_ref
):
    valid = _row_validity(batch, g_ref.shape[0])
    z = _zero_invalid(valid, z_ref[...])
    g = _zero_invalid(valid, g_ref[...])
    sel = sel_ref[...]
    u = z * sc_ref[...][None, :] + sh_ref[...][None, :]
    sig = jax.nn.sigmoid(u)
    th = jnp.tanh(u)
    dadu = (
        sel[0] * (u > 0.0).astype(u.dtype)
        + sel[1] * (1.0 - th * th)
        + sel[2] * sig * (1.0 - sig)
    )
    gu = g * dadu
    dz_ref[...] = gu * sc_ref[...][None, :]
    dsc_tile = jnp.sum(gu * z, axis=0)
    dsh_tile = jnp.sum(gu, axis=0)
    dsel_tile = jnp.stack(
        [
            jnp.sum(g * jnp.maximum(u, 0.0)),
            jnp.sum(g * th),
            jnp.sum(g * sig),
        ]
    )

    @pl.when(pl.program_id(0) == 0)
    def _init():
        dsc_ref[...] = dsc_tile
        dsh_ref[...] = dsh_tile
        dsel_ref[...] = dsel_tile

    @pl.when(pl.program_id(0) != 0)
    def _acc():
        dsc_ref[...] += dsc_tile
        dsh_ref[...] += dsh_tile
        dsel_ref[...] += dsel_tile


def _affine_act_fwd_call(z, scale, shift, sel):
    batch, n = z.shape
    return pl.pallas_call(
        _affine_act_fwd_kernel,
        grid=_grid(batch),
        in_specs=[
            pl.BlockSpec((TILE_B, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((TILE_B, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n), z.dtype),
        interpret=INTERPRET,
    )(z, scale, shift, sel)


def _affine_act_bwd_call(z, scale, shift, sel, g):
    batch, n = z.shape
    return pl.pallas_call(
        functools.partial(_affine_act_bwd_kernel, batch),
        grid=_grid(batch),
        in_specs=[
            pl.BlockSpec((TILE_B, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((3,), lambda i: (0,)),
            pl.BlockSpec((TILE_B, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_B, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, n), z.dtype),
            jax.ShapeDtypeStruct((n,), z.dtype),
            jax.ShapeDtypeStruct((n,), z.dtype),
            jax.ShapeDtypeStruct((3,), z.dtype),
        ],
        interpret=INTERPRET,
    )(z, scale, shift, sel, g)


@jax.custom_vjp
def affine_act(z, scale, shift, sel):
    """Folded-BN affine + blended activation (Pallas fwd+bwd).

    Args:
      z: ``(batch, n)`` pre-activations.
      scale, shift: ``(n,)`` affine (BatchNorm folded, or 1/0 identity).
      sel: ``(3,)`` activation one-hot over {ReLU, tanh, sigmoid}.
    """
    return _affine_act_fwd_call(z, scale, shift, sel)


def _affine_act_vjp_fwd(z, scale, shift, sel):
    return _affine_act_fwd_call(z, scale, shift, sel), (z, scale, shift, sel)


def _affine_act_vjp_bwd(res, g):
    z, scale, shift, sel = res
    return _affine_act_bwd_call(z, scale, shift, sel, g)


affine_act.defvjp(_affine_act_vjp_fwd, _affine_act_vjp_bwd)


# --------------------------------------------------------------------------
# fake_quant: symmetric per-tensor fake quantisation with a straight-through
# estimator. The rounding itself is elementwise and cheap; STE is the point,
# so this stays a custom_vjp over jnp (no kernel needed — it fuses into the
# surrounding HLO).
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def fake_quant(w, bits):
    """Fake-quantise ``w`` to ``bits`` (runtime scalar) with an STE."""
    levels = jnp.exp2(bits - 1.0) - 1.0
    max_abs = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8)
    delta = max_abs / levels
    return jnp.clip(jnp.round(w / delta), -levels - 1.0, levels) * delta


def _fake_quant_fwd(w, bits):
    return fake_quant(w, bits), None


def _fake_quant_bwd(_, g):
    # Straight-through: quantisation is treated as identity for gradients.
    return g, jnp.zeros(())


fake_quant.defvjp(_fake_quant_fwd, _fake_quant_bwd)
