//! Property-style fuzz of the shared HTTP framing parser (`net/`).
//!
//! Both services — the estimation server and the TCP shard transport —
//! read untrusted bytes through `net::read_request`, so the parser must
//! hold two properties against arbitrary input:
//!
//! 1. **No panics.** Malformed framing (truncated heads, bodies that
//!    never arrive, binary garbage) surfaces as a typed `anyhow` error,
//!    never an unwind.
//! 2. **Bounded admission.** A parsed request never carries a body over
//!    `MAX_BODY`, however large the declared `Content-Length`.
//!
//! Everything is seeded (xorshift64), so a failure reproduces exactly;
//! the reader delivers bytes in randomly sized chunks to exercise split
//! reads across the request line / header / body boundaries.

use std::io::Read;

use snac_pack::net::{read_request, MAX_BODY, MAX_HEAD};

/// Tiny deterministic PRNG — the test must not depend on hash ordering
/// or OS entropy, so a failing seed can be replayed verbatim.
struct XorShift(u64);

impl XorShift {
    fn new(seed: u64) -> XorShift {
        XorShift(seed.max(1))
    }

    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }

    /// Uniform in `0..n` (n > 0).
    fn below(&mut self, n: usize) -> usize {
        (self.next() % n as u64) as usize
    }
}

/// A `Read` source that returns the payload in randomly sized chunks, so
/// every parser state can land on a read boundary.
struct SplitReader {
    data: Vec<u8>,
    pos: usize,
    rng: XorShift,
}

impl SplitReader {
    fn new(data: Vec<u8>, seed: u64) -> SplitReader {
        SplitReader {
            data,
            pos: 0,
            rng: XorShift::new(seed),
        }
    }
}

impl Read for SplitReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.pos >= self.data.len() || buf.is_empty() {
            return Ok(0);
        }
        let max = (self.data.len() - self.pos).min(buf.len());
        let n = 1 + self.rng.below(max);
        buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// A syntactically valid request with randomised method, path, header
/// noise, and body. Returns the raw bytes and the offset where the body
/// starts (= length of the head incl. the blank line).
fn valid_request(rng: &mut XorShift) -> (Vec<u8>, usize, String, String, String) {
    let methods = ["GET", "POST", "PUT", "DELETE", "patch"];
    let method = methods[rng.below(methods.len())];
    let path = format!("/endpoint/{}", rng.below(1000));
    let query = if rng.below(2) == 0 { "?q=1&r=2" } else { "" };
    let body: String = (0..rng.below(4096))
        .map(|_| char::from(b'a' + (rng.below(26) as u8)))
        .collect();
    let mut head = format!("{method} {path}{query} HTTP/1.1\r\n");
    for i in 0..rng.below(8) {
        head.push_str(&format!("X-Noise-{i}: {}\r\n", rng.below(100_000)));
    }
    head.push_str(&format!("Content-Length: {}\r\n\r\n", body.len()));
    let body_start = head.len();
    let mut raw = head.into_bytes();
    raw.extend_from_slice(body.as_bytes());
    (
        raw,
        body_start,
        method.to_ascii_uppercase(),
        path,
        body,
    )
}

/// Valid requests parse back to their fields through arbitrarily split
/// reads.
#[test]
fn valid_requests_survive_split_reads() {
    let mut rng = XorShift::new(0x5eed_0001);
    for round in 0..200u64 {
        let (raw, _, method, path, body) = valid_request(&mut rng);
        let req = read_request(SplitReader::new(raw, 0xc0ffee ^ round))
            .unwrap_or_else(|e| panic!("round {round}: valid request rejected: {e:#}"));
        assert_eq!(req.method, method, "round {round}");
        assert_eq!(req.path, path, "round {round}");
        assert_eq!(req.body, body, "round {round}");
    }
}

/// Truncating a request inside its body region is a typed framing error
/// — the promised bytes never arrive, and the parser must say so rather
/// than hang or panic.
#[test]
fn body_truncation_is_a_typed_error() {
    let mut rng = XorShift::new(0x5eed_0002);
    let mut exercised = 0usize;
    for round in 0..300u64 {
        let (raw, body_start, ..) = valid_request(&mut rng);
        if raw.len() == body_start {
            continue; // empty body: nothing to truncate
        }
        // cut strictly inside the body region
        let cut = body_start + rng.below(raw.len() - body_start);
        let err = read_request(SplitReader::new(raw[..cut].to_vec(), round))
            .expect_err("a short body must not parse");
        assert!(
            format!("{err:#}").contains("request body"),
            "round {round}: unexpected error: {err:#}"
        );
        exercised += 1;
    }
    assert!(exercised > 100, "the generator kept producing empty bodies");
}

/// Head-region truncation (mid request-line or mid-headers) never
/// panics; when it parses at all, the admitted body stays bounded.
#[test]
fn head_truncation_never_panics() {
    let mut rng = XorShift::new(0x5eed_0003);
    for round in 0..300u64 {
        let (raw, body_start, ..) = valid_request(&mut rng);
        let cut = rng.below(body_start);
        match read_request(SplitReader::new(raw[..cut].to_vec(), round)) {
            Ok(req) => assert!(req.body.len() <= MAX_BODY),
            Err(err) => {
                let msg = format!("{err:#}");
                assert!(!msg.is_empty(), "errors must carry context");
            }
        }
    }
}

/// A `Content-Length` past the admission cap is refused up front —
/// before any allocation of that size.
#[test]
fn oversized_content_length_is_refused() {
    for declared in [MAX_BODY + 1, MAX_BODY * 16, usize::MAX / 2] {
        let raw = format!("POST /big HTTP/1.1\r\nContent-Length: {declared}\r\n\r\n");
        let err = read_request(SplitReader::new(raw.into_bytes(), 7)).unwrap_err();
        assert!(
            format!("{err:#}").contains("exceeds"),
            "declared {declared}: {err:#}"
        );
    }
    // a non-numeric length is a parse error, not a zero default
    let raw = b"POST / HTTP/1.1\r\nContent-Length: lots\r\n\r\n".to_vec();
    let err = read_request(SplitReader::new(raw, 7)).unwrap_err();
    assert!(format!("{err:#}").contains("Content-Length"), "{err:#}");
}

/// A head region larger than `MAX_HEAD` cannot pin memory: the parser
/// stops reading at the cap and fails (or degrades to a body-less
/// parse) instead of buffering the flood.
#[test]
fn header_floods_are_capped() {
    // one giant request line, no terminator — the head budget exhausts
    let raw = vec![b'A'; MAX_HEAD * 2];
    let err = read_request(SplitReader::new(raw, 11)).unwrap_err();
    assert!(format!("{err:#}").contains("path"), "{err:#}");

    // endless headers after a valid request line: the cap truncates the
    // flood; whatever parses must still respect the body bound
    let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
    while raw.len() < MAX_HEAD * 2 {
        raw.extend_from_slice(b"X-Flood: yes\r\n");
    }
    match read_request(SplitReader::new(raw, 11)) {
        Ok(req) => assert!(req.body.len() <= MAX_BODY),
        Err(err) => assert!(!format!("{err:#}").is_empty()),
    }
}

/// A declared body that arrives as non-UTF-8 bytes is a typed error.
#[test]
fn non_utf8_bodies_are_typed_errors() {
    let mut raw = b"POST /estimate HTTP/1.1\r\nContent-Length: 4\r\n\r\n".to_vec();
    raw.extend_from_slice(&[0xff, 0xfe, 0x80, 0x81]);
    let err = read_request(SplitReader::new(raw, 13)).unwrap_err();
    assert!(format!("{err:#}").contains("UTF-8"), "{err:#}");
}

/// Pure seeded garbage — binary noise, control bytes, stray CRLFs —
/// must never panic the parser, whatever it decides.
#[test]
fn random_garbage_never_panics() {
    let mut rng = XorShift::new(0x5eed_0004);
    for round in 0..500u64 {
        let len = rng.below(2048);
        let data: Vec<u8> = (0..len).map(|_| (rng.next() & 0xff) as u8).collect();
        match read_request(SplitReader::new(data, round)) {
            Ok(req) => assert!(req.body.len() <= MAX_BODY),
            Err(_) => {}
        }
    }
}
