//! Property-style fuzz of the shared HTTP framing parser (`net/`).
//!
//! Both services — the estimation server and the TCP shard transport —
//! read untrusted bytes through `net::RequestReader`, so the parser must
//! hold three properties against arbitrary input:
//!
//! 1. **No panics.** Malformed framing (truncated heads, bodies that
//!    never arrive, binary garbage) surfaces as a typed `anyhow` error,
//!    never an unwind.
//! 2. **Bounded admission.** A parsed request never carries a body over
//!    `MAX_BODY`, however large the declared `Content-Length`.
//! 3. **Typed connection lifecycle.** On a persistent connection the
//!    parser distinguishes a clean close between requests
//!    (`NetError::Closed`), an idle keep-alive expiry (`NetError::Idle`),
//!    and a truncation inside a request (`NetError::Truncated`) — the
//!    server's decision to log, shed, or silently reclaim hangs on it.
//!
//! Everything is seeded (xorshift64), so a failure reproduces exactly;
//! the reader delivers bytes in randomly sized chunks to exercise split
//! reads across the request line / header / body boundaries.

use std::io::Read;

use snac_pack::net::{read_request, NetError, RequestReader, MAX_BODY, MAX_HEAD};

/// Tiny deterministic PRNG — the test must not depend on hash ordering
/// or OS entropy, so a failing seed can be replayed verbatim.
struct XorShift(u64);

impl XorShift {
    fn new(seed: u64) -> XorShift {
        XorShift(seed.max(1))
    }

    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }

    /// Uniform in `0..n` (n > 0).
    fn below(&mut self, n: usize) -> usize {
        (self.next() % n as u64) as usize
    }
}

/// A `Read` source that returns the payload in randomly sized chunks, so
/// every parser state can land on a read boundary.
struct SplitReader {
    data: Vec<u8>,
    pos: usize,
    rng: XorShift,
}

impl SplitReader {
    fn new(data: Vec<u8>, seed: u64) -> SplitReader {
        SplitReader {
            data,
            pos: 0,
            rng: XorShift::new(seed),
        }
    }
}

impl Read for SplitReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.pos >= self.data.len() || buf.is_empty() {
            return Ok(0);
        }
        let max = (self.data.len() - self.pos).min(buf.len());
        let n = 1 + self.rng.below(max);
        buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// A syntactically valid request with randomised method, path, header
/// noise, and body. Returns the raw bytes and the offset where the body
/// starts (= length of the head incl. the blank line).
fn valid_request(rng: &mut XorShift) -> (Vec<u8>, usize, String, String, String) {
    let methods = ["GET", "POST", "PUT", "DELETE", "patch"];
    let method = methods[rng.below(methods.len())];
    let path = format!("/endpoint/{}", rng.below(1000));
    let query = if rng.below(2) == 0 { "?q=1&r=2" } else { "" };
    let body: String = (0..rng.below(4096))
        .map(|_| char::from(b'a' + (rng.below(26) as u8)))
        .collect();
    let mut head = format!("{method} {path}{query} HTTP/1.1\r\n");
    for i in 0..rng.below(8) {
        head.push_str(&format!("X-Noise-{i}: {}\r\n", rng.below(100_000)));
    }
    head.push_str(&format!("Content-Length: {}\r\n\r\n", body.len()));
    let body_start = head.len();
    let mut raw = head.into_bytes();
    raw.extend_from_slice(body.as_bytes());
    (
        raw,
        body_start,
        method.to_ascii_uppercase(),
        path,
        body,
    )
}

/// Valid requests parse back to their fields through arbitrarily split
/// reads.
#[test]
fn valid_requests_survive_split_reads() {
    let mut rng = XorShift::new(0x5eed_0001);
    for round in 0..200u64 {
        let (raw, _, method, path, body) = valid_request(&mut rng);
        let req = read_request(SplitReader::new(raw, 0xc0ffee ^ round))
            .unwrap_or_else(|e| panic!("round {round}: valid request rejected: {e:#}"));
        assert_eq!(req.method, method, "round {round}");
        assert_eq!(req.path, path, "round {round}");
        assert_eq!(req.body, body, "round {round}");
    }
}

/// Truncating a request inside its body region is a typed framing error
/// — the promised bytes never arrive, and the parser must say so rather
/// than hang or panic.
#[test]
fn body_truncation_is_a_typed_error() {
    let mut rng = XorShift::new(0x5eed_0002);
    let mut exercised = 0usize;
    for round in 0..300u64 {
        let (raw, body_start, ..) = valid_request(&mut rng);
        if raw.len() == body_start {
            continue; // empty body: nothing to truncate
        }
        // cut strictly inside the body region
        let cut = body_start + rng.below(raw.len() - body_start);
        let err = read_request(SplitReader::new(raw[..cut].to_vec(), round))
            .expect_err("a short body must not parse");
        assert!(
            format!("{err:#}").contains("request body"),
            "round {round}: unexpected error: {err:#}"
        );
        exercised += 1;
    }
    assert!(exercised > 100, "the generator kept producing empty bodies");
}

/// Head-region truncation (mid request-line or mid-headers) never
/// panics; when it parses at all, the admitted body stays bounded.
#[test]
fn head_truncation_never_panics() {
    let mut rng = XorShift::new(0x5eed_0003);
    for round in 0..300u64 {
        let (raw, body_start, ..) = valid_request(&mut rng);
        let cut = rng.below(body_start);
        match read_request(SplitReader::new(raw[..cut].to_vec(), round)) {
            Ok(req) => assert!(req.body.len() <= MAX_BODY),
            Err(err) => {
                let msg = format!("{err:#}");
                assert!(!msg.is_empty(), "errors must carry context");
            }
        }
    }
}

/// A `Content-Length` past the admission cap is refused up front —
/// before any allocation of that size.
#[test]
fn oversized_content_length_is_refused() {
    for declared in [MAX_BODY + 1, MAX_BODY * 16, usize::MAX / 2] {
        let raw = format!("POST /big HTTP/1.1\r\nContent-Length: {declared}\r\n\r\n");
        let err = read_request(SplitReader::new(raw.into_bytes(), 7)).unwrap_err();
        assert!(
            format!("{err:#}").contains("exceeds"),
            "declared {declared}: {err:#}"
        );
    }
    // a non-numeric length is a parse error, not a zero default
    let raw = b"POST / HTTP/1.1\r\nContent-Length: lots\r\n\r\n".to_vec();
    let err = read_request(SplitReader::new(raw, 7)).unwrap_err();
    assert!(format!("{err:#}").contains("Content-Length"), "{err:#}");
}

/// A head region larger than `MAX_HEAD` cannot pin memory: the parser
/// stops reading at the cap and fails (or degrades to a body-less
/// parse) instead of buffering the flood.
#[test]
fn header_floods_are_capped() {
    // one giant request line, no terminator — the head budget exhausts
    let raw = vec![b'A'; MAX_HEAD * 2];
    let err = read_request(SplitReader::new(raw, 11)).unwrap_err();
    assert!(format!("{err:#}").contains("head cap"), "{err:#}");

    // endless headers after a valid request line: the cap truncates the
    // flood; whatever parses must still respect the body bound
    let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
    while raw.len() < MAX_HEAD * 2 {
        raw.extend_from_slice(b"X-Flood: yes\r\n");
    }
    match read_request(SplitReader::new(raw, 11)) {
        Ok(req) => assert!(req.body.len() <= MAX_BODY),
        Err(err) => assert!(!format!("{err:#}").is_empty()),
    }
}

/// A declared body that arrives as non-UTF-8 bytes is a typed error.
#[test]
fn non_utf8_bodies_are_typed_errors() {
    let mut raw = b"POST /estimate HTTP/1.1\r\nContent-Length: 4\r\n\r\n".to_vec();
    raw.extend_from_slice(&[0xff, 0xfe, 0x80, 0x81]);
    let err = read_request(SplitReader::new(raw, 13)).unwrap_err();
    assert!(format!("{err:#}").contains("UTF-8"), "{err:#}");
}

/// Pure seeded garbage — binary noise, control bytes, stray CRLFs —
/// must never panic the parser, whatever it decides.
#[test]
fn random_garbage_never_panics() {
    let mut rng = XorShift::new(0x5eed_0004);
    for round in 0..500u64 {
        let len = rng.below(2048);
        let data: Vec<u8> = (0..len).map(|_| (rng.next() & 0xff) as u8).collect();
        match read_request(SplitReader::new(data, round)) {
            Ok(req) => assert!(req.body.len() <= MAX_BODY),
            Err(_) => {}
        }
    }
}

/// A pipelined connection — many requests back-to-back on one byte
/// stream — parses each request intact through arbitrarily split reads,
/// then reports the EOF between requests as a clean [`NetError::Closed`].
#[test]
fn pipelined_requests_parse_in_order_through_split_reads() {
    let mut rng = XorShift::new(0x5eed_0005);
    for round in 0..50u64 {
        let mut raw = Vec::new();
        let mut expected = Vec::new();
        for _ in 0..1 + rng.below(6) {
            let (bytes, _, method, path, body) = valid_request(&mut rng);
            raw.extend_from_slice(&bytes);
            expected.push((method, path, body));
        }
        let mut reader = RequestReader::new(SplitReader::new(raw, 0x9199 ^ round));
        for (i, (method, path, body)) in expected.iter().enumerate() {
            let req = reader
                .next_request()
                .unwrap_or_else(|e| panic!("round {round} request {i}: {e:#}"));
            assert_eq!(&req.method, method, "round {round} request {i}");
            assert_eq!(&req.path, path, "round {round} request {i}");
            assert_eq!(&req.body, body, "round {round} request {i}");
        }
        let err = reader.next_request().expect_err("the stream is exhausted");
        assert!(
            matches!(err.downcast_ref::<NetError>(), Some(NetError::Closed)),
            "round {round}: EOF between requests must be Closed, got {err:#}"
        );
    }
}

/// The same truncation point means two different things depending on
/// where it lands: *between* requests it is a clean close (the peer was
/// simply done), *inside* a request it is a typed `Truncated` framing
/// error (the peer promised bytes that never came).
#[test]
fn truncation_between_requests_closes_but_inside_a_request_is_typed() {
    let mut rng = XorShift::new(0x5eed_0006);
    let mut inside = 0usize;
    for round in 0..200u64 {
        let (first, ..) = valid_request(&mut rng);
        let (second, ..) = valid_request(&mut rng);
        let boundary = first.len();
        let mut raw = first;
        raw.extend_from_slice(&second);

        // cut at the boundary: request 1 parses, then a clean close
        let mut reader = RequestReader::new(SplitReader::new(raw[..boundary].to_vec(), round));
        reader.next_request().expect("the complete first request parses");
        let err = reader.next_request().unwrap_err();
        assert!(
            matches!(err.downcast_ref::<NetError>(), Some(NetError::Closed)),
            "round {round}: boundary cut must be Closed, got {err:#}"
        );

        // cut strictly inside request 2: request 1 parses, then Truncated
        if second.len() > 1 {
            let cut = boundary + 1 + rng.below(second.len() - 1);
            let mut reader = RequestReader::new(SplitReader::new(raw[..cut].to_vec(), round));
            reader.next_request().expect("the complete first request parses");
            let err = reader.next_request().unwrap_err();
            assert!(
                matches!(err.downcast_ref::<NetError>(), Some(NetError::Truncated { .. })),
                "round {round}: mid-request cut must be Truncated, got {err:#}"
            );
            inside += 1;
        }
    }
    assert!(inside > 100, "the generator kept producing 1-byte requests");
}

/// A keep-alive connection that goes quiet *between* requests expires as
/// [`NetError::Idle`] once the socket's read timeout elapses — the
/// server-side signal to reclaim the worker without logging an error.
#[test]
fn idle_keep_alive_connections_expire_with_a_typed_idle_error() {
    use std::io::Write as _;
    use std::net::TcpListener;
    use std::time::Duration;

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::scope(|s| {
        s.spawn(move || {
            let mut stream = std::net::TcpStream::connect(addr).unwrap();
            stream
                .write_all(b"GET /one HTTP/1.1\r\n\r\n")
                .unwrap();
            // then go quiet, holding the socket open past the timeout
            std::thread::sleep(Duration::from_millis(600));
        });
        let (stream, _) = listener.accept().unwrap();
        stream
            .set_read_timeout(Some(Duration::from_millis(100)))
            .unwrap();
        let mut reader = RequestReader::new(stream);
        let req = reader.next_request().expect("the first request parses");
        assert_eq!(req.path, "/one");
        let err = reader.next_request().expect_err("the peer went quiet");
        assert!(
            matches!(err.downcast_ref::<NetError>(), Some(NetError::Idle)),
            "idle between requests must be Idle, got {err:#}"
        );
        assert!(snac_pack::net::quiet_close(&err), "Idle closes quietly");
    });
}

/// A server trickling its response one byte at a time cannot stretch a
/// client past its overall deadline: `request_with_timeout` bounds the
/// whole exchange, not each socket read.
#[test]
fn trickled_responses_hit_the_overall_client_deadline() {
    use std::io::{Read as _, Write as _};
    use std::net::TcpListener;
    use std::time::{Duration, Instant};

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::scope(|s| {
        s.spawn(|| {
            let (mut stream, _) = listener.accept().unwrap();
            let mut scratch = [0u8; 1024];
            let _ = stream.read(&mut scratch); // swallow the request head
            // 100 bytes at 20ms each: far slower than the 250ms deadline,
            // but each individual read makes progress
            for b in b"HTTP/1.1 200 OK\r\nContent-Length: 1000\r\n\r\n".iter().cycle().take(100) {
                if stream.write_all(&[*b]).is_err() {
                    return;
                }
                std::thread::sleep(Duration::from_millis(20));
            }
        });
        let t0 = Instant::now();
        let err = snac_pack::net::request_with_timeout(
            &addr,
            "GET",
            "/slow",
            None,
            Duration::from_millis(250),
        )
        .expect_err("a trickled response must time out");
        let elapsed = t0.elapsed();
        assert!(
            matches!(err.downcast_ref::<NetError>(), Some(NetError::Timeout { .. })),
            "expected a typed Timeout, got {err:#}"
        );
        assert!(
            elapsed < Duration::from_millis(1500),
            "deadline must be overall, not per-read: waited {elapsed:?}"
        );
    });
}
