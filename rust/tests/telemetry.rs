//! Observability acceptance: tracing must be provably inert, the
//! exported artifacts must be schema-valid, and a sharded TCP fleet
//! must stitch one cross-process trace under the driver's trace ID.
//!
//! Subprocess-driven (the actual `snac-pack` binary) so every phase
//! gets a fresh process-global tracer and the real CLI wiring —
//! `--trace-out`/`--trace-ops` parsing, driver init, manifest trace
//! stamping, worker adoption, end-of-run export — is what's under test.

use std::io::{BufRead, BufReader, Read};
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};

use snac_pack::coordinator::TrialRecord;
use snac_pack::nn::SearchSpace;
use snac_pack::util::Json;

fn out_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("snac_telemetry_itest")
        .join(format!("{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The micro search budget shared by every run in this file (quickstart
/// preset, NAC objectives — seconds per run, and deterministic modulo
/// wall-clock timings).
fn micro_args(out: &Path) -> Vec<String> {
    [
        "search",
        "--preset",
        "quickstart",
        "--set",
        "trials=6",
        "--set",
        "population=3",
        "--set",
        "epochs=1",
        "--set",
        "n_train=640",
        "--set",
        "n_val=256",
        "--set",
        "n_test=256",
        "--out",
    ]
    .iter()
    .map(|s| s.to_string())
    .chain([out.display().to_string()])
    .collect()
}

/// The trial database with live timings zeroed — everything else must
/// be bit-identical whether or not the run was traced.
fn canonical_trials(path: &Path, space: &SearchSpace) -> String {
    let records = TrialRecord::load_all(path, space)
        .unwrap_or_else(|e| panic!("loading {}: {e:#}", path.display()));
    assert!(!records.is_empty(), "{} is empty", path.display());
    let rows: Vec<Json> = records
        .into_iter()
        .map(|mut r| {
            r.train_seconds = 0.0;
            r.to_json()
        })
        .collect();
    Json::Arr(rows).to_string()
}

/// Run the binary to completion; panic (with its stderr) on failure.
fn run_search(args: &[String], extra: &[&str]) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_snac-pack"))
        .args(args)
        .args(extra)
        .output()
        .expect("spawn snac-pack");
    let log = String::from_utf8_lossy(&out.stderr).into_owned();
    assert!(out.status.success(), "search failed:\n{log}");
    log
}

/// Validate the Chrome-trace shape and return `(trace_id, events)`:
/// every event carries `name`/`ph`/`pid`/`tid`, durations carry
/// `ts` + `dur`, instants carry `ts`, and the metadata names the run.
fn chrome_trace_events(doc: &Json) -> (String, Vec<Json>) {
    assert_eq!(
        doc.get("displayTimeUnit").and_then(Json::as_str),
        Some("ms"),
        "displayTimeUnit"
    );
    let id = doc
        .get("metadata")
        .and_then(|m| m.get("trace_id"))
        .and_then(Json::as_str)
        .expect("metadata.trace_id")
        .to_string();
    assert!(!id.is_empty(), "trace_id must be non-empty");
    let events = doc.get("traceEvents").expect("traceEvents").items().to_vec();
    assert!(!events.is_empty(), "traceEvents must be non-empty");
    for ev in &events {
        let ph = ev.get("ph").and_then(Json::as_str).expect("event ph");
        assert!(ev.get("name").and_then(Json::as_str).is_some(), "event name");
        assert!(ev.get("pid").and_then(Json::as_f64).is_some(), "event pid");
        assert!(ev.get("tid").and_then(Json::as_f64).is_some(), "event tid");
        match ph {
            "X" => {
                assert!(ev.get("ts").and_then(Json::as_f64).is_some(), "X event ts");
                assert!(ev.get("dur").and_then(Json::as_f64).is_some(), "X event dur");
            }
            "i" => assert!(ev.get("ts").and_then(Json::as_f64).is_some(), "i event ts"),
            "M" => {}
            other => panic!("unexpected event phase {other:?}"),
        }
    }
    (id, events)
}

/// Does any duration/instant event match `cat`?
fn has_cat(events: &[Json], cat: &str) -> bool {
    events
        .iter()
        .any(|ev| ev.get("cat").and_then(Json::as_str) == Some(cat))
}

#[test]
fn tracing_is_inert_and_exports_valid_artifacts() {
    let base = out_dir("inert");
    let off = base.join("off");
    let on = base.join("on");
    let sampled = base.join("sampled");
    let trace_on = base.join("trace_on.json");
    let trace_ops = base.join("trace_ops.json");

    let trace_on_s = trace_on.display().to_string();
    let trace_ops_s = trace_ops.display().to_string();
    run_search(&micro_args(&off), &[]);
    run_search(&micro_args(&on), &["--trace-out", trace_on_s.as_str()]);
    run_search(
        &micro_args(&sampled),
        &["--trace-out", trace_ops_s.as_str(), "--trace-ops", "3"],
    );

    // tracing is provably inert: identical trial databases (modulo live
    // wall-clock timings) across off / on / per-op-sampled
    let space = SearchSpace::table1();
    let want = canonical_trials(&off.join("trials.json"), &space);
    assert_eq!(
        want,
        canonical_trials(&on.join("trials.json"), &space),
        "tracing must not change the trial database"
    );
    assert_eq!(
        want,
        canonical_trials(&sampled.join("trials.json"), &space),
        "per-op sampling must not change the trial database"
    );

    // the Chrome-trace export is schema-valid and carries the
    // instrumented stages
    let doc = Json::parse(&std::fs::read_to_string(&trace_on).expect("trace.json written"))
        .expect("trace.json parses");
    let (_, events) = chrome_trace_events(&doc);
    for cat in ["search", "eval"] {
        assert!(has_cat(&events, cat), "traced search must record `{cat}` spans");
    }
    assert!(
        !has_cat(&events, "xla"),
        "per-op spans must be off unless --trace-ops is set"
    );

    // the JSONL flight log beside it: one parseable span per line
    let jsonl =
        std::fs::read_to_string(trace_on.with_extension("jsonl")).expect("flight log written");
    let mut lines = 0usize;
    for line in jsonl.lines() {
        let span = Json::parse(line).expect("flight-log line parses");
        for key in ["name", "cat", "ts", "pid", "tid"] {
            assert!(span.get(key).is_some(), "flight-log span missing `{key}`: {line}");
        }
        lines += 1;
    }
    assert!(lines > 0, "flight log must be non-empty");

    // --trace-ops 3 samples interpreter ops into the same timeline
    let doc = Json::parse(&std::fs::read_to_string(&trace_ops).expect("sampled trace written"))
        .expect("sampled trace parses");
    let (_, events) = chrome_trace_events(&doc);
    assert!(has_cat(&events, "xla"), "--trace-ops must record interpreter op spans");

    let _ = std::fs::remove_dir_all(&base);
}

#[test]
fn tcp_fleet_stitches_one_cross_process_trace() {
    let single = out_dir("stitch-single");
    let fleet = out_dir("stitch-fleet");
    let trace_path = fleet.join("trace.json");

    // untraced single-process reference for the bit-identity check
    run_search(&micro_args(&single), &[]);

    // traced driver: TCP task server, zero local workers — every shard
    // travels over the wire to the external fleet
    let trace_path_s = trace_path.display().to_string();
    let mut driver = Command::new(env!("CARGO_BIN_EXE_snac-pack"))
        .args(micro_args(&fleet))
        .args([
            "--shards",
            "2",
            "--listen",
            "127.0.0.1:0",
            "--set",
            "spawn_workers=0",
            "--workers",
            "2",
            "--trace-out",
            trace_path_s.as_str(),
        ])
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn traced TCP driver");

    // scrape the run token and the bound address from the driver log
    let mut reader = BufReader::new(driver.stderr.take().expect("driver stderr piped"));
    let mut log = String::new();
    let mut token = None;
    let addr = loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("reading driver log");
        log.push_str(&line);
        if n == 0 {
            let _ = driver.kill();
            panic!("driver exited before announcing its address:\n{log}");
        }
        if let Some(rest) = line.split("run token: ").nth(1) {
            token = Some(rest.trim().to_string());
        }
        if let Some(rest) = line.split("tcp://").nth(1) {
            break rest.trim().to_string();
        }
    };
    let token = token.unwrap_or_else(|| panic!("driver never printed its run token:\n{log}"));

    // two external worker processes adopt the driver's trace ID from the
    // manifest and attach their span buffers to result publications
    let workers: Vec<_> = (0..2)
        .map(|_| {
            Command::new(env!("CARGO_BIN_EXE_snac-pack"))
                .args(["worker", "--connect", &addr, "--token", &token, "--workers", "1"])
                .stderr(Stdio::piped())
                .spawn()
                .expect("spawn TCP worker")
        })
        .collect();

    reader.read_to_string(&mut log).expect("draining driver log");
    let status = driver.wait().expect("driver exit status");
    assert!(status.success(), "traced TCP driver failed:\n{log}");
    let mut adopted = 0usize;
    for w in workers {
        let out = w.wait_with_output().expect("worker exit status");
        let wlog = String::from_utf8_lossy(&out.stderr);
        assert!(out.status.success(), "worker failed:\n{wlog}");
        if wlog.contains("tracing under run ") {
            adopted += 1;
        }
    }
    assert_eq!(adopted, 2, "both workers adopted the driver's trace:\n{log}");

    // tracing changes nothing about the result: bit-identical trial
    // database (timings excluded) vs the untraced single-process run
    let space = SearchSpace::table1();
    assert_eq!(
        canonical_trials(&single.join("trials.json"), &space),
        canonical_trials(&fleet.join("trials.json"), &space),
        "traced TCP-dispatched trial database must be bit-identical (timings excluded)"
    );

    // one stitched trace: the driver's export contains spans from other
    // process IDs, and every remote span is tagged with the driver's
    // trace ID
    let doc = Json::parse(&std::fs::read_to_string(&trace_path).expect("stitched trace written"))
        .expect("stitched trace parses");
    let (trace_id, events) = chrome_trace_events(&doc);
    let driver_pid = events
        .iter()
        .find(|ev| {
            ev.get("ph").and_then(Json::as_str) == Some("M")
                && ev.get("args").and_then(|a| a.get("name")).and_then(Json::as_str)
                    == Some("driver")
        })
        .and_then(|ev| ev.get("pid"))
        .and_then(Json::as_f64)
        .expect("driver process_name metadata");
    let remote: Vec<&Json> = events
        .iter()
        .filter(|ev| {
            ev.get("ph").and_then(Json::as_str) == Some("X")
                && ev.get("pid").and_then(Json::as_f64) != Some(driver_pid)
        })
        .collect();
    assert!(
        !remote.is_empty(),
        "stitched trace must contain worker-process spans:\n{log}"
    );
    for ev in &remote {
        assert_eq!(
            ev.get("args").and_then(|a| a.get("trace")).and_then(Json::as_str),
            Some(trace_id.as_str()),
            "remote span must carry the driver's trace ID: {ev:?}"
        );
    }
    assert!(
        remote
            .iter()
            .any(|ev| ev.get("name").and_then(Json::as_str) == Some("shard")),
        "worker shard spans must appear in the stitched trace"
    );

    for dir in [&single, &fleet] {
        let _ = std::fs::remove_dir_all(dir);
    }
}
