//! Full-system integration: the complete SNAC-Pack pipeline at micro scale.
//!
//! Exercises every layer together — PJRT runtime, supernet trainer,
//! surrogate (train + predict), NSGA-II searches with both objective sets,
//! §4 selection, local search, synthesis simulator, and the report layer —
//! and asserts the structural invariants of the outputs.

use snac_pack::config::Preset;
use snac_pack::coordinator::{run_pipeline, TrialRecord};
use snac_pack::nn::SearchSpace;
use snac_pack::runtime::Runtime;

#[test]
fn micro_pipeline_end_to_end() {
    // real AOT artifacts when built, else the checked-in HLO fixtures
    // interpreted by `rust/xla` — never skipped
    let dir = snac_pack::runtime::artifact_dir()
        .expect("no artifacts/ and no xla/tests/fixtures/ manifest in this tree");
    let rt = Runtime::load(&dir).unwrap();
    let mut preset = Preset::by_name("quickstart").unwrap();
    // micro budget: exercise everything, spend seconds not minutes
    preset.set("trials", "6").unwrap();
    preset.set("population", "3").unwrap();
    preset.set("epochs", "1").unwrap();
    preset.set("n_train", "640").unwrap();
    preset.set("n_val", "256").unwrap();
    preset.set("n_test", "256").unwrap();
    preset.set("surrogate_size", "512").unwrap();
    preset.set("surrogate_epochs", "20").unwrap();
    preset.set("imp_iterations", "3").unwrap();
    preset.set("imp_epochs", "1").unwrap();
    preset.set("warmup_epochs", "1").unwrap();
    let out = std::env::temp_dir().join("snac_pipeline_itest");
    let _ = std::fs::remove_dir_all(&out);
    let summary = run_pipeline(&rt, &preset, &out).unwrap();

    // --- three processed models in paper order ---
    assert_eq!(summary.models.len(), 3);
    assert_eq!(summary.models[0].name, "Baseline [12]");
    assert_eq!(summary.models[1].name, "Optimal NAC");
    assert_eq!(summary.models[2].name, "Optimal SNAC-Pack");
    for m in &summary.models {
        assert!(m.final_accuracy > 0.2, "{}: beats chance", m.name);
        assert!(
            (m.sparsity - 0.5).abs() < 0.2,
            "{}: deployment point near 50% ({})",
            m.name,
            m.sparsity
        );
        assert!(m.synth.lut > 0 && m.synth.latency_cc > 0);
        assert_eq!(m.synth.ii_cc, 1, "RF=1 pipeline");
    }
    // baseline keeps its softmax head (4 BRAM) per the legacy [12] config
    assert!(summary.models[0].synth.bram36 >= 4);

    // --- trial databases: saved, loadable, SNAC rows carry estimates ---
    let space = SearchSpace::table1();
    let nac = TrialRecord::load_all(&out.join("trials_nac.json"), &space).unwrap();
    let snac = TrialRecord::load_all(&out.join("trials_snac.json"), &space).unwrap();
    assert_eq!(nac.len(), 6);
    assert_eq!(snac.len(), 6);
    assert!(nac.iter().all(|r| r.est_avg_resources.is_none()));
    assert!(snac.iter().all(|r| r.est_avg_resources.is_some()
        && r.est_clock_cycles.is_some()
        && r.objectives.len() == 3));

    // --- reports on disk ---
    for file in [
        "table2.md",
        "table3.md",
        "figures.txt",
        "fig1.csv",
        "fig2.csv",
        "fig3.csv",
        "fig4.csv",
        "fig1.txt",
        "fig4.txt",
    ] {
        assert!(out.join(file).exists(), "{file} missing");
    }
    assert!(summary.table2.contains("Optimal SNAC-Pack"));
    assert!(summary.table3.contains("| Baseline [12] |"));

    // figure CSVs have one row per trial (+header)
    let fig4 = std::fs::read_to_string(out.join("fig4.csv")).unwrap();
    assert_eq!(fig4.lines().count(), 1 + 6);
}
