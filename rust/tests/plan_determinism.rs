//! End-to-end planning determinism: the micro pipeline's trial databases
//! must be **bit-identical** (modulo the recorded wall-clock timings)
//! whether HLO executes through the naive reference evaluator, the
//! compiled execution plans, or the plans with multithreaded dot-general
//! kernels.
//!
//! This is the system-level counterpart of `rust/xla/tests/differential.rs`:
//! if any planned kernel, arena recycle, or thread partition perturbed a
//! single bit anywhere in training or search, the trial records (losses,
//! accuracies, selection order) would diverge and this test would fail.
//!
//! Lives in its own test binary on purpose: it toggles the process-global
//! `xla::set_reference_mode` / `xla::set_dot_threads` knobs, which must
//! not race the other integration tests.

use std::path::{Path, PathBuf};

use snac_pack::config::Preset;
use snac_pack::coordinator::{run_pipeline, TrialRecord};
use snac_pack::nn::SearchSpace;
use snac_pack::runtime::Runtime;
use snac_pack::util::Json;

fn micro_preset() -> Preset {
    let mut preset = Preset::by_name("quickstart").unwrap();
    // even smaller than pipeline_integration's budget: three runs back to
    // back, and only the DB bytes matter here
    preset.set("trials", "4").unwrap();
    preset.set("population", "2").unwrap();
    preset.set("epochs", "1").unwrap();
    preset.set("n_train", "384").unwrap();
    preset.set("n_val", "128").unwrap();
    preset.set("n_test", "128").unwrap();
    preset.set("surrogate_size", "256").unwrap();
    preset.set("surrogate_epochs", "8").unwrap();
    preset.set("imp_iterations", "2").unwrap();
    preset.set("imp_epochs", "1").unwrap();
    preset.set("warmup_epochs", "1").unwrap();
    preset
}

fn run_once(rt: &Runtime, tag: &str) -> PathBuf {
    let out = std::env::temp_dir().join(format!("snac_plan_det_{tag}"));
    let _ = std::fs::remove_dir_all(&out);
    run_pipeline(rt, &micro_preset(), &out).unwrap();
    out
}

/// The trial DB with its one legitimately nondeterministic field
/// (wall-clock `train_seconds`) zeroed, re-serialised canonically. Every
/// other float — losses, accuracies, BOPs, surrogate estimates, objective
/// vectors — compares at full serialised precision.
fn canonical_db(out: &Path, file: &str, space: &SearchSpace) -> String {
    let mut records = TrialRecord::load_all(&out.join(file), space)
        .unwrap_or_else(|e| panic!("loading {file}: {e}"));
    for r in &mut records {
        r.train_seconds = 0.0;
    }
    Json::Arr(records.iter().map(TrialRecord::to_json).collect()).to_string()
}

#[test]
fn pipeline_trial_dbs_identical_across_reference_planned_and_threaded() {
    let dir = snac_pack::runtime::artifact_dir()
        .expect("no artifacts/ and no xla/tests/fixtures/ manifest in this tree");
    let rt = Runtime::load(&dir).unwrap();

    xla::set_reference_mode(true);
    xla::set_dot_threads(1);
    let reference = run_once(&rt, "reference");
    xla::set_reference_mode(false);

    let planned = run_once(&rt, "planned");
    xla::set_dot_threads(2);
    let threaded = run_once(&rt, "threaded");
    xla::set_dot_threads(1);

    let space = SearchSpace::table1();
    for db in ["trials_nac.json", "trials_snac.json"] {
        let base = canonical_db(&reference, db, &space);
        assert_eq!(
            base,
            canonical_db(&planned, db, &space),
            "{db}: planned execution must reproduce the reference run bit for bit"
        );
        assert_eq!(
            base,
            canonical_db(&threaded, db, &space),
            "{db}: threaded dot-general must reproduce the reference run bit for bit"
        );
    }
}
