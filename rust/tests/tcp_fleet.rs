//! Acceptance test for TCP-transport sharding: a driver hosting the
//! task queue over `--listen`, served by real `snac-pack worker
//! --connect` *processes* with no shared run directory, must produce a
//! bit-identical trial database to the single-process run — only
//! wall-clock timings may differ.
//!
//! This is the process-level complement to the in-process transport
//! tests in `src/eval/tcp.rs`: it exercises the actual binary (ephemeral
//! port binding, address scraping from the driver log, manifest fetch
//! over HTTP, worker-side artifact resolution) over real sockets.

use std::io::{BufRead, BufReader, Read};
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};

use snac_pack::coordinator::TrialRecord;
use snac_pack::nn::SearchSpace;

fn out_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("snac_tcp_fleet_itest")
        .join(format!("{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The micro search budget shared by both runs (quickstart preset, NAC
/// objectives — no surrogate, so workers need no training detour).
fn micro_args(out: &Path) -> Vec<String> {
    [
        "search",
        "--preset",
        "quickstart",
        "--set",
        "trials=6",
        "--set",
        "population=3",
        "--set",
        "epochs=1",
        "--set",
        "n_train=640",
        "--set",
        "n_val=256",
        "--set",
        "n_test=256",
        "--out",
    ]
    .iter()
    .map(|s| s.to_string())
    .chain([out.display().to_string()])
    .collect()
}

/// The trial database with live timings zeroed — everything else must
/// be bit-identical across dispatch transports.
fn canonical_trials(path: &Path, space: &SearchSpace) -> String {
    let records = TrialRecord::load_all(path, space)
        .unwrap_or_else(|e| panic!("loading {}: {e:#}", path.display()));
    assert!(!records.is_empty(), "{} is empty", path.display());
    let rows: Vec<snac_pack::util::Json> = records
        .into_iter()
        .map(|mut r| {
            r.train_seconds = 0.0;
            r.to_json()
        })
        .collect();
    snac_pack::util::Json::Arr(rows).to_string()
}

#[test]
fn tcp_fleet_search_is_bit_identical_to_single_process() {
    let single = out_dir("single");
    let fleet = out_dir("fleet");

    // reference: the same budget in one process
    let reference = Command::new(env!("CARGO_BIN_EXE_snac-pack"))
        .args(micro_args(&single))
        .output()
        .expect("spawn single-process search");
    assert!(
        reference.status.success(),
        "single-process search failed:\n{}",
        String::from_utf8_lossy(&reference.stderr)
    );

    // driver: TCP task server on an ephemeral port, zero local workers —
    // every shard must travel over the wire to the external fleet
    let mut driver = Command::new(env!("CARGO_BIN_EXE_snac-pack"))
        .args(micro_args(&fleet))
        .args([
            "--shards",
            "2",
            "--listen",
            "127.0.0.1:0",
            "--set",
            "spawn_workers=0",
            "--workers",
            "2",
        ])
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn TCP driver");

    // scrape the run token and the bound address from the driver's
    // startup log (the token line precedes the listening line)
    let mut reader = BufReader::new(driver.stderr.take().expect("driver stderr piped"));
    let mut log = String::new();
    let mut token = None;
    let addr = loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("reading driver log");
        log.push_str(&line);
        if n == 0 {
            let _ = driver.kill();
            panic!("driver exited before announcing its address:\n{log}");
        }
        if let Some(rest) = line.split("run token: ").nth(1) {
            token = Some(rest.trim().to_string());
        }
        if let Some(rest) = line.split("tcp://").nth(1) {
            break rest.trim().to_string();
        }
    };
    let token = token.unwrap_or_else(|| panic!("driver never printed its run token:\n{log}"));

    // two external workers join over loopback with the scraped token —
    // no shared filesystem state beyond the artifacts the manifest
    // points at
    let workers: Vec<_> = (0..2)
        .map(|_| {
            Command::new(env!("CARGO_BIN_EXE_snac-pack"))
                .args(["worker", "--connect", &addr, "--token", &token, "--workers", "1"])
                .stderr(Stdio::piped())
                .spawn()
                .expect("spawn TCP worker")
        })
        .collect();

    // drain the driver to completion (EOF = stderr closed = exit imminent)
    reader.read_to_string(&mut log).expect("draining driver log");
    let status = driver.wait().expect("driver exit status");
    assert!(status.success(), "TCP driver failed:\n{log}");

    let mut served = 0usize;
    for w in workers {
        let out = w.wait_with_output().expect("worker exit status");
        let wlog = String::from_utf8_lossy(&out.stderr);
        assert!(out.status.success(), "worker failed:\n{wlog}");
        if wlog.contains("shutdown: served") {
            served += 1;
        }
    }
    assert_eq!(served, 2, "both workers reported serving on shutdown");

    // the determinism contract holds across the wire: identical trial
    // databases modulo wall-clock timings
    let space = SearchSpace::table1();
    assert_eq!(
        canonical_trials(&single.join("trials.json"), &space),
        canonical_trials(&fleet.join("trials.json"), &space),
        "TCP-dispatched trial database must be bit-identical (timings excluded)"
    );

    // the dispatch genuinely ran over TCP
    assert!(
        log.contains("sharded dispatch:") && log.contains("tcp://"),
        "driver log missing the TCP dispatch summary:\n{log}"
    );

    for dir in [&single, &fleet] {
        let _ = std::fs::remove_dir_all(dir);
    }
}
