//! Acceptance test for multi-process sharding: the micro pipeline run
//! through real `snac-pack worker` *processes* (driver auto-spawns them)
//! must produce bit-identical genomes, objectives, and selection to the
//! single-process run — only wall-clock timings may differ.
//!
//! This is the process-level complement to the in-process protocol tests
//! in `src/eval/shard.rs`: it exercises the actual binary (`worker`
//! subcommand, `run.json` manifest, artifact resolution, worker-side
//! surrogate retraining) over a real run directory.

use std::path::{Path, PathBuf};
use std::process::Command;

use snac_pack::coordinator::TrialRecord;
use snac_pack::nn::SearchSpace;

fn out_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("snac_sharded_itest")
        .join(format!("{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Run the micro pipeline via the real binary; panics on failure and
/// returns the combined stderr (stage logs).
fn run_pipeline(out: &Path, extra: &[&str]) -> String {
    let micro = [
        "pipeline",
        "--preset",
        "quickstart",
        "--set",
        "trials=6",
        "--set",
        "population=3",
        "--set",
        "epochs=1",
        "--set",
        "n_train=640",
        "--set",
        "n_val=256",
        "--set",
        "n_test=256",
        "--set",
        "surrogate_size=512",
        "--set",
        "surrogate_epochs=20",
        "--set",
        "imp_iterations=3",
        "--set",
        "imp_epochs=1",
        "--set",
        "warmup_epochs=1",
        "--out",
    ];
    let output = Command::new(env!("CARGO_BIN_EXE_snac-pack"))
        .args(micro)
        .arg(out)
        .args(extra)
        .output()
        .expect("spawn snac-pack pipeline");
    let stderr = String::from_utf8_lossy(&output.stderr).into_owned();
    assert!(
        output.status.success(),
        "pipeline run failed ({extra:?}):\n{stderr}"
    );
    stderr
}

/// The trial database with live timings zeroed — everything else must be
/// bit-identical across dispatch backends.
fn canonical_trials(path: &Path, space: &SearchSpace) -> String {
    let records = TrialRecord::load_all(path, space)
        .unwrap_or_else(|e| panic!("loading {}: {e:#}", path.display()));
    assert!(!records.is_empty(), "{} is empty", path.display());
    let rows: Vec<snac_pack::util::Json> = records
        .into_iter()
        .map(|mut r| {
            r.train_seconds = 0.0;
            r.to_json()
        })
        .collect();
    snac_pack::util::Json::Arr(rows).to_string()
}

#[test]
fn worker_backed_micro_pipeline_is_bit_identical_to_single_process() {
    let single = out_dir("single");
    let sharded = out_dir("sharded");
    let run_dir = out_dir("run");

    run_pipeline(&single, &[]);
    // --shards 2 auto-spawns two `snac-pack worker` processes over the
    // run directory; --workers 2 keeps each worker's thread pool small
    let log = run_pipeline(
        &sharded,
        &[
            "--shards",
            "2",
            "--run-dir",
            run_dir.to_str().unwrap(),
            "--workers",
            "2",
        ],
    );

    let space = SearchSpace::table1();
    for db in ["trials_nac.json", "trials_snac.json"] {
        assert_eq!(
            canonical_trials(&single.join(db), &space),
            canonical_trials(&sharded.join(db), &space),
            "{db}: sharded trial database must be bit-identical (timings excluded)"
        );
    }
    // the selected architectures and their synthesis land in the tables —
    // identical trials must yield byte-identical reports
    for report in ["table2.md", "table3.md"] {
        let a = std::fs::read_to_string(single.join(report)).unwrap();
        let b = std::fs::read_to_string(sharded.join(report)).unwrap();
        assert_eq!(a, b, "{report} differs between dispatch backends");
    }
    // the worker fleet actually ran: the driver logged its spawn and the
    // sharded dispatch summary for every sharded stage, and the workers
    // reported serving shards on shutdown (consumed protocol files are
    // cleaned up, so the log is the evidence)
    assert!(
        log.contains("spawned 2 local worker(s)"),
        "driver spawned its fleet:\n{log}"
    );
    for stage in ["search-nac", "search-snac"] {
        assert!(
            log.contains(&format!("[{stage}] sharded dispatch:")),
            "no sharded dispatch summary for stage {stage}:\n{log}"
        );
    }
    assert!(
        log.contains("shutdown: served"),
        "workers reported work on shutdown:\n{log}"
    );

    for dir in [&single, &sharded, &run_dir] {
        let _ = std::fs::remove_dir_all(dir);
    }
}
