//! Acceptance smoke for the estimation service: a real `snac-pack
//! serve` process (ephemeral port, HLO-fixture runtime) must answer
//! concurrent mixed single/batch `/estimate` requests with values
//! exactly equal to an in-process `SurrogatePredictor` trained under the
//! identical protocol, and shut down cleanly on `POST /shutdown`.
//!
//! This is the process-level complement to the in-process tests in
//! `src/serve/`: it exercises the actual binary — CLI flags, surrogate
//! training from the preset seed, the listener line the smoke clients
//! scrape, and the drain-on-shutdown path.

use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};

use snac_pack::config::Preset;
use snac_pack::hls::{FpgaDevice, HlsConfig};
use snac_pack::nn::{Genome, SearchSpace};
use snac_pack::runtime::Runtime;
use snac_pack::serve::http;
use snac_pack::surrogate::{train_surrogate, SurrogatePredictor};
use snac_pack::util::{Json, Rng};

/// Kill the server if the test panics before the clean shutdown.
struct Reap(Child);

impl Drop for Reap {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

fn f64_field(j: &Json, k: &str) -> f64 {
    j.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN)
}

#[test]
fn concurrent_estimates_match_the_offline_predictor() {
    // micro surrogate budget so the smoke trains in seconds; the preset
    // seed makes the server's surrogate bit-identical to ours below
    let mut child = Command::new(env!("CARGO_BIN_EXE_snac-pack"))
        .args([
            "serve",
            "--preset",
            "quickstart",
            "--set",
            "surrogate_size=256",
            "--set",
            "surrogate_epochs=10",
            "--port",
            "0",
            "--batch-deadline-ms",
            "5",
        ])
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn snac-pack serve");
    let stdout = child.stdout.take().expect("piped stdout");
    let mut child = Reap(child);

    // the server prints `listening on http://ADDR` once bound
    let mut addr = String::new();
    for line in BufReader::new(stdout).lines() {
        let line = line.expect("server stdout");
        if let Some(rest) = line.split("listening on http://").nth(1) {
            addr = rest.trim().to_string();
            break;
        }
    }
    assert!(!addr.is_empty(), "server never printed its address");

    // in-process reference: same fixtures, same training protocol
    let art = snac_pack::runtime::artifact_dir().expect("no artifact manifest found");
    let rt = Runtime::load(&art).unwrap();
    let space = SearchSpace::table1();
    let device = FpgaDevice::vu13p();
    let mut preset = Preset::by_name("quickstart").unwrap();
    preset.set("surrogate_size", "256").unwrap();
    preset.set("surrogate_epochs", "10").unwrap();
    let (params, _mse) =
        train_surrogate(&rt, &space, &preset.surrogate, &HlsConfig::default(), &device).unwrap();
    let reference = SurrogatePredictor::new(&rt, params);

    let (status, body) = http::request(&addr, "GET", "/healthz", None).unwrap();
    assert_eq!(status, 200, "{body}");

    let mut rng = Rng::new(99);
    let genomes: Vec<Genome> = (0..8).map(|_| space.sample(&mut rng)).collect();
    let bits = preset.local.bits;
    let sparsity = preset.local.target_sparsity;

    // concurrent fan-out: one thread per single estimate, plus a batch
    // thread re-estimating the whole set at once
    let addr_ref = addr.as_str();
    let genomes_ref = genomes.as_slice();
    let (singles, batch) = std::thread::scope(|s| {
        let singles: Vec<_> = genomes_ref
            .iter()
            .map(|g| {
                s.spawn(move || {
                    let req = Json::obj(vec![("genome", g.to_json())]).to_string();
                    http::request(addr_ref, "POST", "/estimate", Some(&req)).unwrap()
                })
            })
            .collect();
        let batch = s.spawn(move || {
            let req = Json::obj(vec![(
                "requests",
                Json::Arr(
                    genomes_ref
                        .iter()
                        .map(|g| Json::obj(vec![("genome", g.to_json())]))
                        .collect(),
                ),
            )])
            .to_string();
            http::request(addr_ref, "POST", "/estimate/batch", Some(&req)).unwrap()
        });
        (
            singles
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect::<Vec<_>>(),
            batch.join().unwrap(),
        )
    });

    // every response is a 200 whose values equal the offline predictor's
    for (g, (status, body)) in genomes.iter().zip(&singles) {
        assert_eq!(*status, 200, "{body}");
        let j = Json::parse(body).unwrap();
        let want = reference.predict(g, &space, bits, sparsity).unwrap();
        assert_eq!(f64_field(&j, "bram"), want.bram);
        assert_eq!(f64_field(&j, "dsp"), want.dsp);
        assert_eq!(f64_field(&j, "ff"), want.ff);
        assert_eq!(f64_field(&j, "lut"), want.lut);
        assert_eq!(f64_field(&j, "latency_cc"), want.latency_cc);
        assert_eq!(f64_field(&j, "ii_cc"), want.ii_cc);
        assert_eq!(f64_field(&j, "avg_resources"), want.avg_resources(&device));
    }
    let (status, body) = &batch;
    assert_eq!(*status, 200, "{body}");
    let parsed = Json::parse(body).unwrap();
    let results = parsed.get("results").unwrap().items();
    assert_eq!(results.len(), genomes.len());
    for (g, j) in genomes.iter().zip(results) {
        let want = reference.predict(g, &space, bits, sparsity).unwrap();
        assert_eq!(f64_field(j, "lut"), want.lut);
        assert_eq!(f64_field(j, "latency_cc"), want.latency_cc);
    }

    // clean shutdown: 200, then the process exits successfully
    let (status, _) = http::request(&addr, "POST", "/shutdown", None).unwrap();
    assert_eq!(status, 200);
    let exit = child.0.wait().expect("server exit status");
    assert!(exit.success(), "server exited with {exit:?}");
}
