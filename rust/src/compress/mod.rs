//! Local search (§3/§4): model compression of a selected architecture.
//!
//! Paper protocol: "a 5 epoch warm-up, followed by 10 iterations of
//! iterative magnitude pruning, each 10 epochs, with 20 % pruned per
//! iteration, with QAT at 8-bit precision." We snapshot the model at every
//! sparsity level so a deployment point (~50 % in Table 3) can be selected
//! afterwards, and count the exact multiplier work HLS will synthesise
//! (pruned + quantised-to-zero weights are elided).

use anyhow::Result;

use crate::data::Split;
use crate::nn::{
    quant, Genome, PruneMasks, SearchSpace, SupernetInputs, SupernetParams, IN_DIM,
    NUM_LAYERS, OUT_DIM, PAD,
};
use crate::trainer::{TrainConfig, TrainedModel, Trainer};
use crate::util::Rng;

/// Local-search schedule.
#[derive(Debug, Clone)]
pub struct LocalSearchConfig {
    /// Dense warm-up epochs before pruning starts (paper: 5).
    pub warmup_epochs: usize,
    /// IMP iterations (paper: 10).
    pub imp_iterations: usize,
    /// Training epochs per IMP iteration (paper: 10).
    pub epochs_per_iteration: usize,
    /// Fraction of surviving weights pruned per iteration (paper: 0.2).
    pub prune_fraction: f64,
    /// QAT precision (paper: 8-bit).
    pub bits: u32,
    /// Deployment sparsity to select from the sweep (paper: ~0.5).
    pub target_sparsity: f64,
}

impl Default for LocalSearchConfig {
    fn default() -> Self {
        LocalSearchConfig {
            warmup_epochs: 5,
            imp_iterations: 10,
            epochs_per_iteration: 10,
            prune_fraction: 0.2,
            bits: 8,
            target_sparsity: 0.5,
        }
    }
}

/// One point of the IMP sweep.
#[derive(Debug, Clone)]
pub struct ImpRecord {
    /// IMP iteration (0 = dense warm-up).
    pub iteration: usize,
    /// Mask sparsity over active coordinates.
    pub sparsity: f64,
    /// Validation accuracy at this point (QAT eval mode).
    pub val_accuracy: f64,
    /// Validation CE loss.
    pub val_loss: f64,
}

/// Local-search output: the selected deployment point plus the full sweep.
pub struct LocalSearchResult {
    /// Model at the selected sparsity.
    pub model: TrainedModel,
    /// Prune masks at the selected sparsity.
    pub masks: PruneMasks,
    /// Selected iteration index into `history`.
    pub selected: usize,
    /// The sparsity/accuracy sweep (one record per iteration).
    pub history: Vec<ImpRecord>,
}

/// Run the paper's local search on one architecture.
pub fn local_search(
    trainer: &Trainer<'_>,
    genome: &Genome,
    space: &SearchSpace,
    cfg: &LocalSearchConfig,
    rng: &mut Rng,
) -> Result<LocalSearchResult> {
    let inputs = SupernetInputs::compile(genome, space);
    let mut masks = PruneMasks::ones();
    let mut model = trainer.init_model(rng);

    // ---- dense warm-up (no QAT, per the lottery-ticket recipe) ----
    let warm_cfg = TrainConfig {
        epochs: cfg.warmup_epochs,
        qat: false,
        bits: cfg.bits,
        ..Default::default()
    };
    trainer.train(&mut model, &inputs, &masks, &warm_cfg, rng)?;
    let qat_cfg = TrainConfig {
        epochs: cfg.epochs_per_iteration,
        qat: true,
        bits: cfg.bits,
        ..Default::default()
    };
    let (acc0, loss0) = trainer.evaluate(&model, &inputs, &masks, &qat_cfg, Split::Val)?;
    let mut history = vec![ImpRecord {
        iteration: 0,
        sparsity: 0.0,
        val_accuracy: acc0,
        val_loss: loss0,
    }];
    let mut snapshots = vec![(model.clone(), masks.clone())];

    // ---- iterative magnitude pruning with QAT retraining ----
    for iter in 1..=cfg.imp_iterations {
        masks.prune_step(&model.params, &inputs, cfg.prune_fraction);
        trainer.train(&mut model, &inputs, &masks, &qat_cfg, rng)?;
        let (acc, loss) = trainer.evaluate(&model, &inputs, &masks, &qat_cfg, Split::Val)?;
        history.push(ImpRecord {
            iteration: iter,
            sparsity: masks.sparsity(&inputs),
            val_accuracy: acc,
            val_loss: loss,
        });
        snapshots.push((model.clone(), masks.clone()));
    }

    // ---- select the deployment point closest to the target sparsity ----
    let selected = history
        .iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| {
            (a.sparsity - cfg.target_sparsity)
                .abs()
                .total_cmp(&(b.sparsity - cfg.target_sparsity).abs())
        })
        .map(|(i, _)| i)
        .unwrap();
    let (model, masks) = snapshots.swap_remove(selected);
    Ok(LocalSearchResult {
        model,
        masks,
        selected,
        history,
    })
}

/// Per-dense-layer non-zero multiplier counts as HLS will see them:
/// a weight survives if its prune mask is 1 AND its quantised value ≠ 0.
///
/// Quantisation deltas mirror the graph exactly: per-*tensor* max-abs over
/// the whole pruned padded tensor (w0 / wh-stack / wo), not per layer.
pub fn synthesis_nnz(
    params: &SupernetParams,
    masks: &PruneMasks,
    _inputs: &SupernetInputs,
    genome: &Genome,
    space: &SearchSpace,
    bits: u32,
) -> Vec<usize> {
    let pruned =
        |w: &[f32], m: &[f32]| -> Vec<f32> { w.iter().zip(m).map(|(a, b)| a * b).collect() };
    let q0 = quant::fake_quant(&pruned(&params.w0, &masks.p0), bits);
    let qh = quant::fake_quant(&pruned(&params.wh, &masks.ph), bits);
    let qo = quant::fake_quant(&pruned(&params.wo, &masks.po), bits);

    let widths = genome.widths(space);
    let mut out = Vec::with_capacity(genome.n_layers + 1);
    // layer 0: w0 (IN_DIM × PAD), active cols < widths[0]
    let w0_nnz = (0..IN_DIM)
        .flat_map(|r| (0..widths[0]).map(move |c| (r, c)))
        .filter(|&(r, c)| q0[r * PAD + c] != 0.0)
        .count();
    out.push(w0_nnz);
    // layers 1..n-1: wh[i-1], rows < widths[i-1], cols < widths[i]
    for i in 1..genome.n_layers {
        let base = (i - 1) * PAD * PAD;
        let nnz = (0..widths[i - 1])
            .flat_map(|r| (0..widths[i]).map(move |c| (r, c)))
            .filter(|&(r, c)| qh[base + r * PAD + c] != 0.0)
            .count();
        out.push(nnz);
    }
    // head: wo (PAD × OUT_DIM), rows < last width
    let last = widths[genome.n_layers - 1];
    let head_nnz = (0..last)
        .flat_map(|r| (0..OUT_DIM).map(move |c| (r, c)))
        .filter(|&(r, c)| qo[r * OUT_DIM + c] != 0.0)
        .count();
    out.push(head_nnz);
    debug_assert_eq!(out.len(), genome.layer_dims(space).len());
    let _ = NUM_LAYERS;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn synthesis_nnz_counts_active_slices_only() {
        let space = SearchSpace::table1();
        let genome = space.baseline(); // dims (24,64)(64,32)(32,32)(32,32)(32,5)
        let inputs = SupernetInputs::compile(&genome, &space);
        let params = SupernetParams::init(&mut Rng::new(0));
        let masks = PruneMasks::ones();
        let nnz = synthesis_nnz(&params, &masks, &inputs, &genome, &space, 8);
        assert_eq!(nnz.len(), 5);
        // dense random init: nearly everything survives 8-bit quantisation
        let dims = genome.layer_dims(&space);
        for (n, (i, o)) in nnz.iter().zip(dims) {
            assert!(*n <= i * o);
            assert!(*n as f64 > 0.9 * (i * o) as f64, "{n} of {}", i * o);
        }
    }

    #[test]
    fn pruning_reduces_synthesis_nnz() {
        let space = SearchSpace::table1();
        let genome = space.baseline();
        let inputs = SupernetInputs::compile(&genome, &space);
        let params = SupernetParams::init(&mut Rng::new(1));
        let mut masks = PruneMasks::ones();
        let dense: usize =
            synthesis_nnz(&params, &masks, &inputs, &genome, &space, 8).iter().sum();
        masks.prune_step(&params, &inputs, 0.5);
        let sparse: usize =
            synthesis_nnz(&params, &masks, &inputs, &genome, &space, 8).iter().sum();
        assert!(
            (sparse as f64) < 0.55 * dense as f64,
            "pruning halves mults: {sparse} vs {dense}"
        );
    }

    #[test]
    fn low_precision_elides_more_weights() {
        let space = SearchSpace::table1();
        let genome = space.baseline();
        let inputs = SupernetInputs::compile(&genome, &space);
        let params = SupernetParams::init(&mut Rng::new(2));
        let masks = PruneMasks::ones();
        let n8: usize =
            synthesis_nnz(&params, &masks, &inputs, &genome, &space, 8).iter().sum();
        let n2: usize =
            synthesis_nnz(&params, &masks, &inputs, &genome, &space, 2).iter().sum();
        assert!(n2 < n8, "2-bit grid zeroes more weights: {n2} vs {n8}");
    }
}
