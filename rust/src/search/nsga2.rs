//! NSGA-II (Deb et al. 2002) — the paper's global-search algorithm.
//!
//! Evaluation is expensive (each candidate trains for several epochs on the
//! PJRT runtime), so the algorithm is factored as a *generational state
//! machine*: the coordinator asks for a population, evaluates it (possibly
//! concurrently), hands the results back, and receives the next population.
//! All randomness flows through the injected [`Rng`].


use anyhow::{Context, Result};

use crate::nn::{Genome, SearchSpace};
use crate::pareto::{crowding_distance, non_dominated_sort};
use crate::util::{Json, Rng};

/// A genome with its (minimised) objective vector.
#[derive(Debug, Clone)]
pub struct EvaluatedIndividual {
    /// The architecture/hyperparameter point.
    pub genome: Genome,
    /// Minimised objectives (accuracy enters negated).
    pub objectives: Vec<f64>,
}

impl EvaluatedIndividual {
    /// Serialise for the search-loop checkpoint (non-finite objectives
    /// follow the `util::Json` `null` convention).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("genome", self.genome.to_json()),
            ("objectives", Json::nums(self.objectives.iter().copied())),
        ])
    }

    /// Parse back from a checkpoint.
    pub fn from_json(j: &Json) -> Result<EvaluatedIndividual> {
        let objectives: Vec<f64> = j
            .get("objectives")
            .context("individual missing objectives")?
            .items()
            .iter()
            .filter_map(Json::as_f64_or_nan)
            .collect();
        anyhow::ensure!(!objectives.is_empty(), "individual has an empty objective vector");
        Ok(EvaluatedIndividual {
            genome: Genome::from_json(j.get("genome").context("individual missing genome")?)?,
            objectives,
        })
    }
}

/// Evolution parameters.
#[derive(Debug, Clone)]
pub struct Nsga2Config {
    /// Population size (paper: 20).
    pub population: usize,
    /// Per-gene mutation probability.
    pub p_mutation: f64,
    /// Probability of applying crossover (else clone a parent).
    pub p_crossover: f64,
}

impl Default for Nsga2Config {
    fn default() -> Self {
        Nsga2Config {
            population: 20,
            p_mutation: 0.15,
            p_crossover: 0.9,
        }
    }
}

/// The NSGA-II engine.
pub struct Nsga2 {
    space: SearchSpace,
    cfg: Nsga2Config,
    /// current parent pool (evaluated)
    parents: Vec<EvaluatedIndividual>,
}

impl Nsga2 {
    /// New engine over a space.
    pub fn new(space: SearchSpace, cfg: Nsga2Config) -> Self {
        Nsga2 {
            space,
            cfg,
            parents: Vec::new(),
        }
    }

    /// The search space.
    pub fn space(&self) -> &SearchSpace {
        &self.space
    }

    /// Random initial population.
    pub fn initial_population(&self, rng: &mut Rng) -> Vec<Genome> {
        (0..self.cfg.population)
            .map(|_| self.space.sample(rng))
            .collect()
    }

    /// (front rank, crowding distance) for every member of `pop`.
    fn rank_and_crowd(pop: &[EvaluatedIndividual]) -> Vec<(usize, f64)> {
        let pts: Vec<Vec<f64>> = pop.iter().map(|e| e.objectives.clone()).collect();
        let fronts = non_dominated_sort(&pts);
        let mut out = vec![(0usize, 0.0f64); pop.len()];
        for (rank, front) in fronts.iter().enumerate() {
            let front_pts: Vec<Vec<f64>> = front.iter().map(|&i| pts[i].clone()).collect();
            let crowd = crowding_distance(&front_pts);
            for (k, &i) in front.iter().enumerate() {
                out[i] = (rank, crowd[k]);
            }
        }
        out
    }

    /// Binary tournament on (rank, crowding).
    fn tournament<'a>(
        pop: &'a [EvaluatedIndividual],
        meta: &[(usize, f64)],
        rng: &mut Rng,
    ) -> &'a Genome {
        let a = rng.below(pop.len());
        let b = rng.below(pop.len());
        let better = if meta[a].0 != meta[b].0 {
            if meta[a].0 < meta[b].0 {
                a
            } else {
                b
            }
        } else if meta[a].1 > meta[b].1 {
            a
        } else {
            b
        };
        &pop[better].genome
    }

    /// Absorb evaluated individuals: environmental selection (elitist
    /// μ+λ truncation by rank then crowding) over parents ∪ offspring,
    /// then breed the next generation of genomes to evaluate.
    pub fn next_generation(
        &mut self,
        evaluated: Vec<EvaluatedIndividual>,
        rng: &mut Rng,
    ) -> Vec<Genome> {
        // --- environmental selection ---
        let mut pool = std::mem::take(&mut self.parents);
        pool.extend(evaluated);
        let meta = Self::rank_and_crowd(&pool);
        let mut order: Vec<usize> = (0..pool.len()).collect();
        order.sort_by(|&a, &b| {
            meta[a]
                .0
                .cmp(&meta[b].0)
                .then(meta[b].1.total_cmp(&meta[a].1))
        });
        order.truncate(self.cfg.population);
        self.parents = order.into_iter().map(|i| pool[i].clone()).collect();

        // --- variation ---
        let meta = Self::rank_and_crowd(&self.parents);
        let mut offspring = Vec::with_capacity(self.cfg.population);
        while offspring.len() < self.cfg.population {
            let p1 = Self::tournament(&self.parents, &meta, rng);
            let p2 = Self::tournament(&self.parents, &meta, rng);
            let mut child = if rng.chance(self.cfg.p_crossover) {
                self.space.crossover(p1, p2, rng)
            } else {
                p1.clone()
            };
            self.space.mutate(&mut child, self.cfg.p_mutation, rng);
            offspring.push(child);
        }
        offspring
    }

    /// Current elite pool (after the last `next_generation` call).
    pub fn parents(&self) -> &[EvaluatedIndividual] {
        &self.parents
    }

    /// Replace the elite pool wholesale — the checkpoint/resume path
    /// restores the exact pool a snapshot captured, so selection pressure
    /// continues from where the killed run stopped.
    pub fn restore(&mut self, parents: Vec<EvaluatedIndividual>) {
        self.parents = parents;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Activation;

    /// Synthetic objective: accuracy ∝ capacity (diminishing), cost ∝ size.
    /// A known trade-off with a computable front.
    fn toy_objectives(g: &Genome, space: &SearchSpace) -> Vec<f64> {
        let weights = g.num_weights(space) as f64;
        let acc = 1.0 - (-weights / 4000.0).exp();
        vec![-acc, weights]
    }

    fn run_generations(gens: usize, seed: u64) -> (Nsga2, Vec<EvaluatedIndividual>) {
        let space = SearchSpace::table1();
        let mut engine = Nsga2::new(space.clone(), Nsga2Config::default());
        let mut rng = Rng::new(seed);
        let mut pop = engine.initial_population(&mut rng);
        let mut last = Vec::new();
        for _ in 0..gens {
            let evaluated: Vec<EvaluatedIndividual> = pop
                .iter()
                .map(|g| EvaluatedIndividual {
                    genome: g.clone(),
                    objectives: toy_objectives(g, engine.space()),
                })
                .collect();
            last = evaluated.clone();
            pop = engine.next_generation(evaluated, &mut rng);
        }
        (engine, last)
    }

    #[test]
    fn population_size_is_stable() {
        let (engine, _) = run_generations(5, 0);
        assert_eq!(engine.parents().len(), 20);
    }

    #[test]
    fn evolution_improves_hypervolume() {
        let space = SearchSpace::table1();
        let mut engine = Nsga2::new(space.clone(), Nsga2Config::default());
        let mut rng = Rng::new(1);
        let mut pop = engine.initial_population(&mut rng);
        let reference = [0.0, 60_000.0]; // worst acc, huge cost
        let mut hv_first = None;
        let mut hv_last = 0.0;
        for gen in 0..15 {
            let evaluated: Vec<EvaluatedIndividual> = pop
                .iter()
                .map(|g| EvaluatedIndividual {
                    genome: g.clone(),
                    objectives: toy_objectives(g, &space),
                })
                .collect();
            pop = engine.next_generation(evaluated, &mut rng);
            let pts: Vec<Vec<f64>> = engine
                .parents()
                .iter()
                .map(|e| e.objectives.clone())
                .collect();
            let hv = crate::pareto::hypervolume(&pts, &reference);
            if gen == 0 {
                hv_first = Some(hv);
            }
            hv_last = hv;
        }
        assert!(
            hv_last >= hv_first.unwrap() * 1.001,
            "hypervolume should grow: {hv_first:?} → {hv_last}"
        );
    }

    #[test]
    fn elitism_never_loses_the_best() {
        let space = SearchSpace::table1();
        let mut engine = Nsga2::new(space.clone(), Nsga2Config::default());
        let mut rng = Rng::new(2);
        let mut pop = engine.initial_population(&mut rng);
        let mut best_acc: f64 = f64::INFINITY; // minimised -acc
        for _ in 0..10 {
            let evaluated: Vec<EvaluatedIndividual> = pop
                .iter()
                .map(|g| EvaluatedIndividual {
                    genome: g.clone(),
                    objectives: toy_objectives(g, &space),
                })
                .collect();
            pop = engine.next_generation(evaluated, &mut rng);
            let gen_best = engine
                .parents()
                .iter()
                .map(|e| e.objectives[0])
                .fold(f64::INFINITY, f64::min);
            assert!(gen_best <= best_acc + 1e-12, "elite regressed");
            best_acc = best_acc.min(gen_best);
        }
    }

    #[test]
    fn offspring_are_valid_genomes() {
        let (engine, _) = run_generations(3, 3);
        let mut rng = Rng::new(4);
        let mut e2 = Nsga2::new(engine.space().clone(), Nsga2Config::default());
        let pop = e2.initial_population(&mut rng);
        let evaluated: Vec<EvaluatedIndividual> = pop
            .iter()
            .map(|g| EvaluatedIndividual {
                genome: g.clone(),
                objectives: toy_objectives(g, e2.space()),
            })
            .collect();
        for g in e2.next_generation(evaluated, &mut rng) {
            assert!(e2.space().contains(&g));
        }
    }

    #[test]
    fn deterministic_under_seed() {
        let (_, a) = run_generations(5, 9);
        let (_, b) = run_generations(5, 9);
        let ga: Vec<_> = a.iter().map(|e| e.genome.clone()).collect();
        let gb: Vec<_> = b.iter().map(|e| e.genome.clone()).collect();
        assert_eq!(ga, gb);
    }

    #[test]
    fn search_finds_small_accurate_nets() {
        // with the toy objective the front should include genuinely small nets
        let (engine, _) = run_generations(12, 5);
        let smallest = engine
            .parents()
            .iter()
            .map(|e| e.objectives[1])
            .fold(f64::INFINITY, f64::min);
        // random Table 1 nets are ~5-20k weights; the front must reach low
        assert!(smallest < 6_000.0, "smallest on front: {smallest}");
        // and the space should still retain a high-accuracy member
        let best_acc = engine
            .parents()
            .iter()
            .map(|e| -e.objectives[0])
            .fold(0.0f64, f64::max);
        assert!(best_acc > 0.9, "best acc {best_acc}");
        let _ = Activation::ReLU; // keep import used
    }
}
