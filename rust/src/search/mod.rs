//! Global-search strategy: NSGA-II over the Table 1 genome space.

pub mod nsga2;

pub use nsga2::{EvaluatedIndividual, Nsga2, Nsga2Config};
