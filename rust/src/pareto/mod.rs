//! Pareto machinery: dominance, front extraction, crowding distance,
//! hypervolume, and the accuracy-threshold selection rule of §4.
//!
//! Convention: **all objectives are minimised**. Accuracy is negated by
//! the objective plumbing (`objectives::`) before it gets here.

/// True iff `a` Pareto-dominates `b` (≤ everywhere, < somewhere).
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strictly = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strictly = true;
        }
    }
    strictly
}

/// Fast non-dominated sort (Deb et al., NSGA-II). Returns fronts of indices,
/// best front first.
pub fn non_dominated_sort(points: &[Vec<f64>]) -> Vec<Vec<usize>> {
    let n = points.len();
    let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n]; // S_p
    let mut counts = vec![0usize; n]; // n_p
    let mut fronts: Vec<Vec<usize>> = vec![Vec::new()];
    for p in 0..n {
        for q in 0..n {
            if p == q {
                continue;
            }
            if dominates(&points[p], &points[q]) {
                dominated_by[p].push(q);
            } else if dominates(&points[q], &points[p]) {
                counts[p] += 1;
            }
        }
        if counts[p] == 0 {
            fronts[0].push(p);
        }
    }
    let mut i = 0;
    while !fronts[i].is_empty() {
        let mut next = Vec::new();
        for &p in &fronts[i] {
            for &q in &dominated_by[p] {
                counts[q] -= 1;
                if counts[q] == 0 {
                    next.push(q);
                }
            }
        }
        i += 1;
        fronts.push(next);
    }
    fronts.pop(); // drop trailing empty front
    fronts
}

/// Indices of the (first) Pareto front.
pub fn pareto_front(points: &[Vec<f64>]) -> Vec<usize> {
    if points.is_empty() {
        return Vec::new();
    }
    non_dominated_sort(points).remove(0)
}

/// Crowding distance of each member of a front (NSGA-II diversity measure).
pub fn crowding_distance(front: &[Vec<f64>]) -> Vec<f64> {
    let n = front.len();
    if n == 0 {
        return Vec::new();
    }
    let m = front[0].len();
    let mut dist = vec![0.0f64; n];
    if n <= 2 {
        return vec![f64::INFINITY; n];
    }
    for obj in 0..m {
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| front[a][obj].total_cmp(&front[b][obj]));
        let lo = front[idx[0]][obj];
        let hi = front[idx[n - 1]][obj];
        dist[idx[0]] = f64::INFINITY;
        dist[idx[n - 1]] = f64::INFINITY;
        let range = (hi - lo).max(1e-12);
        for k in 1..n - 1 {
            dist[idx[k]] += (front[idx[k + 1]][obj] - front[idx[k - 1]][obj]) / range;
        }
    }
    dist
}

/// Hypervolume dominated by `points` w.r.t. `reference` (minimisation;
/// every point must be ≤ reference coordinate-wise to contribute).
/// Exact sweep for 2-D; WFG-style recursive slicing for higher dims
/// (fine for the front sizes here, ≤ a few hundred points).
pub fn hypervolume(points: &[Vec<f64>], reference: &[f64]) -> f64 {
    let pts: Vec<Vec<f64>> = pareto_front(points)
        .into_iter()
        .map(|i| points[i].clone())
        .filter(|p| p.iter().zip(reference).all(|(x, r)| x < r))
        .collect();
    if pts.is_empty() {
        return 0.0;
    }
    hv_recursive(&pts, reference)
}

fn hv_recursive(pts: &[Vec<f64>], reference: &[f64]) -> f64 {
    let dim = reference.len();
    if dim == 1 {
        let best = pts.iter().map(|p| p[0]).fold(f64::INFINITY, f64::min);
        return (reference[0] - best).max(0.0);
    }
    if dim == 2 {
        // sweep on x ascending; accumulate rectangles
        let mut sorted = pts.to_vec();
        sorted.sort_by(|a, b| a[0].total_cmp(&b[0]));
        let mut hv = 0.0;
        let mut prev_y = reference[1];
        for p in &sorted {
            if p[1] < prev_y {
                hv += (reference[0] - p[0]) * (prev_y - p[1]);
                prev_y = p[1];
            }
        }
        return hv;
    }
    // slice on the last objective
    let mut sorted = pts.to_vec();
    let last = dim - 1;
    sorted.sort_by(|a, b| a[last].total_cmp(&b[last]));
    let mut hv = 0.0;
    for i in 0..sorted.len() {
        let depth = if i + 1 < sorted.len() {
            sorted[i + 1][last] - sorted[i][last]
        } else {
            reference[last] - sorted[i][last]
        };
        if depth <= 0.0 {
            continue;
        }
        let slab: Vec<Vec<f64>> = sorted[..=i]
            .iter()
            .map(|p| p[..last].to_vec())
            .collect();
        let front: Vec<Vec<f64>> = pareto_front(&slab)
            .into_iter()
            .map(|k| slab[k].clone())
            .collect();
        hv += depth * hv_recursive(&front, &reference[..last]);
    }
    hv
}

/// §4 selection rule: among Pareto-front members whose (max-)accuracy
/// exceeds `threshold`, pick the one with the lowest *normalised* cost —
/// each non-accuracy objective is divided by its maximum over the eligible
/// set so that, e.g., latency-in-cycles (tens) cannot drown out mean
/// utilisation (units). `acc_index` is the slot holding *negated* accuracy.
pub fn select_above_accuracy(
    points: &[Vec<f64>],
    acc_index: usize,
    threshold: f64,
) -> Option<usize> {
    let front = pareto_front(points);
    let eligible: Vec<usize> = front
        .into_iter()
        .filter(|&i| -points[i][acc_index] >= threshold)
        .collect();
    if eligible.is_empty() {
        return None;
    }
    let m = points[eligible[0]].len();
    let mut scale = vec![0.0f64; m];
    for &i in &eligible {
        for (k, v) in points[i].iter().enumerate() {
            scale[k] = scale[k].max(v.abs());
        }
    }
    eligible.into_iter().min_by(|&a, &b| {
        let cost = |i: usize| -> f64 {
            points[i]
                .iter()
                .enumerate()
                .filter(|&(k, _)| k != acc_index)
                .map(|(k, v)| v / scale[k].max(1e-12))
                .sum()
        };
        cost(a).total_cmp(&cost(b))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_basics() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[2.0, 2.0]));
        assert!(!dominates(&[1.0, 3.0], &[2.0, 2.0]));
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0]), "equal points don't dominate");
    }

    #[test]
    fn sort_layers_fronts_correctly() {
        let pts = vec![
            vec![1.0, 4.0], // front 0
            vec![2.0, 2.0], // front 0
            vec![4.0, 1.0], // front 0
            vec![3.0, 3.0], // front 1 (dominated by [2,2])
            vec![5.0, 5.0], // front 2
        ];
        let fronts = non_dominated_sort(&pts);
        assert_eq!(fronts[0], vec![0, 1, 2]);
        assert_eq!(fronts[1], vec![3]);
        assert_eq!(fronts[2], vec![4]);
    }

    #[test]
    fn front_members_are_mutually_nondominated() {
        let pts: Vec<Vec<f64>> = (0..50)
            .map(|i| {
                let x = i as f64 * 0.17 % 3.0;
                vec![x, (x * 7.3).sin().abs() * 2.0, ((i * 31) % 11) as f64 * 0.3]
            })
            .collect();
        let front = pareto_front(&pts);
        for &a in &front {
            for &b in &front {
                assert!(!dominates(&pts[a], &pts[b]));
            }
        }
        // everything not on the front is dominated by someone on it
        for i in 0..pts.len() {
            if !front.contains(&i) {
                assert!(front.iter().any(|&f| dominates(&pts[f], &pts[i])));
            }
        }
    }

    #[test]
    fn crowding_boundary_is_infinite() {
        let front = vec![vec![0.0, 3.0], vec![1.0, 2.0], vec![2.0, 1.0], vec![3.0, 0.0]];
        let d = crowding_distance(&front);
        assert!(d[0].is_infinite());
        assert!(d[3].is_infinite());
        assert!(d[1].is_finite() && d[1] > 0.0);
    }

    #[test]
    fn crowding_prefers_isolated_points() {
        // middle point 1 is crowded; point 2 sits in a gap
        let front = vec![
            vec![0.0, 10.0],
            vec![0.5, 9.0],
            vec![5.0, 5.0],
            vec![10.0, 0.0],
        ];
        let d = crowding_distance(&front);
        assert!(d[2] > d[1]);
    }

    #[test]
    fn hypervolume_2d_exact() {
        let pts = vec![vec![1.0, 2.0], vec![2.0, 1.0]];
        // ref (3,3): rect1 (3-1)*(3-2)=2 + rect2 (3-2)*(2-1)=1 → 3
        assert!((hypervolume(&pts, &[3.0, 3.0]) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn hypervolume_3d_box() {
        let pts = vec![vec![0.0, 0.0, 0.0]];
        assert!((hypervolume(&pts, &[2.0, 3.0, 4.0]) - 24.0).abs() < 1e-9);
        // two disjoint-ish boxes
        let pts = vec![vec![0.0, 1.0, 1.0], vec![1.0, 0.0, 1.0]];
        let hv = hypervolume(&pts, &[2.0, 2.0, 2.0]);
        // union = 2*1*1 + 1*2*1 - 1*1*1 = 3
        assert!((hv - 3.0).abs() < 1e-9, "hv={hv}");
    }

    #[test]
    fn hypervolume_monotone_in_points() {
        let a = vec![vec![2.0, 2.0]];
        let mut b = a.clone();
        b.push(vec![1.0, 3.0]);
        let r = [4.0, 4.0];
        assert!(hypervolume(&b, &r) >= hypervolume(&a, &r));
    }

    #[test]
    fn selection_respects_threshold() {
        // objectives: [-accuracy, cost]
        let pts = vec![
            vec![-0.70, 10.0], // accurate but costly
            vec![-0.65, 3.0],  // good trade-off
            vec![-0.60, 1.0],  // cheap but below threshold
        ];
        let sel = select_above_accuracy(&pts, 0, 0.638).unwrap();
        assert_eq!(sel, 1);
        // raising the bar forces the expensive one
        let sel = select_above_accuracy(&pts, 0, 0.68).unwrap();
        assert_eq!(sel, 0);
        // impossible bar → none
        assert!(select_above_accuracy(&pts, 0, 0.99).is_none());
    }
}
