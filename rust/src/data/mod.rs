//! Jet-classification data substrate.
//!
//! The paper uses the hls4ml LHC jet dataset (Zenodo 3602260), which is not
//! available here; `jets.rs` implements a physics-inspired synthetic
//! generator with the same interface contract: 5 classes (q, g, W, Z, t),
//! 8 leading constituents × (pT, η, φ) = 24 standardised features
//! (DESIGN.md substitution #3). `dataset.rs` handles splits, normalisation
//! and minibatching.

pub mod dataset;
pub mod jets;

pub use dataset::{Batch, Dataset, Split};
pub use jets::{JetClass, JetGenerator};
