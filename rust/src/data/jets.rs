//! Physics-inspired synthetic jet generator (substitution for the hls4ml
//! LHC jet dataset — see DESIGN.md §Substitutions #3).
//!
//! Each jet is generated from a class-dependent prong model in the plane of
//! relative (η, φ) around the jet axis:
//!
//! * **q** (light quark): 1 hard core + soft radiation, narrow (σ ≈ 0.04);
//! * **g** (gluon): democratic fragmentation, wider (σ ≈ 0.10) — the classic
//!   quark/gluon width difference;
//! * **W**: two prongs with ΔR set by m/pT kinematics (m ≈ 80 GeV);
//! * **Z**: two prongs, m ≈ 91 GeV — overlaps heavily with W, exactly the
//!   confusion structure that caps accuracy in the mid-60s on the real
//!   dataset;
//! * **t** (top): three prongs (b + W→qq̄), widest.
//!
//! The 8 highest-pT constituents are kept, sorted by descending pT, giving
//! the 8×(pT, η, φ) = 24 features of the paper's 8-constituent MLP
//! baseline (Odagiu et al.). Features are standardised downstream.

use crate::util::Rng;

/// The five jet classes of the hls4ml LHC dataset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JetClass {
    Quark = 0,
    Gluon = 1,
    WBoson = 2,
    ZBoson = 3,
    Top = 4,
}

impl JetClass {
    /// All classes, label-order.
    pub const ALL: [JetClass; 5] = [
        JetClass::Quark,
        JetClass::Gluon,
        JetClass::WBoson,
        JetClass::ZBoson,
        JetClass::Top,
    ];

    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            JetClass::Quark => "q",
            JetClass::Gluon => "g",
            JetClass::WBoson => "W",
            JetClass::ZBoson => "Z",
            JetClass::Top => "t",
        }
    }
}

/// Number of constituents kept per jet.
pub const N_CONST: usize = 8;
/// Features per constituent: (pT, η, φ).
pub const N_FEAT_PER_CONST: usize = 3;
/// Total features per jet.
pub const N_FEATURES: usize = N_CONST * N_FEAT_PER_CONST;

/// Configurable generator.
#[derive(Debug, Clone)]
pub struct JetGenerator {
    /// Jet transverse momentum range [GeV] (hls4ml dataset: ~1 TeV jets).
    pub pt_range: (f64, f64),
    /// Angular smearing added to every constituent (detector resolution).
    pub smear: f64,
    /// Fraction of pT carried by soft (uncorrelated) radiation.
    pub soft_fraction: f64,
}

impl Default for JetGenerator {
    fn default() -> Self {
        JetGenerator {
            pt_range: (800.0, 1200.0),
            // tuned so a good MLP lands in the paper's ~60-70 % band:
            // W/Z nearly degenerate, q/g partially overlapping
            smear: 0.025,
            soft_fraction: 0.25,
        }
    }
}

struct Prong {
    eta: f64,
    phi: f64,
    weight: f64,
    width: f64,
}

impl JetGenerator {
    fn prongs(&self, class: JetClass, pt: f64, rng: &mut Rng) -> Vec<Prong> {
        // ΔR between decay prongs ~ 2m/pT, smeared by the unknown momentum
        // sharing; the W/Z mass difference is the *only* W-vs-Z signal.
        let two_body = |mass: f64, rng: &mut Rng| -> Vec<Prong> {
            let dr = 2.0 * mass / pt * (1.0 + 0.18 * rng.normal());
            let axis = rng.uniform() * std::f64::consts::TAU;
            let z = 0.35 + 0.3 * rng.uniform(); // momentum fraction of prong 1
            vec![
                Prong {
                    eta: dr * (1.0 - z) * axis.cos(),
                    phi: dr * (1.0 - z) * axis.sin(),
                    weight: z,
                    width: 0.03,
                },
                Prong {
                    eta: -dr * z * axis.cos(),
                    phi: -dr * z * axis.sin(),
                    weight: 1.0 - z,
                    width: 0.03,
                },
            ]
        };
        match class {
            JetClass::Quark => vec![Prong {
                eta: 0.0,
                phi: 0.0,
                weight: 1.0,
                width: 0.04,
            }],
            JetClass::Gluon => vec![Prong {
                eta: 0.0,
                phi: 0.0,
                weight: 1.0,
                width: 0.10,
            }],
            JetClass::WBoson => two_body(80.4, rng),
            JetClass::ZBoson => two_body(91.2, rng),
            JetClass::Top => {
                // t → b W(→ q q̄): a b prong plus a displaced W system
                let mut p = two_body(80.4, rng);
                let dr_b = 2.0 * 172.8 / pt * (1.0 + 0.15 * rng.normal());
                let axis = rng.uniform() * std::f64::consts::TAU;
                // shift the W pair away from the b
                for prong in &mut p {
                    prong.eta += 0.55 * dr_b * axis.cos();
                    prong.phi += 0.55 * dr_b * axis.sin();
                    prong.weight *= 0.65;
                }
                p.push(Prong {
                    eta: -0.45 * dr_b * axis.cos(),
                    phi: -0.45 * dr_b * axis.sin(),
                    weight: 0.35,
                    width: 0.04,
                });
                p
            }
        }
    }

    /// Generate one jet: 24 features, leading-pT ordered.
    pub fn generate(&self, class: JetClass, rng: &mut Rng) -> [f32; N_FEATURES] {
        let pt = self.pt_range.0 + (self.pt_range.1 - self.pt_range.0) * rng.uniform();
        let prongs = self.prongs(class, pt, rng);
        // fragmentation: draw candidate constituents per prong, exponential
        // pT sharing; gluons fragment more democratically (more pieces).
        let n_pieces = match class {
            JetClass::Gluon => 14,
            JetClass::Quark => 9,
            _ => 12,
        };
        let mut consts: Vec<(f64, f64, f64)> = Vec::with_capacity(n_pieces + 4);
        for k in 0..n_pieces {
            // pick a prong proportional to weight
            let mut u = rng.uniform();
            let mut prong = &prongs[0];
            for p in &prongs {
                if u < p.weight {
                    prong = p;
                    break;
                }
                u -= p.weight;
            }
            // leading piece of each prong carries an O(1) fraction
            let frac = if k < prongs.len() {
                0.5 + 0.2 * rng.uniform()
            } else {
                -rng.uniform().max(1e-9).ln() * 0.08
            };
            let c_pt = pt * (1.0 - self.soft_fraction) * frac * prong.weight;
            let eta = prong.eta + prong.width * rng.normal() + self.smear * rng.normal();
            let phi = prong.phi + prong.width * rng.normal() + self.smear * rng.normal();
            consts.push((c_pt, eta, phi));
        }
        // soft radiation: wide, uncorrelated
        for _ in 0..4 {
            let c_pt = pt * self.soft_fraction * (-rng.uniform().max(1e-9).ln()) * 0.12;
            consts.push((c_pt, 0.35 * rng.normal(), 0.35 * rng.normal()));
        }
        consts.sort_by(|a, b| b.0.total_cmp(&a.0));
        consts.truncate(N_CONST);
        let total_pt: f64 = consts.iter().map(|c| c.0).sum();
        let mut out = [0.0f32; N_FEATURES];
        for (i, &(c_pt, eta, phi)) in consts.iter().enumerate() {
            out[i * 3] = (c_pt / total_pt) as f32; // relative pT (softmax-like)
            out[i * 3 + 1] = eta as f32;
            out[i * 3 + 2] = phi as f32;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn features_are_finite_and_ordered() {
        let gen = JetGenerator::default();
        let mut rng = Rng::new(0);
        for &class in &JetClass::ALL {
            for _ in 0..200 {
                let f = gen.generate(class, &mut rng);
                assert!(f.iter().all(|v| v.is_finite()));
                // leading-pT ordering
                for i in 1..N_CONST {
                    assert!(f[(i - 1) * 3] >= f[i * 3], "pT ordering broken");
                }
                // relative pT sums to ~1
                let s: f32 = (0..N_CONST).map(|i| f[i * 3]).sum();
                assert!((s - 1.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn gluons_are_wider_than_quarks() {
        let gen = JetGenerator::default();
        let mut rng = Rng::new(1);
        let width = |class: JetClass, rng: &mut Rng| -> f64 {
            let mut acc = 0.0;
            for _ in 0..500 {
                let f = gen.generate(class, rng);
                // pT-weighted angular spread
                let mut w = 0.0;
                for i in 0..N_CONST {
                    let (pt, eta, phi) = (f[i * 3] as f64, f[i * 3 + 1] as f64, f[i * 3 + 2] as f64);
                    w += pt * (eta * eta + phi * phi).sqrt();
                }
                acc += w;
            }
            acc / 500.0
        };
        let wq = width(JetClass::Quark, &mut rng);
        let wg = width(JetClass::Gluon, &mut rng);
        assert!(wg > 1.3 * wq, "gluon {wg} vs quark {wq}");
    }

    #[test]
    fn tops_are_widest() {
        let gen = JetGenerator::default();
        let mut rng = Rng::new(2);
        // pT-weighted spread: soft radiation is angularly wide for every
        // class, so an unweighted max would wash the prong structure out.
        let spread = |class: JetClass, rng: &mut Rng| -> f64 {
            let mut acc = 0.0;
            for _ in 0..500 {
                let f = gen.generate(class, rng);
                let mut w: f64 = 0.0;
                for i in 0..N_CONST {
                    w += f[i * 3] as f64
                        * (f[i * 3 + 1].powi(2) + f[i * 3 + 2].powi(2)).sqrt() as f64;
                }
                acc += w;
            }
            acc / 500.0
        };
        let sq = spread(JetClass::Quark, &mut rng);
        let st = spread(JetClass::Top, &mut rng);
        assert!(st > 2.0 * sq, "top {st} vs quark {sq}");
    }

    #[test]
    fn w_and_z_overlap_but_differ_slightly() {
        let gen = JetGenerator::default();
        let mut rng = Rng::new(3);
        let mean_dr = |class: JetClass, rng: &mut Rng| -> f64 {
            let mut acc = 0.0;
            for _ in 0..2000 {
                let f = gen.generate(class, rng);
                // ΔR between the two leading constituents ≈ prong separation
                let (e1, p1) = (f[1] as f64, f[2] as f64);
                let (e2, p2) = (f[4] as f64, f[5] as f64);
                acc += ((e1 - e2).powi(2) + (p1 - p2).powi(2)).sqrt();
            }
            acc / 2000.0
        };
        let dw = mean_dr(JetClass::WBoson, &mut rng);
        let dz = mean_dr(JetClass::ZBoson, &mut rng);
        assert!(dz > dw, "Z prongs wider apart: {dz} vs {dw}");
        assert!(dz < 1.35 * dw, "but heavily overlapping: {dz} vs {dw}");
    }

    #[test]
    fn deterministic_given_seed() {
        let gen = JetGenerator::default();
        let a = gen.generate(JetClass::Top, &mut Rng::new(9));
        let b = gen.generate(JetClass::Top, &mut Rng::new(9));
        assert_eq!(a, b);
    }
}
