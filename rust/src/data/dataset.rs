//! Dataset container: generation, standardisation, splits, minibatching.

use super::jets::{JetClass, JetGenerator, N_FEATURES};
use crate::nn::{BATCH, IN_DIM, OUT_DIM};
use crate::util::Rng;

/// Which split to read.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
    Test,
}

/// One minibatch in the supernet's input layout.
#[derive(Debug, Clone)]
pub struct Batch {
    /// `(BATCH, IN_DIM)` features, row-major.
    pub x: Vec<f32>,
    /// `(BATCH, OUT_DIM)` one-hot labels.
    pub y1h: Vec<f32>,
    /// Number of *real* rows (tail batches are zero-padded).
    pub rows: usize,
}

/// In-memory standardised jet dataset with train/val/test splits.
#[derive(Debug, Clone)]
pub struct Dataset {
    features: Vec<f32>, // (n, IN_DIM)
    labels: Vec<u8>,
    n_train: usize,
    n_val: usize,
    n_test: usize,
    /// per-feature standardisation (fit on train only)
    pub mean: Vec<f32>,
    pub std: Vec<f32>,
}

impl Dataset {
    /// Generate a balanced dataset and standardise with train-split stats,
    /// mirroring the Odagiu et al. preprocessing ("data processed and
    /// normalized as done there").
    pub fn generate(n_train: usize, n_val: usize, n_test: usize, seed: u64) -> Self {
        assert_eq!(N_FEATURES, IN_DIM);
        let gen = JetGenerator::default();
        let mut rng = Rng::new(seed);
        let total = n_train + n_val + n_test;
        let mut features = Vec::with_capacity(total * IN_DIM);
        let mut labels = Vec::with_capacity(total);
        for i in 0..total {
            let class = JetClass::ALL[i % OUT_DIM];
            features.extend_from_slice(&gen.generate(class, &mut rng));
            labels.push(class as u8);
        }
        // shuffle rows so splits are class-balanced in expectation but not
        // block-structured
        let perm = rng.permutation(total);
        let mut shuf_f = vec![0.0f32; total * IN_DIM];
        let mut shuf_l = vec![0u8; total];
        for (dst, &src) in perm.iter().enumerate() {
            shuf_f[dst * IN_DIM..(dst + 1) * IN_DIM]
                .copy_from_slice(&features[src * IN_DIM..(src + 1) * IN_DIM]);
            shuf_l[dst] = labels[src];
        }
        let mut ds = Dataset {
            features: shuf_f,
            labels: shuf_l,
            n_train,
            n_val,
            n_test,
            mean: vec![0.0; IN_DIM],
            std: vec![1.0; IN_DIM],
        };
        ds.fit_standardiser();
        ds.apply_standardiser();
        ds
    }

    fn fit_standardiser(&mut self) {
        let n = self.n_train.max(1);
        for j in 0..IN_DIM {
            let mut m = 0.0f64;
            for i in 0..n {
                m += self.features[i * IN_DIM + j] as f64;
            }
            m /= n as f64;
            let mut v = 0.0f64;
            for i in 0..n {
                let d = self.features[i * IN_DIM + j] as f64 - m;
                v += d * d;
            }
            v /= n as f64;
            self.mean[j] = m as f32;
            self.std[j] = (v.sqrt() as f32).max(1e-6);
        }
    }

    fn apply_standardiser(&mut self) {
        let total = self.labels.len();
        for i in 0..total {
            for j in 0..IN_DIM {
                let v = &mut self.features[i * IN_DIM + j];
                *v = (*v - self.mean[j]) / self.std[j];
            }
        }
    }

    fn split_range(&self, split: Split) -> (usize, usize) {
        match split {
            Split::Train => (0, self.n_train),
            Split::Val => (self.n_train, self.n_train + self.n_val),
            Split::Test => (
                self.n_train + self.n_val,
                self.n_train + self.n_val + self.n_test,
            ),
        }
    }

    /// Number of examples in a split.
    pub fn len(&self, split: Split) -> usize {
        let (a, b) = self.split_range(split);
        b - a
    }

    /// True if the split is empty.
    pub fn is_empty(&self, split: Split) -> bool {
        self.len(split) == 0
    }

    /// Row accessors (standardised features, label).
    pub fn row(&self, split: Split, i: usize) -> (&[f32], u8) {
        let (a, _) = self.split_range(split);
        let idx = a + i;
        (
            &self.features[idx * IN_DIM..(idx + 1) * IN_DIM],
            self.labels[idx],
        )
    }

    /// Shuffled epoch of training minibatches (drops the ragged tail, as
    /// the usual `drop_last=True` training loader does).
    pub fn train_epoch(&self, rng: &mut Rng) -> Vec<Batch> {
        let n = self.len(Split::Train);
        let perm = rng.permutation(n);
        let n_batches = n / BATCH;
        let mut out = Vec::with_capacity(n_batches);
        for b in 0..n_batches {
            let mut x = vec![0.0f32; BATCH * IN_DIM];
            let mut y = vec![0.0f32; BATCH * OUT_DIM];
            for r in 0..BATCH {
                let (feat, label) = self.row(Split::Train, perm[b * BATCH + r]);
                x[r * IN_DIM..(r + 1) * IN_DIM].copy_from_slice(feat);
                y[r * OUT_DIM + label as usize] = 1.0;
            }
            out.push(Batch { x, y1h: y, rows: BATCH });
        }
        out
    }

    /// Sequential fixed-size tiles over a split, zero-padding the tail
    /// (`rows` records the real count for correct accuracy accounting).
    pub fn eval_tiles(&self, split: Split, tile: usize) -> Vec<Batch> {
        let n = self.len(split);
        let mut out = Vec::with_capacity(n.div_ceil(tile));
        let mut i = 0;
        while i < n {
            let rows = tile.min(n - i);
            let mut x = vec![0.0f32; tile * IN_DIM];
            let mut y = vec![0.0f32; tile * OUT_DIM];
            for r in 0..rows {
                let (feat, label) = self.row(split, i + r);
                x[r * IN_DIM..(r + 1) * IN_DIM].copy_from_slice(feat);
                y[r * OUT_DIM + label as usize] = 1.0;
            }
            // padded rows keep an all-zero one-hot; argmax(0-vector) == class
            // 0 == argmax(logits of zero input) only by accident, so rust
            // discounts them via `rows` instead of trusting the graph.
            out.push(Batch { x, y1h: y, rows });
            i += rows;
        }
        out
    }

    /// Class balance of a split (fractions, label order).
    pub fn class_balance(&self, split: Split) -> [f64; OUT_DIM] {
        let n = self.len(split);
        let mut counts = [0usize; OUT_DIM];
        for i in 0..n {
            counts[self.row(split, i).1 as usize] += 1;
        }
        let mut out = [0.0; OUT_DIM];
        for (o, c) in out.iter_mut().zip(counts) {
            *o = c as f64 / n.max(1) as f64;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Dataset {
        Dataset::generate(1280, 320, 320, 7)
    }

    #[test]
    fn splits_have_requested_sizes() {
        let ds = small();
        assert_eq!(ds.len(Split::Train), 1280);
        assert_eq!(ds.len(Split::Val), 320);
        assert_eq!(ds.len(Split::Test), 320);
    }

    #[test]
    fn train_features_are_standardised() {
        let ds = small();
        for j in 0..IN_DIM {
            let n = ds.len(Split::Train);
            let mut m = 0.0f64;
            let mut v = 0.0f64;
            for i in 0..n {
                m += ds.row(Split::Train, i).0[j] as f64;
            }
            m /= n as f64;
            for i in 0..n {
                let d = ds.row(Split::Train, i).0[j] as f64 - m;
                v += d * d;
            }
            v /= n as f64;
            assert!(m.abs() < 1e-4, "feature {j} mean {m}");
            assert!((v - 1.0).abs() < 1e-3, "feature {j} var {v}");
        }
    }

    #[test]
    fn classes_are_balanced() {
        let ds = small();
        for f in ds.class_balance(Split::Train) {
            assert!((f - 0.2).abs() < 0.06, "balance {f}");
        }
    }

    #[test]
    fn train_epoch_batches_are_onehot() {
        let ds = small();
        let mut rng = Rng::new(0);
        let batches = ds.train_epoch(&mut rng);
        assert_eq!(batches.len(), 1280 / BATCH);
        for b in &batches {
            assert_eq!(b.rows, BATCH);
            for r in 0..BATCH {
                let s: f32 = b.y1h[r * OUT_DIM..(r + 1) * OUT_DIM].iter().sum();
                assert_eq!(s, 1.0);
            }
        }
    }

    #[test]
    fn epochs_are_reshuffled() {
        let ds = small();
        let mut rng = Rng::new(0);
        let a = ds.train_epoch(&mut rng);
        let b = ds.train_epoch(&mut rng);
        assert_ne!(a[0].x, b[0].x, "shuffling must change batch composition");
    }

    #[test]
    fn eval_tiles_cover_split_exactly_once() {
        let ds = small();
        let tiles = ds.eval_tiles(Split::Test, 512);
        let total: usize = tiles.iter().map(|t| t.rows).sum();
        assert_eq!(total, 320);
        assert_eq!(tiles.len(), 1);
        // padded tail rows are zero
        let t = &tiles[0];
        assert!(t.x[320 * IN_DIM..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn generation_is_deterministic() {
        let a = Dataset::generate(256, 64, 64, 3);
        let b = Dataset::generate(256, 64, 64, 3);
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
    }
}
