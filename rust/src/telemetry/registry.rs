//! The unified metrics registry: counters, gauges, and latency
//! histograms shared by `GET /metrics` and the trace exporter.
//!
//! Instruments are relaxed atomics — recording on a hot path takes no
//! shared lock — and a [`Registry`] is an *instance*, not a global:
//! every [`crate::serve::ServeMetrics`] (and any test) owns its own, so
//! parallel test binaries never bleed counts into each other. The trace
//! exporter reads attached registries through
//! [`crate::telemetry::attach_registry`], so a run's `trace.json` and
//! its `/metrics` endpoint report the same source of truth.
//!
//! Quantile math lives in [`crate::util::stats`] (ceil-rank, shared
//! with the bench harness) — a histogram here only owns its buckets.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::stats::bucket_quantile_index;
use crate::util::Json;

/// Latency bucket upper bounds in microseconds; one overflow bucket is
/// appended. Spans 50µs (memo hit on loopback) to 250ms (a cold flush
/// behind a long batching deadline).
pub const BUCKET_US: [u64; 12] =
    [50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000, 250_000];

/// A monotonically increasing counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Add one.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// An instantaneous up/down gauge.
#[derive(Default)]
pub struct Gauge(AtomicUsize);

impl Gauge {
    /// Add one.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Subtract one (saturating at the atomic's wraparound is fine — a
    /// balanced inc/dec discipline is the caller's contract).
    pub fn dec(&self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> usize {
        self.0.load(Ordering::Relaxed)
    }

    /// Increment now, decrement when the guard drops — pairs the
    /// decrement with every exit path of a scope.
    pub fn guard(&self) -> GaugeGuard<'_> {
        self.inc();
        GaugeGuard(self)
    }
}

/// Decrements its gauge when dropped (see [`Gauge::guard`]).
pub struct GaugeGuard<'a>(&'a Gauge);

impl Drop for GaugeGuard<'_> {
    fn drop(&mut self) {
        self.0.dec();
    }
}

/// One fixed-bucket latency histogram (lock-free observe path).
pub struct Histogram {
    counts: [AtomicU64; BUCKET_US.len() + 1],
    sum_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Fresh, all-zero histogram.
    pub fn new() -> Self {
        Histogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
        }
    }

    /// Record one latency observation.
    pub fn observe(&self, elapsed: Duration) {
        self.observe_us(u64::try_from(elapsed.as_micros()).unwrap_or(u64::MAX));
    }

    /// Record one latency observation in microseconds.
    pub fn observe_us(&self, us: u64) {
        let idx = BUCKET_US.iter().position(|&b| us <= b).unwrap_or(BUCKET_US.len());
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Mean latency in milliseconds (0 when empty).
    pub fn mean_ms(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64 / 1_000.0
    }

    /// Conservative quantile in milliseconds: the upper bound of the
    /// bucket holding the q-th observation (the overflow bucket reports
    /// four times the last bound). 0 when empty. Rank selection is the
    /// shared [`bucket_quantile_index`] ceil-rank.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        let snapshot: Vec<u64> = self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        match bucket_quantile_index(&snapshot, q) {
            None => 0.0,
            Some(i) => {
                let bound_us =
                    BUCKET_US.get(i).copied().unwrap_or(BUCKET_US[BUCKET_US.len() - 1] * 4);
                bound_us as f64 / 1_000.0
            }
        }
    }

    /// The scrape-document shape (`count`/`mean_ms`/`p50_ms`/`p99_ms`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count() as f64)),
            ("mean_ms", Json::Num(self.mean_ms())),
            ("p50_ms", Json::Num(self.quantile_ms(0.50))),
            ("p99_ms", Json::Num(self.quantile_ms(0.99))),
        ])
    }
}

enum Instrument {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// A named collection of instruments. Handles are `Arc`s: register once,
/// then record through the handle with no registry lookup on hot paths.
#[derive(Default)]
pub struct Registry {
    entries: Mutex<Vec<(String, Instrument)>>,
}

fn lock_entries(
    m: &Mutex<Vec<(String, Instrument)>>,
) -> std::sync::MutexGuard<'_, Vec<(String, Instrument)>> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Self {
        Registry::default()
    }

    /// Get-or-create the counter named `name`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut entries = lock_entries(&self.entries);
        for (n, inst) in entries.iter() {
            if let (true, Instrument::Counter(c)) = (n == name, inst) {
                return Arc::clone(c);
            }
        }
        let c = Arc::new(Counter::default());
        entries.push((name.to_string(), Instrument::Counter(Arc::clone(&c))));
        c
    }

    /// Get-or-create the gauge named `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut entries = lock_entries(&self.entries);
        for (n, inst) in entries.iter() {
            if let (true, Instrument::Gauge(g)) = (n == name, inst) {
                return Arc::clone(g);
            }
        }
        let g = Arc::new(Gauge::default());
        entries.push((name.to_string(), Instrument::Gauge(Arc::clone(&g))));
        g
    }

    /// Get-or-create the histogram named `name`.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut entries = lock_entries(&self.entries);
        for (n, inst) in entries.iter() {
            if let (true, Instrument::Histogram(h)) = (n == name, inst) {
                return Arc::clone(h);
            }
        }
        let h = Arc::new(Histogram::new());
        entries.push((name.to_string(), Instrument::Histogram(Arc::clone(&h))));
        h
    }

    /// Snapshot every instrument, grouped by kind (one consistent-enough
    /// scrape: each value is individually atomic, the document is not a
    /// transaction — the standard contract for scrape-style metrics).
    pub fn to_json(&self) -> Json {
        let entries = lock_entries(&self.entries);
        let mut counters: Vec<(&str, Json)> = Vec::new();
        let mut gauges: Vec<(&str, Json)> = Vec::new();
        let mut histograms: Vec<(&str, Json)> = Vec::new();
        for (name, inst) in entries.iter() {
            match inst {
                Instrument::Counter(c) => counters.push((name, Json::Num(c.get() as f64))),
                Instrument::Gauge(g) => gauges.push((name, Json::Num(g.get() as f64))),
                Instrument::Histogram(h) => histograms.push((name, h.to_json())),
            }
        }
        Json::obj(vec![
            ("counters", Json::obj(counters)),
            ("gauges", Json::obj(gauges)),
            ("histograms", Json::obj(histograms)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_are_conservative_bucket_bounds() {
        let h = Histogram::new();
        assert_eq!(h.quantile_ms(0.5), 0.0, "empty histogram reports zero");
        for _ in 0..99 {
            h.observe(Duration::from_micros(80)); // second bucket (≤100µs)
        }
        h.observe(Duration::from_millis(40)); // ≤50ms bucket
        assert_eq!(h.count(), 100);
        assert_eq!(h.quantile_ms(0.5), 0.1, "p50 lands in the ≤100µs bucket");
        assert_eq!(h.quantile_ms(0.99), 0.1);
        assert_eq!(h.quantile_ms(1.0), 50.0, "max lands in the ≤50ms bucket");
        assert!(h.mean_ms() > 0.0);

        // overflow bucket: far past the last bound
        let h = Histogram::new();
        h.observe(Duration::from_secs(2));
        assert_eq!(h.quantile_ms(0.5), 1_000.0, "overflow reports 4x the last bound");
    }

    #[test]
    fn registry_hands_out_stable_named_handles() {
        let reg = Registry::new();
        let a = reg.counter("requests");
        let b = reg.counter("requests");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3, "same name → same counter");

        let g = reg.gauge("in_flight");
        {
            let _guard = g.guard();
            assert_eq!(reg.gauge("in_flight").get(), 1);
        }
        assert_eq!(g.get(), 0, "guard decrements on drop");

        reg.histogram("latency").observe(Duration::from_micros(40));
        let snap = reg.to_json();
        assert_eq!(
            snap.get("counters").and_then(|c| c.get("requests")).and_then(Json::as_usize),
            Some(3)
        );
        assert_eq!(
            snap.get("gauges").and_then(|g| g.get("in_flight")).and_then(Json::as_usize),
            Some(0)
        );
        assert_eq!(
            snap.get("histograms")
                .and_then(|h| h.get("latency"))
                .and_then(|l| l.get("count"))
                .and_then(Json::as_usize),
            Some(1)
        );

        // registries are instances: a second one starts from zero
        assert_eq!(Registry::new().counter("requests").get(), 0);
    }
}
