//! Workspace-wide structured tracing: spans, instant events, Chrome-trace
//! export, and cross-process stitching.
//!
//! The tracer is global and deliberately boring: one relaxed atomic gates
//! every instrumentation site, so a run without `--trace-out` pays a
//! single load per span — no clock read, no allocation, no lock. When
//! enabled, spans buffer in a `thread_local` vector and flush to a shared
//! sink in batches, so the hot path (per-trial evaluation, per-op kernel
//! timing) still takes no shared lock per record.
//!
//! Tracing is **observational only**: nothing downstream reads a span, so
//! trial databases are bit-identical with telemetry off, on, or sampled
//! (asserted by `rust/tests/telemetry.rs`).
//!
//! Cross-process story: the driver mints a trace ID (`init`), stamps it
//! into the run manifest, and shard workers adopt it. Workers drain their
//! spans into each result publication (`local_spans_json`) and echo the
//! ID on every `/shard/*` request via the `X-Snac-Trace` header; the
//! driver folds remote spans back in (`ingest_remote`), tagging each with
//! the worker's trace ID and process, so `export` writes one coherent
//! multi-process `trace.json` (plus a JSONL flight-recorder log).
//!
//! Metrics live next door in [`registry`]: instrument collections are
//! instances (see `ServeMetrics`), but a process can `attach_registry`
//! them here so the exporter snapshots the same numbers `GET /metrics`
//! serves.

pub mod registry;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError, Weak};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

use crate::util::Json;
use registry::Registry;

/// Per-thread buffer capacity before a batch flush to the shared sink.
const FLUSH_EVERY: usize = 64;

/// Hard cap on retained records; beyond it new records are counted as
/// dropped rather than growing without bound (flight-recorder semantics).
const SINK_CAP: usize = 1 << 20;

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static SINK: Mutex<Vec<SpanRecord>> = Mutex::new(Vec::new());
static TRACE_ID: Mutex<Option<String>> = Mutex::new(None);
#[allow(clippy::type_complexity)]
static REGISTRIES: Mutex<Vec<(String, Weak<Registry>)>> = Mutex::new(Vec::new());

/// Monotonic anchor paired with the wall-clock microseconds at the anchor,
/// so every record gets a wall-aligned timestamp from a monotonic read
/// (Chrome-trace timelines from different processes line up on the wall
/// clock without any process ever stepping backwards).
static EPOCH: OnceLock<(Instant, u64)> = OnceLock::new();

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

fn epoch() -> &'static (Instant, u64) {
    EPOCH.get_or_init(|| {
        let wall = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| u64::try_from(d.as_micros()).unwrap_or(u64::MAX))
            .unwrap_or(0);
        (Instant::now(), wall)
    })
}

fn wall_us_at(at: Instant) -> u64 {
    let &(anchor, wall) = epoch();
    wall.saturating_add(
        u64::try_from(at.saturating_duration_since(anchor).as_micros()).unwrap_or(u64::MAX),
    )
}

/// One recorded span (`dur_us: Some`) or instant event (`dur_us: None`).
#[derive(Debug, Clone)]
pub struct SpanRecord {
    pub name: String,
    pub cat: String,
    /// Wall-aligned start time in microseconds since the Unix epoch.
    pub ts_us: u64,
    pub dur_us: Option<u64>,
    pub pid: u32,
    pub tid: u64,
    pub args: Vec<(String, Json)>,
}

struct ThreadBuf {
    tid: u64,
    buf: Vec<SpanRecord>,
}

impl ThreadBuf {
    fn flush(&mut self) {
        if !self.buf.is_empty() {
            push_all(self.buf.drain(..));
        }
    }
}

impl Drop for ThreadBuf {
    fn drop(&mut self) {
        self.flush();
    }
}

thread_local! {
    static THREAD: RefCell<ThreadBuf> = RefCell::new(ThreadBuf {
        tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
        buf: Vec::new(),
    });
}

fn push_all<I: IntoIterator<Item = SpanRecord>>(records: I) {
    let mut sink = lock(&SINK);
    for r in records {
        if sink.len() >= SINK_CAP {
            DROPPED.fetch_add(1, Ordering::Relaxed);
        } else {
            sink.push(r);
        }
    }
}

fn record(r: SpanRecord) {
    // `try_with` + `try_borrow_mut` keep this callable from thread
    // destructors and from Drop impls running inside a record call;
    // the fallback pushes straight to the sink (tid 0).
    let mut slot = Some(r);
    THREAD
        .try_with(|cell| {
            if let Ok(mut tb) = cell.try_borrow_mut() {
                if let Some(mut r) = slot.take() {
                    r.tid = tb.tid;
                    tb.buf.push(r);
                    if tb.buf.len() >= FLUSH_EVERY {
                        tb.flush();
                    }
                }
            }
        })
        .ok();
    if let Some(r) = slot.take() {
        push_all(std::iter::once(r));
    }
}

/// Is tracing on? One relaxed load — the only cost every instrumentation
/// site pays when telemetry is disabled.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Mint a fresh trace ID: process ID and wall-clock millis, both hex.
pub fn mint_trace_id() -> String {
    let millis = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| u64::try_from(d.as_millis()).unwrap_or(u64::MAX))
        .unwrap_or(0);
    format!("{:x}-{millis:x}", std::process::id())
}

/// Turn tracing on under `trace_id` (minting one when `None`) and return
/// the active ID. Drivers mint; workers adopt the driver's ID from the
/// run manifest so the stitched trace is one logical run.
pub fn init(trace_id: Option<String>) -> String {
    let id = trace_id.unwrap_or_else(mint_trace_id);
    *lock(&TRACE_ID) = Some(id.clone());
    epoch();
    ENABLED.store(true, Ordering::Relaxed);
    id
}

/// Turn tracing off and discard all buffered state (test isolation and
/// end-of-run cleanup).
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
    THREAD.try_with(|cell| {
        if let Ok(mut tb) = cell.try_borrow_mut() {
            tb.buf.clear();
        }
    })
    .ok();
    lock(&SINK).clear();
    *lock(&TRACE_ID) = None;
    lock(&REGISTRIES).clear();
    DROPPED.store(0, Ordering::Relaxed);
}

/// The active trace ID, if tracing is on.
pub fn trace_id() -> Option<String> {
    lock(&TRACE_ID).clone()
}

/// RAII span: records name/category/duration when dropped. Inert (no
/// clock, no allocation) when tracing is off.
pub struct SpanGuard {
    live: Option<LiveSpan>,
}

struct LiveSpan {
    name: &'static str,
    cat: &'static str,
    start: Instant,
    args: Vec<(String, Json)>,
}

impl SpanGuard {
    /// Attach an argument to a live span (no-op when tracing is off).
    pub fn arg(&mut self, key: &str, value: Json) {
        if let Some(live) = self.live.as_mut() {
            live.args.push((key.to_string(), value));
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(live) = self.live.take() {
            let dur = u64::try_from(live.start.elapsed().as_micros()).unwrap_or(u64::MAX);
            record(SpanRecord {
                name: live.name.to_string(),
                cat: live.cat.to_string(),
                ts_us: wall_us_at(live.start),
                dur_us: Some(dur),
                pid: std::process::id(),
                tid: 0,
                args: live.args,
            });
        }
    }
}

/// Open a span; it closes (and records) when the guard drops.
pub fn span(name: &'static str, cat: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard { live: None };
    }
    SpanGuard {
        live: Some(LiveSpan { name, cat, start: Instant::now(), args: Vec::new() }),
    }
}

/// Open a span with arguments attached up front.
pub fn span_args(name: &'static str, cat: &'static str, args: Vec<(&str, Json)>) -> SpanGuard {
    let mut g = span(name, cat);
    if let Some(live) = g.live.as_mut() {
        live.args = args.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
    }
    g
}

/// Record an instant event (a point on the timeline, no duration).
pub fn event(name: &'static str, cat: &'static str, args: Vec<(&str, Json)>) {
    if !enabled() {
        return;
    }
    record(SpanRecord {
        name: name.to_string(),
        cat: cat.to_string(),
        ts_us: wall_us_at(Instant::now()),
        dur_us: None,
        pid: std::process::id(),
        tid: 0,
        args: args.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
    });
}

/// Sink for sampled per-op timings from the `xla` interpreter (matches
/// `xla::OpSink`, which cannot depend on this crate). The op already
/// finished, so the span is backdated by its duration.
pub fn xla_op_sink(kind: &'static str, comp: &str, dur_us: u64) {
    if !enabled() {
        return;
    }
    record(SpanRecord {
        name: kind.to_string(),
        cat: "xla".to_string(),
        ts_us: wall_us_at(Instant::now()).saturating_sub(dur_us),
        dur_us: Some(dur_us),
        pid: std::process::id(),
        tid: 0,
        args: vec![("comp".to_string(), Json::Str(comp.to_string()))],
    });
}

/// Flush the calling thread's buffer to the shared sink.
pub fn flush_thread() {
    THREAD.try_with(|cell| {
        if let Ok(mut tb) = cell.try_borrow_mut() {
            tb.flush();
        }
    })
    .ok();
}

/// Flush the calling thread and take every record accumulated so far.
pub fn drain() -> Vec<SpanRecord> {
    flush_thread();
    std::mem::take(&mut *lock(&SINK))
}

/// Register a metrics registry for export under `name`. Held weakly:
/// a dropped registry silently leaves the export.
pub fn attach_registry(name: &str, reg: &Arc<Registry>) {
    lock(&REGISTRIES).push((name.to_string(), Arc::downgrade(reg)));
}

fn registries_json() -> Json {
    let regs = lock(&REGISTRIES);
    let mut out: Vec<(&str, Json)> = Vec::new();
    for (name, weak) in regs.iter() {
        if let Some(reg) = weak.upgrade() {
            out.push((name, reg.to_json()));
        }
    }
    Json::obj(out)
}

fn span_to_json(r: &SpanRecord) -> Json {
    let mut fields = vec![
        ("name", Json::Str(r.name.clone())),
        ("cat", Json::Str(r.cat.clone())),
        ("ts", Json::Num(r.ts_us as f64)),
        ("pid", Json::Num(f64::from(r.pid))),
        ("tid", Json::Num(r.tid as f64)),
    ];
    match r.dur_us {
        Some(d) => fields.push(("dur", Json::Num(d as f64))),
        None => fields.push(("dur", Json::Null)),
    }
    let args: Vec<(&str, Json)> = r.args.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
    fields.push(("args", Json::obj(args)));
    Json::obj(fields)
}

fn span_from_json(j: &Json) -> Option<SpanRecord> {
    let mut args = Vec::new();
    if let Some(Json::Obj(map)) = j.get("args") {
        for (k, v) in map {
            args.push((k.clone(), v.clone()));
        }
    }
    Some(SpanRecord {
        name: j.get("name")?.as_str()?.to_string(),
        cat: j.get("cat")?.as_str()?.to_string(),
        ts_us: j.get("ts")?.as_f64()? as u64,
        dur_us: j.get("dur").and_then(Json::as_f64).map(|d| d as u64),
        pid: j.get("pid")?.as_f64()? as u32,
        tid: j.get("tid")?.as_f64()? as u64,
        args,
    })
}

/// Drain this process's spans into the wire shape a worker attaches to a
/// result publication: `{pid, trace, spans: [...]}`.
pub fn local_spans_json() -> Json {
    let records = drain();
    Json::obj(vec![
        ("pid", Json::Num(f64::from(std::process::id()))),
        ("trace", trace_id().map(Json::Str).unwrap_or(Json::Null)),
        ("spans", Json::Arr(records.iter().map(span_to_json).collect())),
    ])
}

/// Fold a worker's `local_spans_json` document back into this process's
/// sink, tagging every span with the worker's trace ID so the stitched
/// export proves which run each remote span belonged to.
pub fn ingest_remote(doc: &Json) {
    if !enabled() {
        return;
    }
    let trace = doc.get("trace").and_then(Json::as_str).map(str::to_string);
    let spans = match doc.get("spans") {
        Some(Json::Arr(items)) => items,
        _ => return,
    };
    let mut out = Vec::with_capacity(spans.len());
    for item in spans {
        if let Some(mut r) = span_from_json(item) {
            if let Some(t) = &trace {
                r.args.push(("trace".to_string(), Json::Str(t.clone())));
            }
            out.push(r);
        }
    }
    push_all(out);
}

/// Build a Chrome-trace (`chrome://tracing` / Perfetto) document from
/// `records`. Pure so tests can validate the schema without touching
/// global state.
pub fn chrome_trace(records: &[SpanRecord], trace_id: &str) -> Json {
    let self_pid = std::process::id();
    let mut events: Vec<Json> = Vec::with_capacity(records.len() + 4);
    let mut pids: Vec<u32> = Vec::new();
    for r in records {
        if !pids.contains(&r.pid) {
            pids.push(r.pid);
        }
    }
    pids.sort_unstable();
    for pid in &pids {
        let label = if *pid == self_pid {
            "driver".to_string()
        } else {
            format!("worker {pid}")
        };
        events.push(Json::obj(vec![
            ("name", Json::Str("process_name".to_string())),
            ("ph", Json::Str("M".to_string())),
            ("pid", Json::Num(f64::from(*pid))),
            ("tid", Json::Num(0.0)),
            ("args", Json::obj(vec![("name", Json::Str(label))])),
        ]));
    }
    for r in records {
        let args: Vec<(&str, Json)> = r.args.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
        let mut fields = vec![
            ("name", Json::Str(r.name.clone())),
            ("cat", Json::Str(r.cat.clone())),
            ("ts", Json::Num(r.ts_us as f64)),
            ("pid", Json::Num(f64::from(r.pid))),
            ("tid", Json::Num(r.tid as f64)),
            ("args", Json::obj(args)),
        ];
        match r.dur_us {
            Some(d) => {
                fields.push(("ph", Json::Str("X".to_string())));
                fields.push(("dur", Json::Num(d as f64)));
            }
            None => fields.push(("ph", Json::Str("i".to_string()))),
        }
        events.push(Json::obj(fields));
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
        (
            "metadata",
            Json::obj(vec![
                ("trace_id", Json::Str(trace_id.to_string())),
                ("dropped", Json::Num(DROPPED.load(Ordering::Relaxed) as f64)),
                ("registries", registries_json()),
            ]),
        ),
    ])
}

/// Render the end-of-run summary: top time sinks grouped by
/// category/name, with call counts and total/mean duration.
pub fn summary(records: &[SpanRecord]) -> String {
    let mut agg: BTreeMap<(String, String), (u64, u64)> = BTreeMap::new();
    for r in records {
        if let Some(d) = r.dur_us {
            let entry = agg.entry((r.cat.clone(), r.name.clone())).or_insert((0, 0));
            entry.0 += 1;
            entry.1 += d;
        }
    }
    let mut rows: Vec<((String, String), (u64, u64))> = agg.into_iter().collect();
    rows.sort_by(|a, b| b.1 .1.cmp(&a.1 .1));
    let mut out = String::from("trace summary (top time sinks)\n");
    out.push_str(&format!(
        "  {:<28} {:>8} {:>12} {:>10}\n",
        "stage", "count", "total_ms", "mean_ms"
    ));
    for ((cat, name), (count, total_us)) in rows.iter().take(12) {
        let total_ms = *total_us as f64 / 1_000.0;
        let mean_ms = total_ms / *count as f64;
        out.push_str(&format!(
            "  {:<28} {count:>8} {total_ms:>12.3} {mean_ms:>10.3}\n",
            format!("{cat}/{name}")
        ));
    }
    if rows.is_empty() {
        out.push_str("  (no spans recorded)\n");
    }
    out
}

/// Drain everything and write the Chrome-trace JSON to `path` plus a
/// JSONL flight-recorder log beside it (`path` with a `.jsonl`
/// extension). Returns the rendered summary table.
pub fn export(path: &std::path::Path) -> std::io::Result<String> {
    let records = drain();
    let id = trace_id().unwrap_or_default();
    let doc = chrome_trace(&records, &id);
    std::fs::write(path, doc.to_string())?;
    let mut jsonl = String::new();
    for r in &records {
        jsonl.push_str(&span_to_json(r).to_string());
        jsonl.push('\n');
    }
    std::fs::write(path.with_extension("jsonl"), jsonl)?;
    Ok(summary(&records))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The tracer is process-global; tests in this binary serialise on
    /// this gate so enable/disable phases don't interleave.
    fn gate() -> MutexGuard<'static, ()> {
        static GATE: Mutex<()> = Mutex::new(());
        GATE.lock().unwrap_or_else(PoisonError::into_inner)
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let _g = gate();
        disable();
        {
            let mut s = span("noop", "test");
            s.arg("k", Json::Num(1.0));
        }
        event("e", "test", vec![]);
        xla_op_sink("dot", "main", 10);
        assert!(drain().is_empty());
        assert!(trace_id().is_none());
    }

    #[test]
    fn spans_events_and_remote_ingest_round_trip() {
        let _g = gate();
        disable();
        let id = init(Some("test-trace".to_string()));
        assert_eq!(id, "test-trace");
        assert_eq!(trace_id().as_deref(), Some("test-trace"));

        {
            let mut s = span("generation", "search");
            s.arg("gen", Json::Num(0.0));
        }
        event("checkpoint", "search", vec![("trials", Json::Num(4.0))]);

        // Worker wire round trip: drain → wire JSON → parse → ingest.
        let wire = local_spans_json();
        assert!(drain().is_empty(), "local_spans_json drains the sink");
        let parsed = Json::parse(&wire.to_string()).unwrap();
        ingest_remote(&parsed);
        let records = drain();
        assert_eq!(records.len(), 2);
        let gen = records.iter().find(|r| r.name == "generation").unwrap();
        assert!(gen.dur_us.is_some(), "span keeps its duration through the wire");
        assert!(
            gen.args.iter().any(|(k, v)| k == "trace" && v.as_str() == Some("test-trace")),
            "ingested spans are tagged with the remote trace id"
        );
        let ev = records.iter().find(|r| r.name == "checkpoint").unwrap();
        assert!(ev.dur_us.is_none(), "instant events stay instant");
        disable();
    }

    #[test]
    fn chrome_trace_document_is_well_formed() {
        let _g = gate();
        let records = vec![
            SpanRecord {
                name: "generation".to_string(),
                cat: "search".to_string(),
                ts_us: 1_000,
                dur_us: Some(500),
                pid: std::process::id(),
                tid: 1,
                args: vec![("gen".to_string(), Json::Num(0.0))],
            },
            SpanRecord {
                name: "shard".to_string(),
                cat: "eval".to_string(),
                ts_us: 1_100,
                dur_us: Some(200),
                pid: std::process::id().wrapping_add(1),
                tid: 1,
                args: vec![],
            },
            SpanRecord {
                name: "mark".to_string(),
                cat: "search".to_string(),
                ts_us: 1_600,
                dur_us: None,
                pid: std::process::id(),
                tid: 1,
                args: vec![],
            },
        ];
        let doc = chrome_trace(&records, "abc-123");
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(
            parsed.get("metadata").and_then(|m| m.get("trace_id")).and_then(Json::as_str),
            Some("abc-123")
        );
        let events = parsed.get("traceEvents").unwrap().items();
        // two process_name metadata events (two pids) + three records
        assert_eq!(events.len(), 5);
        let phases: Vec<&str> =
            events.iter().filter_map(|e| e.get("ph").and_then(Json::as_str)).collect();
        assert_eq!(phases.iter().filter(|p| **p == "M").count(), 2);
        assert_eq!(phases.iter().filter(|p| **p == "X").count(), 2);
        assert_eq!(phases.iter().filter(|p| **p == "i").count(), 1);
        for e in events {
            assert!(e.get("pid").is_some() && e.get("tid").is_some());
        }
        let text = summary(&records);
        assert!(text.contains("search/generation"));
        assert!(text.contains("eval/shard"));
    }
}
