//! xoshiro256** PRNG seeded via SplitMix64 (Blackman & Vigna).
//!
//! Deterministic, fast, and dependency-free; all stochastic components of
//! the search (genome sampling, crossover, mutation, data generation,
//! weight init) derive from this so experiments replay exactly.

use anyhow::{Context, Result};

use super::Json;

/// A seedable xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller normal
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Derive an independent child stream (for per-trial determinism that
    /// is stable under scheduling order).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Next raw 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift bounded sampling (Lemire); bias negligible for our n
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        // u1 in (0,1] to avoid ln(0)
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Standard normal as `f32`.
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with `N(0, sigma^2)` samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32() * sigma;
        }
    }

    /// Sample an index from a slice uniformly.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            items.swap(i, self.below(i + 1));
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx
    }

    /// Serialise the exact generator state. The `u64` state words (and the
    /// cached Box–Muller spare, when present) travel as 16-digit hex
    /// strings because `Json::Num` is an `f64` and cannot carry 64 bits
    /// losslessly — a shard worker replaying this stream in another
    /// process must reproduce it bit for bit.
    pub fn to_json(&self) -> Json {
        let mut words: Vec<Json> = self
            .s
            .iter()
            .map(|w| Json::Str(format!("{w:016x}")))
            .collect();
        if let Some(spare) = self.spare {
            words.push(Json::Str(format!("{:016x}", spare.to_bits())));
        }
        Json::Arr(words)
    }

    /// Restore a generator serialised by [`Rng::to_json`].
    pub fn from_json(j: &Json) -> Result<Rng> {
        let words = j.items();
        anyhow::ensure!(
            words.len() == 4 || words.len() == 5,
            "rng state must hold 4 words (+ optional spare), got {}",
            words.len()
        );
        let word = |i: usize| -> Result<u64> {
            let s = words[i]
                .as_str()
                .with_context(|| format!("rng state word {i} is not a string"))?;
            u64::from_str_radix(s, 16).with_context(|| format!("rng state word {i}: `{s}`"))
        };
        Ok(Rng {
            s: [word(0)?, word(1)?, word(2)?, word(3)?],
            spare: if words.len() == 5 {
                Some(f64::from_bits(word(4)?))
            } else {
                None
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_replay() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut mean = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            mean += u;
        }
        mean /= n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let (mut m, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            m += z;
            m2 += z * z;
        }
        m /= n as f64;
        m2 /= n as f64;
        assert!(m.abs() < 0.02, "mean={m}");
        assert!((m2 - 1.0).abs() < 0.03, "var={m2}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(11);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    /// A serialised generator replays the identical stream in another
    /// process (the shard-worker contract), including mid-stream state
    /// with a cached Box–Muller spare.
    #[test]
    fn json_state_round_trips_exactly() {
        let mut r = Rng::new(77);
        // advance into an interesting state: odd number of normals leaves
        // a cached spare behind
        for _ in 0..13 {
            r.next_u64();
        }
        r.normal();
        let text = r.to_json().to_string();
        let mut back = Rng::from_json(&Json::parse(&text).unwrap()).unwrap();
        // both draw normals first (exercises the spare), then raw words
        for _ in 0..8 {
            assert_eq!(r.normal().to_bits(), back.normal().to_bits());
        }
        for _ in 0..64 {
            assert_eq!(r.next_u64(), back.next_u64());
        }
        // garbage is rejected, not panicked on
        assert!(Rng::from_json(&Json::parse("[1,2]").unwrap()).is_err());
        assert!(Rng::from_json(&Json::parse("[\"zz\",\"0\",\"0\",\"0\"]").unwrap()).is_err());
    }
}
