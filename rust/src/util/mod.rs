//! Small shared utilities: deterministic RNG and streaming statistics.
//!
//! The whole reproduction is seeded end-to-end; we use our own SplitMix64 /
//! xoshiro256** instead of an external crate so that every published number
//! is bit-reproducible from a single `u64` seed across platforms.

pub mod json;
mod rng;
mod stats;

pub use json::Json;
pub use rng::Rng;
pub use stats::OnlineStats;
