//! Streaming mean/variance (Welford) used by metrics and data
//! normalisation, plus the workspace's one quantile implementation.
//!
//! Every latency quantile in the tree — the serving histogram's bucketed
//! p50/p99, the bench harness's sorted-sample percentiles — routes
//! through the ceil-rank helpers below, so "p99" means the same
//! (conservative, never-interpolating) thing everywhere.

/// 1-based conservative rank of quantile `q` in a population of `total`
/// observations: the smallest rank whose cumulative share is ≥ `q`
/// (`⌈q·total⌉`, clamped into `1..=total`). Never interpolates — the
/// reported quantile is always a value that was actually observed (or,
/// for bucketed data, a bucket bound that bounds it from above).
pub fn ceil_rank(total: u64, q: f64) -> u64 {
    ((q * total as f64).ceil() as u64).clamp(1, total.max(1))
}

/// Quantile of an ascending-sorted sample via [`ceil_rank`]. Returns
/// `NaN` on an empty sample.
pub fn sorted_quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    sorted[(ceil_rank(sorted.len() as u64, q) - 1) as usize]
}

/// Index of the bucket containing the [`ceil_rank`] of `q` over a
/// snapshot of bucket counts. `None` when the histogram is empty.
pub fn bucket_quantile_index(counts: &[u64], q: f64) -> Option<usize> {
    let total: u64 = counts.iter().sum();
    if total == 0 || counts.is_empty() {
        return None;
    }
    let rank = ceil_rank(total, q);
    let mut seen = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        seen += c;
        if seen >= rank {
            return Some(i);
        }
    }
    Some(counts.len() - 1)
}

/// Online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    /// Empty accumulator.
    pub fn new() -> Self {
        OnlineStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean (0 when empty).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (0 when n < 2).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest observation.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest observation.
    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_closed_form() {
        let mut s = OnlineStats::new();
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        for &x in &xs {
            s.push(x);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.variance() - 2.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn empty_is_safe() {
        let s = OnlineStats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.variance(), 0.0);
    }

    /// The bench call site: ceil-rank over an ascending sorted sample —
    /// p50 of [1..=4] is the 2nd value, p99 the last, and a singleton
    /// answers every quantile with itself.
    #[test]
    fn sorted_quantiles_are_conservative_sample_values() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(sorted_quantile(&xs, 0.5), 2.0);
        assert_eq!(sorted_quantile(&xs, 0.75), 3.0);
        assert_eq!(sorted_quantile(&xs, 0.99), 4.0);
        // q=0 still clamps to rank 1 (the minimum), never index -1
        assert_eq!(sorted_quantile(&xs, 0.0), 1.0);
        assert_eq!(sorted_quantile(&[7.5], 0.5), 7.5);
        assert!(sorted_quantile(&[], 0.5).is_nan());
    }

    /// The serving-histogram call site: the rank lands in the first
    /// bucket whose cumulative count reaches it, and an empty histogram
    /// has no quantile at all.
    #[test]
    fn bucket_quantiles_pick_the_covering_bucket() {
        // counts: 5 in bucket 0, 4 in bucket 1, 1 in bucket 3
        let counts = [5u64, 4, 0, 1];
        // rank(p50) = 5 → bucket 0; rank(p90) = 9 → bucket 1;
        // rank(p99) = 10 → bucket 3
        assert_eq!(bucket_quantile_index(&counts, 0.5), Some(0));
        assert_eq!(bucket_quantile_index(&counts, 0.9), Some(1));
        assert_eq!(bucket_quantile_index(&counts, 0.99), Some(3));
        assert_eq!(bucket_quantile_index(&[0u64; 4], 0.5), None);
        assert_eq!(bucket_quantile_index(&[], 0.5), None);
        // a single observation answers every quantile from its bucket
        assert_eq!(bucket_quantile_index(&[0, 1, 0], 0.01), Some(1));
        assert_eq!(bucket_quantile_index(&[0, 1, 0], 0.99), Some(1));
    }

    /// Both call sites agree on the rank itself.
    #[test]
    fn ceil_rank_clamps_into_the_population() {
        assert_eq!(ceil_rank(100, 0.5), 50);
        assert_eq!(ceil_rank(100, 0.99), 99);
        assert_eq!(ceil_rank(100, 1.0), 100);
        assert_eq!(ceil_rank(1, 0.0), 1);
        assert_eq!(ceil_rank(0, 0.5), 1, "degenerate population still yields a rank");
    }
}
