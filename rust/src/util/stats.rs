//! Streaming mean/variance (Welford) used by metrics and data normalisation.

/// Online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    /// Empty accumulator.
    pub fn new() -> Self {
        OnlineStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean (0 when empty).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (0 when n < 2).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest observation.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest observation.
    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_closed_form() {
        let mut s = OnlineStats::new();
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        for &x in &xs {
            s.push(x);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.variance() - 2.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn empty_is_safe() {
        let s = OnlineStats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.variance(), 0.0);
    }
}
