//! Minimal JSON value type, recursive-descent parser, and writer.
//!
//! Built in-tree because the image has no crate network access (see
//! Cargo.toml). Used for the AOT ABI manifest (`artifacts/manifest.json`),
//! trial-database checkpoints, and report emission. Covers the full JSON
//! grammar except `\u` surrogate pairs beyond the BMP (not needed for our
//! ASCII artifacts).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap so emission order is deterministic.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------- constructors ----------

    /// Object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Array of numbers.
    pub fn nums<I: IntoIterator<Item = f64>>(it: I) -> Json {
        Json::Arr(it.into_iter().map(Json::Num).collect())
    }

    // ---------- accessors ----------

    /// Member of an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array elements.
    pub fn items(&self) -> &[Json] {
        match self {
            Json::Arr(v) => v,
            _ => &[],
        }
    }

    /// Numeric value.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value honouring the writer's non-finite convention: this
    /// module emits NaN/±inf as `null` (JSON has no such literals), so
    /// readers of *required* numeric fields map `null` back to NaN rather
    /// than shrinking arrays or failing the whole document. Readers of
    /// *optional* fields keep [`Json::as_f64`], where `null` means absent.
    pub fn as_f64_or_nan(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            Json::Null => Some(f64::NAN),
            _ => None,
        }
    }

    /// Integer value (rounded).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f.round() as usize)
    }

    /// String value.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Bool value.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // ---------- writer ----------

    /// Serialise compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity literal; `write!` would emit
                    // `NaN`/`inf`, which `Json::parse` rejects — one such
                    // value used to poison a whole document (the persistent
                    // eval-cache snapshot). Emit `null`; readers that care
                    // map it back to NaN (see eval/cache.rs).
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---------- parser ----------

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing garbage at byte {pos}"));
        }
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, lit: &str) -> Result<(), String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        Err(format!("expected `{lit}` at byte {pos}", pos = *pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end of input".into());
    }
    match b[*pos] {
        b'n' => expect(b, pos, "null").map(|_| Json::Null),
        b't' => expect(b, pos, "true").map(|_| Json::Bool(true)),
        b'f' => expect(b, pos, "false").map(|_| Json::Bool(false)),
        b'"' => parse_string(b, pos).map(Json::Str),
        b'[' => {
            *pos += 1;
            let mut arr = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Json::Arr(arr));
            }
            loop {
                arr.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(arr));
                    }
                    _ => return Err(format!("expected , or ] at byte {pos}", pos = *pos)),
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut map = BTreeMap::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                expect(b, pos, ":")?;
                let val = parse_value(b, pos)?;
                map.insert(key, val);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(map));
                    }
                    _ => return Err(format!("expected , or }} at byte {pos}", pos = *pos)),
                }
            }
        }
        _ => parse_number(b, pos),
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at byte {pos}", pos = *pos));
    }
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|e| e.to_string())?;
                        let code =
                            u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                        out.push(char::from_u32(code).ok_or("bad \\u escape")?);
                        *pos += 4;
                    }
                    _ => return Err("bad escape".into()),
                }
                *pos += 1;
            }
            _ => {
                // copy one UTF-8 scalar
                let s = &b[*pos..];
                let len = utf8_len(s[0]);
                out.push_str(
                    std::str::from_utf8(&s[..len]).map_err(|e| e.to_string())?,
                );
                *pos += len;
            }
        }
    }
    Err("unterminated string".into())
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| format!("bad number at byte {start}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().items().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
        assert_eq!(v.get("a").unwrap().items()[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parses_the_real_manifest() {
        // real AOT manifest when built, else the always-present fixture one
        let real = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        let fixture = concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/xla/tests/fixtures/manifest.json"
        );
        let text = std::fs::read_to_string(real)
            .or_else(|_| std::fs::read_to_string(fixture))
            .expect("no manifest.json found (fixtures are checked in)");
        let man = Json::parse(&text).unwrap();
        assert_eq!(
            man.get("constants").unwrap().get("pad").unwrap().as_usize(),
            Some(128)
        );
        assert!(man.get("artifacts").unwrap().get("train_step").is_some());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"η φ π\"").unwrap();
        assert_eq!(v.as_str(), Some("η φ π"));
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(128.0).to_string(), "128");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn non_finite_numbers_emit_null_and_stay_parseable() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let doc = Json::obj(vec![("v", Json::Num(bad)), ("ok", Json::Num(1.5))]);
            let text = doc.to_string();
            // the document as a whole must survive a round trip
            let back = Json::parse(&text).unwrap_or_else(|e| {
                panic!("non-finite {bad} produced unparseable JSON `{text}`: {e}")
            });
            assert_eq!(back.get("v"), Some(&Json::Null));
            assert_eq!(back.get("ok").and_then(Json::as_f64), Some(1.5));
        }
    }

    #[test]
    fn object_emission_is_deterministic() {
        let a = Json::parse(r#"{"z":1,"a":2}"#).unwrap();
        let b = Json::parse(r#"{"a":2,"z":1}"#).unwrap();
        assert_eq!(a.to_string(), b.to_string());
    }
}
