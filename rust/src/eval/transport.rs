//! The shard-protocol transport seam.
//!
//! [`super::shard`] holds the transport-agnostic protocol core — task
//! encoding, the lease/heartbeat state machine, exactly-once reclaim,
//! dispatch-order merge — and talks to the outside world only through
//! the [`ShardTransport`] trait defined here: publish/claim/heartbeat/
//! result/sentinel operations over *some* shared medium. Two media
//! exist:
//!
//! * [`FsTransport`] (this module) — the original shared-run-directory
//!   protocol: claims are atomic renames, heartbeats are sidecar files,
//!   results are hard-link first-writer-wins publishes. Bit-for-bit the
//!   same on-disk layout as before the trait existed, so drivers and
//!   workers of mixed vintage interoperate on one run directory.
//! * [`super::tcp`] — a driver-hosted TCP task server speaking the
//!   shared [`crate::net`] HTTP framing, for worker fleets with no
//!   shared filesystem.
//!
//! Every operation is keyed by the shard *name*; names are unique per
//! driver instance (label + run tag + batch + index), so transports
//! never need to understand their contents.

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{Context, Result};

/// The shared run directory: path helpers + the shutdown sentinel.
#[derive(Debug, Clone)]
pub struct RunDir {
    root: PathBuf,
}

impl RunDir {
    /// Wrap a root path (no I/O; see [`RunDir::ensure`]).
    pub fn new(root: impl Into<PathBuf>) -> RunDir {
        RunDir { root: root.into() }
    }

    /// Create the protocol subdirectories (idempotent; both driver and
    /// workers call this so startup order does not matter).
    pub fn ensure(&self) -> Result<()> {
        for dir in [self.queue(), self.claims(), self.results(), self.tmp()] {
            std::fs::create_dir_all(&dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
        Ok(())
    }

    /// The run-dir root.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Pending shard task files.
    pub fn queue(&self) -> PathBuf {
        self.root.join("queue")
    }

    /// Claimed shards + heartbeat sidecars.
    pub fn claims(&self) -> PathBuf {
        self.root.join("claims")
    }

    /// Completed per-shard result files.
    pub fn results(&self) -> PathBuf {
        self.root.join("results")
    }

    /// Staging area for atomic publishes.
    pub fn tmp(&self) -> PathBuf {
        self.root.join("tmp")
    }

    /// The run manifest the CLI driver writes for its workers.
    pub fn manifest_path(&self) -> PathBuf {
        self.root.join("run.json")
    }

    fn shutdown_path(&self) -> PathBuf {
        self.root.join("shutdown")
    }

    /// Tell every worker on this run directory to exit.
    pub fn request_shutdown(&self) -> Result<()> {
        std::fs::write(self.shutdown_path(), b"shutdown\n")
            .with_context(|| format!("writing {}", self.shutdown_path().display()))
    }

    /// Has a shutdown been requested?
    pub fn is_shutdown(&self) -> bool {
        self.shutdown_path().exists()
    }

    /// Remove a stale shutdown sentinel (a fresh driver reusing the run
    /// directory of a finished run must not stop its new workers).
    pub fn clear_shutdown(&self) {
        let _ = std::fs::remove_file(self.shutdown_path());
    }

    /// Write `text` to `dest` atomically (staged in `tmp/`, renamed into
    /// place), so queue/result consumers never observe a partial file.
    /// Overwrites an existing `dest`.
    pub fn publish(&self, dest: &Path, text: &str) -> Result<()> {
        let tmp = self.stage(dest, text)?;
        std::fs::rename(&tmp, dest)
            .with_context(|| format!("publishing {}", dest.display()))
    }

    /// Atomic **first-writer-wins** publish: links the staged file into
    /// place and reports `false` (without touching `dest`) when another
    /// publisher already won — there is no exists-then-rename window in
    /// which a late writer could clobber a consumed result.
    pub fn publish_new(&self, dest: &Path, text: &str) -> Result<bool> {
        let tmp = self.stage(dest, text)?;
        let outcome = match std::fs::hard_link(&tmp, dest) {
            Ok(()) => Ok(true),
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => Ok(false),
            Err(e) => {
                Err(anyhow::Error::new(e).context(format!("publishing {}", dest.display())))
            }
        };
        let _ = std::fs::remove_file(&tmp);
        outcome
    }

    fn stage(&self, dest: &Path, text: &str) -> Result<PathBuf> {
        let base = dest
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_else(|| "file".to_string());
        let tmp = self
            .tmp()
            .join(format!("{base}.{}.tmp", std::process::id()));
        std::fs::write(&tmp, text).with_context(|| format!("writing {}", tmp.display()))?;
        Ok(tmp)
    }
}

/// Age of a file's mtime. `None` strictly means the file is missing (or
/// unstattable); an mtime in the future — clock skew, NTP steps — reads
/// as age zero, so a live worker's lease can never look stale because of
/// a clock adjustment.
fn mtime_age(path: &Path) -> Option<Duration> {
    let modified = std::fs::metadata(path).ok()?.modified().ok()?;
    Some(modified.elapsed().unwrap_or(Duration::ZERO))
}

/// Sorted shard file names currently queued (a missing or unreadable
/// queue directory reads as empty — `ensure()` recreates it).
pub(crate) fn queue_names(dir: &RunDir) -> Vec<String> {
    let mut names: Vec<String> = std::fs::read_dir(dir.queue())
        .into_iter()
        .flatten()
        .flatten()
        .filter_map(|e| e.file_name().into_string().ok())
        .filter(|n| n.ends_with(".json"))
        .collect();
    names.sort();
    names
}

/// Driver-side view of one shard's claim.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LeaseStatus {
    /// Not claimed: still queued, or between a reclaim and a re-claim.
    Unclaimed,
    /// Claimed by some worker. `heartbeat_age` is `None` when the
    /// claimant has not yet produced a heartbeat (the driver grants one
    /// full lease of grace from first observation).
    Claimed {
        /// Age of the freshest heartbeat, if any exists.
        heartbeat_age: Option<Duration>,
    },
}

/// A task handed to a worker by [`ShardTransport::claim_next`].
#[derive(Debug)]
pub struct ClaimedTask {
    /// The shard name (claim is already held; the worker must publish a
    /// result or die and be reclaimed).
    pub name: String,
    /// The task text, or why it could not be fetched — the worker
    /// publishes the failure so the driver fails the shard loudly
    /// instead of waiting out the lease.
    pub task: Result<String>,
}

/// The medium the shard protocol runs over. Implementations must make
/// [`claim_next`](ShardTransport::claim_next) hand each queued shard to
/// exactly one caller and [`publish_result`](ShardTransport::publish_result)
/// first-writer-wins; everything else (lease accounting, reclaim policy,
/// dispatch-order merge, determinism) lives in the protocol core.
pub trait ShardTransport: Send + Sync {
    /// Human-readable endpoint for logs and [`super::ShardError::Stalled`].
    fn describe(&self) -> String;

    /// The run manifest text, if this transport carries one.
    fn manifest(&self) -> Result<Option<String>>;

    /// Has a shutdown been requested?
    fn is_shutdown(&self) -> bool;

    /// Tell every worker on this transport to exit.
    fn request_shutdown(&self) -> Result<()>;

    // ---- driver side ----

    /// Publish a shard task into the queue (atomic: a worker sees the
    /// whole task or nothing).
    fn publish_task(&self, name: &str, text: &str) -> Result<()>;

    /// The shard's published result text, if one has landed. `None`
    /// simply means "not yet" — the driver polls.
    fn take_result(&self, name: &str) -> Result<Option<String>>;

    /// Drop every protocol artifact of a resolved shard (task, claim,
    /// heartbeat, result). Best-effort; names are run-unique so leftover
    /// artifacts are garbage, never a hazard.
    fn scrub(&self, name: &str);

    /// Claim + heartbeat status for the lease state machine.
    fn lease(&self, name: &str) -> LeaseStatus;

    /// Return a dead claim to the queue. Exactly-once: of all concurrent
    /// reclaimers (and the claim holder's own completion) at most one
    /// wins; returns whether this caller was it.
    fn reclaim(&self, name: &str) -> bool;

    /// Remove straggler results carrying this driver's run tag (a
    /// reclaimed zombie may publish after the consumed copy was
    /// scrubbed; nothing will ever read it).
    fn sweep_results(&self, run_tag: &str);

    // ---- worker side ----

    /// Claim the next queued shard, if any. The claim is held (and its
    /// lease running) from the moment this returns `Some`.
    fn claim_next(&self) -> Result<Option<ClaimedTask>>;

    /// Refresh the claim's lease.
    fn heartbeat(&self, name: &str);

    /// First-writer-wins result publish; `false` means another worker's
    /// result already landed (this one is discarded, which is safe:
    /// results are deterministic).
    fn publish_result(&self, name: &str, text: &str) -> Result<bool>;

    /// Release a completed claim (best-effort tidy-up; the driver's
    /// scrub covers crashed workers).
    fn finish_claim(&self, name: &str);

    /// Adopt a trace ID for span propagation: networked transports echo
    /// it on every subsequent request (the `X-Snac-Trace` header) so the
    /// driver can attribute protocol traffic to the run's trace. Default
    /// no-op — file transports have no request to tag, and tracing never
    /// changes protocol behaviour.
    fn set_trace(&self, _id: &str) {}
}

/// The original shared-filesystem transport: every operation is a file
/// operation under a [`RunDir`], with atomicity from rename/hard-link.
/// On-disk layout and semantics are bit-for-bit the pre-trait protocol.
#[derive(Debug, Clone)]
pub struct FsTransport {
    dir: RunDir,
}

impl FsTransport {
    /// Open (and create) the protocol directories under `run_dir`.
    pub fn new(run_dir: impl Into<PathBuf>) -> Result<FsTransport> {
        let dir = RunDir::new(run_dir);
        dir.ensure()?;
        Ok(FsTransport { dir })
    }

    /// The underlying run directory.
    pub fn dir(&self) -> &RunDir {
        &self.dir
    }

    fn hb_path(&self, name: &str) -> PathBuf {
        self.dir.claims().join(format!("{name}.hb"))
    }
}

impl ShardTransport for FsTransport {
    fn describe(&self) -> String {
        self.dir.root().display().to_string()
    }

    fn manifest(&self) -> Result<Option<String>> {
        Ok(std::fs::read_to_string(self.dir.manifest_path()).ok())
    }

    fn is_shutdown(&self) -> bool {
        self.dir.is_shutdown()
    }

    fn request_shutdown(&self) -> Result<()> {
        self.dir.request_shutdown()
    }

    fn publish_task(&self, name: &str, text: &str) -> Result<()> {
        self.dir.publish(&self.dir.queue().join(name), text)
    }

    fn take_result(&self, name: &str) -> Result<Option<String>> {
        // any read failure reads as "not yet": the file may be missing,
        // mid-rename, or transiently unreadable — the driver polls
        Ok(std::fs::read_to_string(self.dir.results().join(name)).ok())
    }

    fn scrub(&self, name: &str) {
        let _ = std::fs::remove_file(self.dir.results().join(name));
        let _ = std::fs::remove_file(self.dir.queue().join(name));
        let _ = std::fs::remove_file(self.dir.claims().join(name));
        let _ = std::fs::remove_file(self.hb_path(name));
    }

    fn lease(&self, name: &str) -> LeaseStatus {
        if !self.dir.claims().join(name).exists() {
            return LeaseStatus::Unclaimed;
        }
        LeaseStatus::Claimed {
            heartbeat_age: mtime_age(&self.hb_path(name)),
        }
    }

    fn reclaim(&self, name: &str) -> bool {
        // claim-by-rename in reverse: only one reclaimer can win, and
        // the task file travels back into the queue intact
        let won = std::fs::rename(
            self.dir.claims().join(name),
            self.dir.queue().join(name),
        )
        .is_ok();
        if won {
            let _ = std::fs::remove_file(self.hb_path(name));
        }
        won
    }

    fn sweep_results(&self, run_tag: &str) {
        for entry in std::fs::read_dir(self.dir.results())
            .into_iter()
            .flatten()
            .flatten()
        {
            if entry.file_name().to_string_lossy().contains(run_tag) {
                let _ = std::fs::remove_file(entry.path());
            }
        }
    }

    fn claim_next(&self) -> Result<Option<ClaimedTask>> {
        for name in queue_names(&self.dir) {
            let claim = self.dir.claims().join(&name);
            // claim-by-rename: exactly one worker wins this shard
            if std::fs::rename(self.dir.queue().join(&name), &claim).is_err() {
                continue;
            }
            self.heartbeat(&name);
            let task = match std::fs::read_to_string(&claim) {
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                    // the claim vanished under us: the driver resolved
                    // this shard through another worker's result (our
                    // lease was reclaimed while we stalled) — the shard
                    // is no longer ours, so hand back nothing
                    let _ = std::fs::remove_file(self.hb_path(&name));
                    continue;
                }
                Err(e) => Err(anyhow::Error::new(e)
                    .context(format!("reading shard task {}", claim.display()))),
                Ok(text) => Ok(text),
            };
            return Ok(Some(ClaimedTask { name, task }));
        }
        Ok(None)
    }

    fn heartbeat(&self, name: &str) {
        let _ = std::fs::write(self.hb_path(name), b"hb\n");
    }

    fn publish_result(&self, name: &str, text: &str) -> Result<bool> {
        self.dir.publish_new(&self.dir.results().join(name), text)
    }

    fn finish_claim(&self, name: &str) {
        let _ = std::fs::remove_file(self.dir.claims().join(name));
        let _ = std::fs::remove_file(self.hb_path(name));
    }
}
