//! Multi-process generation sharding: the transport-agnostic protocol
//! core of the distributed work queue.
//!
//! The search loop's throughput ceiling is trial evaluation, and one
//! process only holds so many cores. This module scales the
//! [`super::ParallelEvaluator`] batch seam past a single process: a
//! **driver** ([`ShardDriver`]) partitions each generation's
//! `Vec<EvalRequest>` into shard task files, N `snac-pack worker`
//! processes ([`run_worker`] / [`run_worker_on`]) pull shards, evaluate
//! them with their local thread pools, and publish per-shard results
//! that the driver merges back — in dispatch order — into the shared
//! [`EvalCache`] and the caller's trial-ordered stream.
//!
//! Everything here — task encoding, the lease/heartbeat state machine,
//! exactly-once reclaim, manifest fingerprinting, the dispatch-order
//! merge — is medium-agnostic: drivers and workers touch the outside
//! world only through the [`ShardTransport`] trait
//! ([`super::transport`]). Two transports exist: [`FsTransport`] (the
//! original shared-run-directory protocol, whose on-disk layout is
//! documented on the trait) and [`super::tcp`] (a driver-hosted TCP
//! task server for fleets with no shared filesystem).
//!
//! # Lease protocol
//!
//! A worker *claims* a shard through the transport — exactly one
//! claimant wins, and the task travels with the claim (a reclaim needs
//! no other state). Immediately after claiming, and then every
//! [`WorkerOptions::heartbeat`], the worker refreshes the claim's
//! heartbeat; the driver treats a claim whose heartbeat is older than
//! [`ShardTimings::lease_timeout`] (or that never produced one within a
//! lease of being first observed) as dead and *reclaims* it back into
//! the queue, where the next live worker picks it up. A zombie worker
//! that later publishes its result anyway is harmless: results are
//! deterministic, publishes are first-writer-wins, and the driver
//! consumes exactly one result per shard.
//!
//! # Determinism
//!
//! The merged outcome is bit-identical to a single-process
//! [`super::ParallelEvaluator`] run for any shard/worker count — over
//! any transport — because every decision that affects numbers is made
//! driver-side before dispatch, exactly as the in-process pool makes it:
//!
//! 1. per-trial RNGs are forked in trial-id order *before* partitioning
//!    and travel inside the shard files (exact state, hex-encoded);
//! 2. duplicate genomes are collapsed to their first dispatch index
//!    *before* sharding, so a duplicate never trains twice across shards;
//! 3. shards are contiguous chunks of the collapsed dispatch list, so
//!    "first failed dispatch" is shard-count-invariant;
//! 4. emission routes through the same trial-ordered drain as the
//!    in-process pool ([`super::parallel::drain_ready`]): the caller (and
//!    its non-`Send` progress sinks) observes the identical stream.
//!
//! Only wall-clock timings differ.

use std::collections::HashSet;
use std::fmt;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use anyhow::{Context, Result};

use crate::nn::Genome;
use crate::objectives::ObjectiveKind;
use crate::telemetry;
use crate::util::Json;

use super::parallel::drain_ready;
use super::transport::{FsTransport, LeaseStatus, ShardTransport};
use super::{EvalCache, EvalPool, EvalRequest, EvaluatedTrial, TrialEvaluation};

/// What a worker must reproduce to evaluate a shard: the training
/// protocol slice that varies per pipeline stage. Everything else
/// (dataset, search space, device, precision) comes from the run
/// manifest's preset and is stage-invariant.
#[derive(Debug, Clone, PartialEq)]
pub struct StageSpec {
    /// Objective set to price trials with (workers train their own
    /// surrogate — deterministically, from the preset seed — when the
    /// set needs one).
    pub objectives: Vec<ObjectiveKind>,
    /// Training epochs per trial.
    pub epochs: usize,
}

impl StageSpec {
    /// Serialise for a shard task file.
    pub fn to_json(&self) -> Json {
        let names: Vec<&str> = self.objectives.iter().map(|o| o.name()).collect();
        Json::obj(vec![
            ("objectives", Json::Str(names.join(","))),
            ("epochs", Json::Num(self.epochs as f64)),
        ])
    }

    /// Parse back from a shard task file.
    pub fn from_json(j: &Json) -> Result<StageSpec> {
        Ok(StageSpec {
            objectives: ObjectiveKind::parse_set(
                j.get("objectives")
                    .and_then(Json::as_str)
                    .context("stage missing objectives")?,
            )?,
            epochs: j
                .get("epochs")
                .and_then(Json::as_usize)
                .context("stage missing epochs")?,
        })
    }
}

/// Driver-side timing knobs for the lease protocol.
#[derive(Debug, Clone)]
pub struct ShardTimings {
    /// A claim whose heartbeat is older than this is reclaimed.
    pub lease_timeout: Duration,
    /// Driver poll cadence while waiting on shard results.
    pub poll: Duration,
    /// No result, no live claim, and no fresh heartbeat for this long →
    /// the batch fails with [`ShardError::Stalled`] instead of hanging a
    /// search forever on a queue nobody serves.
    pub stall_timeout: Duration,
}

impl Default for ShardTimings {
    fn default() -> Self {
        ShardTimings {
            lease_timeout: Duration::from_secs(30),
            poll: Duration::from_millis(25),
            stall_timeout: Duration::from_secs(600),
        }
    }
}

/// Typed shard-protocol failures (carried inside `anyhow::Error`;
/// downcast to branch on them).
#[derive(Debug)]
pub enum ShardError {
    /// A per-shard result existed but could not be parsed or did not
    /// match the shard's request list. Sibling shards' results are still
    /// committed to the cache before this propagates.
    CorruptResult {
        /// The shard file name (e.g. `search-nac-b0003-s01.json`).
        shard: String,
        /// Why it was rejected.
        detail: String,
    },
    /// A worker picked the shard up but could not evaluate it at all
    /// (e.g. the task file was unreadable on its side).
    WorkerFailed {
        /// The shard file name.
        shard: String,
        /// The worker-reported failure.
        detail: String,
    },
    /// No worker served the queue for the whole stall timeout.
    Stalled {
        /// The queue endpoint nobody is serving
        /// ([`ShardTransport::describe`]): a run directory for the
        /// filesystem transport, a listen address for TCP.
        endpoint: String,
        /// How long the driver waited.
        waited: Duration,
    },
}

impl fmt::Display for ShardError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ShardError::CorruptResult { shard, detail } => {
                write!(f, "corrupt result file for shard `{shard}`: {detail}")
            }
            ShardError::WorkerFailed { shard, detail } => {
                write!(f, "worker failed on shard `{shard}`: {detail}")
            }
            ShardError::Stalled { endpoint, waited } => write!(
                f,
                "no worker served {endpoint} for {waited:.0?} — start one with `snac-pack worker`"
            ),
        }
    }
}

impl std::error::Error for ShardError {}

/// Cheap content fingerprint (FNV-1a) of a run manifest. The driver
/// stamps its expectation from `run.json`; workers echo the fingerprint
/// of the manifest they actually loaded in every result file — so a
/// worker that booted from a stale `run.json` (reused run directory,
/// races around driver startup) fails the batch *loudly* as a corrupt
/// result instead of silently committing numbers computed under the
/// wrong configuration.
pub fn manifest_fingerprint(text: &str) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in text.as_bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    format!("{h:016x}")
}

// ---------------------------------------------------------------------------
// shard task / result codecs
// ---------------------------------------------------------------------------

struct ShardTask {
    shard: String,
    stage: StageSpec,
    requests: Vec<EvalRequest>,
}

impl ShardTask {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shard", Json::Str(self.shard.clone())),
            ("stage", self.stage.to_json()),
            (
                "requests",
                Json::Arr(self.requests.iter().map(EvalRequest::to_json).collect()),
            ),
        ])
    }

    fn from_json(j: &Json) -> Result<ShardTask> {
        Ok(ShardTask {
            shard: j
                .get("shard")
                .and_then(Json::as_str)
                .context("task missing shard name")?
                .to_string(),
            stage: StageSpec::from_json(j.get("stage").context("task missing stage")?)?,
            requests: j
                .get("requests")
                .context("task missing requests")?
                .items()
                .iter()
                .map(EvalRequest::from_json)
                .collect::<Result<_>>()?,
        })
    }
}

fn with_manifest(mut doc: Json, manifest: Option<&str>) -> Json {
    if let (Json::Obj(map), Some(fp)) = (&mut doc, manifest) {
        map.insert("manifest".to_string(), Json::Str(fp.to_string()));
    }
    doc
}

fn result_to_json(
    shard: &str,
    rows: &[(usize, Result<TrialEvaluation, String>)],
    manifest: Option<&str>,
    spans: Option<Json>,
) -> Json {
    let rows = rows
        .iter()
        .map(|(trial_id, outcome)| match outcome {
            Ok(evaluation) => Json::obj(vec![
                ("trial_id", Json::Num(*trial_id as f64)),
                ("evaluation", evaluation.to_json()),
            ]),
            Err(msg) => Json::obj(vec![
                ("trial_id", Json::Num(*trial_id as f64)),
                ("error", Json::Str(msg.clone())),
            ]),
        })
        .collect();
    let mut doc = Json::obj(vec![
        ("shard", Json::Str(shard.to_string())),
        ("results", Json::Arr(rows)),
    ]);
    // the worker's span buffer rides the publication under a key the
    // row parser never reads — tracing cannot perturb trial numbers
    if let (Json::Obj(map), Some(spans)) = (&mut doc, spans) {
        map.insert("spans".to_string(), spans);
    }
    with_manifest(doc, manifest)
}

fn worker_failure_to_json(shard: &str, detail: &str, manifest: Option<&str>) -> Json {
    with_manifest(
        Json::obj(vec![
            ("shard", Json::Str(shard.to_string())),
            ("failed", Json::Str(detail.to_string())),
        ]),
        manifest,
    )
}

/// One parsed result row per request: the evaluation, or the worker's
/// per-trial error message.
type ShardRows = Vec<Result<TrialEvaluation, String>>;

/// Parsed result rows, positionally aligned with the shard's requests.
/// Inner `Err(detail)` = worker-level failure; outer `anyhow` error =
/// corrupt file (including a manifest-fingerprint mismatch: the worker
/// evaluated under a different run configuration).
fn parse_result_file(
    text: &str,
    expected: &[EvalRequest],
    expected_manifest: Option<&str>,
) -> Result<Result<ShardRows, String>> {
    let doc = Json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
    if let Some(expect) = expected_manifest {
        let got = doc.get("manifest").and_then(Json::as_str);
        anyhow::ensure!(
            got == Some(expect),
            "result produced under a different run manifest (fingerprint {:?}, driver has \
             {expect:?}) — a worker loaded a stale run.json",
            got
        );
    }
    if let Some(detail) = doc.get("failed").and_then(Json::as_str) {
        return Ok(Err(detail.to_string()));
    }
    let rows = doc.get("results").context("result file missing `results`")?.items();
    anyhow::ensure!(
        rows.len() == expected.len(),
        "result holds {} rows, shard has {} requests",
        rows.len(),
        expected.len()
    );
    let mut out = Vec::with_capacity(rows.len());
    for (row, req) in rows.iter().zip(expected) {
        let trial_id = row
            .get("trial_id")
            .and_then(Json::as_usize)
            .context("result row missing trial_id")?;
        anyhow::ensure!(
            trial_id == req.trial_id,
            "result row for trial {trial_id} does not match request trial {}",
            req.trial_id
        );
        if let Some(msg) = row.get("error").and_then(Json::as_str) {
            out.push(Err(msg.to_string()));
        } else {
            out.push(Ok(TrialEvaluation::from_json(
                row.get("evaluation").context("result row missing evaluation")?,
            )?));
        }
    }
    Ok(Ok(out))
}

// ---------------------------------------------------------------------------
// driver
// ---------------------------------------------------------------------------

/// Driver-side state for one in-flight shard.
struct ShardState {
    name: String,
    /// Dispatch index of this shard's first request (shards are
    /// contiguous chunks of the collapsed dispatch list).
    base: usize,
    requests: Vec<EvalRequest>,
    resolved: bool,
    /// When the driver first observed the current claim with no heartbeat
    /// — on initial claim *or* after a transient heartbeat loss — the
    /// claimant gets one full lease of grace from this instant before
    /// being declared dead.
    no_hb_since: Option<Instant>,
}

/// The driver side of the shard protocol: an [`EvalPool`] whose batches
/// are evaluated by `snac-pack worker` processes over a
/// [`ShardTransport`], merged back into the shared [`EvalCache`] under
/// the same determinism contract as the in-process pool.
pub struct ShardDriver {
    transport: Arc<dyn ShardTransport>,
    label: String,
    /// Per-driver-instance uniquifier baked into every shard file name
    /// (pid + wall-clock millis): a reused run directory can never serve
    /// a previous run's leftover result files as this run's — old names
    /// simply never match (file names carry no determinism; results are
    /// matched to requests positionally).
    run_tag: String,
    /// Fingerprint of the run manifest as it stood when this driver
    /// started (`None` when the transport carries no manifest, e.g.
    /// in-process protocol tests). Every result file must echo it.
    manifest: Option<String>,
    stage: StageSpec,
    shards: usize,
    cache: EvalCache,
    timings: ShardTimings,
    batch: AtomicUsize,
    evaluations: AtomicUsize,
    hits: AtomicUsize,
    reclaims: AtomicUsize,
}

impl ShardDriver {
    /// New driver over the filesystem transport rooted at `run_dir` (the
    /// common case; see [`ShardDriver::with_transport`] for the general
    /// form). `label` namespaces this driver's shard files (the pipeline
    /// runs several drivers over one run directory — `baseline`,
    /// `search-nac`, `search-snac` — strictly in sequence). `shards` is
    /// the per-generation partition count (clamped to the batch size at
    /// dispatch; `0` behaves as `1`).
    pub fn new(
        run_dir: &Path,
        label: &str,
        stage: StageSpec,
        shards: usize,
        cache: EvalCache,
        timings: ShardTimings,
    ) -> Result<ShardDriver> {
        Self::with_transport(
            Arc::new(FsTransport::new(run_dir)?),
            label,
            stage,
            shards,
            cache,
            timings,
        )
    }

    /// New driver over an arbitrary transport.
    pub fn with_transport(
        transport: Arc<dyn ShardTransport>,
        label: &str,
        stage: StageSpec,
        shards: usize,
        cache: EvalCache,
        timings: ShardTimings,
    ) -> Result<ShardDriver> {
        let millis = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis())
            .unwrap_or(0);
        let manifest = transport
            .manifest()?
            .map(|text| manifest_fingerprint(&text));
        Ok(ShardDriver {
            transport,
            label: label.to_string(),
            run_tag: format!("{:x}-{millis:x}", std::process::id()),
            manifest,
            stage,
            shards: shards.max(1),
            cache,
            timings,
            batch: AtomicUsize::new(0),
            evaluations: AtomicUsize::new(0),
            hits: AtomicUsize::new(0),
            reclaims: AtomicUsize::new(0),
        })
    }

    /// Shards reclaimed from dead workers so far.
    pub fn reclaims(&self) -> usize {
        self.reclaims.load(Ordering::Relaxed)
    }

    /// The per-generation shard count.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// The transport this driver dispatches over.
    pub fn transport(&self) -> &Arc<dyn ShardTransport> {
        &self.transport
    }

    /// Evaluate one generation through the worker fleet, streaming
    /// per-trial results to `on_trial` in trial-id order (the
    /// [`super::ParallelEvaluator::evaluate_stream`] contract).
    pub fn evaluate_stream<F>(&self, requests: Vec<EvalRequest>, mut on_trial: F) -> Result<()>
    where
        F: FnMut(EvaluatedTrial),
    {
        // ---- collapse to first-occurrence, uncached genomes (identical
        // to the in-process pool, so shard contents are deterministic) ----
        let mut pending: Vec<EvalRequest> = Vec::new();
        let mut fresh: HashSet<Genome> = HashSet::new();
        for req in &requests {
            if self.cache.contains(&req.genome) || fresh.contains(&req.genome) {
                continue;
            }
            fresh.insert(req.genome.clone());
            pending.push(req.clone());
        }

        let mut errors: Vec<(usize, anyhow::Error)> = Vec::new();
        let mut next = 0usize;

        if !pending.is_empty() {
            let batch = self.batch.fetch_add(1, Ordering::Relaxed);
            let mut span = telemetry::span("dispatch", "shard");
            span.arg("batch", Json::Num(batch as f64));
            span.arg("pending", Json::Num(pending.len() as f64));
            span.arg("shards", Json::Num(self.shards.min(pending.len()) as f64));
            // sweep this driver's stragglers before dispatching: a
            // reclaimed zombie may have re-published a result *after*
            // the consumed copy was deleted — nothing will ever read it,
            // and without the sweep such orphans would accumulate
            // across generations
            self.transport.sweep_results(&self.run_tag);
            let mut shards = self.partition(batch, pending);
            self.dispatch(&shards)?;
            self.collect(
                &requests,
                &mut shards,
                &mut fresh,
                &mut next,
                &mut errors,
                &mut on_trial,
            )?;
        }

        // batches served entirely from cache never dispatch anything
        drain_ready(&self.cache, &self.hits, &requests, &mut fresh, &mut next, &mut on_trial);

        if let Some((_, err)) = errors.into_iter().min_by_key(|&(idx, _)| idx) {
            return Err(err);
        }
        debug_assert_eq!(next, requests.len(), "every trial emitted exactly once");
        Ok(())
    }

    /// Contiguous near-equal partition of the collapsed dispatch list.
    fn partition(&self, batch: usize, pending: Vec<EvalRequest>) -> Vec<ShardState> {
        let n = pending.len();
        let count = self.shards.min(n);
        let (chunk, extra) = (n / count, n % count);
        let mut out = Vec::with_capacity(count);
        let mut iter = pending.into_iter();
        let mut base = 0usize;
        for idx in 0..count {
            let size = chunk + usize::from(idx < extra);
            out.push(ShardState {
                name: format!("{}-{}-b{batch:04}-s{idx:02}.json", self.label, self.run_tag),
                base,
                requests: iter.by_ref().take(size).collect(),
                resolved: false,
                no_hb_since: None,
            });
            base += size;
        }
        out
    }

    /// Publish every shard's task into the queue.
    fn dispatch(&self, shards: &[ShardState]) -> Result<()> {
        for s in shards {
            let task = ShardTask {
                shard: s.name.clone(),
                stage: self.stage.clone(),
                requests: s.requests.clone(),
            };
            self.transport
                .publish_task(&s.name, &task.to_json().to_string())?;
        }
        Ok(())
    }

    /// Poll until every shard has a consumed result, committing and
    /// draining as results land, reclaiming dead claims along the way.
    #[allow(clippy::too_many_arguments)]
    fn collect(
        &self,
        requests: &[EvalRequest],
        shards: &mut [ShardState],
        fresh: &mut HashSet<Genome>,
        next: &mut usize,
        errors: &mut Vec<(usize, anyhow::Error)>,
        on_trial: &mut impl FnMut(EvaluatedTrial),
    ) -> Result<()> {
        let mut last_progress = Instant::now();
        loop {
            let mut progressed = false;
            for s in shards.iter_mut().filter(|s| !s.resolved) {
                let Some(text) = self.transport.take_result(&s.name)? else {
                    continue;
                };
                // stitch the worker's attached span buffer into this
                // process's trace before the rows are judged — even a
                // corrupt-row result keeps its timeline
                if telemetry::enabled() {
                    if let Ok(doc) = Json::parse(&text) {
                        telemetry::ingest_remote(&doc);
                    }
                }
                match parse_result_file(&text, &s.requests, self.manifest.as_deref()) {
                    Ok(Ok(rows)) => {
                        for (k, (req, outcome)) in s.requests.iter().zip(rows).enumerate() {
                            match outcome {
                                Ok(evaluation) => {
                                    self.cache.insert(req.genome.clone(), evaluation);
                                    self.evaluations.fetch_add(1, Ordering::Relaxed);
                                }
                                // dispatch index = position in the
                                // collapsed list (shard-count-invariant)
                                Err(msg) => errors.push((s.base + k, anyhow::anyhow!("{msg}"))),
                            }
                        }
                    }
                    Ok(Err(detail)) => errors.push((
                        s.base,
                        anyhow::Error::new(ShardError::WorkerFailed {
                            shard: s.name.clone(),
                            detail,
                        }),
                    )),
                    Err(e) => errors.push((
                        s.base,
                        anyhow::Error::new(ShardError::CorruptResult {
                            shard: s.name.clone(),
                            detail: format!("{e:#}"),
                        }),
                    )),
                }
                s.resolved = true;
                progressed = true;
                // Tidy every protocol artifact this shard leaves behind:
                // the consumed result (names are run-unique, nothing
                // else will ever read it — without this, results
                // accumulate shards × generations over a long run), a
                // stray claim from a worker that crashed between
                // publishing and cleanup, and the re-queued task a
                // reclaimed zombie's late result would otherwise leave
                // for a live worker to re-train pointlessly.
                self.transport.scrub(&s.name);
            }

            drain_ready(&self.cache, &self.hits, requests, fresh, next, &mut *on_trial);
            if shards.iter().all(|s| s.resolved) {
                return Ok(());
            }

            // ---- lease bookkeeping for the shards still in flight ----
            let mut live = false;
            for s in shards.iter_mut().filter(|s| !s.resolved) {
                let stale = match self.transport.lease(&s.name) {
                    // still queued (or between reclaim and re-claim)
                    LeaseStatus::Unclaimed => {
                        s.no_hb_since = None;
                        continue;
                    }
                    LeaseStatus::Claimed {
                        heartbeat_age: Some(age),
                    } => {
                        if age <= self.timings.lease_timeout {
                            s.no_hb_since = None;
                        }
                        age > self.timings.lease_timeout
                    }
                    // claimed with no heartbeat — either freshly claimed,
                    // or the heartbeat transiently vanished: one full
                    // lease of grace from first observation
                    LeaseStatus::Claimed { heartbeat_age: None } => {
                        let since = *s.no_hb_since.get_or_insert_with(Instant::now);
                        since.elapsed() > self.timings.lease_timeout
                    }
                };
                if stale {
                    // exactly-once: of all concurrent reclaimers at most
                    // one wins, and the task travels back intact
                    if self.transport.reclaim(&s.name) {
                        self.reclaims.fetch_add(1, Ordering::Relaxed);
                        s.no_hb_since = None;
                        eprintln!(
                            "[shard] reclaimed `{}` from a dead worker (stale lease)",
                            s.name
                        );
                        progressed = true;
                    }
                } else {
                    live = true;
                }
            }

            if progressed || live {
                last_progress = Instant::now();
            } else if last_progress.elapsed() > self.timings.stall_timeout {
                return Err(anyhow::Error::new(ShardError::Stalled {
                    endpoint: self.transport.describe(),
                    waited: last_progress.elapsed(),
                }));
            }
            std::thread::sleep(self.timings.poll);
        }
    }
}

impl EvalPool for ShardDriver {
    fn evaluate_stream_dyn(
        &self,
        requests: Vec<EvalRequest>,
        on_trial: &mut dyn FnMut(EvaluatedTrial),
    ) -> Result<()> {
        self.evaluate_stream(requests, |trial| on_trial(trial))
    }

    fn evaluations(&self) -> usize {
        self.evaluations.load(Ordering::Relaxed)
    }

    fn cache_hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    fn cache(&self) -> &EvalCache {
        &self.cache
    }
}

// ---------------------------------------------------------------------------
// worker
// ---------------------------------------------------------------------------

/// Worker-side knobs.
#[derive(Debug, Clone)]
pub struct WorkerOptions {
    /// Queue poll cadence while idle.
    pub poll: Duration,
    /// Heartbeat refresh cadence while evaluating a claim (keep this well
    /// under the driver's lease timeout).
    pub heartbeat: Duration,
    /// [`manifest_fingerprint`] of the run manifest this worker's
    /// evaluator stack was built from, echoed in every result file so the
    /// driver rejects results computed under a stale configuration.
    /// `None` for manifest-less harnesses (in-process tests, benches).
    pub manifest: Option<String>,
}

impl Default for WorkerOptions {
    fn default() -> Self {
        WorkerOptions {
            poll: Duration::from_millis(50),
            heartbeat: Duration::from_secs(1),
            manifest: None,
        }
    }
}

/// What a worker did before shutdown.
#[derive(Debug, Default, Clone, Copy)]
pub struct WorkerSummary {
    /// Shards claimed and published.
    pub shards: usize,
    /// Trials evaluated (failed evaluations included).
    pub trials: usize,
}

/// Stops (and joins) the heartbeat thread when dropped — including on
/// unwind out of a panicking `eval_shard`, where a leaked beat thread
/// would keep the dead claim's lease fresh forever and the driver would
/// hang instead of reclaiming the shard.
struct Heartbeat {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Heartbeat {
    fn start(
        transport: Arc<dyn ShardTransport>,
        name: String,
        interval: Duration,
    ) -> Heartbeat {
        transport.heartbeat(&name);
        let stop = Arc::new(AtomicBool::new(false));
        let thread = {
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    std::thread::sleep(interval);
                    transport.heartbeat(&name);
                }
            })
        };
        Heartbeat {
            stop,
            thread: Some(thread),
        }
    }
}

impl Drop for Heartbeat {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(thread) = self.thread.take() {
            let _ = thread.join();
        }
    }
}

/// Serve shards from the run directory at `run_dir` until a shutdown is
/// requested — [`run_worker_on`] over an [`FsTransport`].
pub fn run_worker<F>(
    run_dir: &Path,
    opts: &WorkerOptions,
    eval_shard: F,
) -> Result<WorkerSummary>
where
    F: FnMut(&StageSpec, &[EvalRequest]) -> Vec<Result<TrialEvaluation>>,
{
    run_worker_on(Arc::new(FsTransport::new(run_dir)?), opts, eval_shard)
}

/// Serve shards from `transport` until a shutdown is requested.
///
/// `eval_shard` scores one claimed shard: it receives the stage spec and
/// the shard's requests and must return one `Result` per request, in
/// request order (per-request errors travel to the driver individually —
/// the PR-2 batch-failure guarantee: a failed trial never discards a
/// successful sibling). The claim/heartbeat/publish machinery lives here;
/// the binary's `worker` subcommand supplies an `eval_shard` that
/// rebuilds the full train-and-score stack, tests supply mocks.
pub fn run_worker_on<F>(
    transport: Arc<dyn ShardTransport>,
    opts: &WorkerOptions,
    mut eval_shard: F,
) -> Result<WorkerSummary>
where
    F: FnMut(&StageSpec, &[EvalRequest]) -> Vec<Result<TrialEvaluation>>,
{
    let mut summary = WorkerSummary::default();
    loop {
        if transport.is_shutdown() {
            return Ok(summary);
        }
        let mut claimed_any = false;
        while let Some(claimed) = transport.claim_next()? {
            claimed_any = true;
            let name = claimed.name;
            // heartbeat thread: keeps the lease alive however long the
            // shard trains; the guard stops it even if eval_shard panics
            let beat = Heartbeat::start(Arc::clone(&transport), name.clone(), opts.heartbeat);
            let text = claimed
                .task
                .and_then(|text| {
                    ShardTask::from_json(&Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?)
                })
                .map(|task| {
                    let mut span = telemetry::span("shard", "eval");
                    span.arg("shard", Json::Str(task.shard.clone()));
                    span.arg("trials", Json::Num(task.requests.len() as f64));
                    let outcomes = eval_shard(&task.stage, &task.requests);
                    summary.trials += outcomes.len();
                    drop(span);
                    let rows: Vec<(usize, Result<TrialEvaluation, String>)> = task
                        .requests
                        .iter()
                        .zip(outcomes)
                        .map(|(req, outcome)| {
                            (req.trial_id, outcome.map_err(|e| format!("{e:#}")))
                        })
                        .collect();
                    // attach this worker's span buffer to the publication
                    // (drained here; pool threads flush every few records,
                    // so a straggler span rides the *next* publication —
                    // same trace, just a later attach)
                    let spans = telemetry::enabled().then(telemetry::local_spans_json);
                    result_to_json(&task.shard, &rows, opts.manifest.as_deref(), spans)
                        .to_string()
                })
                .unwrap_or_else(|e| {
                    worker_failure_to_json(&name, &format!("{e:#}"), opts.manifest.as_deref())
                        .to_string()
                });
            // first-writer-wins publish: a result someone else already
            // published (our lease was reclaimed and the replacement
            // finished first) is never clobbered — in particular a late
            // failure report cannot overwrite a consumed success
            let published = transport.publish_result(&name, &text);
            drop(beat);
            published?;
            transport.finish_claim(&name);
            summary.shards += 1;
        }
        if !claimed_any {
            std::thread::sleep(opts.poll);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{global_search_with, SearchLoopConfig, SearchOutcome};
    use crate::eval::transport::{queue_names, RunDir};
    use crate::eval::{ParallelEvaluator, TrialEvaluator};
    use crate::nn::SearchSpace;
    use crate::search::Nsga2Config;
    use crate::util::Rng;
    use std::path::PathBuf;

    fn toy_stage() -> StageSpec {
        StageSpec {
            objectives: ObjectiveKind::nac_set(),
            epochs: 1,
        }
    }

    fn fast_timings() -> ShardTimings {
        ShardTimings {
            lease_timeout: Duration::from_millis(300),
            poll: Duration::from_millis(5),
            stall_timeout: Duration::from_secs(30),
        }
    }

    fn worker_opts() -> WorkerOptions {
        WorkerOptions {
            poll: Duration::from_millis(5),
            heartbeat: Duration::from_millis(50),
            manifest: None,
        }
    }

    fn tmp_run_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join("snac_shard_tests")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    /// Requests shutdown when dropped, so worker threads always exit —
    /// even when a test assertion panics mid-scope (otherwise the scope
    /// would join forever and the failure would present as a hang).
    struct ShutdownOnDrop(RunDir);

    impl Drop for ShutdownOnDrop {
        fn drop(&mut self) {
            let _ = self.0.request_shutdown();
        }
    }

    /// The deterministic toy scorer shared by driver and workers (same
    /// rule as the search-loop tests: accuracy mixes in the trial RNG so
    /// any perturbation of the fork/replay discipline is caught).
    fn toy_score(space: &SearchSpace, genome: &Genome, rng: &mut Rng) -> TrialEvaluation {
        let weights = genome.num_weights(space) as f64;
        let accuracy = (1.0 - (-weights / 4000.0).exp()) * (0.95 + 0.05 * rng.uniform());
        TrialEvaluation {
            accuracy,
            bops: weights,
            est_avg_resources: None,
            est_clock_cycles: None,
            objectives: vec![-accuracy, weights],
            train_seconds: 0.001,
        }
    }

    struct ToyEvaluator {
        space: SearchSpace,
    }

    impl TrialEvaluator for ToyEvaluator {
        fn evaluate(&self, genome: &Genome, rng: &mut Rng) -> Result<TrialEvaluation> {
            Ok(toy_score(&self.space, genome, rng))
        }
    }

    fn requests(genomes: &[Genome], seed: u64) -> Vec<EvalRequest> {
        let mut root = Rng::new(seed);
        genomes
            .iter()
            .enumerate()
            .map(|(trial_id, genome)| EvalRequest {
                trial_id,
                genome: genome.clone(),
                rng: root.fork(trial_id as u64),
            })
            .collect()
    }

    fn distinct_genomes(n: usize, seed: u64) -> Vec<Genome> {
        let space = SearchSpace::table1();
        let mut rng = Rng::new(seed);
        let mut out: Vec<Genome> = Vec::new();
        while out.len() < n {
            let g = space.sample(&mut rng);
            if !out.contains(&g) {
                out.push(g);
            }
        }
        out
    }

    #[test]
    fn shard_task_and_result_files_round_trip() {
        let space = SearchSpace::table1();
        let genomes = distinct_genomes(3, 9);
        let task = ShardTask {
            shard: "t-b0000-s00.json".to_string(),
            stage: StageSpec {
                objectives: ObjectiveKind::snac_set(),
                epochs: 5,
            },
            requests: requests(&genomes, 4),
        };
        let text = task.to_json().to_string();
        let back = ShardTask::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.shard, task.shard);
        assert_eq!(back.stage, task.stage);
        assert_eq!(back.requests.len(), 3);
        for (a, b) in task.requests.iter().zip(&back.requests) {
            assert_eq!(a.trial_id, b.trial_id);
            assert_eq!(a.genome, b.genome);
            // the RNG stream replays bit-for-bit after the round trip
            let mut ra = a.rng.clone();
            let mut rb = b.rng.clone();
            for _ in 0..32 {
                assert_eq!(ra.next_u64(), rb.next_u64());
            }
        }

        // result rows: evaluations round-trip, per-trial errors survive
        let mut rng = Rng::new(1);
        let rows: Vec<(usize, Result<TrialEvaluation, String>)> = vec![
            (0, Ok(toy_score(&space, &genomes[0], &mut rng))),
            (1, Err("mock trial failure".to_string())),
            (2, Ok(toy_score(&space, &genomes[2], &mut rng))),
        ];
        let text = result_to_json(&task.shard, &rows, Some("fp-1"), None).to_string();
        let parsed = parse_result_file(&text, &task.requests, Some("fp-1"))
            .unwrap()
            .unwrap();
        assert_eq!(parsed.len(), 3);
        let (Ok(e0), Err(msg), Ok(e2)) = (&parsed[0], &parsed[1], &parsed[2]) else {
            panic!("row shapes survived");
        };
        assert_eq!(e0.accuracy, rows[0].1.as_ref().unwrap().accuracy);
        assert_eq!(e0.objectives, rows[0].1.as_ref().unwrap().objectives);
        assert_eq!(msg, "mock trial failure");
        assert_eq!(e2.bops, rows[2].1.as_ref().unwrap().bops);

        // mismatched rows are a corrupt result, not a silent misalignment
        assert!(parse_result_file(&text, &task.requests[..2], Some("fp-1")).is_err());
        // a result computed under a different run manifest is rejected —
        // a worker that booted from a stale run.json fails loudly instead
        // of committing wrong numbers
        let err = parse_result_file(&text, &task.requests, Some("fp-2")).unwrap_err();
        assert!(
            format!("{err:#}").contains("different run manifest"),
            "{err:#}"
        );
        // drivers without a manifest (in-process harnesses) skip the check
        assert!(parse_result_file(&text, &task.requests, None).is_ok());
        // fingerprints are content-derived and stable
        assert_eq!(manifest_fingerprint("abc"), manifest_fingerprint("abc"));
        assert_ne!(manifest_fingerprint("abc"), manifest_fingerprint("abd"));
    }

    /// Drive a micro search through the shard protocol with in-process
    /// worker threads; returns the outcome.
    fn sharded_search(
        run_dir: &Path,
        shards: usize,
        workers: usize,
        trials: usize,
        seed: u64,
    ) -> SearchOutcome {
        let space = SearchSpace::table1();
        let driver = ShardDriver::new(
            run_dir,
            "toy",
            toy_stage(),
            shards,
            EvalCache::in_memory(),
            fast_timings(),
        )
        .unwrap();
        let outcome = std::thread::scope(|s| {
            let _guard = ShutdownOnDrop(RunDir::new(run_dir));
            for _ in 0..workers {
                let space = space.clone();
                s.spawn(move || {
                    run_worker(run_dir, &worker_opts(), |_stage, reqs| {
                        reqs.iter()
                            .map(|req| {
                                let mut rng = req.rng.clone();
                                Ok(toy_score(&space, &req.genome, &mut rng))
                            })
                            .collect()
                    })
                    .unwrap();
                });
            }
            global_search_with(
                &driver,
                &space,
                SearchLoopConfig {
                    nsga2: Nsga2Config {
                        population: 6,
                        ..Default::default()
                    },
                    trials,
                    seed,
                    accuracy_threshold: 0.0,
                    progress: None,
                    checkpoint: None,
                },
            )
            .unwrap()
        });
        outcome
    }

    /// The acceptance matrix: the micro search pipeline at
    /// `shards ∈ {1,2,4} × workers ∈ {1,2}` produces identical genomes,
    /// objectives, and Pareto selection to the single-process pool for
    /// all six configurations (timings excluded — they are live
    /// measurement).
    #[test]
    fn sharded_search_matches_single_process_for_every_shard_and_worker_count() {
        let space = SearchSpace::table1();
        let pool = ParallelEvaluator::new(
            ToyEvaluator {
                space: space.clone(),
            },
            1,
        );
        let reference = global_search_with(
            &pool,
            &space,
            SearchLoopConfig {
                nsga2: Nsga2Config {
                    population: 6,
                    ..Default::default()
                },
                trials: 24,
                seed: 42,
                accuracy_threshold: 0.0,
                progress: None,
                checkpoint: None,
            },
        )
        .unwrap();

        for shards in [1usize, 2, 4] {
            for workers in [1usize, 2] {
                let run_dir = tmp_run_dir(&format!("matrix-s{shards}-w{workers}"));
                let outcome = sharded_search(&run_dir, shards, workers, 24, 42);
                assert_eq!(
                    outcome.records.len(),
                    reference.records.len(),
                    "shards={shards} workers={workers}"
                );
                for (a, b) in reference.records.iter().zip(&outcome.records) {
                    assert_eq!(a.id, b.id, "shards={shards} workers={workers}");
                    assert_eq!(a.genome, b.genome, "shards={shards} workers={workers}");
                    assert_eq!(a.accuracy, b.accuracy, "shards={shards} workers={workers}");
                    assert_eq!(
                        a.objectives, b.objectives,
                        "shards={shards} workers={workers}"
                    );
                    assert_eq!(a.generation, b.generation);
                }
                assert_eq!(outcome.front, reference.front, "shards={shards} workers={workers}");
                assert_eq!(
                    outcome.selected, reference.selected,
                    "shards={shards} workers={workers}"
                );
                assert_eq!(outcome.evaluations, reference.evaluations);
                assert_eq!(outcome.cache_hits, reference.cache_hits);
                let _ = std::fs::remove_dir_all(&run_dir);
            }
        }
    }

    /// Fault injection: a worker that claims a shard and dies (stale
    /// heartbeat) must have its shard reclaimed and re-evaluated exactly
    /// once, with the merged outcome unchanged.
    #[test]
    fn dead_worker_shard_is_reclaimed_and_reevaluated_exactly_once() {
        let space = SearchSpace::table1();
        let genomes = distinct_genomes(8, 31);
        let run_dir = tmp_run_dir("reclaim");
        let driver = ShardDriver::new(
            &run_dir,
            "toy",
            toy_stage(),
            2,
            EvalCache::in_memory(),
            fast_timings(),
        )
        .unwrap();
        let dir = RunDir::new(&run_dir);
        let calls = AtomicUsize::new(0);

        let mut streamed: Vec<usize> = Vec::new();
        std::thread::scope(|s| {
            let _guard = ShutdownOnDrop(dir.clone());
            // the honest worker: starts only after the dead worker has
            // stolen its claim, then serves everything that remains
            let space_ref = &space;
            let calls_ref = &calls;
            let dir_ref = &dir;
            let rd: &Path = run_dir.as_path();
            s.spawn(move || {
                // "dead" worker: claim the first queued shard, heartbeat
                // once, then vanish without ever publishing a result
                let queued = loop {
                    if let Some(first) = queue_names(dir_ref).first() {
                        break first.clone();
                    }
                    std::thread::sleep(Duration::from_millis(2));
                };
                let claim = dir_ref.claims().join(&queued);
                if std::fs::rename(dir_ref.queue().join(&queued), &claim).is_ok() {
                    let _ = std::fs::write(
                        dir_ref.claims().join(format!("{queued}.hb")),
                        b"hb\n",
                    );
                }
                // died. the honest worker takes over from here.
                run_worker(rd, &worker_opts(), |_stage, reqs| {
                    reqs.iter()
                        .map(|req| {
                            calls_ref.fetch_add(1, Ordering::SeqCst);
                            let mut rng = req.rng.clone();
                            Ok(toy_score(space_ref, &req.genome, &mut rng))
                        })
                        .collect()
                })
                .unwrap();
            });

            driver
                .evaluate_stream(requests(&genomes, 7), |t| streamed.push(t.trial_id))
                .unwrap();
            dir.request_shutdown().unwrap();
        });

        assert_eq!(driver.reclaims(), 1, "the dead worker's lease was reclaimed once");
        assert_eq!(streamed, (0..8).collect::<Vec<_>>(), "trial order preserved");
        assert_eq!(
            calls.load(Ordering::SeqCst),
            8,
            "the reclaimed shard was re-evaluated exactly once (no double work)"
        );
        assert_eq!(EvalPool::evaluations(&driver), 8);

        // and the merged numbers equal the in-process pool's
        let pool = ParallelEvaluator::new(
            ToyEvaluator {
                space: space.clone(),
            },
            1,
        );
        let reference = pool.evaluate_batch(requests(&genomes, 7)).unwrap();
        for r in &reference {
            let cached = driver.cache().lookup(&r.genome).unwrap();
            assert_eq!(cached.accuracy, r.evaluation.accuracy);
            assert_eq!(cached.objectives, r.evaluation.objectives);
        }
        let _ = std::fs::remove_dir_all(&run_dir);
    }

    /// Fault injection: one corrupt per-shard result file must surface as
    /// a typed error naming the shard, while the sibling shard's results
    /// are still committed to the cache (the PR-2 batch-failure
    /// guarantee, lifted to shards).
    #[test]
    fn corrupt_result_file_is_a_typed_error_and_siblings_commit() {
        let genomes = distinct_genomes(8, 57);
        let run_dir = tmp_run_dir("corrupt");
        let driver = ShardDriver::new(
            &run_dir,
            "toy",
            toy_stage(),
            2,
            EvalCache::in_memory(),
            fast_timings(),
        )
        .unwrap();
        let dir = RunDir::new(&run_dir);
        let space = SearchSpace::table1();

        let mut streamed: Vec<usize> = Vec::new();
        let err = std::thread::scope(|s| {
            let _guard = ShutdownOnDrop(dir.clone());
            let space_ref = &space;
            let dir_ref = &dir;
            let rd: &Path = run_dir.as_path();
            s.spawn(move || {
                // sabotage the SECOND shard: steal its claim so no honest
                // worker can serve it, then publish garbage as its result
                let second = loop {
                    let names = queue_names(dir_ref);
                    if names.len() >= 2 {
                        break names[1].clone();
                    }
                    std::thread::sleep(Duration::from_millis(2));
                };
                let _ = std::fs::rename(
                    dir_ref.queue().join(&second),
                    dir_ref.claims().join(&second),
                );
                std::fs::write(dir_ref.results().join(&second), b"{not json at all")
                    .unwrap();
                // honest worker serves the surviving first shard
                run_worker(rd, &worker_opts(), |_stage, reqs| {
                    reqs.iter()
                        .map(|req| {
                            let mut rng = req.rng.clone();
                            Ok(toy_score(space_ref, &req.genome, &mut rng))
                        })
                        .collect()
                })
                .unwrap();
            });

            let err = driver
                .evaluate_stream(requests(&genomes, 3), |t| streamed.push(t.trial_id))
                .unwrap_err();
            dir.request_shutdown().unwrap();
            err
        });

        let shard_err = err
            .downcast_ref::<ShardError>()
            .expect("typed ShardError, not a stringly error");
        match shard_err {
            ShardError::CorruptResult { shard, .. } => {
                assert!(
                    shard.contains("-s01"),
                    "error names the corrupt shard: {shard}"
                );
            }
            other => panic!("expected CorruptResult, got {other}"),
        }
        // the sibling shard's four evaluations were committed, and the
        // stream emitted exactly the prefix the sibling covers
        assert_eq!(EvalPool::evaluations(&driver), 4);
        assert_eq!(streamed, vec![0, 1, 2, 3]);
        for g in &genomes[..4] {
            assert!(driver.cache().contains(g), "sibling results committed");
        }
        for g in &genomes[4..] {
            assert!(!driver.cache().contains(g));
        }
        let _ = std::fs::remove_dir_all(&run_dir);
    }

    /// Per-trial worker errors travel through the result file and keep
    /// the PR-2 contract: successes commit, the first dispatch-order
    /// error propagates.
    #[test]
    fn per_trial_errors_propagate_first_in_dispatch_order() {
        let space = SearchSpace::table1();
        let genomes = distinct_genomes(6, 91);
        let bad = [genomes[1].clone(), genomes[4].clone()];
        let run_dir = tmp_run_dir("trial-errors");
        let driver = ShardDriver::new(
            &run_dir,
            "toy",
            toy_stage(),
            3,
            EvalCache::in_memory(),
            fast_timings(),
        )
        .unwrap();
        let dir = RunDir::new(&run_dir);

        let err = std::thread::scope(|s| {
            let _guard = ShutdownOnDrop(dir.clone());
            let space_ref = &space;
            let bad_ref = &bad;
            let rd: &Path = run_dir.as_path();
            s.spawn(move || {
                run_worker(rd, &worker_opts(), |_stage, reqs| {
                    reqs.iter()
                        .map(|req| {
                            if let Some(i) = bad_ref.iter().position(|g| *g == req.genome) {
                                anyhow::bail!("mock failure #{i}");
                            }
                            let mut rng = req.rng.clone();
                            Ok(toy_score(space_ref, &req.genome, &mut rng))
                        })
                        .collect()
                })
                .unwrap();
            });
            let err = driver
                .evaluate_stream(requests(&genomes, 2), |_| {})
                .unwrap_err();
            dir.request_shutdown().unwrap();
            err
        });

        assert!(
            format!("{err:#}").contains("mock failure #0"),
            "first dispatch-order error wins: {err:#}"
        );
        assert_eq!(EvalPool::evaluations(&driver), 4, "successful siblings committed");
        let _ = std::fs::remove_dir_all(&run_dir);
    }

    /// A batch served entirely from the (restored) cache dispatches no
    /// shards at all — and a second sharded batch over the same genomes
    /// is pure cache hits.
    #[test]
    fn cached_batches_skip_dispatch_entirely() {
        let space = SearchSpace::table1();
        let genomes = distinct_genomes(5, 14);
        let run_dir = tmp_run_dir("cached");
        let driver = ShardDriver::new(
            &run_dir,
            "toy",
            toy_stage(),
            2,
            EvalCache::in_memory(),
            fast_timings(),
        )
        .unwrap();
        let dir = RunDir::new(&run_dir);
        std::thread::scope(|s| {
            let _guard = ShutdownOnDrop(dir.clone());
            let space_ref = &space;
            let rd: &Path = run_dir.as_path();
            s.spawn(move || {
                run_worker(rd, &worker_opts(), |_stage, reqs| {
                    reqs.iter()
                        .map(|req| {
                            let mut rng = req.rng.clone();
                            Ok(toy_score(space_ref, &req.genome, &mut rng))
                        })
                        .collect()
                })
                .unwrap();
            });
            let first = {
                let mut out = Vec::new();
                driver
                    .evaluate_stream(requests(&genomes, 8), |t| out.push(t))
                    .unwrap();
                out
            };
            assert!(first.iter().all(|t| !t.cached));
            // second batch: all hits, no new shard files needed (the
            // worker could be dead by now and this would still succeed)
            dir.request_shutdown().unwrap();
            let second = {
                let mut out = Vec::new();
                driver
                    .evaluate_stream(requests(&genomes, 8), |t| out.push(t))
                    .unwrap();
                out
            };
            assert!(second.iter().all(|t| t.cached));
            assert_eq!(EvalPool::cache_hits(&driver), 5);
            for (a, b) in first.iter().zip(&second) {
                assert_eq!(a.evaluation.accuracy, b.evaluation.accuracy);
            }
        });
        let _ = std::fs::remove_dir_all(&run_dir);
    }
}
