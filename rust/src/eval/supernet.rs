//! The train-and-score evaluator: the paper's per-trial protocol.

use std::time::Instant;

use anyhow::Result;

use crate::data::{Dataset, Split};
use crate::nn::{bops, Genome, PruneMasks, SearchSpace, SupernetInputs};
use crate::objectives::{ObjectiveContext, ObjectiveKind};
use crate::runtime::Runtime;
use crate::trainer::{TrainConfig, Trainer};
use crate::util::Rng;

use super::{TrialEvaluation, TrialEvaluator};

/// Trains a candidate inside the supernet for the trial budget, scores it
/// on the validation split, and prices it with the configured objective
/// set. This is the block that used to live inline in
/// `coordinator::search_loop::global_search` (and, for the baseline, in
/// `coordinator::pipeline`).
pub struct SupernetEvaluator<'a> {
    trainer: Trainer<'a>,
    space: &'a SearchSpace,
    objectives: &'a [ObjectiveKind],
    ctx: &'a ObjectiveContext<'a>,
    train: TrainConfig,
    /// Global search trains dense models.
    prune: PruneMasks,
}

impl<'a> SupernetEvaluator<'a> {
    /// New evaluator over a runtime, dataset, objective set, and training
    /// budget. `space` must be the space genomes are sampled from — it is
    /// what candidates are compiled against (`ctx.space` only prices
    /// objectives, mirroring the pre-refactor split).
    pub fn new(
        rt: &'a Runtime,
        ds: &'a Dataset,
        space: &'a SearchSpace,
        objectives: &'a [ObjectiveKind],
        ctx: &'a ObjectiveContext<'a>,
        train: TrainConfig,
    ) -> Self {
        SupernetEvaluator {
            trainer: Trainer::new(rt, ds),
            space,
            objectives,
            ctx,
            train,
            prune: PruneMasks::ones(),
        }
    }
}

impl TrialEvaluator for SupernetEvaluator<'_> {
    /// Prefetch the whole generation's surrogate estimates in
    /// ⌈N/`SUR_BATCH`⌉ batched executions (a no-op for objective sets
    /// without surrogate terms); the per-trial `ctx.evaluate` calls in
    /// [`evaluate`](Self::evaluate) then hit the predictor memo.
    fn prepare(&self, genomes: &[Genome]) -> Result<()> {
        self.ctx.prefetch(self.objectives, genomes).map(|_| ())
    }

    fn evaluate(&self, genome: &Genome, rng: &mut Rng) -> Result<TrialEvaluation> {
        let t0 = Instant::now();
        let inputs = SupernetInputs::compile(genome, self.space);
        let mut model = self.trainer.init_model(rng);
        self.trainer
            .train(&mut model, &inputs, &self.prune, &self.train, rng)?;
        let (accuracy, _val_loss) =
            self.trainer
                .evaluate(&model, &inputs, &self.prune, &self.train, Split::Val)?;
        let (objectives, est_pair) = self.ctx.evaluate(self.objectives, genome, accuracy)?;
        Ok(TrialEvaluation {
            accuracy,
            bops: bops::genome_bops(
                genome,
                self.space,
                self.ctx.bits,
                self.ctx.bits,
                self.ctx.sparsity,
            ),
            est_avg_resources: est_pair.map(|p| p.0),
            est_clock_cycles: est_pair.map(|p| p.1),
            objectives,
            train_seconds: t0.elapsed().as_secs_f64(),
        })
    }
}
