//! The TCP shard transport: a driver-hosted task server for worker
//! fleets with no shared filesystem.
//!
//! The [`super::shard`] protocol core is medium-agnostic; this module
//! supplies the network medium. The **driver** hosts a [`TcpHost`]: the
//! whole queue/claims/results state lives in its memory, an accept loop
//! serves it over the shared [`crate::net`] HTTP framing, and the
//! driver's own [`super::ShardTransport`] calls touch that state
//! directly (no self-request round trips). **Workers** anywhere on the
//! network join with `snac-pack worker --connect HOST:PORT`, which wraps
//! a [`TcpWorker`] — a thin HTTP client — in the same
//! [`super::run_worker_on`] loop the filesystem transport uses.
//!
//! Endpoints (all JSON, served over persistent keep-alive connections —
//! a worker claims, heartbeats, and publishes over one socket):
//!
//! | method+path        | body                 | response                           |
//! |--------------------|----------------------|------------------------------------|
//! | `POST /shard/claim`| `{}`                 | `{"status":"task","name","task"}` \| `{"status":"empty"}` \| `{"status":"shutdown"}` |
//! | `POST /shard/heartbeat` | `{"name"}`      | `{}`                               |
//! | `POST /shard/result`    | `{"name","result"}` | `{"published":bool}`            |
//! | `POST /shard/done` | `{"name"}`           | `{}`                               |
//! | `GET /run.json`    | —                    | manifest text (404 when none)      |
//!
//! **Admission control:** every `/shard/*` request must carry the run's
//! shared token as an `Authorization: Bearer` header. The driver mints
//! the token at launch and prints it with the join command; a mismatch
//! is a `403` that the worker surfaces as a typed [`ShardAuthError`]
//! (fail loudly — a wrong token never fixes itself). `GET /run.json`
//! stays open so `curl` can inspect a run zero-setup.
//!
//! The exactly-once properties the protocol core relies on fall out of
//! one mutex over the host state: a claim atomically moves the task from
//! the queue into the claims table (so the task travels with the claim,
//! and a reclaim needs no other state), and a result insert is
//! first-writer-wins. Lease ages are tracked host-side from the last
//! claim/heartbeat request, so worker clocks never matter.
//!
//! A worker whose driver dies does not hang: every request runs under
//! an overall [`crate::net::HttpClient`] deadline, and after
//! [`MAX_CONSECUTIVE_FAILURES`] straight connection failures the worker
//! treats the run as over and exits cleanly.

use std::collections::{BTreeMap, HashMap};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::net;
use crate::telemetry;
use crate::util::Json;

use super::cache::lock_unpoisoned;
use super::transport::{ClaimedTask, LeaseStatus, ShardTransport};

/// Consecutive connection-level failures after which a [`TcpWorker`]
/// declares the driver dead and reports shutdown to its worker loop.
pub const MAX_CONSECUTIVE_FAILURES: usize = 8;

/// One claimed shard on the host: the task travels with the claim so a
/// reclaim can requeue it from host state alone.
struct Claim {
    task: String,
    last_hb: Instant,
}

#[derive(Default)]
struct HostInner {
    /// Pending tasks, iterated in name order (the sorted-queue contract
    /// workers see from the filesystem transport too).
    queue: BTreeMap<String, String>,
    claims: HashMap<String, Claim>,
    results: HashMap<String, String>,
}

struct HostShared {
    inner: Mutex<HostInner>,
    shutdown: AtomicBool,
    manifest: Option<String>,
    /// The run's shared bearer token; `/shard/*` requests without it
    /// are refused with `403`.
    token: String,
}

impl HostShared {
    /// Atomically move the first queued task into the claims table.
    fn claim(&self) -> Option<(String, String)> {
        let mut inner = lock_unpoisoned(&self.inner);
        let name = inner.queue.keys().next().cloned()?;
        let task = inner.queue.remove(&name)?;
        inner.claims.insert(
            name.clone(),
            Claim {
                task: task.clone(),
                last_hb: Instant::now(),
            },
        );
        Some((name, task))
    }

    fn heartbeat(&self, name: &str) {
        if let Some(claim) = lock_unpoisoned(&self.inner).claims.get_mut(name) {
            claim.last_hb = Instant::now();
        }
    }

    /// First-writer-wins result insert.
    fn publish_result(&self, name: &str, text: &str) -> bool {
        let mut inner = lock_unpoisoned(&self.inner);
        if inner.results.contains_key(name) {
            return false;
        }
        inner.results.insert(name.to_string(), text.to_string());
        true
    }

    fn finish_claim(&self, name: &str) {
        lock_unpoisoned(&self.inner).claims.remove(name);
    }
}

/// A worker's run token was refused by the driver. This never resolves
/// by retrying, so worker loops propagate it and fail loudly instead of
/// polling forever against a fleet they cannot join.
#[derive(Debug)]
pub struct ShardAuthError {
    /// The driver that refused the token.
    pub addr: String,
    /// The driver's error body.
    pub detail: String,
}

impl std::fmt::Display for ShardAuthError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "driver at {} refused this worker's run token (HTTP 403): {} — start the worker \
             with the `--token` value the driver printed at launch",
            self.addr, self.detail
        )
    }
}

impl std::error::Error for ShardAuthError {}

/// Route one parsed request against the host state.
fn route(shared: &HostShared, req: &net::Request) -> (u16, String) {
    // a traced worker echoes the run's trace ID on every request; mark
    // the arrival on the driver timeline (instant event, no duration)
    if req.trace.is_some() && telemetry::enabled() {
        telemetry::event("rpc", "net", vec![("path", Json::Str(req.path.clone()))]);
    }
    // admission control: shard mutations require this run's token
    if req.path.starts_with("/shard/") && req.bearer.as_deref() != Some(shared.token.as_str()) {
        let detail = if req.bearer.is_some() { "token mismatch" } else { "missing bearer token" };
        return (
            403,
            Json::obj(vec![(
                "error",
                Json::Str(format!("shard endpoints require this run's token ({detail})")),
            )])
            .to_string(),
        );
    }
    let with_name = |handler: &dyn Fn(&str) -> (u16, String)| -> (u16, String) {
        match Json::parse(&req.body)
            .ok()
            .as_ref()
            .and_then(|doc| doc.get("name").and_then(Json::as_str).map(str::to_string))
        {
            Some(name) => handler(&name),
            None => (400, r#"{"error":"body missing `name`"}"#.to_string()),
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/run.json") => match &shared.manifest {
            Some(text) => (200, text.clone()),
            None => (404, r#"{"error":"this run has no manifest"}"#.to_string()),
        },
        ("POST", "/shard/claim") => {
            if shared.shutdown.load(Ordering::SeqCst) {
                return (200, r#"{"status":"shutdown"}"#.to_string());
            }
            match shared.claim() {
                Some((name, task)) => (
                    200,
                    Json::obj(vec![
                        ("status", Json::Str("task".to_string())),
                        ("name", Json::Str(name)),
                        ("task", Json::Str(task)),
                    ])
                    .to_string(),
                ),
                None => (200, r#"{"status":"empty"}"#.to_string()),
            }
        }
        ("POST", "/shard/heartbeat") => with_name(&|name| {
            shared.heartbeat(name);
            (200, "{}".to_string())
        }),
        ("POST", "/shard/result") => {
            let doc = match Json::parse(&req.body) {
                Ok(doc) => doc,
                Err(e) => return (400, format!(r#"{{"error":"unparseable body: {e}"}}"#)),
            };
            let (Some(name), Some(result)) = (
                doc.get("name").and_then(Json::as_str),
                doc.get("result").and_then(Json::as_str),
            ) else {
                return (400, r#"{"error":"body missing `name`/`result`"}"#.to_string());
            };
            let published = shared.publish_result(name, result);
            (
                200,
                Json::obj(vec![("published", Json::Bool(published))]).to_string(),
            )
        }
        ("POST", "/shard/done") => with_name(&|name| {
            shared.finish_claim(name);
            (200, "{}".to_string())
        }),
        (method, path) => (404, format!(r#"{{"error":"no such endpoint {method} {path}"}}"#)),
    }
}

/// Serve one connection for its whole life: a worker claims,
/// heartbeats, and publishes over one persistent socket (closed on
/// `Connection: close`, a 10s idle, or a framing fault).
fn serve_connection(shared: &HostShared, stream: TcpStream) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
    let _ = stream.set_nodelay(true);
    let mut reader = net::RequestReader::new(&stream);
    loop {
        match reader.next_request() {
            Ok(req) => {
                let (status, body) = route(shared, &req);
                let mut w = &stream;
                if net::write_response(&mut w, status, &body, req.keep_alive).is_err()
                    || !req.keep_alive
                {
                    return;
                }
            }
            Err(e) => {
                if !net::quiet_close(&e) {
                    let body = Json::obj(vec![(
                        "error",
                        Json::Str(format!("bad request: {e:#}")),
                    )])
                    .to_string();
                    let mut w = &stream;
                    let _ = net::write_response(&mut w, 400, &body, false);
                }
                return;
            }
        }
    }
}

/// The driver side of the TCP transport: owns the queue state and the
/// accept loop serving it. The driver's own protocol calls go straight
/// to memory; only workers cross the network.
pub struct TcpHost {
    shared: Arc<HostShared>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl TcpHost {
    /// Bind `bind` (e.g. `127.0.0.1:0` for an ephemeral port) and start
    /// serving the task queue. `manifest` is the `run.json` text served
    /// to joining workers, when the run has one; `token` is the run's
    /// shared bearer token — only workers presenting it may claim,
    /// heartbeat, or publish.
    pub fn listen(bind: &str, manifest: Option<String>, token: &str) -> Result<TcpHost> {
        let listener =
            TcpListener::bind(bind).with_context(|| format!("binding task server on {bind}"))?;
        listener
            .set_nonblocking(true)
            .context("setting the task listener non-blocking")?;
        let addr = listener.local_addr().context("reading the bound address")?;
        let shared = Arc::new(HostShared {
            inner: Mutex::new(HostInner::default()),
            shutdown: AtomicBool::new(false),
            manifest,
            token: token.to_string(),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let accept_thread = {
            let shared = Arc::clone(&shared);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let shared = Arc::clone(&shared);
                            // requests are tiny and bounded by stream
                            // timeouts; a detached thread per connection
                            // keeps one stalled client from wedging the
                            // fleet
                            std::thread::spawn(move || serve_connection(&shared, stream));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        // transient accept errors (ECONNABORTED, EINTR)
                        // must not take the queue down
                        Err(_) => std::thread::sleep(Duration::from_millis(2)),
                    }
                }
            })
        };
        Ok(TcpHost {
            shared,
            addr,
            stop,
            accept_thread: Some(accept_thread),
        })
    }

    /// The address workers connect to (`--connect HOST:PORT`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for TcpHost {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(thread) = self.accept_thread.take() {
            let _ = thread.join();
        }
    }
}

impl ShardTransport for TcpHost {
    fn describe(&self) -> String {
        format!("tcp://{}", self.addr)
    }

    fn manifest(&self) -> Result<Option<String>> {
        Ok(self.shared.manifest.clone())
    }

    fn is_shutdown(&self) -> bool {
        self.shared.shutdown.load(Ordering::SeqCst)
    }

    fn request_shutdown(&self) -> Result<()> {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        Ok(())
    }

    fn publish_task(&self, name: &str, text: &str) -> Result<()> {
        lock_unpoisoned(&self.shared.inner)
            .queue
            .insert(name.to_string(), text.to_string());
        Ok(())
    }

    fn take_result(&self, name: &str) -> Result<Option<String>> {
        Ok(lock_unpoisoned(&self.shared.inner).results.get(name).cloned())
    }

    fn scrub(&self, name: &str) {
        let mut inner = lock_unpoisoned(&self.shared.inner);
        inner.results.remove(name);
        inner.queue.remove(name);
        inner.claims.remove(name);
    }

    fn lease(&self, name: &str) -> LeaseStatus {
        match lock_unpoisoned(&self.shared.inner).claims.get(name) {
            Some(claim) => LeaseStatus::Claimed {
                heartbeat_age: Some(claim.last_hb.elapsed()),
            },
            None => LeaseStatus::Unclaimed,
        }
    }

    fn reclaim(&self, name: &str) -> bool {
        let mut inner = lock_unpoisoned(&self.shared.inner);
        match inner.claims.remove(name) {
            Some(claim) => {
                inner.queue.insert(name.to_string(), claim.task);
                true
            }
            None => false,
        }
    }

    fn sweep_results(&self, run_tag: &str) {
        lock_unpoisoned(&self.shared.inner)
            .results
            .retain(|name, _| !name.contains(run_tag));
    }

    fn claim_next(&self) -> Result<Option<ClaimedTask>> {
        if self.is_shutdown() {
            return Ok(None);
        }
        Ok(self.shared.claim().map(|(name, task)| ClaimedTask {
            name,
            task: Ok(task),
        }))
    }

    fn heartbeat(&self, name: &str) {
        self.shared.heartbeat(name);
    }

    fn publish_result(&self, name: &str, text: &str) -> Result<bool> {
        Ok(self.shared.publish_result(name, text))
    }

    fn finish_claim(&self, name: &str) {
        self.shared.finish_claim(name);
    }
}

/// The worker side of the TCP transport: a persistent keep-alive
/// [`net::HttpClient`] over the shared framing (claims, heartbeats, and
/// results ride one socket). All requests are bounded by the configured
/// overall deadline, and [`MAX_CONSECUTIVE_FAILURES`] straight
/// connection failures flip the transport into a shutdown state — a
/// worker never hangs on (or spins against) a dead driver. A `403`
/// (wrong run token) is a typed [`ShardAuthError`] instead: that never
/// resolves by retrying.
pub struct TcpWorker {
    addr: String,
    /// The persistent connection, shared by the worker loop and its
    /// heartbeat thread (requests are tiny; serializing them on one
    /// socket costs less than a connection per call).
    client: Mutex<net::HttpClient>,
    failures: AtomicUsize,
    dead: AtomicBool,
}

impl TcpWorker {
    /// A client for the task server at `addr` (`HOST:PORT`) presenting
    /// `token` on every shard request. `timeout` bounds every request
    /// round trip; keep it under the driver's lease timeout so a
    /// retried heartbeat still lands in time.
    pub fn connect(addr: &str, timeout: Duration, token: &str) -> TcpWorker {
        TcpWorker {
            addr: addr.to_string(),
            client: Mutex::new(net::HttpClient::new(addr, timeout).bearer(token)),
            failures: AtomicUsize::new(0),
            dead: AtomicBool::new(false),
        }
    }

    fn note_failure(&self, err: &anyhow::Error) {
        let n = self.failures.fetch_add(1, Ordering::SeqCst) + 1;
        if n >= MAX_CONSECUTIVE_FAILURES && !self.dead.swap(true, Ordering::SeqCst) {
            eprintln!(
                "[worker] driver at {} unreachable ({n} consecutive failures, last: {err:#}) — \
                 treating the run as over",
                self.addr
            );
        }
    }

    /// POST returning the parsed response. `Ok(None)` = connection-level
    /// failure (counted toward the dead-driver threshold; the caller
    /// retries on its poll cadence). `Err` = the driver answered but
    /// refused the run token ([`ShardAuthError`]) or violated the
    /// protocol — neither resolves itself, so they propagate and fail
    /// the worker loudly.
    fn post(&self, path: &str, body: &str) -> Result<Option<Json>> {
        let outcome = lock_unpoisoned(&self.client).request("POST", path, Some(body));
        match outcome {
            Err(e) => {
                self.note_failure(&e);
                Ok(None)
            }
            Ok((403, text)) => Err(anyhow::Error::new(ShardAuthError {
                addr: self.addr.clone(),
                detail: text,
            })),
            Ok((status, text)) => {
                self.failures.store(0, Ordering::SeqCst);
                anyhow::ensure!(
                    status == 200,
                    "driver at {} answered {path} with HTTP {status}: {text}",
                    self.addr
                );
                let doc = Json::parse(&text).map_err(|e| {
                    anyhow::anyhow!("unparseable response from driver at {}: {e}", self.addr)
                })?;
                Ok(Some(doc))
            }
        }
    }

    fn named_body(name: &str) -> String {
        Json::obj(vec![("name", Json::Str(name.to_string()))]).to_string()
    }
}

impl ShardTransport for TcpWorker {
    fn describe(&self) -> String {
        format!("tcp://{}", self.addr)
    }

    fn manifest(&self) -> Result<Option<String>> {
        let (status, body) = lock_unpoisoned(&self.client)
            .request("GET", "/run.json", None)
            .with_context(|| format!("fetching run manifest from {}", self.addr))?;
        match status {
            200 => Ok(Some(body)),
            404 => Ok(None),
            _ => bail!(
                "driver at {} answered /run.json with HTTP {status}: {body}",
                self.addr
            ),
        }
    }

    fn is_shutdown(&self) -> bool {
        self.dead.load(Ordering::SeqCst)
    }

    fn request_shutdown(&self) -> Result<()> {
        bail!("a TCP worker cannot request a fleet shutdown (the driver owns the queue)")
    }

    fn publish_task(&self, _name: &str, _text: &str) -> Result<()> {
        bail!("publish_task is a driver-side operation; this is a worker transport")
    }

    fn take_result(&self, _name: &str) -> Result<Option<String>> {
        bail!("take_result is a driver-side operation; this is a worker transport")
    }

    fn scrub(&self, _name: &str) {}

    fn lease(&self, _name: &str) -> LeaseStatus {
        LeaseStatus::Unclaimed
    }

    fn reclaim(&self, _name: &str) -> bool {
        false
    }

    fn sweep_results(&self, _run_tag: &str) {}

    fn claim_next(&self) -> Result<Option<ClaimedTask>> {
        if self.is_shutdown() {
            return Ok(None);
        }
        let Some(doc) = self.post("/shard/claim", "{}")? else {
            return Ok(None);
        };
        match doc.get("status").and_then(Json::as_str) {
            Some("task") => {
                let name = doc
                    .get("name")
                    .and_then(Json::as_str)
                    .context("claim response missing `name`")?
                    .to_string();
                let task = doc
                    .get("task")
                    .and_then(Json::as_str)
                    .context("claim response missing `task`")?
                    .to_string();
                Ok(Some(ClaimedTask {
                    name,
                    task: Ok(task),
                }))
            }
            Some("empty") => Ok(None),
            Some("shutdown") => {
                self.dead.store(true, Ordering::SeqCst);
                Ok(None)
            }
            other => bail!(
                "malformed claim response from driver at {} (status {other:?})",
                self.addr
            ),
        }
    }

    fn heartbeat(&self, name: &str) {
        // best-effort, like the filesystem heartbeat write: a missed beat
        // costs at worst a spurious reclaim, which the protocol absorbs
        let _ = self.post("/shard/heartbeat", &Self::named_body(name));
    }

    fn publish_result(&self, name: &str, text: &str) -> Result<bool> {
        let body = Json::obj(vec![
            ("name", Json::Str(name.to_string())),
            ("result", Json::Str(text.to_string())),
        ])
        .to_string();
        let doc = self
            .post("/shard/result", &body)?
            .with_context(|| format!("publishing shard result to dead driver at {}", self.addr))?;
        doc.get("published")
            .and_then(Json::as_bool)
            .with_context(|| format!("malformed publish response from driver at {}", self.addr))
    }

    fn finish_claim(&self, name: &str) {
        let _ = self.post("/shard/done", &Self::named_body(name));
    }

    fn set_trace(&self, id: &str) {
        lock_unpoisoned(&self.client).set_trace(id);
    }
}

#[cfg(test)]
mod tests {
    use super::super::shard::{run_worker_on, ShardDriver, ShardTimings, StageSpec, WorkerOptions};
    use super::super::{EvalCache, ParallelEvaluator, TrialEvaluation, TrialEvaluator};
    use super::*;
    use crate::coordinator::{global_search_with, SearchLoopConfig};
    use crate::nn::{Genome, SearchSpace};
    use crate::objectives::ObjectiveKind;
    use crate::search::Nsga2Config;
    use crate::util::Rng;

    fn toy_score(space: &SearchSpace, genome: &Genome, rng: &mut Rng) -> TrialEvaluation {
        let weights = genome.num_weights(space) as f64;
        let accuracy = (1.0 - (-weights / 4000.0).exp()) * (0.95 + 0.05 * rng.uniform());
        TrialEvaluation {
            accuracy,
            bops: weights,
            est_avg_resources: None,
            est_clock_cycles: None,
            objectives: vec![-accuracy, weights],
            train_seconds: 0.001,
        }
    }

    struct ToyEvaluator {
        space: SearchSpace,
    }

    impl TrialEvaluator for ToyEvaluator {
        fn evaluate(&self, genome: &Genome, rng: &mut Rng) -> anyhow::Result<TrialEvaluation> {
            Ok(toy_score(&self.space, genome, rng))
        }
    }

    fn fast_timings() -> ShardTimings {
        ShardTimings {
            lease_timeout: Duration::from_millis(300),
            poll: Duration::from_millis(5),
            stall_timeout: Duration::from_secs(30),
        }
    }

    fn worker_opts() -> WorkerOptions {
        WorkerOptions {
            poll: Duration::from_millis(5),
            heartbeat: Duration::from_millis(50),
            manifest: None,
        }
    }

    fn micro_config(trials: usize, seed: u64) -> SearchLoopConfig {
        SearchLoopConfig {
            nsga2: Nsga2Config {
                population: 6,
                ..Default::default()
            },
            trials,
            seed,
            accuracy_threshold: 0.0,
            progress: None,
            checkpoint: None,
        }
    }

    /// The wire protocol round-trips through real sockets: manifest
    /// fetch, claim, heartbeat, first-writer-wins result, done.
    #[test]
    fn host_and_worker_speak_the_wire_protocol() {
        let host =
            TcpHost::listen("127.0.0.1:0", Some("{\"preset\":\"x\"}".to_string()), "tok-wire")
                .unwrap();
        let worker = TcpWorker::connect(&host.addr().to_string(), Duration::from_secs(5), "tok-wire");

        assert_eq!(worker.manifest().unwrap().as_deref(), Some("{\"preset\":\"x\"}"));

        // the manifest stays open (zero-setup inspection needs no token)
        let (status, body) =
            net::request(&host.addr().to_string(), "GET", "/run.json", None).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "{\"preset\":\"x\"}");

        // empty queue → no claim
        assert!(worker.claim_next().unwrap().is_none());

        // publish a task with JSON-hostile content; it survives embedding
        let task_text = "{\"shard\":\"a\",\"note\":\"quotes \\\" and\\nnewlines\"}";
        host.publish_task("toy-b0000-s00.json", task_text).unwrap();
        let claimed = worker.claim_next().unwrap().expect("one queued task");
        assert_eq!(claimed.name, "toy-b0000-s00.json");
        assert_eq!(claimed.task.unwrap(), task_text);

        // claimed: the host tracks the lease from the claim request
        assert!(matches!(
            host.lease("toy-b0000-s00.json"),
            LeaseStatus::Claimed { heartbeat_age: Some(_) }
        ));
        worker.heartbeat("toy-b0000-s00.json");

        // first-writer-wins over the wire
        assert!(worker.publish_result("toy-b0000-s00.json", "{\"results\":[]}").unwrap());
        assert!(!worker.publish_result("toy-b0000-s00.json", "{\"late\":true}").unwrap());
        assert_eq!(
            host.take_result("toy-b0000-s00.json").unwrap().as_deref(),
            Some("{\"results\":[]}")
        );
        worker.finish_claim("toy-b0000-s00.json");
        assert_eq!(host.lease("toy-b0000-s00.json"), LeaseStatus::Unclaimed);

        // reclaim requeues the task intact (exactly-once: second loses)
        host.publish_task("toy-b0000-s01.json", "t").unwrap();
        let _ = worker.claim_next().unwrap().expect("claimable");
        assert!(host.reclaim("toy-b0000-s01.json"));
        assert!(!host.reclaim("toy-b0000-s01.json"));
        let back = host.claim_next().unwrap().expect("requeued");
        assert_eq!(back.task.unwrap(), "t");

        // shutdown propagates to polling workers
        host.request_shutdown().unwrap();
        assert!(worker.claim_next().unwrap().is_none());
        assert!(worker.is_shutdown());
    }

    /// Admission control: a worker with the wrong run token is refused
    /// with a typed [`ShardAuthError`] on every shard endpoint, the
    /// queue state is untouched, and the right token still claims.
    #[test]
    fn mismatched_run_token_is_a_typed_rejection() {
        let host = TcpHost::listen("127.0.0.1:0", None, "right-token").unwrap();
        let addr = host.addr().to_string();
        host.publish_task("tok-b0000-s00.json", "t").unwrap();

        let wrong = TcpWorker::connect(&addr, Duration::from_secs(5), "wrong-token");
        let err = wrong.claim_next().unwrap_err();
        let auth = err
            .downcast_ref::<ShardAuthError>()
            .unwrap_or_else(|| panic!("expected ShardAuthError, got {err:#}"));
        assert_eq!(auth.addr, addr);
        let err = wrong.publish_result("tok-b0000-s00.json", "{}").unwrap_err();
        assert!(err.downcast_ref::<ShardAuthError>().is_some(), "{err:#}");

        // a tokenless client is refused too (heartbeat shares the gate)
        let (status, body) =
            net::request(&addr, "POST", "/shard/heartbeat", Some("{\"name\":\"x\"}")).unwrap();
        assert_eq!(status, 403, "{body}");
        assert!(body.contains("missing bearer token"), "{body}");

        // the rejected claim consumed nothing: the right token gets it
        let right = TcpWorker::connect(&addr, Duration::from_secs(5), "right-token");
        let claimed = right.claim_next().unwrap().expect("task still queued");
        assert_eq!(claimed.name, "tok-b0000-s00.json");
    }

    /// The acceptance matrix over TCP: the micro search at
    /// `shards ∈ {1,2,4} × workers ∈ {1,2}` — with workers talking to the
    /// driver through real sockets — produces bit-identical records to
    /// the single-process pool. The determinism contract is transport-
    /// independent.
    #[test]
    fn tcp_sharded_search_matches_single_process_for_every_shard_and_worker_count() {
        let space = SearchSpace::table1();
        let pool = ParallelEvaluator::new(
            ToyEvaluator {
                space: space.clone(),
            },
            1,
        );
        let reference = global_search_with(&pool, &space, micro_config(24, 42)).unwrap();

        for shards in [1usize, 2, 4] {
            for workers in [1usize, 2] {
                let host: Arc<TcpHost> =
                    Arc::new(TcpHost::listen("127.0.0.1:0", None, "tok-matrix").unwrap());
                let addr = host.addr().to_string();
                let stage = StageSpec {
                    objectives: ObjectiveKind::nac_set(),
                    epochs: 1,
                };
                let driver = ShardDriver::with_transport(
                    Arc::clone(&host) as Arc<dyn ShardTransport>,
                    "toy",
                    stage,
                    shards,
                    EvalCache::in_memory(),
                    fast_timings(),
                )
                .unwrap();
                let outcome = std::thread::scope(|s| {
                    for _ in 0..workers {
                        let space = space.clone();
                        let addr = addr.clone();
                        s.spawn(move || {
                            let client: Arc<dyn ShardTransport> = Arc::new(TcpWorker::connect(
                                &addr,
                                Duration::from_secs(5),
                                "tok-matrix",
                            ));
                            run_worker_on(client, &worker_opts(), |_stage, reqs| {
                                reqs.iter()
                                    .map(|req| {
                                        let mut rng = req.rng.clone();
                                        Ok(toy_score(&space, &req.genome, &mut rng))
                                    })
                                    .collect()
                            })
                            .unwrap();
                        });
                    }
                    let outcome = global_search_with(&driver, &space, micro_config(24, 42));
                    // stop the worker threads whether or not the search
                    // succeeded, or a failed assertion would hang the scope
                    host.request_shutdown().unwrap();
                    outcome.unwrap()
                });

                assert_eq!(
                    outcome.records.len(),
                    reference.records.len(),
                    "tcp shards={shards} workers={workers}"
                );
                for (a, b) in reference.records.iter().zip(&outcome.records) {
                    assert_eq!(a.id, b.id, "tcp shards={shards} workers={workers}");
                    assert_eq!(a.genome, b.genome, "tcp shards={shards} workers={workers}");
                    assert_eq!(a.accuracy, b.accuracy, "tcp shards={shards} workers={workers}");
                    assert_eq!(
                        a.objectives, b.objectives,
                        "tcp shards={shards} workers={workers}"
                    );
                }
                assert_eq!(outcome.front, reference.front);
                assert_eq!(outcome.selected, reference.selected);
                assert_eq!(outcome.evaluations, reference.evaluations);
                assert_eq!(outcome.cache_hits, reference.cache_hits);
            }
        }
    }

    /// A worker whose driver vanishes exits cleanly (typed timeouts +
    /// the dead-driver threshold) instead of hanging forever.
    #[test]
    fn worker_survives_a_dead_driver() {
        let addr = {
            // bind, learn the port, and close the listener again: nothing
            // serves this address afterwards
            let host = TcpHost::listen("127.0.0.1:0", None, "tok-dead").unwrap();
            host.addr().to_string()
        };
        let client: Arc<dyn ShardTransport> =
            Arc::new(TcpWorker::connect(&addr, Duration::from_millis(50), "tok-dead"));
        let t0 = Instant::now();
        let summary = run_worker_on(client, &worker_opts(), |_stage, _reqs| Vec::new()).unwrap();
        assert_eq!(summary.shards, 0);
        assert!(
            t0.elapsed() < Duration::from_secs(20),
            "worker exited promptly, took {:?}",
            t0.elapsed()
        );
    }
}
