//! Persistent genome-keyed evaluation cache.
//!
//! The memoisation table that [`super::ParallelEvaluator`] commits trial
//! evaluations into, promoted to a first-class subsystem: an [`EvalCache`]
//! can snapshot itself to a JSON file (write-through on every commit) and
//! restore from it on start, so repeated searches share prior training
//! work across runs instead of retraining identical candidates.
//!
//! # Snapshot layout
//!
//! One cache file holds several **scopes** — independent entry sets keyed
//! by a caller-chosen string (objective set, epoch budget, …):
//!
//! ```json
//! {"search|[Accuracy, Bops]|epochs=5": [{"genome": {...}, "accuracy": 0.64, ...}],
//!  "baseline|epochs=5": [...]}
//! ```
//!
//! Scopes exist because an evaluation is only reusable under the *same*
//! training protocol: the NAC and SNAC searches record different objective
//! vectors for the same genome, and the baseline protocol trains with its
//! own RNG stream. Each stage loads exactly its scope; the other scopes
//! are preserved verbatim on save, so the whole pipeline can point at one
//! `--cache-path`.
//!
//! A missing file is an empty cache; a corrupted file is an empty cache
//! plus a warning (the search must never abort over a bad snapshot — the
//! next commit rewrites it). Saves go through a temp-file rename so a
//! crash mid-write cannot destroy the previous snapshot.

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::{Mutex, MutexGuard};

use anyhow::{Context, Result};

use crate::nn::{Genome, SearchSpace};
use crate::util::Json;

use super::TrialEvaluation;

/// Lock a mutex, recovering the data from a poisoned lock. A worker panic
/// already surfaces through `std::thread::scope`; turning every later
/// lock into an opaque `PoisonError` unwrap far from the root cause would
/// only hide it.
pub(crate) fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Where and under which scope a cache persists.
struct Persist {
    path: PathBuf,
    scope: String,
    /// Entry arrays of *other* scopes found in the snapshot, carried
    /// through every save untouched.
    others: BTreeMap<String, Json>,
}

/// Genome-keyed evaluation memo, optionally backed by a JSON snapshot.
pub struct EvalCache {
    entries: Mutex<HashMap<Genome, TrialEvaluation>>,
    restored: usize,
    persist: Option<Persist>,
}

impl EvalCache {
    /// A process-lifetime cache with no backing file (the PR-1 behaviour).
    pub fn in_memory() -> EvalCache {
        EvalCache {
            entries: Mutex::new(HashMap::new()),
            restored: 0,
            persist: None,
        }
    }

    /// Open `path` and restore this `scope`'s entries. Missing file →
    /// empty cache; corrupted file → empty cache + a warning on stderr.
    /// Either way the cache stays attached to `path` and writes through
    /// on every insert.
    pub fn load(path: &Path, space: &SearchSpace, scope: &str) -> EvalCache {
        let mut entries = HashMap::new();
        let mut others = BTreeMap::new();
        if let Ok(text) = std::fs::read_to_string(path) {
            match parse_snapshot(&text, space, scope) {
                Ok((own, rest)) => {
                    entries = own;
                    others = rest;
                }
                Err(e) => eprintln!(
                    "[eval-cache] warning: ignoring corrupted cache file {}: {e:#}",
                    path.display()
                ),
            }
        }
        let restored = entries.len();
        EvalCache {
            entries: Mutex::new(entries),
            restored,
            persist: Some(Persist {
                path: path.to_path_buf(),
                scope: scope.to_string(),
                others,
            }),
        }
    }

    /// [`EvalCache::load`] when a path is configured, else
    /// [`EvalCache::in_memory`].
    pub fn open(path: Option<&Path>, space: &SearchSpace, scope: &str) -> EvalCache {
        match path {
            Some(p) => EvalCache::load(p, space, scope),
            None => EvalCache::in_memory(),
        }
    }

    /// Is this genome already evaluated?
    pub fn contains(&self, genome: &Genome) -> bool {
        lock_unpoisoned(&self.entries).contains_key(genome)
    }

    /// The memoised evaluation for `genome`, if any.
    pub fn lookup(&self, genome: &Genome) -> Option<TrialEvaluation> {
        lock_unpoisoned(&self.entries).get(genome).cloned()
    }

    /// Commit one evaluation, writing the snapshot through when a path is
    /// attached. Persistence failures warn rather than fail: losing the
    /// snapshot must not lose the search.
    pub fn insert(&self, genome: Genome, evaluation: TrialEvaluation) {
        let mut entries = lock_unpoisoned(&self.entries);
        entries.insert(genome, evaluation);
        if let Some(persist) = &self.persist {
            if let Err(e) = save_snapshot(persist, &entries) {
                eprintln!(
                    "[eval-cache] warning: could not persist to {}: {e}",
                    persist.path.display()
                );
            }
        }
    }

    /// Distinct genomes memoised so far.
    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.entries).len()
    }

    /// True when nothing is memoised yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// How many entries were restored from the snapshot at load time.
    pub fn restored(&self) -> usize {
        self.restored
    }

    /// The backing snapshot path, if persistent.
    pub fn path(&self) -> Option<&Path> {
        self.persist.as_ref().map(|p| p.path.as_path())
    }
}

fn entry_to_json(genome: &Genome, e: &TrialEvaluation) -> Json {
    let opt = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
    Json::obj(vec![
        ("genome", genome.to_json()),
        ("accuracy", Json::Num(e.accuracy)),
        ("bops", Json::Num(e.bops)),
        ("est_avg_resources", opt(e.est_avg_resources)),
        ("est_clock_cycles", opt(e.est_clock_cycles)),
        ("objectives", Json::nums(e.objectives.iter().copied())),
        ("train_seconds", Json::Num(e.train_seconds)),
    ])
}

fn entry_from_json(j: &Json, space: &SearchSpace) -> Result<(Genome, TrialEvaluation)> {
    let genome = Genome::from_json(j.get("genome").context("cache entry missing genome")?)?;
    anyhow::ensure!(space.contains(&genome), "cached genome outside the search space");
    // required fields read `null` back as NaN (the writer serialises
    // non-finite numbers as `null`); optional estimates keep `as_f64`,
    // where `null` legitimately means "not estimated"
    let f = |k: &str| -> Result<f64> {
        j.get(k)
            .and_then(Json::as_f64_or_nan)
            .with_context(|| format!("cache entry missing `{k}`"))
    };
    let optf = |k: &str| j.get(k).and_then(Json::as_f64);
    let objectives: Vec<f64> = j
        .get("objectives")
        .context("cache entry missing objectives")?
        .items()
        .iter()
        .filter_map(Json::as_f64_or_nan)
        .collect();
    anyhow::ensure!(!objectives.is_empty(), "cache entry has an empty objective vector");
    Ok((
        genome,
        TrialEvaluation {
            accuracy: f("accuracy")?,
            bops: f("bops")?,
            est_avg_resources: optf("est_avg_resources"),
            est_clock_cycles: optf("est_clock_cycles"),
            objectives,
            train_seconds: f("train_seconds")?,
        },
    ))
}

type Scoped = (HashMap<Genome, TrialEvaluation>, BTreeMap<String, Json>);

fn parse_snapshot(text: &str, space: &SearchSpace, scope: &str) -> Result<Scoped> {
    let doc = Json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let Json::Obj(map) = doc else {
        anyhow::bail!("cache snapshot must be a JSON object keyed by scope");
    };
    let mut entries = HashMap::new();
    let mut others = BTreeMap::new();
    for (key, value) in map {
        if key == scope {
            for item in value.items() {
                let (genome, evaluation) = entry_from_json(item, space)?;
                entries.insert(genome, evaluation);
            }
        } else {
            others.insert(key, value);
        }
    }
    Ok((entries, others))
}

/// Cheap total order over genomes (the snapshot sort key): the snapshot
/// bytes stay deterministic regardless of hash-map iteration order,
/// without serialising every entry twice.
fn genome_key(g: &Genome) -> (usize, [usize; crate::nn::NUM_LAYERS], usize, bool, usize, usize, usize) {
    (
        g.n_layers,
        g.width_idx,
        g.act.index(),
        g.batch_norm,
        g.lr_idx,
        g.l1_idx,
        g.dropout_idx,
    )
}

fn save_snapshot(
    persist: &Persist,
    entries: &HashMap<Genome, TrialEvaluation>,
) -> std::io::Result<()> {
    let mut rows: Vec<(&Genome, &TrialEvaluation)> = entries.iter().collect();
    rows.sort_by_key(|(g, _)| genome_key(g));
    let mut map = persist.others.clone();
    map.insert(
        persist.scope.clone(),
        Json::Arr(rows.into_iter().map(|(g, e)| entry_to_json(g, e)).collect()),
    );
    if let Some(dir) = persist.path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let tmp = persist.path.with_extension("tmp");
    std::fs::write(&tmp, Json::Obj(map).to_string())?;
    std::fs::rename(&tmp, &persist.path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn evaluation(acc: f64, res: Option<f64>, cc: Option<f64>) -> TrialEvaluation {
        TrialEvaluation {
            accuracy: acc,
            bops: 1234.0,
            est_avg_resources: res,
            est_clock_cycles: cc,
            objectives: vec![-acc, 1234.0],
            train_seconds: 0.25,
        }
    }

    fn tmp_path(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("snac_eval_cache_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn snapshot_roundtrips_including_optional_estimates() {
        let space = SearchSpace::table1();
        let mut rng = Rng::new(41);
        let path = tmp_path("roundtrip.json");
        let _ = std::fs::remove_file(&path);

        let a = space.sample(&mut rng);
        let b = space.sample(&mut rng);
        let cache = EvalCache::load(&path, &space, "test");
        assert_eq!(cache.restored(), 0);
        cache.insert(a.clone(), evaluation(0.61, None, None));
        cache.insert(b.clone(), evaluation(0.66, Some(3.5), Some(41.0)));

        let reloaded = EvalCache::load(&path, &space, "test");
        assert_eq!(reloaded.restored(), 2);
        assert_eq!(reloaded.len(), 2);
        let ea = reloaded.lookup(&a).unwrap();
        assert_eq!(ea.accuracy, 0.61);
        assert_eq!(ea.est_avg_resources, None);
        assert_eq!(ea.est_clock_cycles, None);
        assert_eq!(ea.objectives, vec![-0.61, 1234.0]);
        assert_eq!(ea.train_seconds, 0.25);
        let eb = reloaded.lookup(&b).unwrap();
        assert_eq!(eb.est_avg_resources, Some(3.5));
        assert_eq!(eb.est_clock_cycles, Some(41.0));
    }

    #[test]
    fn scopes_are_isolated_but_share_one_file() {
        let space = SearchSpace::table1();
        let mut rng = Rng::new(42);
        let path = tmp_path("scopes.json");
        let _ = std::fs::remove_file(&path);

        let g = space.sample(&mut rng);
        let nac = EvalCache::load(&path, &space, "nac");
        nac.insert(g.clone(), evaluation(0.6, None, None));

        // a different scope sees none of nac's entries...
        let snac = EvalCache::load(&path, &space, "snac");
        assert_eq!(snac.restored(), 0);
        assert!(!snac.contains(&g));
        snac.insert(g.clone(), evaluation(0.7, Some(1.0), Some(2.0)));

        // ...and saving it preserved nac's entries verbatim
        let nac2 = EvalCache::load(&path, &space, "nac");
        assert_eq!(nac2.restored(), 1);
        assert_eq!(nac2.lookup(&g).unwrap().accuracy, 0.6);
        let snac2 = EvalCache::load(&path, &space, "snac");
        assert_eq!(snac2.lookup(&g).unwrap().accuracy, 0.7);
    }

    #[test]
    fn corrupted_snapshot_falls_back_to_empty_and_recovers() {
        let space = SearchSpace::table1();
        let mut rng = Rng::new(43);
        let path = tmp_path("corrupt.json");
        for garbage in ["{\"test\": [{]", "[1,2,3]", "{\"test\": [{\"genome\": 7}]}"] {
            std::fs::write(&path, garbage).unwrap();
            // load warns but must not abort
            let cache = EvalCache::load(&path, &space, "test");
            assert_eq!(cache.restored(), 0);
            assert!(cache.is_empty());
            // the cache stays usable: the next commit rewrites the file
            let g = space.sample(&mut rng);
            cache.insert(g.clone(), evaluation(0.5, None, None));
            let reloaded = EvalCache::load(&path, &space, "test");
            assert_eq!(reloaded.restored(), 1);
            assert!(reloaded.contains(&g));
        }
    }

    #[test]
    fn nan_objective_round_trips_without_poisoning_the_snapshot() {
        // regression: `write!`-serialised NaN/inf produced `NaN`/`inf`
        // tokens Json::parse rejects, so one bad objective made the whole
        // snapshot read back as "corrupted" and silently discarded every
        // cached evaluation on the next run.
        let space = SearchSpace::table1();
        let mut rng = Rng::new(44);
        let path = tmp_path("nan_objective.json");
        let _ = std::fs::remove_file(&path);

        let good = space.sample(&mut rng);
        let bad = space.sample(&mut rng);
        let cache = EvalCache::load(&path, &space, "test");
        cache.insert(good.clone(), evaluation(0.62, Some(2.0), Some(7.0)));
        let mut poisoned = evaluation(f64::NAN, None, None);
        poisoned.objectives = vec![f64::NAN, 1234.0];
        cache.insert(bad.clone(), poisoned);

        let reloaded = EvalCache::load(&path, &space, "test");
        assert_eq!(
            reloaded.restored(),
            2,
            "NaN entry must not discard the snapshot"
        );
        // the good sibling is fully intact...
        let g = reloaded.lookup(&good).unwrap();
        assert_eq!(g.accuracy, 0.62);
        assert_eq!(g.objectives, vec![-0.62, 1234.0]);
        // ...and the NaN entry reads back as NaN with its full shape
        let b = reloaded.lookup(&bad).unwrap();
        assert!(b.accuracy.is_nan());
        assert_eq!(b.objectives.len(), 2);
        assert!(b.objectives[0].is_nan());
        assert_eq!(b.objectives[1], 1234.0);
    }

    #[test]
    fn missing_file_is_an_empty_cache() {
        let space = SearchSpace::table1();
        let path = tmp_path("never_written.json");
        let _ = std::fs::remove_file(&path);
        let cache = EvalCache::load(&path, &space, "test");
        assert_eq!(cache.restored(), 0);
        assert!(cache.is_empty());
        assert!(!path.exists(), "load alone must not create the file");
    }

    #[test]
    fn in_memory_cache_has_no_path() {
        let cache = EvalCache::in_memory();
        assert!(cache.path().is_none());
        assert_eq!(cache.restored(), 0);
    }
}
