//! Persistent genome-keyed evaluation cache.
//!
//! The memoisation table that [`super::ParallelEvaluator`] commits trial
//! evaluations into, promoted to a first-class subsystem: an [`EvalCache`]
//! can snapshot itself to a JSON file (write-through on every commit) and
//! restore from it on start, so repeated searches share prior training
//! work across runs instead of retraining identical candidates.
//!
//! # Snapshot layout
//!
//! One cache file holds several **scopes** — independent entry sets keyed
//! by a caller-chosen string (objective set, epoch budget, …):
//!
//! ```json
//! {"search|[Accuracy, Bops]|epochs=5": [{"genome": {...}, "accuracy": 0.64, ...}],
//!  "baseline|epochs=5": [...]}
//! ```
//!
//! Scopes exist because an evaluation is only reusable under the *same*
//! training protocol: the NAC and SNAC searches record different objective
//! vectors for the same genome, and the baseline protocol trains with its
//! own RNG stream. Each stage loads exactly its scope; the other scopes
//! are preserved verbatim on save, so the whole pipeline can point at one
//! `--cache-path`.
//!
//! A missing file is an empty cache; a corrupted file is an empty cache
//! plus a warning (the search must never abort over a bad snapshot — the
//! next commit rewrites it).
//!
//! # Multi-process write-through
//!
//! Several *processes* may write through to one snapshot path — the shard
//! subsystem's driver plus independent runs pointed at the same
//! `--cache-path`. Three mechanisms keep that safe:
//!
//! 1. every save writes a **uniquely named** temp file (pid + sequence)
//!    and atomically renames it over the snapshot, so a reader (or a
//!    concurrent writer's rename) can never observe a half-written file;
//! 2. saves serialise on a **`.lock` sidecar** (`create_new`, stolen when
//!    visibly stale), so read-merge-write cycles do not interleave;
//! 3. each save **re-reads and merges** the on-disk snapshot under the
//!    lock: other scopes are taken from disk (freshest wins), and for this
//!    cache's own scope the on-disk entries are unioned with the in-memory
//!    map (memory wins per genome) — two processes hammering one scope
//!    converge to the union of their work instead of last-writer-wins.
//!
//! Lock acquisition is bounded: rather than ever losing the search to a
//! dead writer, a save that cannot get the lock within its patience
//! proceeds unlocked (still atomic thanks to the unique temp + rename).

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::nn::{Genome, SearchSpace};
use crate::util::Json;

use super::TrialEvaluation;

/// Lock a mutex, recovering the data from a poisoned lock. A worker panic
/// already surfaces through `std::thread::scope`; turning every later
/// lock into an opaque `PoisonError` unwrap far from the root cause would
/// only hide it.
pub(crate) fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Where and under which scope a cache persists.
struct Persist {
    path: PathBuf,
    scope: String,
    /// Entry arrays of *other* scopes, refreshed from disk whenever an
    /// external modification is detected and falling back to the
    /// load-time copy when the disk read fails.
    others: Mutex<BTreeMap<String, Json>>,
    /// `(mtime, len)` of the snapshot as this cache last wrote it. While
    /// the on-disk file still matches, saves skip the read-merge pass —
    /// the single-writer hot path stays O(serialise), not
    /// O(parse whole snapshot) per commit.
    last_saved: Mutex<Option<(std::time::SystemTime, u64)>>,
}

/// Genome-keyed evaluation memo, optionally backed by a JSON snapshot.
pub struct EvalCache {
    entries: Mutex<HashMap<Genome, TrialEvaluation>>,
    restored: usize,
    persist: Option<Persist>,
}

impl EvalCache {
    /// A process-lifetime cache with no backing file (the PR-1 behaviour).
    pub fn in_memory() -> EvalCache {
        EvalCache {
            entries: Mutex::new(HashMap::new()),
            restored: 0,
            persist: None,
        }
    }

    /// Open `path` and restore this `scope`'s entries. Missing file →
    /// empty cache; corrupted file → empty cache + a warning on stderr.
    /// Either way the cache stays attached to `path` and writes through
    /// on every insert.
    pub fn load(path: &Path, space: &SearchSpace, scope: &str) -> EvalCache {
        let mut entries = HashMap::new();
        let mut others = BTreeMap::new();
        if let Ok(text) = std::fs::read_to_string(path) {
            match parse_snapshot(&text, space, scope) {
                Ok((own, rest)) => {
                    entries = own;
                    others = rest;
                }
                Err(e) => eprintln!(
                    "[eval-cache] warning: ignoring corrupted cache file {}: {e:#}",
                    path.display()
                ),
            }
        }
        let restored = entries.len();
        EvalCache {
            entries: Mutex::new(entries),
            restored,
            persist: Some(Persist {
                path: path.to_path_buf(),
                scope: scope.to_string(),
                others: Mutex::new(others),
                last_saved: Mutex::new(None),
            }),
        }
    }

    /// [`EvalCache::load`] when a path is configured, else
    /// [`EvalCache::in_memory`].
    pub fn open(path: Option<&Path>, space: &SearchSpace, scope: &str) -> EvalCache {
        match path {
            Some(p) => EvalCache::load(p, space, scope),
            None => EvalCache::in_memory(),
        }
    }

    /// Is this genome already evaluated?
    pub fn contains(&self, genome: &Genome) -> bool {
        lock_unpoisoned(&self.entries).contains_key(genome)
    }

    /// The memoised evaluation for `genome`, if any.
    pub fn lookup(&self, genome: &Genome) -> Option<TrialEvaluation> {
        lock_unpoisoned(&self.entries).get(genome).cloned()
    }

    /// Commit one evaluation, writing the snapshot through when a path is
    /// attached. Persistence failures warn rather than fail: losing the
    /// snapshot must not lose the search.
    pub fn insert(&self, genome: Genome, evaluation: TrialEvaluation) {
        lock_unpoisoned(&self.entries).insert(genome, evaluation);
        // the save re-acquires the entries lock only for the brief
        // fold-and-serialise step — file I/O and cross-process lock
        // waits never block concurrent lookups/commits
        if let Some(persist) = &self.persist {
            if let Err(e) = save_snapshot(persist, &self.entries) {
                eprintln!(
                    "[eval-cache] warning: could not persist to {}: {e}",
                    persist.path.display()
                );
            }
        }
    }

    /// Distinct genomes memoised so far.
    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.entries).len()
    }

    /// True when nothing is memoised yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// How many entries were restored from the snapshot at load time.
    pub fn restored(&self) -> usize {
        self.restored
    }

    /// The backing snapshot path, if persistent.
    pub fn path(&self) -> Option<&Path> {
        self.persist.as_ref().map(|p| p.path.as_path())
    }
}

fn entry_to_json(genome: &Genome, e: &TrialEvaluation) -> Json {
    // the shared TrialEvaluation codec plus the genome key
    let Json::Obj(mut obj) = e.to_json() else {
        unreachable!("TrialEvaluation::to_json returns an object")
    };
    obj.insert("genome".to_string(), genome.to_json());
    Json::Obj(obj)
}

fn entry_from_json(j: &Json, space: &SearchSpace) -> Result<(Genome, TrialEvaluation)> {
    let genome = Genome::from_json(j.get("genome").context("cache entry missing genome")?)?;
    anyhow::ensure!(space.contains(&genome), "cached genome outside the search space");
    let evaluation = TrialEvaluation::from_json(j).context("cache entry")?;
    Ok((genome, evaluation))
}

type Scoped = (HashMap<Genome, TrialEvaluation>, BTreeMap<String, Json>);

fn parse_snapshot(text: &str, space: &SearchSpace, scope: &str) -> Result<Scoped> {
    let doc = Json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let Json::Obj(map) = doc else {
        anyhow::bail!("cache snapshot must be a JSON object keyed by scope");
    };
    let mut entries = HashMap::new();
    let mut others = BTreeMap::new();
    for (key, value) in map {
        if key == scope {
            for item in value.items() {
                let (genome, evaluation) = entry_from_json(item, space)?;
                entries.insert(genome, evaluation);
            }
        } else {
            others.insert(key, value);
        }
    }
    Ok((entries, others))
}

/// Cheap total order over genomes (the snapshot sort key): the snapshot
/// bytes stay deterministic regardless of hash-map iteration order,
/// without serialising every entry twice.
fn genome_key(g: &Genome) -> (usize, [usize; crate::nn::NUM_LAYERS], usize, bool, usize, usize, usize) {
    (
        g.n_layers,
        g.width_idx,
        g.act.index(),
        g.batch_norm,
        g.lr_idx,
        g.l1_idx,
        g.dropout_idx,
    )
}

/// Advisory cross-process save lock: a `create_new` sidecar file next to
/// the snapshot. Held for one read-merge-write cycle; removed on drop;
/// stolen when visibly stale (a crashed writer). Acquisition is bounded —
/// a writer that cannot get the lock proceeds unlocked rather than ever
/// stalling the search (the unique-temp + rename below keeps even that
/// race tear-free; only union-merging needs the lock).
struct SnapshotLock {
    path: PathBuf,
}

impl SnapshotLock {
    fn acquire(snapshot: &Path) -> Option<SnapshotLock> {
        let path = snapshot.with_extension("lock");
        // patience (~12 s) deliberately exceeds the 10 s stale-steal
        // threshold: a crashed writer's lock is always stolen before a
        // competitor gives up and falls back to an unlocked save, so
        // only a genuinely wedged (alive but >12 s) writer can force the
        // unserialised path
        for _ in 0..2400 {
            match std::fs::OpenOptions::new()
                .write(true)
                .create_new(true)
                .open(&path)
            {
                Ok(_) => return Some(SnapshotLock { path }),
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let stale = std::fs::metadata(&path)
                        .and_then(|m| m.modified())
                        .ok()
                        .and_then(|m| m.elapsed().ok())
                        .is_some_and(|age| age > Duration::from_secs(10));
                    if stale {
                        // steal by *rename*, not remove: two stealers
                        // racing can only retire the stale lock once — a
                        // fresh lock created by the faster stealer can
                        // never be deleted by the slower one
                        let stolen = path.with_extension(format!(
                            "lock.stale.{}.{}",
                            std::process::id(),
                            SAVE_SEQ.fetch_add(1, Ordering::Relaxed)
                        ));
                        if std::fs::rename(&path, &stolen).is_ok() {
                            let _ = std::fs::remove_file(&stolen);
                        }
                    } else {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                }
                // e.g. a read-only directory: fall through to an
                // unlocked (still atomic) save
                Err(_) => return None,
            }
        }
        None
    }
}

impl Drop for SnapshotLock {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Per-process temp-name sequence (two caches on one path in one process
/// may save concurrently when the lock times out).
static SAVE_SEQ: AtomicU64 = AtomicU64::new(0);

/// `(mtime, len)` fingerprint for the skip-merge fast path.
fn file_stat(path: &Path) -> Option<(std::time::SystemTime, u64)> {
    let meta = std::fs::metadata(path).ok()?;
    Some((meta.modified().ok()?, meta.len()))
}

fn save_snapshot(
    persist: &Persist,
    entries_mutex: &Mutex<HashMap<Genome, TrialEvaluation>>,
) -> std::io::Result<()> {
    if let Some(dir) = persist.path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let _lock = SnapshotLock::acquire(&persist.path);

    // Merge with the freshest on-disk state when (and only when) someone
    // else has written the snapshot since our last save: other scopes
    // refresh from disk, and disk-only genomes of our own scope fold into
    // the in-memory map (memory wins per genome) — two processes
    // hammering one scope converge to the union of their work. The
    // single-writer hot path skips all of this: the file still carries
    // our own last write, so there is nothing to learn from re-parsing
    // it on every commit. File reading and parsing happen *before* the
    // entries lock is taken, so concurrent evaluation threads are never
    // blocked on disk.
    let stat = file_stat(&persist.path);
    let externally_modified = stat.is_some() && stat != *lock_unpoisoned(&persist.last_saved);
    let mut disk_own: Vec<(Genome, TrialEvaluation)> = Vec::new();
    if externally_modified {
        let mut others = lock_unpoisoned(&persist.others);
        if let Ok(text) = std::fs::read_to_string(&persist.path) {
            if let Ok(Json::Obj(map)) = Json::parse(&text).map_err(|e| e.to_string()) {
                for (key, value) in map {
                    if key == persist.scope {
                        for item in value.items() {
                            let genome = item.get("genome").map(Genome::from_json);
                            if let (Some(Ok(genome)), Ok(evaluation)) =
                                (genome, TrialEvaluation::from_json(item))
                            {
                                disk_own.push((genome, evaluation));
                            }
                        }
                    } else {
                        others.insert(key, value);
                    }
                }
            }
        }
    }

    // brief critical section: fold the disk-only genomes in (memory wins)
    // and serialise a point-in-time view; deterministic row order
    // regardless of hash-map iteration or which process merged last
    let own_rows = {
        let mut entries = lock_unpoisoned(entries_mutex);
        for (genome, evaluation) in disk_own {
            entries.entry(genome).or_insert(evaluation);
        }
        let mut rows: Vec<(&Genome, &TrialEvaluation)> = entries.iter().collect();
        rows.sort_by_key(|(g, _)| genome_key(g));
        Json::Arr(rows.into_iter().map(|(g, e)| entry_to_json(g, e)).collect())
    };
    let mut map = lock_unpoisoned(&persist.others).clone();
    map.insert(persist.scope.clone(), own_rows);

    // uniquely named temp + atomic rename: no reader or concurrent
    // writer can ever observe (or clobber) a half-written snapshot
    let tmp = persist.path.with_extension(format!(
        "tmp.{}.{}",
        std::process::id(),
        SAVE_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::write(&tmp, Json::Obj(map).to_string())?;
    // fingerprint the temp file, not the destination: rename preserves
    // the inode, so this is exactly what the destination will carry —
    // and a concurrent writer renaming over us right after cannot be
    // mistaken for our own write on the next save
    let written = file_stat(&tmp);
    std::fs::rename(&tmp, &persist.path)?;
    *lock_unpoisoned(&persist.last_saved) = written;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn evaluation(acc: f64, res: Option<f64>, cc: Option<f64>) -> TrialEvaluation {
        TrialEvaluation {
            accuracy: acc,
            bops: 1234.0,
            est_avg_resources: res,
            est_clock_cycles: cc,
            objectives: vec![-acc, 1234.0],
            train_seconds: 0.25,
        }
    }

    fn tmp_path(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("snac_eval_cache_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn snapshot_roundtrips_including_optional_estimates() {
        let space = SearchSpace::table1();
        let mut rng = Rng::new(41);
        let path = tmp_path("roundtrip.json");
        let _ = std::fs::remove_file(&path);

        let a = space.sample(&mut rng);
        let b = space.sample(&mut rng);
        let cache = EvalCache::load(&path, &space, "test");
        assert_eq!(cache.restored(), 0);
        cache.insert(a.clone(), evaluation(0.61, None, None));
        cache.insert(b.clone(), evaluation(0.66, Some(3.5), Some(41.0)));

        let reloaded = EvalCache::load(&path, &space, "test");
        assert_eq!(reloaded.restored(), 2);
        assert_eq!(reloaded.len(), 2);
        let ea = reloaded.lookup(&a).unwrap();
        assert_eq!(ea.accuracy, 0.61);
        assert_eq!(ea.est_avg_resources, None);
        assert_eq!(ea.est_clock_cycles, None);
        assert_eq!(ea.objectives, vec![-0.61, 1234.0]);
        assert_eq!(ea.train_seconds, 0.25);
        let eb = reloaded.lookup(&b).unwrap();
        assert_eq!(eb.est_avg_resources, Some(3.5));
        assert_eq!(eb.est_clock_cycles, Some(41.0));
    }

    #[test]
    fn scopes_are_isolated_but_share_one_file() {
        let space = SearchSpace::table1();
        let mut rng = Rng::new(42);
        let path = tmp_path("scopes.json");
        let _ = std::fs::remove_file(&path);

        let g = space.sample(&mut rng);
        let nac = EvalCache::load(&path, &space, "nac");
        nac.insert(g.clone(), evaluation(0.6, None, None));

        // a different scope sees none of nac's entries...
        let snac = EvalCache::load(&path, &space, "snac");
        assert_eq!(snac.restored(), 0);
        assert!(!snac.contains(&g));
        snac.insert(g.clone(), evaluation(0.7, Some(1.0), Some(2.0)));

        // ...and saving it preserved nac's entries verbatim
        let nac2 = EvalCache::load(&path, &space, "nac");
        assert_eq!(nac2.restored(), 1);
        assert_eq!(nac2.lookup(&g).unwrap().accuracy, 0.6);
        let snac2 = EvalCache::load(&path, &space, "snac");
        assert_eq!(snac2.lookup(&g).unwrap().accuracy, 0.7);
    }

    #[test]
    fn corrupted_snapshot_falls_back_to_empty_and_recovers() {
        let space = SearchSpace::table1();
        let mut rng = Rng::new(43);
        let path = tmp_path("corrupt.json");
        for garbage in ["{\"test\": [{]", "[1,2,3]", "{\"test\": [{\"genome\": 7}]}"] {
            std::fs::write(&path, garbage).unwrap();
            // load warns but must not abort
            let cache = EvalCache::load(&path, &space, "test");
            assert_eq!(cache.restored(), 0);
            assert!(cache.is_empty());
            // the cache stays usable: the next commit rewrites the file
            let g = space.sample(&mut rng);
            cache.insert(g.clone(), evaluation(0.5, None, None));
            let reloaded = EvalCache::load(&path, &space, "test");
            assert_eq!(reloaded.restored(), 1);
            assert!(reloaded.contains(&g));
        }
    }

    #[test]
    fn nan_objective_round_trips_without_poisoning_the_snapshot() {
        // regression: `write!`-serialised NaN/inf produced `NaN`/`inf`
        // tokens Json::parse rejects, so one bad objective made the whole
        // snapshot read back as "corrupted" and silently discarded every
        // cached evaluation on the next run.
        let space = SearchSpace::table1();
        let mut rng = Rng::new(44);
        let path = tmp_path("nan_objective.json");
        let _ = std::fs::remove_file(&path);

        let good = space.sample(&mut rng);
        let bad = space.sample(&mut rng);
        let cache = EvalCache::load(&path, &space, "test");
        cache.insert(good.clone(), evaluation(0.62, Some(2.0), Some(7.0)));
        let mut poisoned = evaluation(f64::NAN, None, None);
        poisoned.objectives = vec![f64::NAN, 1234.0];
        cache.insert(bad.clone(), poisoned);

        let reloaded = EvalCache::load(&path, &space, "test");
        assert_eq!(
            reloaded.restored(),
            2,
            "NaN entry must not discard the snapshot"
        );
        // the good sibling is fully intact...
        let g = reloaded.lookup(&good).unwrap();
        assert_eq!(g.accuracy, 0.62);
        assert_eq!(g.objectives, vec![-0.62, 1234.0]);
        // ...and the NaN entry reads back as NaN with its full shape
        let b = reloaded.lookup(&bad).unwrap();
        assert!(b.accuracy.is_nan());
        assert_eq!(b.objectives.len(), 2);
        assert!(b.objectives[0].is_nan());
        assert_eq!(b.objectives[1], 1234.0);
    }

    #[test]
    fn missing_file_is_an_empty_cache() {
        let space = SearchSpace::table1();
        let path = tmp_path("never_written.json");
        let _ = std::fs::remove_file(&path);
        let cache = EvalCache::load(&path, &space, "test");
        assert_eq!(cache.restored(), 0);
        assert!(cache.is_empty());
        assert!(!path.exists(), "load alone must not create the file");
    }

    #[test]
    fn in_memory_cache_has_no_path() {
        let cache = EvalCache::in_memory();
        assert!(cache.path().is_none());
        assert_eq!(cache.restored(), 0);
    }

    /// Two caches on one path in one process (≈ the pipeline's stages, or
    /// a driver plus a second run): committing through both must lose
    /// neither scope's entries and never leave a torn file.
    #[test]
    fn two_caches_interleaving_commits_converge_to_the_union() {
        let space = SearchSpace::table1();
        let path = tmp_path("interleaved.json");
        let _ = std::fs::remove_file(&path);
        let a = EvalCache::load(&path, &space, "ia");
        let b = EvalCache::load(&path, &space, "ib");
        let mut rng = Rng::new(50);
        let mut genomes = Vec::new();
        while genomes.len() < 8 {
            let g = space.sample(&mut rng);
            if !genomes.contains(&g) {
                genomes.push(g);
            }
        }
        for (i, g) in genomes.iter().enumerate() {
            let cache = if i % 2 == 0 { &a } else { &b };
            cache.insert(g.clone(), evaluation(0.5 + i as f64 * 0.01, None, None));
        }
        let ra = EvalCache::load(&path, &space, "ia");
        let rb = EvalCache::load(&path, &space, "ib");
        assert_eq!(ra.restored(), 4, "scope ia kept all its entries");
        assert_eq!(rb.restored(), 4, "scope ib kept all its entries");
    }

    const HAMMER_PATH_ENV: &str = "SNAC_CACHE_HAMMER_PATH";
    const HAMMER_SCOPE_ENV: &str = "SNAC_CACHE_HAMMER_SCOPE";
    const HAMMER_SEED_ENV: &str = "SNAC_CACHE_HAMMER_SEED";
    const HAMMER_ENTRIES: usize = 12;

    /// Child half of the multi-process hammer below: a no-op under a
    /// normal `cargo test` run; when the env vars are set it writes
    /// `HAMMER_ENTRIES` distinct genomes through a persistent cache as
    /// fast as it can.
    #[test]
    fn cache_hammer_child_process() {
        let (Ok(path), Ok(scope), Ok(seed)) = (
            std::env::var(HAMMER_PATH_ENV),
            std::env::var(HAMMER_SCOPE_ENV),
            std::env::var(HAMMER_SEED_ENV),
        ) else {
            return;
        };
        let space = SearchSpace::table1();
        let cache = EvalCache::load(Path::new(&path), &space, &scope);
        let mut rng = Rng::new(seed.parse().unwrap());
        let mut genomes: Vec<Genome> = Vec::new();
        while genomes.len() < HAMMER_ENTRIES {
            let g = space.sample(&mut rng);
            if !genomes.contains(&g) {
                genomes.push(g);
            }
        }
        for (i, g) in genomes.into_iter().enumerate() {
            cache.insert(g, evaluation(0.4 + i as f64 * 0.001, None, None));
        }
    }

    /// Regression for the sharded-run concurrency hazard: two *processes*
    /// writing through to the same snapshot path must never tear it (a
    /// reader sees either the previous or the next complete snapshot,
    /// never a partial write) and must not clobber each other's scopes —
    /// the file converges to the union of both processes' work.
    #[test]
    fn concurrent_processes_do_not_tear_the_snapshot() {
        let space = SearchSpace::table1();
        let path = tmp_path("hammer.json");
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(path.with_extension("lock"));

        let exe = std::env::current_exe().unwrap();
        let spawn = |scope: &str, seed: u64| {
            std::process::Command::new(&exe)
                .args([
                    "eval::cache::tests::cache_hammer_child_process",
                    "--exact",
                    "--test-threads",
                    "1",
                ])
                .env(HAMMER_PATH_ENV, &path)
                .env(HAMMER_SCOPE_ENV, scope)
                .env(HAMMER_SEED_ENV, seed.to_string())
                .stdout(std::process::Stdio::null())
                .stderr(std::process::Stdio::null())
                .spawn()
                .expect("spawn hammer child")
        };
        let mut children = vec![spawn("hammer-a", 101), spawn("hammer-b", 202)];

        // while the children hammer, every observable file state must be a
        // complete, parseable snapshot (this is the tear check)
        let mut observed = 0usize;
        loop {
            let done = children.iter_mut().all(|c| {
                matches!(c.try_wait(), Ok(Some(status)) if {
                    assert!(status.success(), "hammer child failed");
                    true
                })
            });
            if let Ok(text) = std::fs::read_to_string(&path) {
                if !text.is_empty() {
                    Json::parse(&text).unwrap_or_else(|e| {
                        panic!("torn snapshot observed mid-hammer: {e}\n{text}")
                    });
                    observed += 1;
                }
            }
            if done {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert!(observed > 0, "the hammer ran long enough to observe the file");

        // union check: both processes' scopes kept every entry
        for scope in ["hammer-a", "hammer-b"] {
            let reloaded = EvalCache::load(&path, &space, scope);
            assert_eq!(
                reloaded.restored(),
                HAMMER_ENTRIES,
                "scope {scope} lost entries to the concurrent writer"
            );
        }
    }
}
