//! The trial-evaluation subsystem: how candidate architectures get scored.
//!
//! The global-search loop used to train-and-score every NSGA-II candidate
//! inline and strictly serially. This module factors that block into a
//! reusable subsystem shared by both search stages, the CLI, and the
//! benches:
//!
//! * [`TrialEvaluator`] — the interface: genome + per-trial RNG in,
//!   [`TrialEvaluation`] (accuracy, BOPs, surrogate estimates, minimised
//!   objective vector, timing) out.
//! * [`SupernetEvaluator`] — the paper's train-and-score path, extracted
//!   from the old `coordinator::search_loop` body: compile the genome to
//!   supernet inputs, train for the trial budget, evaluate on the
//!   validation split, price with the configured objective set.
//! * [`ParallelEvaluator`] — a scoped-thread pool that evaluates a whole
//!   generation concurrently with a configurable worker count, streaming
//!   each finished trial to the driver in trial order (no chunk barriers),
//!   plus a genome-keyed memoisation cache so a duplicate genome proposed
//!   across generations is trained once and recorded per-trial.
//! * [`EvalCache`] — that memoisation table as a first-class persistent
//!   subsystem: JSON snapshot/restore keyed by protocol scope
//!   (`--cache-path`), write-through on every commit — safe across
//!   processes, not just threads — so repeated runs share prior training
//!   work instead of retraining identical genomes.
//! * [`ShardDriver`] / [`run_worker`] — the multi-process seam
//!   (`eval/shard.rs`): a driver partitions each generation into a
//!   shard work queue, `snac-pack worker` processes claim shards
//!   (lease + heartbeat, reclaimed on worker death), and the driver
//!   merges the per-shard results back under the same determinism
//!   contract. The protocol is medium-agnostic behind [`ShardTransport`]
//!   (`eval/transport.rs`): [`FsTransport`] serves a shared `--run-dir`
//!   by atomic rename, [`TcpHost`]/[`TcpWorker`] (`eval/tcp.rs`) serve a
//!   driver-hosted TCP task queue for fleets with no shared filesystem.
//!   [`EvalPool`] abstracts over the dispatch backends so the search
//!   loop cannot tell them apart.
//!
//! # Determinism
//!
//! Results are *identical for every worker count* (everything except the
//! recorded wall-clock timings, which are live measurement). Three rules
//! make that hold:
//!
//! 1. per-trial RNGs are forked from the master stream **serially, in
//!    trial-id order**, before anything is dispatched (exactly the old
//!    `rng.fork(records.len() as u64)` sequence);
//! 2. within a batch, duplicate genomes are collapsed *before* dispatch —
//!    a genome is always evaluated with the RNG of its **first** trial id,
//!    regardless of scheduling;
//! 3. per-trial results are *emitted* in trial-id order: workers push
//!    completions to a driver-side channel in whatever order they finish,
//!    and the driver holds each trial back until every earlier trial has
//!    been emitted — so callers (and their progress sinks, which run on
//!    the driver thread and need not be `Send`) always observe the same
//!    stream.
//!
//! # Thread-safety
//!
//! Workers share one `&Runtime` (and its loaded executables) plus the
//! surrogate predictor; per-trial state (model parameters, Adam moments,
//! BN statistics) is created per evaluation, so nothing mutable is shared.
//! PJRT clients are thread-safe for concurrent execution and the offline
//! facade is plain data; if a future backend is not, load one `Runtime`
//! per worker or run with `workers = 1` (see `rust/xla/README.md`).

mod cache;
mod parallel;
mod shard;
mod supernet;
mod tcp;
mod transport;

use anyhow::{Context, Result};

use crate::nn::Genome;
use crate::util::{Json, Rng};

pub(crate) use cache::lock_unpoisoned;
pub use cache::EvalCache;
pub use parallel::{parallel_map, resolve_workers, EvaluatedTrial, ParallelEvaluator};
pub use shard::{
    manifest_fingerprint, run_worker, run_worker_on, ShardDriver, ShardError, ShardTimings,
    StageSpec, WorkerOptions, WorkerSummary,
};
pub use supernet::SupernetEvaluator;
pub use tcp::{ShardAuthError, TcpHost, TcpWorker};
pub use transport::{ClaimedTask, FsTransport, LeaseStatus, RunDir, ShardTransport};

/// Everything a single trial evaluation produces.
#[derive(Debug, Clone)]
pub struct TrialEvaluation {
    /// Validation accuracy after the trial's training budget.
    pub accuracy: f64,
    /// BOPs at the assumed deployment point (always computed — Table 2).
    pub bops: f64,
    /// Surrogate estimate: mean utilisation % (when a surrogate ran).
    pub est_avg_resources: Option<f64>,
    /// Surrogate estimate: latency cycles (when a surrogate ran).
    pub est_clock_cycles: Option<f64>,
    /// The minimised objective vector fed back to NSGA-II
    /// (slot 0 is negated accuracy by convention).
    pub objectives: Vec<f64>,
    /// Wall-clock seconds this evaluation cost.
    pub train_seconds: f64,
}

impl TrialEvaluation {
    /// Serialise to JSON — the shared codec behind the persistent
    /// [`EvalCache`] snapshot and the shard-protocol result files, so
    /// both round-trip numbers identically (non-finite values follow the
    /// `util::Json` `null` convention).
    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        Json::obj(vec![
            ("accuracy", Json::Num(self.accuracy)),
            ("bops", Json::Num(self.bops)),
            ("est_avg_resources", opt(self.est_avg_resources)),
            ("est_clock_cycles", opt(self.est_clock_cycles)),
            ("objectives", Json::nums(self.objectives.iter().copied())),
            ("train_seconds", Json::Num(self.train_seconds)),
        ])
    }

    /// Parse back from JSON. Required fields read `null` back as NaN (the
    /// writer serialises non-finite numbers as `null`); the optional
    /// estimates keep `as_f64`, where `null` legitimately means "not
    /// estimated".
    pub fn from_json(j: &Json) -> Result<TrialEvaluation> {
        let f = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(Json::as_f64_or_nan)
                .with_context(|| format!("evaluation missing `{k}`"))
        };
        let optf = |k: &str| j.get(k).and_then(Json::as_f64);
        let objectives: Vec<f64> = j
            .get("objectives")
            .context("evaluation missing objectives")?
            .items()
            .iter()
            .filter_map(Json::as_f64_or_nan)
            .collect();
        anyhow::ensure!(!objectives.is_empty(), "evaluation has an empty objective vector");
        Ok(TrialEvaluation {
            accuracy: f("accuracy")?,
            bops: f("bops")?,
            est_avg_resources: optf("est_avg_resources"),
            est_clock_cycles: optf("est_clock_cycles"),
            objectives,
            train_seconds: f("train_seconds")?,
        })
    }
}

/// One candidate scheduled for evaluation.
///
/// The RNG must already be forked from the master stream, keyed on
/// `trial_id` — the scheduler never touches the master stream itself, so
/// worker scheduling cannot perturb determinism.
#[derive(Debug, Clone)]
pub struct EvalRequest {
    /// Sequential trial id (stable across worker counts).
    pub trial_id: usize,
    /// The candidate architecture.
    pub genome: Genome,
    /// The trial's private RNG stream.
    pub rng: Rng,
}

impl EvalRequest {
    /// Serialise for a shard task file: the exact RNG state travels with
    /// the request so a worker process replays the identical stream.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("trial_id", Json::Num(self.trial_id as f64)),
            ("genome", self.genome.to_json()),
            ("rng", self.rng.to_json()),
        ])
    }

    /// Parse back from a shard task file.
    pub fn from_json(j: &Json) -> Result<EvalRequest> {
        Ok(EvalRequest {
            trial_id: j
                .get("trial_id")
                .and_then(Json::as_usize)
                .context("request missing trial_id")?,
            genome: Genome::from_json(j.get("genome").context("request missing genome")?)?,
            rng: Rng::from_json(j.get("rng").context("request missing rng")?)?,
        })
    }
}

/// Scores one genome. Implementations must be cheap to share across
/// threads (`Sync`); all per-trial mutable state belongs inside
/// `evaluate`.
pub trait TrialEvaluator: Sync {
    /// Evaluate one candidate with its pre-forked trial RNG.
    fn evaluate(&self, genome: &Genome, rng: &mut Rng) -> Result<TrialEvaluation>;

    /// Batch-stage shared work for a whole generation before its trials
    /// are dispatched. The pool calls this once per batch with the
    /// collapsed (deduplicated, uncached) genome list, on the driver
    /// thread, before any `evaluate` runs. Implementations must not
    /// change what `evaluate` returns — only how cheaply it gets there
    /// (e.g. [`SupernetEvaluator`] prefetches the generation's surrogate
    /// estimates in ⌈N/`SUR_BATCH`⌉ executions instead of N per-trial
    /// ones). Pools treat a failure as a skipped optimisation and fall
    /// back to per-trial work, which surfaces the same error under the
    /// normal batch error contract. The default does nothing.
    fn prepare(&self, _genomes: &[Genome]) -> Result<()> {
        Ok(())
    }
}

/// A driver-side evaluation pool: something that can score a whole
/// generation of [`EvalRequest`]s and stream the per-trial results back
/// **in trial order** under the subsystem's determinism contract.
///
/// Two implementations exist: [`ParallelEvaluator`] (scoped threads in
/// this process) and [`ShardDriver`] (a file-based work queue served by
/// `snac-pack worker` processes). `coordinator::global_search_with` is
/// generic over this trait, so the NSGA-II loop is identical whichever
/// dispatch backend scores its candidates.
pub trait EvalPool {
    /// Evaluate a batch, emitting each finished trial to `on_trial` in
    /// trial-id order (the [`ParallelEvaluator::evaluate_stream`]
    /// contract: successes commit even when a sibling fails, and the
    /// first failed dispatch's error propagates after the batch drains).
    fn evaluate_stream_dyn(
        &self,
        requests: Vec<EvalRequest>,
        on_trial: &mut dyn FnMut(EvaluatedTrial),
    ) -> Result<()>;

    /// Total successful inner evaluations committed so far.
    fn evaluations(&self) -> usize;

    /// Total trials served from the cache so far.
    fn cache_hits(&self) -> usize;

    /// The evaluation cache backing this pool.
    fn cache(&self) -> &EvalCache;
}
