//! The trial-evaluation subsystem: how candidate architectures get scored.
//!
//! The global-search loop used to train-and-score every NSGA-II candidate
//! inline and strictly serially. This module factors that block into a
//! reusable subsystem shared by both search stages, the CLI, and the
//! benches:
//!
//! * [`TrialEvaluator`] — the interface: genome + per-trial RNG in,
//!   [`TrialEvaluation`] (accuracy, BOPs, surrogate estimates, minimised
//!   objective vector, timing) out.
//! * [`SupernetEvaluator`] — the paper's train-and-score path, extracted
//!   from the old `coordinator::search_loop` body: compile the genome to
//!   supernet inputs, train for the trial budget, evaluate on the
//!   validation split, price with the configured objective set.
//! * [`ParallelEvaluator`] — a scoped-thread pool that evaluates a whole
//!   generation concurrently with a configurable worker count, streaming
//!   each finished trial to the driver in trial order (no chunk barriers),
//!   plus a genome-keyed memoisation cache so a duplicate genome proposed
//!   across generations is trained once and recorded per-trial.
//! * [`EvalCache`] — that memoisation table as a first-class persistent
//!   subsystem: JSON snapshot/restore keyed by protocol scope
//!   (`--cache-path`), write-through on every commit, so repeated runs
//!   share prior training work instead of retraining identical genomes.
//!
//! # Determinism
//!
//! Results are *identical for every worker count* (everything except the
//! recorded wall-clock timings, which are live measurement). Three rules
//! make that hold:
//!
//! 1. per-trial RNGs are forked from the master stream **serially, in
//!    trial-id order**, before anything is dispatched (exactly the old
//!    `rng.fork(records.len() as u64)` sequence);
//! 2. within a batch, duplicate genomes are collapsed *before* dispatch —
//!    a genome is always evaluated with the RNG of its **first** trial id,
//!    regardless of scheduling;
//! 3. per-trial results are *emitted* in trial-id order: workers push
//!    completions to a driver-side channel in whatever order they finish,
//!    and the driver holds each trial back until every earlier trial has
//!    been emitted — so callers (and their progress sinks, which run on
//!    the driver thread and need not be `Send`) always observe the same
//!    stream.
//!
//! # Thread-safety
//!
//! Workers share one `&Runtime` (and its loaded executables) plus the
//! surrogate predictor; per-trial state (model parameters, Adam moments,
//! BN statistics) is created per evaluation, so nothing mutable is shared.
//! PJRT clients are thread-safe for concurrent execution and the offline
//! facade is plain data; if a future backend is not, load one `Runtime`
//! per worker or run with `workers = 1` (see `rust/xla/README.md`).

mod cache;
mod parallel;
mod supernet;

use anyhow::Result;

use crate::nn::Genome;
use crate::util::Rng;

pub use cache::EvalCache;
pub use parallel::{parallel_map, resolve_workers, EvaluatedTrial, ParallelEvaluator};
pub use supernet::SupernetEvaluator;

/// Everything a single trial evaluation produces.
#[derive(Debug, Clone)]
pub struct TrialEvaluation {
    /// Validation accuracy after the trial's training budget.
    pub accuracy: f64,
    /// BOPs at the assumed deployment point (always computed — Table 2).
    pub bops: f64,
    /// Surrogate estimate: mean utilisation % (when a surrogate ran).
    pub est_avg_resources: Option<f64>,
    /// Surrogate estimate: latency cycles (when a surrogate ran).
    pub est_clock_cycles: Option<f64>,
    /// The minimised objective vector fed back to NSGA-II
    /// (slot 0 is negated accuracy by convention).
    pub objectives: Vec<f64>,
    /// Wall-clock seconds this evaluation cost.
    pub train_seconds: f64,
}

/// One candidate scheduled for evaluation.
///
/// The RNG must already be forked from the master stream, keyed on
/// `trial_id` — the scheduler never touches the master stream itself, so
/// worker scheduling cannot perturb determinism.
#[derive(Debug, Clone)]
pub struct EvalRequest {
    /// Sequential trial id (stable across worker counts).
    pub trial_id: usize,
    /// The candidate architecture.
    pub genome: Genome,
    /// The trial's private RNG stream.
    pub rng: Rng,
}

/// Scores one genome. Implementations must be cheap to share across
/// threads (`Sync`); all per-trial mutable state belongs inside
/// `evaluate`.
pub trait TrialEvaluator: Sync {
    /// Evaluate one candidate with its pre-forked trial RNG.
    fn evaluate(&self, genome: &Genome, rng: &mut Rng) -> Result<TrialEvaluation>;
}
