//! Scoped-thread evaluation pool + genome-keyed memoisation.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use crate::nn::Genome;
use crate::util::Rng;

use super::{EvalRequest, TrialEvaluation, TrialEvaluator};

/// Resolve a requested worker count: `0` means "use all available
/// parallelism" (the CLI default).
pub fn resolve_workers(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }
}

/// Run `f` over `items` on up to `workers` scoped threads, returning the
/// results **in input order**. A shared work queue keeps all workers busy
/// regardless of per-item cost skew; `workers <= 1` runs inline with zero
/// threading overhead. Also used by the pipeline to fan out the
/// independent local-search + synthesis stages.
pub fn parallel_map<T, R, F>(workers: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    let workers = resolve_workers(workers).min(n.max(1));
    if workers <= 1 {
        return items
            .into_iter()
            .enumerate()
            .map(|(i, item)| f(i, item))
            .collect();
    }
    let queue: Mutex<VecDeque<(usize, T)>> = Mutex::new(items.into_iter().enumerate().collect());
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let next = queue.lock().unwrap().pop_front();
                let Some((i, item)) = next else { break };
                let result = f(i, item);
                *slots[i].lock().unwrap() = Some(result);
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .unwrap()
                .expect("every queued item was processed")
        })
        .collect()
}

/// One scheduled trial, scored.
#[derive(Debug, Clone)]
pub struct EvaluatedTrial {
    /// Sequential trial id (from the request).
    pub trial_id: usize,
    /// The candidate.
    pub genome: Genome,
    /// The (possibly memoised) evaluation.
    pub evaluation: TrialEvaluation,
    /// True if this trial reused a previous evaluation of the same genome
    /// (earlier batch, or an earlier trial id within this batch).
    pub cached: bool,
}

/// Evaluates batches of trials concurrently over scoped threads, memoising
/// by genome so duplicate candidates proposed across generations are
/// trained exactly once.
///
/// Determinism contract (see the module docs): duplicate genomes within a
/// batch are collapsed *before* dispatch and always evaluated with the RNG
/// of their first trial id, and outputs are returned in trial order — so
/// results are identical for every worker count.
pub struct ParallelEvaluator<E: TrialEvaluator> {
    inner: E,
    workers: usize,
    cache: Mutex<HashMap<Genome, TrialEvaluation>>,
    evaluations: AtomicUsize,
    hits: AtomicUsize,
}

impl<E: TrialEvaluator> ParallelEvaluator<E> {
    /// Wrap an evaluator. `workers == 0` resolves to available parallelism.
    pub fn new(inner: E, workers: usize) -> Self {
        ParallelEvaluator {
            inner,
            workers: resolve_workers(workers),
            cache: Mutex::new(HashMap::new()),
            evaluations: AtomicUsize::new(0),
            hits: AtomicUsize::new(0),
        }
    }

    /// The resolved worker count.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Total *successful* inner evaluations committed to the cache so far
    /// (failed evaluations are not counted).
    pub fn evaluations(&self) -> usize {
        self.evaluations.load(Ordering::Relaxed)
    }

    /// Total trials served from the cache so far.
    pub fn cache_hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Distinct genomes memoised so far.
    pub fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// The wrapped evaluator.
    pub fn inner(&self) -> &E {
        &self.inner
    }

    /// Evaluate one generation's worth of trials. Requests must carry
    /// pre-forked RNGs keyed on their trial ids; results come back in
    /// request (= trial) order.
    pub fn evaluate_batch(&self, requests: Vec<EvalRequest>) -> Result<Vec<EvaluatedTrial>> {
        // ---- collapse to first-occurrence, uncached genomes ----
        let mut pending: Vec<(Genome, Rng)> = Vec::new();
        let mut fresh: HashSet<Genome> = HashSet::new();
        {
            let cache = self.cache.lock().unwrap();
            for req in &requests {
                if cache.contains_key(&req.genome) || fresh.contains(&req.genome) {
                    continue;
                }
                fresh.insert(req.genome.clone());
                pending.push((req.genome.clone(), req.rng.clone()));
            }
        }

        // ---- score unique genomes concurrently ----
        let results = parallel_map(self.workers, pending, |_, (genome, mut rng)| {
            let evaluation = self.inner.evaluate(&genome, &mut rng);
            (genome, evaluation)
        });

        // ---- commit in dispatch order (first error wins, deterministically) ----
        {
            let mut cache = self.cache.lock().unwrap();
            for (genome, evaluation) in results {
                cache.insert(genome, evaluation?);
                self.evaluations.fetch_add(1, Ordering::Relaxed);
            }
        }

        // ---- emit per-trial results in trial order ----
        let cache = self.cache.lock().unwrap();
        let mut out = Vec::with_capacity(requests.len());
        for req in requests {
            let cached = !fresh.remove(&req.genome);
            if cached {
                self.hits.fetch_add(1, Ordering::Relaxed);
            }
            let evaluation = cache
                .get(&req.genome)
                .expect("evaluated or cached above")
                .clone();
            out.push(EvaluatedTrial {
                trial_id: req.trial_id,
                genome: req.genome,
                evaluation,
                cached,
            });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::SearchSpace;

    /// Deterministic mock: accuracy derives from the trial RNG so tests
    /// catch any perturbation of the fork-per-trial-id discipline.
    struct MockEval {
        space: SearchSpace,
        calls: AtomicUsize,
        fail: bool,
    }

    impl MockEval {
        fn new() -> Self {
            MockEval {
                space: SearchSpace::table1(),
                calls: AtomicUsize::new(0),
                fail: false,
            }
        }
    }

    impl TrialEvaluator for MockEval {
        fn evaluate(&self, genome: &Genome, rng: &mut Rng) -> Result<TrialEvaluation> {
            self.calls.fetch_add(1, Ordering::SeqCst);
            if self.fail {
                anyhow::bail!("mock evaluator failure");
            }
            let accuracy = 0.5 + 0.4 * rng.uniform();
            let bops = genome.num_weights(&self.space) as f64;
            Ok(TrialEvaluation {
                accuracy,
                bops,
                est_avg_resources: None,
                est_clock_cycles: None,
                objectives: vec![-accuracy, bops],
                train_seconds: 0.0,
            })
        }
    }

    fn requests(genomes: &[Genome], seed: u64) -> Vec<EvalRequest> {
        let mut root = Rng::new(seed);
        genomes
            .iter()
            .enumerate()
            .map(|(trial_id, genome)| EvalRequest {
                trial_id,
                genome: genome.clone(),
                rng: root.fork(trial_id as u64),
            })
            .collect()
    }

    #[test]
    fn parallel_map_preserves_order_and_covers_all_items() {
        let items: Vec<usize> = (0..97).collect();
        let doubled = parallel_map(4, items.clone(), |i, x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(doubled, items.iter().map(|x| x * 2).collect::<Vec<_>>());
        // inline path agrees
        let inline = parallel_map(1, items.clone(), |_, x| x * 2);
        assert_eq!(doubled, inline);
    }

    #[test]
    fn resolve_workers_is_at_least_one() {
        assert!(resolve_workers(0) >= 1);
        assert_eq!(resolve_workers(3), 3);
    }

    #[test]
    fn duplicate_genomes_are_evaluated_once_but_recorded_per_trial() {
        let space = SearchSpace::table1();
        let mut rng = Rng::new(5);
        let a = space.sample(&mut rng);
        let b = space.sample(&mut rng);
        assert_ne!(a, b);
        // trials 0 and 2 and 3 share genome `a`
        let genomes = vec![a.clone(), b.clone(), a.clone(), a.clone()];
        let pool = ParallelEvaluator::new(MockEval::new(), 3);
        let batch = pool
            .evaluate_batch(requests(&genomes, 11))
            .unwrap();

        assert_eq!(batch.len(), 4, "every trial gets a record");
        assert_eq!(pool.evaluations(), 2, "only unique genomes are trained");
        assert_eq!(pool.cache_hits(), 2);
        assert_eq!(pool.cache_len(), 2);
        assert!(!batch[0].cached && !batch[1].cached);
        assert!(batch[2].cached && batch[3].cached);
        // duplicates reuse the FIRST trial's evaluation exactly
        assert_eq!(batch[0].evaluation.accuracy, batch[2].evaluation.accuracy);
        assert_eq!(batch[0].evaluation.accuracy, batch[3].evaluation.accuracy);
        // trial ids and genomes are preserved in order
        assert_eq!(
            batch.iter().map(|t| t.trial_id).collect::<Vec<_>>(),
            vec![0, 1, 2, 3]
        );
        assert_eq!(batch[3].genome, a);

        // a later batch with the same genomes is served fully from cache
        let again = pool
            .evaluate_batch(requests(&[a.clone(), b.clone()], 99))
            .unwrap();
        assert_eq!(pool.evaluations(), 2, "no re-training across batches");
        assert!(again.iter().all(|t| t.cached));
        assert_eq!(again[0].evaluation.accuracy, batch[0].evaluation.accuracy);
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let space = SearchSpace::table1();
        let mut rng = Rng::new(21);
        let genomes: Vec<Genome> = (0..24).map(|_| space.sample(&mut rng)).collect();
        let serial = ParallelEvaluator::new(MockEval::new(), 1)
            .evaluate_batch(requests(&genomes, 7))
            .unwrap();
        let parallel = ParallelEvaluator::new(MockEval::new(), 4)
            .evaluate_batch(requests(&genomes, 7))
            .unwrap();
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.trial_id, p.trial_id);
            assert_eq!(s.genome, p.genome);
            assert_eq!(s.evaluation.accuracy, p.evaluation.accuracy);
            assert_eq!(s.evaluation.objectives, p.evaluation.objectives);
            assert_eq!(s.cached, p.cached);
        }
    }

    #[test]
    fn evaluator_errors_propagate() {
        let space = SearchSpace::table1();
        let mut rng = Rng::new(3);
        let genomes: Vec<Genome> = (0..6).map(|_| space.sample(&mut rng)).collect();
        let mut mock = MockEval::new();
        mock.fail = true;
        let pool = ParallelEvaluator::new(mock, 2);
        let err = pool
            .evaluate_batch(requests(&genomes, 1))
            .unwrap_err();
        assert!(format!("{err:#}").contains("mock evaluator failure"));
        assert_eq!(pool.evaluations(), 0, "failures are not counted as trained");
    }
}
