//! Scoped-thread evaluation pool: streaming completions + genome memo.
//!
//! Workers pull trials from a shared queue and push finished evaluations
//! into an `mpsc` completion channel as they finish; the **driver** (the
//! calling thread) commits each completion to the evaluation cache the
//! moment it arrives and emits per-trial results strictly in trial-id
//! order. There are no chunk barriers anywhere — a worker that finishes a
//! cheap trial immediately starts the next one, even while an expensive
//! sibling is still training — and because the driver loop runs on the
//! calling thread, progress sinks need not be `Send`.

use std::collections::{HashSet, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};

use anyhow::Result;

use crate::nn::Genome;
use crate::telemetry;
use crate::util::{Json, Rng};

use super::cache::{lock_unpoisoned, EvalCache};
use super::{EvalPool, EvalRequest, TrialEvaluation, TrialEvaluator};

/// Resolve a requested worker count: `0` means "use all available
/// parallelism" (the CLI default).
pub fn resolve_workers(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }
}

/// Run `f` over `items` on up to `workers` scoped threads, returning the
/// results **in input order**. A shared work queue keeps all workers busy
/// regardless of per-item cost skew; `workers <= 1` runs inline with zero
/// threading overhead. Also used by the pipeline to fan out the
/// independent local-search + synthesis stages.
pub fn parallel_map<T, R, F>(workers: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    let workers = resolve_workers(workers).min(n.max(1));
    if workers <= 1 {
        return items
            .into_iter()
            .enumerate()
            .map(|(i, item)| f(i, item))
            .collect();
    }
    let queue: Mutex<VecDeque<(usize, T)>> = Mutex::new(items.into_iter().enumerate().collect());
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let next = lock_unpoisoned(&queue).pop_front();
                let Some((i, item)) = next else { break };
                let result = f(i, item);
                *lock_unpoisoned(&slots[i]) = Some(result);
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .unwrap_or_else(|poisoned| poisoned.into_inner())
                .expect("every queued item was processed")
        })
        .collect()
}

/// One scheduled trial, scored.
#[derive(Debug, Clone)]
pub struct EvaluatedTrial {
    /// Sequential trial id (from the request).
    pub trial_id: usize,
    /// The candidate.
    pub genome: Genome,
    /// The (possibly memoised) evaluation.
    pub evaluation: TrialEvaluation,
    /// True if this trial reused a previous evaluation of the same genome
    /// (a restored snapshot, an earlier batch, or an earlier trial id
    /// within this batch).
    pub cached: bool,
}

/// Evaluates batches of trials concurrently over scoped threads, memoising
/// by genome — through an [`EvalCache`], optionally persistent — so
/// duplicate candidates are trained exactly once per cache lifetime.
///
/// Determinism contract (see the module docs): duplicate genomes within a
/// batch are collapsed *before* dispatch and always evaluated with the RNG
/// of their first trial id, and outputs are emitted in trial order — so
/// results are identical for every worker count, whatever order the
/// completion channel delivers them in.
pub struct ParallelEvaluator<E: TrialEvaluator> {
    inner: E,
    workers: usize,
    cache: EvalCache,
    evaluations: AtomicUsize,
    hits: AtomicUsize,
}

impl<E: TrialEvaluator> ParallelEvaluator<E> {
    /// Wrap an evaluator with a fresh in-memory cache. `workers == 0`
    /// resolves to available parallelism.
    pub fn new(inner: E, workers: usize) -> Self {
        Self::with_cache(inner, workers, EvalCache::in_memory())
    }

    /// Wrap an evaluator around an existing cache — typically one restored
    /// from a `--cache-path` snapshot, so prior runs' training is reused.
    pub fn with_cache(inner: E, workers: usize, cache: EvalCache) -> Self {
        ParallelEvaluator {
            inner,
            workers: resolve_workers(workers),
            cache,
            evaluations: AtomicUsize::new(0),
            hits: AtomicUsize::new(0),
        }
    }

    /// The resolved worker count.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Total *successful* inner evaluations committed to the cache so far
    /// (failed evaluations are not counted).
    pub fn evaluations(&self) -> usize {
        self.evaluations.load(Ordering::Relaxed)
    }

    /// Total trials served from the cache so far (snapshot-restored
    /// entries included).
    pub fn cache_hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Distinct genomes memoised so far.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// The evaluation cache.
    pub fn cache(&self) -> &EvalCache {
        &self.cache
    }

    /// The wrapped evaluator.
    pub fn inner(&self) -> &E {
        &self.inner
    }

    /// Evaluate one generation's worth of trials, collecting the per-trial
    /// results in request (= trial) order. Requests must carry pre-forked
    /// RNGs keyed on their trial ids.
    pub fn evaluate_batch(&self, requests: Vec<EvalRequest>) -> Result<Vec<EvaluatedTrial>> {
        let mut out = Vec::with_capacity(requests.len());
        self.evaluate_stream(requests, |trial| out.push(trial))?;
        Ok(out)
    }

    /// Evaluate a batch, streaming each finished trial to `on_trial` in
    /// trial-id order as soon as it (and every earlier trial) completes —
    /// no chunk barriers, so workers stay busy under any per-trial cost
    /// skew while the caller still observes a deterministic stream.
    ///
    /// `on_trial` runs on the calling thread (the driver side of the
    /// completion channel), so it may borrow non-`Send` state freely.
    ///
    /// Error contract: every successfully evaluated genome is committed to
    /// the cache — completed training work survives a failed sibling — and
    /// the error of the *first failed dispatch* (first occurrence order,
    /// which is worker-count-invariant) is returned after the whole batch
    /// has drained.
    pub fn evaluate_stream<F>(&self, requests: Vec<EvalRequest>, mut on_trial: F) -> Result<()>
    where
        F: FnMut(EvaluatedTrial),
    {
        // ---- collapse to first-occurrence, uncached genomes ----
        let mut pending: VecDeque<(usize, Genome, Rng)> = VecDeque::new();
        let mut fresh: HashSet<Genome> = HashSet::new();
        for req in &requests {
            if self.cache.contains(&req.genome) || fresh.contains(&req.genome) {
                continue;
            }
            fresh.insert(req.genome.clone());
            pending.push_back((pending.len(), req.genome.clone(), req.rng.clone()));
        }

        // one generation-level staging pass over the collapsed genome
        // list (e.g. the batched surrogate prefetch) before any trial
        // dispatches. Staging is best-effort: on failure we fall through
        // to per-trial work, which hits the same underlying error — so
        // the batch error contract (cached siblings still stream, the
        // first dispatch-order error propagates after the batch drains)
        // is exactly the pre-batching behaviour.
        if !pending.is_empty() {
            let genomes: Vec<Genome> = pending.iter().map(|(_, g, _)| g.clone()).collect();
            if let Err(e) = self.inner.prepare(&genomes) {
                eprintln!("[eval] batch staging failed, falling back to per-trial: {e:#}");
            }
        }

        let mut errors: Vec<(usize, anyhow::Error)> = Vec::new();
        let mut next = 0usize;
        let workers = self.workers.min(pending.len().max(1));

        if workers <= 1 {
            // Inline driver: completions arrive in dispatch order on this
            // thread, interleaving evaluation with in-order emission (so a
            // progress sink streams even at `--workers 1`).
            while let Some((idx, genome, mut rng)) = pending.pop_front() {
                let mut span = telemetry::span("trial", "eval");
                span.arg("dispatch", Json::Num(idx as f64));
                let outcome = self.inner.evaluate(&genome, &mut rng);
                drop(span);
                match outcome {
                    Ok(evaluation) => {
                        self.commit(genome, evaluation);
                        self.drain_ready(&requests, &mut fresh, &mut next, &mut on_trial);
                    }
                    Err(e) => errors.push((idx, e)),
                }
            }
        } else {
            // Streaming pool: workers push completions into the channel
            // the moment they finish; the driver loop below commits them
            // and advances the in-order emission cursor.
            let queue = Mutex::new(pending);
            let queue = &queue;
            let (tx, rx) = mpsc::channel::<(usize, Genome, Result<TrialEvaluation>)>();
            std::thread::scope(|s| {
                for _ in 0..workers {
                    let tx = tx.clone();
                    s.spawn(move || {
                        loop {
                            let item = lock_unpoisoned(queue).pop_front();
                            let Some((idx, genome, mut rng)) = item else { break };
                            let mut span = telemetry::span("trial", "eval");
                            span.arg("dispatch", Json::Num(idx as f64));
                            let result = self.inner.evaluate(&genome, &mut rng);
                            drop(span);
                            if tx.send((idx, genome, result)).is_err() {
                                break;
                            }
                        }
                        // pool threads die with the scope: hand any
                        // buffered spans to the global sink now rather
                        // than relying on thread-exit destructors
                        telemetry::flush_thread();
                    });
                }
                // the workers hold the only remaining senders, so the
                // receive loop ends exactly when the queue is drained
                drop(tx);
                for (idx, genome, result) in rx {
                    match result {
                        Ok(evaluation) => {
                            self.commit(genome, evaluation);
                            self.drain_ready(&requests, &mut fresh, &mut next, &mut on_trial);
                        }
                        Err(e) => errors.push((idx, e)),
                    }
                }
            });
        }

        // batches served entirely from cache never enter the loops above
        self.drain_ready(&requests, &mut fresh, &mut next, &mut on_trial);

        if let Some((_, err)) = errors.into_iter().min_by_key(|&(idx, _)| idx) {
            return Err(err);
        }
        debug_assert_eq!(next, requests.len(), "every trial emitted exactly once");
        Ok(())
    }

    /// Commit one successful evaluation (write-through when persistent).
    fn commit(&self, genome: Genome, evaluation: TrialEvaluation) {
        self.cache.insert(genome, evaluation);
        self.evaluations.fetch_add(1, Ordering::Relaxed);
    }

    fn drain_ready<F>(
        &self,
        requests: &[EvalRequest],
        fresh: &mut HashSet<Genome>,
        next: &mut usize,
        on_trial: &mut F,
    ) where
        F: FnMut(EvaluatedTrial),
    {
        drain_ready(&self.cache, &self.hits, requests, fresh, next, on_trial);
    }
}

/// Emit every not-yet-emitted trial whose genome has an evaluation in
/// `cache`, in trial order, stopping at the first still-pending (or
/// failed) genome. Shared between [`ParallelEvaluator`] and the shard
/// driver, so both dispatch backends observe the identical emission
/// contract (a trial counts as a hit in `hits` unless its genome is
/// removed from `fresh` — i.e. it was evaluated fresh in this batch).
pub(crate) fn drain_ready(
    cache: &EvalCache,
    hits: &AtomicUsize,
    requests: &[EvalRequest],
    fresh: &mut HashSet<Genome>,
    next: &mut usize,
    on_trial: &mut impl FnMut(EvaluatedTrial),
) {
    while *next < requests.len() {
        let req = &requests[*next];
        let Some(evaluation) = cache.lookup(&req.genome) else {
            break;
        };
        let cached = !fresh.remove(&req.genome);
        if cached {
            hits.fetch_add(1, Ordering::Relaxed);
        }
        on_trial(EvaluatedTrial {
            trial_id: req.trial_id,
            genome: req.genome.clone(),
            evaluation,
            cached,
        });
        *next += 1;
    }
}

impl<E: TrialEvaluator> EvalPool for ParallelEvaluator<E> {
    fn evaluate_stream_dyn(
        &self,
        requests: Vec<EvalRequest>,
        on_trial: &mut dyn FnMut(EvaluatedTrial),
    ) -> Result<()> {
        self.evaluate_stream(requests, |trial| on_trial(trial))
    }

    fn evaluations(&self) -> usize {
        ParallelEvaluator::evaluations(self)
    }

    fn cache_hits(&self) -> usize {
        ParallelEvaluator::cache_hits(self)
    }

    fn cache(&self) -> &EvalCache {
        ParallelEvaluator::cache(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::SearchSpace;

    /// Deterministic mock: accuracy derives from the trial RNG so tests
    /// catch any perturbation of the fork-per-trial-id discipline.
    struct MockEval {
        space: SearchSpace,
        calls: AtomicUsize,
        fail_all: bool,
        fail_on: Vec<Genome>,
    }

    impl MockEval {
        fn new() -> Self {
            MockEval {
                space: SearchSpace::table1(),
                calls: AtomicUsize::new(0),
                fail_all: false,
                fail_on: Vec::new(),
            }
        }
    }

    impl TrialEvaluator for MockEval {
        fn evaluate(&self, genome: &Genome, rng: &mut Rng) -> Result<TrialEvaluation> {
            self.calls.fetch_add(1, Ordering::SeqCst);
            if self.fail_all {
                anyhow::bail!("mock evaluator failure");
            }
            if let Some(i) = self.fail_on.iter().position(|g| g == genome) {
                anyhow::bail!("mock failure #{i}");
            }
            let accuracy = 0.5 + 0.4 * rng.uniform();
            let bops = genome.num_weights(&self.space) as f64;
            Ok(TrialEvaluation {
                accuracy,
                bops,
                est_avg_resources: None,
                est_clock_cycles: None,
                objectives: vec![-accuracy, bops],
                train_seconds: 0.0,
            })
        }
    }

    fn requests(genomes: &[Genome], seed: u64) -> Vec<EvalRequest> {
        let mut root = Rng::new(seed);
        genomes
            .iter()
            .enumerate()
            .map(|(trial_id, genome)| EvalRequest {
                trial_id,
                genome: genome.clone(),
                rng: root.fork(trial_id as u64),
            })
            .collect()
    }

    /// Sample `n` pairwise-distinct genomes so call/cache-count assertions
    /// cannot be perturbed by a lucky sampling collision.
    fn distinct_genomes(n: usize, seed: u64) -> Vec<Genome> {
        let space = SearchSpace::table1();
        let mut rng = Rng::new(seed);
        let mut out: Vec<Genome> = Vec::new();
        while out.len() < n {
            let g = space.sample(&mut rng);
            if !out.contains(&g) {
                out.push(g);
            }
        }
        out
    }

    #[test]
    fn parallel_map_preserves_order_and_covers_all_items() {
        let items: Vec<usize> = (0..97).collect();
        let doubled = parallel_map(4, items.clone(), |i, x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(doubled, items.iter().map(|x| x * 2).collect::<Vec<_>>());
        // inline path agrees
        let inline = parallel_map(1, items.clone(), |_, x| x * 2);
        assert_eq!(doubled, inline);
    }

    #[test]
    fn resolve_workers_is_at_least_one() {
        assert!(resolve_workers(0) >= 1);
        assert_eq!(resolve_workers(3), 3);
    }

    #[test]
    fn duplicate_genomes_are_evaluated_once_but_recorded_per_trial() {
        let space = SearchSpace::table1();
        let mut rng = Rng::new(5);
        let a = space.sample(&mut rng);
        let b = space.sample(&mut rng);
        assert_ne!(a, b);
        // trials 0 and 2 and 3 share genome `a`
        let genomes = vec![a.clone(), b.clone(), a.clone(), a.clone()];
        let pool = ParallelEvaluator::new(MockEval::new(), 3);
        let batch = pool.evaluate_batch(requests(&genomes, 11)).unwrap();

        assert_eq!(batch.len(), 4, "every trial gets a record");
        assert_eq!(pool.evaluations(), 2, "only unique genomes are trained");
        assert_eq!(pool.cache_hits(), 2);
        assert_eq!(pool.cache_len(), 2);
        assert!(!batch[0].cached && !batch[1].cached);
        assert!(batch[2].cached && batch[3].cached);
        // duplicates reuse the FIRST trial's evaluation exactly
        assert_eq!(batch[0].evaluation.accuracy, batch[2].evaluation.accuracy);
        assert_eq!(batch[0].evaluation.accuracy, batch[3].evaluation.accuracy);
        // trial ids and genomes are preserved in order
        assert_eq!(
            batch.iter().map(|t| t.trial_id).collect::<Vec<_>>(),
            vec![0, 1, 2, 3]
        );
        assert_eq!(batch[3].genome, a);

        // a later batch with the same genomes is served fully from cache
        let again = pool
            .evaluate_batch(requests(&[a.clone(), b.clone()], 99))
            .unwrap();
        assert_eq!(pool.evaluations(), 2, "no re-training across batches");
        assert!(again.iter().all(|t| t.cached));
        assert_eq!(again[0].evaluation.accuracy, batch[0].evaluation.accuracy);
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let space = SearchSpace::table1();
        let mut rng = Rng::new(21);
        let genomes: Vec<Genome> = (0..24).map(|_| space.sample(&mut rng)).collect();
        let serial = ParallelEvaluator::new(MockEval::new(), 1)
            .evaluate_batch(requests(&genomes, 7))
            .unwrap();
        let parallel = ParallelEvaluator::new(MockEval::new(), 4)
            .evaluate_batch(requests(&genomes, 7))
            .unwrap();
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.trial_id, p.trial_id);
            assert_eq!(s.genome, p.genome);
            assert_eq!(s.evaluation.accuracy, p.evaluation.accuracy);
            assert_eq!(s.evaluation.objectives, p.evaluation.objectives);
            assert_eq!(s.cached, p.cached);
        }
    }

    #[test]
    fn stream_emits_every_trial_in_order() {
        let mut genomes = distinct_genomes(12, 12);
        genomes[7] = genomes[2].clone(); // duplicate inside the batch
        let pool = ParallelEvaluator::new(MockEval::new(), 4);
        let mut seen: Vec<(usize, bool)> = Vec::new();
        pool.evaluate_stream(requests(&genomes, 5), |t| seen.push((t.trial_id, t.cached)))
            .unwrap();
        assert_eq!(
            seen.iter().map(|&(id, _)| id).collect::<Vec<_>>(),
            (0..12).collect::<Vec<_>>(),
            "streamed trials arrive in trial order"
        );
        assert!(seen[7].1, "duplicate genome is served from the in-batch memo");
        assert!(seen.iter().take(7).all(|&(_, cached)| !cached));
        assert_eq!(pool.evaluations(), 11);
        assert_eq!(pool.cache_hits(), 1);
    }

    #[test]
    fn evaluator_errors_propagate() {
        let space = SearchSpace::table1();
        let mut rng = Rng::new(3);
        let genomes: Vec<Genome> = (0..6).map(|_| space.sample(&mut rng)).collect();
        let mut mock = MockEval::new();
        mock.fail_all = true;
        let pool = ParallelEvaluator::new(mock, 2);
        let err = pool.evaluate_batch(requests(&genomes, 1)).unwrap_err();
        assert!(format!("{err:#}").contains("mock evaluator failure"));
        assert_eq!(pool.evaluations(), 0, "failures are not counted as trained");
    }

    /// Regression (the PR-1 batch-failure bug): one failed trial must not
    /// discard the completed training work of its successful siblings, and
    /// the propagated error must be the first in dispatch order for every
    /// worker count.
    #[test]
    fn failed_trial_keeps_successful_siblings_cached() {
        let genomes = distinct_genomes(6, 8);
        for workers in [1usize, 3] {
            let mut mock = MockEval::new();
            // trials 1 and 4 fail; dispatch order == trial order here, so
            // trial 1's error must win deterministically
            mock.fail_on = vec![genomes[1].clone(), genomes[4].clone()];
            let pool = ParallelEvaluator::new(mock, workers);
            let err = pool.evaluate_batch(requests(&genomes, 2)).unwrap_err();
            assert!(
                format!("{err:#}").contains("mock failure #0"),
                "first dispatch-order error wins (workers={workers}): {err:#}"
            );
            // the four successful siblings were committed, not discarded
            assert_eq!(pool.evaluations(), 4, "workers={workers}");
            assert_eq!(pool.cache_len(), 4);
            assert_eq!(pool.inner().calls.load(Ordering::SeqCst), 6);
            // retrying without the failing genomes is served from cache
            let ok = vec![
                genomes[0].clone(),
                genomes[2].clone(),
                genomes[3].clone(),
                genomes[5].clone(),
            ];
            let again = pool.evaluate_batch(requests(&ok, 2)).unwrap();
            assert!(again.iter().all(|t| t.cached));
            assert_eq!(
                pool.inner().calls.load(Ordering::SeqCst),
                6,
                "no retraining after the failed batch"
            );
        }
    }

    /// Evaluator panic in a worker: the original panic surfaces (via the
    /// thread scope), and later batches run normally instead of hitting
    /// an opaque `PoisonError` unwrap far from the root cause.
    #[test]
    fn worker_panic_does_not_poison_later_batches() {
        struct PanickingEval {
            bad: Genome,
            space: SearchSpace,
        }
        impl TrialEvaluator for PanickingEval {
            fn evaluate(&self, genome: &Genome, rng: &mut Rng) -> Result<TrialEvaluation> {
                if *genome == self.bad {
                    panic!("original worker panic");
                }
                let accuracy = 0.5 + 0.4 * rng.uniform();
                let bops = genome.num_weights(&self.space) as f64;
                Ok(TrialEvaluation {
                    accuracy,
                    bops,
                    est_avg_resources: None,
                    est_clock_cycles: None,
                    objectives: vec![-accuracy, bops],
                    train_seconds: 0.0,
                })
            }
        }

        let genomes = distinct_genomes(6, 77);
        for workers in [1usize, 4] {
            let pool = ParallelEvaluator::new(
                PanickingEval {
                    bad: genomes[2].clone(),
                    space: SearchSpace::table1(),
                },
                workers,
            );
            let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let _ = pool.evaluate_batch(requests(&genomes, 1));
            }));
            assert!(panicked.is_err(), "the original panic must surface");
            // locks recover: a later batch over the healthy genomes works
            let good: Vec<Genome> = genomes
                .iter()
                .filter(|g| **g != genomes[2])
                .cloned()
                .collect();
            let batch = pool.evaluate_batch(requests(&good, 1)).unwrap();
            assert_eq!(batch.len(), 5, "workers={workers}");
        }
    }

    #[test]
    fn persistent_cache_skips_retraining_across_pools() {
        let space = SearchSpace::table1();
        let genomes = distinct_genomes(5, 6);
        let dir = std::env::temp_dir().join("snac_parallel_cache_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("eval_cache.json");
        let _ = std::fs::remove_file(&path);

        let pool = ParallelEvaluator::with_cache(
            MockEval::new(),
            2,
            EvalCache::load(&path, &space, "t"),
        );
        let first = pool.evaluate_batch(requests(&genomes, 3)).unwrap();
        assert_eq!(pool.evaluations(), 5);

        // a fresh pool (≈ a new process) restores the snapshot and
        // retrains nothing
        let pool2 = ParallelEvaluator::with_cache(
            MockEval::new(),
            2,
            EvalCache::load(&path, &space, "t"),
        );
        assert_eq!(pool2.cache().restored(), 5);
        let second = pool2.evaluate_batch(requests(&genomes, 3)).unwrap();
        assert_eq!(pool2.evaluations(), 0, "second run retrains nothing");
        assert_eq!(pool2.cache_hits(), 5);
        assert!(second.iter().all(|t| t.cached));
        for (a, b) in first.iter().zip(&second) {
            assert_eq!(a.trial_id, b.trial_id);
            assert_eq!(a.genome, b.genome);
            assert_eq!(a.evaluation.accuracy, b.evaluation.accuracy);
            assert_eq!(a.evaluation.objectives, b.evaluation.objectives);
        }
    }
}
