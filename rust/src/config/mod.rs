//! Experiment configuration: presets + simple key=value overrides.
//!
//! Three presets scale the same pipeline:
//! * `paper`      — the paper's settings (500 trials, pop 20, 5 epochs,
//!   10×10-epoch IMP). Hours of compute on this single-core box.
//! * `ci`         — the default for `make experiments`: same structure,
//!   scaled to finish in minutes; all shapes of the paper's tables/figures
//!   are preserved.
//! * `quickstart` — seconds; used by `examples/quickstart.rs`.

use anyhow::{bail, Result};

use crate::compress::LocalSearchConfig;
use crate::search::Nsga2Config;
use crate::surrogate::SurrogateTrainConfig;

/// Dataset sizing.
#[derive(Debug, Clone)]
pub struct DataConfig {
    /// Training examples.
    pub n_train: usize,
    /// Validation examples (accuracy objective).
    pub n_val: usize,
    /// Test examples (final tables).
    pub n_test: usize,
    /// Generator seed.
    pub seed: u64,
}

/// Global-search sizing.
#[derive(Debug, Clone)]
pub struct SearchBudget {
    /// Total candidate evaluations ("trials" in the paper).
    pub trials: usize,
    /// NSGA-II population (paper: 20).
    pub population: usize,
    /// Training epochs per trial (paper: 5).
    pub epochs: usize,
    /// Trial-evaluation workers (0 = all available parallelism). Genomes,
    /// objectives, and selection are identical for every value; only the
    /// recorded wall-clock timings change.
    pub workers: usize,
}

/// A full experiment preset.
#[derive(Debug, Clone)]
pub struct Preset {
    /// Preset name.
    pub name: String,
    /// Dataset sizing.
    pub data: DataConfig,
    /// Global-search budget.
    pub search: SearchBudget,
    /// Surrogate training.
    pub surrogate: SurrogateTrainConfig,
    /// Local-search schedule.
    pub local: LocalSearchConfig,
    /// Master seed for search/training RNG streams.
    pub seed: u64,
    /// Evaluation-cache snapshot file (`--cache-path`): restored on start
    /// and written through on every commit, so repeated runs never
    /// retrain a previously evaluated genome. `None` = in-memory only.
    pub cache_path: Option<String>,
}

impl Preset {
    /// Look up a preset by name.
    pub fn by_name(name: &str) -> Result<Preset> {
        match name {
            "paper" => Ok(Preset {
                name: name.into(),
                data: DataConfig {
                    n_train: 16_384,
                    n_val: 4_096,
                    n_test: 4_096,
                    seed: 7,
                },
                search: SearchBudget {
                    trials: 500,
                    population: 20,
                    epochs: 5,
                    workers: 0,
                },
                surrogate: SurrogateTrainConfig::default(),
                local: LocalSearchConfig::default(),
                seed: 1,
                cache_path: None,
            }),
            "ci" => Ok(Preset {
                name: name.into(),
                data: DataConfig {
                    n_train: 4_096,
                    n_val: 1_024,
                    n_test: 1_024,
                    seed: 7,
                },
                search: SearchBudget {
                    trials: 64,
                    population: 16,
                    epochs: 5,
                    workers: 0,
                },
                surrogate: SurrogateTrainConfig::default(),
                local: LocalSearchConfig {
                    warmup_epochs: 3,
                    imp_iterations: 8,
                    epochs_per_iteration: 3,
                    ..Default::default()
                },
                seed: 1,
                cache_path: None,
            }),
            "quickstart" => Ok(Preset {
                name: name.into(),
                data: DataConfig {
                    n_train: 1_280,
                    n_val: 384,
                    n_test: 384,
                    seed: 7,
                },
                search: SearchBudget {
                    trials: 12,
                    population: 6,
                    epochs: 2,
                    workers: 0,
                },
                surrogate: SurrogateTrainConfig {
                    dataset_size: 1024,
                    epochs: 12,
                    ..Default::default()
                },
                local: LocalSearchConfig {
                    warmup_epochs: 1,
                    imp_iterations: 4,
                    epochs_per_iteration: 1,
                    ..Default::default()
                },
                seed: 1,
                cache_path: None,
            }),
            other => bail!("unknown preset `{other}` (paper | ci | quickstart)"),
        }
    }

    /// NSGA-II config slice of this preset.
    pub fn nsga2(&self) -> Nsga2Config {
        Nsga2Config {
            population: self.search.population,
            ..Default::default()
        }
    }

    /// Apply a `key=value` override (CLI `--set`).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let uint = || -> Result<usize> { Ok(value.parse()?) };
        match key {
            "trials" => self.search.trials = uint()?,
            "population" => self.search.population = uint()?,
            "epochs" => self.search.epochs = uint()?,
            "workers" => self.search.workers = uint()?,
            "n_train" => self.data.n_train = uint()?,
            "n_val" => self.data.n_val = uint()?,
            "n_test" => self.data.n_test = uint()?,
            "surrogate_size" => self.surrogate.dataset_size = uint()?,
            "surrogate_epochs" => self.surrogate.epochs = uint()?,
            "imp_iterations" => self.local.imp_iterations = uint()?,
            "imp_epochs" => self.local.epochs_per_iteration = uint()?,
            "warmup_epochs" => self.local.warmup_epochs = uint()?,
            "target_sparsity" => self.local.target_sparsity = value.parse()?,
            "seed" => self.seed = value.parse()?,
            "cache_path" => self.cache_path = Some(value.to_string()),
            other => bail!("unknown override `{other}`"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for name in ["paper", "ci", "quickstart"] {
            let p = Preset::by_name(name).unwrap();
            assert_eq!(p.name, name);
            assert!(p.search.trials >= p.search.population);
        }
        assert!(Preset::by_name("nope").is_err());
    }

    #[test]
    fn paper_preset_matches_section4() {
        let p = Preset::by_name("paper").unwrap();
        assert_eq!(p.search.trials, 500);
        assert_eq!(p.search.population, 20);
        assert_eq!(p.search.epochs, 5);
        assert_eq!(p.local.warmup_epochs, 5);
        assert_eq!(p.local.imp_iterations, 10);
        assert_eq!(p.local.epochs_per_iteration, 10);
        assert_eq!(p.local.prune_fraction, 0.2);
        assert_eq!(p.local.bits, 8);
    }

    #[test]
    fn overrides_apply() {
        let mut p = Preset::by_name("ci").unwrap();
        p.set("trials", "99").unwrap();
        p.set("target_sparsity", "0.7").unwrap();
        p.set("workers", "4").unwrap();
        p.set("cache_path", "results/eval_cache.json").unwrap();
        assert_eq!(p.search.trials, 99);
        assert_eq!(p.local.target_sparsity, 0.7);
        assert_eq!(p.search.workers, 4);
        assert_eq!(p.cache_path.as_deref(), Some("results/eval_cache.json"));
        assert!(p.set("bogus", "1").is_err());
    }
}
