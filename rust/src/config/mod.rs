//! Experiment configuration: presets + simple key=value overrides.
//!
//! Three presets scale the same pipeline:
//! * `paper`      — the paper's settings (500 trials, pop 20, 5 epochs,
//!   10×10-epoch IMP). Hours of compute on this single-core box.
//! * `ci`         — the default for `make experiments`: same structure,
//!   scaled to finish in minutes; all shapes of the paper's tables/figures
//!   are preserved.
//! * `quickstart` — seconds; used by `examples/quickstart.rs`.

use anyhow::{bail, Context, Result};

use crate::compress::LocalSearchConfig;
use crate::search::Nsga2Config;
use crate::surrogate::SurrogateTrainConfig;
use crate::util::Json;

/// Dataset sizing.
#[derive(Debug, Clone)]
pub struct DataConfig {
    /// Training examples.
    pub n_train: usize,
    /// Validation examples (accuracy objective).
    pub n_val: usize,
    /// Test examples (final tables).
    pub n_test: usize,
    /// Generator seed.
    pub seed: u64,
}

/// Global-search sizing.
#[derive(Debug, Clone)]
pub struct SearchBudget {
    /// Total candidate evaluations ("trials" in the paper).
    pub trials: usize,
    /// NSGA-II population (paper: 20).
    pub population: usize,
    /// Training epochs per trial (paper: 5).
    pub epochs: usize,
    /// Trial-evaluation workers (0 = all available parallelism). Genomes,
    /// objectives, and selection are identical for every value; only the
    /// recorded wall-clock timings change.
    pub workers: usize,
    /// Shards per generation for multi-process dispatch (`--shards`).
    /// `0` = in-process evaluation (the default); `N > 0` partitions every
    /// generation into N shard files served by `snac-pack worker`
    /// processes over `run_dir`. Genomes, objectives, and selection are
    /// identical for every shard count; only timings change.
    pub shards: usize,
    /// Interpreter threads for the blocked dot-general kernels
    /// (`--threads`; `0` = all available parallelism, `1` = serial, the
    /// default). Accumulation order is partitioned over independent output
    /// rows, so results are bit-identical for every value; only wall-clock
    /// changes.
    pub threads: usize,
    /// Statically verify every compiled execution plan
    /// (`--verify-plans`; also `SNAC_XLA_VERIFY=1`). Debug builds always
    /// verify; this knob turns the verifier on in release builds, where it
    /// is off by default. Purely a checking layer: results are identical
    /// either way.
    pub verify_plans: bool,
    /// Driver checkpointing (`--checkpoint-interval`): snapshot the
    /// search state every N generations so a killed driver resumes
    /// mid-run instead of restarting from trial 0. `0` = off (the
    /// default). Resumed runs produce bit-identical trial databases
    /// (modulo live timings), so this is purely a fault-tolerance knob.
    pub checkpoint_interval: usize,
}

/// `snac-pack serve` — the estimation service's knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// TCP port to bind on 127.0.0.1 (`--port`; `0` = ephemeral, the
    /// chosen port is printed on startup).
    pub port: u16,
    /// Micro-batching flush deadline in milliseconds
    /// (`--batch-deadline-ms`): how long the first queued estimate waits
    /// for co-travellers before a partial batch executes.
    pub batch_deadline_ms: u64,
    /// Connection-worker threads (`--pool-size`; `0` = auto: the
    /// available parallelism, clamped to 2..=32). Each worker owns one
    /// connection at a time, so this bounds concurrently-served
    /// keep-alive clients.
    pub pool_size: usize,
    /// Admission-queue capacity (`--queue-depth`; `0` = auto: four per
    /// worker). Accepted connections wait here for a free worker; when
    /// the queue is full the server sheds with a fast `503`.
    pub queue_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            port: 7878,
            batch_deadline_ms: 2,
            pool_size: 0,
            queue_depth: 0,
        }
    }
}

/// A full experiment preset.
#[derive(Debug, Clone)]
pub struct Preset {
    /// Preset name.
    pub name: String,
    /// Dataset sizing.
    pub data: DataConfig,
    /// Global-search budget.
    pub search: SearchBudget,
    /// Surrogate training.
    pub surrogate: SurrogateTrainConfig,
    /// Local-search schedule.
    pub local: LocalSearchConfig,
    /// Master seed for search/training RNG streams.
    pub seed: u64,
    /// Evaluation-cache snapshot file (`--cache-path`): restored on start
    /// and written through on every commit, so repeated runs never
    /// retrain a previously evaluated genome. `None` = in-memory only.
    pub cache_path: Option<String>,
    /// Shared run directory for sharded dispatch (`--run-dir`). Required
    /// when `shards > 0` for the driver; defaults to `<out>/shard-run`
    /// in the CLI when omitted.
    pub run_dir: Option<String>,
    /// How many local `snac-pack worker` processes the CLI driver spawns
    /// for a sharded run. `None` = auto (one per shard); `Some(0)` =
    /// spawn none (workers are managed externally, e.g. on other
    /// terminals or other machines).
    pub spawn_workers: Option<usize>,
    /// Driver-hosted TCP task server (`--listen HOST:PORT`). When set on
    /// a sharded run, the driver serves its shard queue over TCP instead
    /// of a shared run directory, and workers join with
    /// `snac-pack worker --connect HOST:PORT` — no shared filesystem
    /// needed. `HOST:0` binds an ephemeral port (printed on startup).
    pub listen: Option<String>,
    /// Worker-side peer (`--connect HOST:PORT`): serve shards for a
    /// driver listening on this address instead of over `--run-dir`.
    pub connect: Option<String>,
    /// Estimation-service settings (`snac-pack serve`).
    pub serve: ServeConfig,
    /// Structured-trace output (`--trace-out PATH`): write a Chrome-trace
    /// `trace.json` (plus a JSONL flight-recorder log beside it) covering
    /// the whole run. `None` = tracing off (the default). Tracing is
    /// observational only — trial databases are bit-identical with it on
    /// or off — and the path rides `run.json` so shard workers of a
    /// traced run enable their tracers too (each worker exports through
    /// its result publications, not to this path).
    pub trace_out: Option<String>,
    /// Per-op interpreter timing sample rate (`--trace-ops N`): record a
    /// span for every Nth executed plan step. `0` = off (the default) so
    /// kernels stay fast; only meaningful when `trace_out` is set.
    pub trace_ops: u64,
}

impl Preset {
    /// Look up a preset by name.
    pub fn by_name(name: &str) -> Result<Preset> {
        match name {
            "paper" => Ok(Preset {
                name: name.into(),
                data: DataConfig {
                    n_train: 16_384,
                    n_val: 4_096,
                    n_test: 4_096,
                    seed: 7,
                },
                search: SearchBudget {
                    trials: 500,
                    population: 20,
                    epochs: 5,
                    workers: 0,
                    shards: 0,
                    threads: 1,
                    verify_plans: false,
                    checkpoint_interval: 0,
                },
                surrogate: SurrogateTrainConfig::default(),
                local: LocalSearchConfig::default(),
                seed: 1,
                cache_path: None,
                run_dir: None,
                spawn_workers: None,
                listen: None,
                connect: None,
                serve: ServeConfig::default(),
                trace_out: None,
                trace_ops: 0,
            }),
            "ci" => Ok(Preset {
                name: name.into(),
                data: DataConfig {
                    n_train: 4_096,
                    n_val: 1_024,
                    n_test: 1_024,
                    seed: 7,
                },
                search: SearchBudget {
                    trials: 64,
                    population: 16,
                    epochs: 5,
                    workers: 0,
                    shards: 0,
                    threads: 1,
                    verify_plans: false,
                    checkpoint_interval: 0,
                },
                surrogate: SurrogateTrainConfig::default(),
                local: LocalSearchConfig {
                    warmup_epochs: 3,
                    imp_iterations: 8,
                    epochs_per_iteration: 3,
                    ..Default::default()
                },
                seed: 1,
                cache_path: None,
                run_dir: None,
                spawn_workers: None,
                listen: None,
                connect: None,
                serve: ServeConfig::default(),
                trace_out: None,
                trace_ops: 0,
            }),
            "quickstart" => Ok(Preset {
                name: name.into(),
                data: DataConfig {
                    n_train: 1_280,
                    n_val: 384,
                    n_test: 384,
                    seed: 7,
                },
                search: SearchBudget {
                    trials: 12,
                    population: 6,
                    epochs: 2,
                    workers: 0,
                    shards: 0,
                    threads: 1,
                    verify_plans: false,
                    checkpoint_interval: 0,
                },
                surrogate: SurrogateTrainConfig {
                    dataset_size: 1024,
                    epochs: 12,
                    ..Default::default()
                },
                local: LocalSearchConfig {
                    warmup_epochs: 1,
                    imp_iterations: 4,
                    epochs_per_iteration: 1,
                    ..Default::default()
                },
                seed: 1,
                cache_path: None,
                run_dir: None,
                spawn_workers: None,
                listen: None,
                connect: None,
                serve: ServeConfig::default(),
                trace_out: None,
                trace_ops: 0,
            }),
            other => bail!("unknown preset `{other}` (paper | ci | quickstart)"),
        }
    }

    /// NSGA-II config slice of this preset.
    pub fn nsga2(&self) -> Nsga2Config {
        Nsga2Config {
            population: self.search.population,
            ..Default::default()
        }
    }

    /// Apply a `key=value` override (CLI `--set`).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let uint = || -> Result<usize> { Ok(value.parse()?) };
        match key {
            "trials" => self.search.trials = uint()?,
            "population" => self.search.population = uint()?,
            "epochs" => self.search.epochs = uint()?,
            "workers" => self.search.workers = uint()?,
            "n_train" => self.data.n_train = uint()?,
            "n_val" => self.data.n_val = uint()?,
            "n_test" => self.data.n_test = uint()?,
            "surrogate_size" => self.surrogate.dataset_size = uint()?,
            "surrogate_epochs" => self.surrogate.epochs = uint()?,
            "imp_iterations" => self.local.imp_iterations = uint()?,
            "imp_epochs" => self.local.epochs_per_iteration = uint()?,
            "warmup_epochs" => self.local.warmup_epochs = uint()?,
            "target_sparsity" => self.local.target_sparsity = value.parse()?,
            "seed" => self.seed = value.parse()?,
            "cache_path" => self.cache_path = Some(value.to_string()),
            "port" => self.serve.port = value.parse().context("port expects a u16")?,
            "batch_deadline_ms" => {
                self.serve.batch_deadline_ms =
                    value.parse().context("batch_deadline_ms expects an integer")?
            }
            "pool_size" => self.serve.pool_size = uint()?,
            "queue_depth" => self.serve.queue_depth = uint()?,
            "shards" => self.search.shards = uint()?,
            "threads" => self.search.threads = uint()?,
            "verify_plans" => {
                self.search.verify_plans = match value {
                    "1" | "true" => true,
                    "0" | "false" => false,
                    other => bail!("verify_plans expects 0/1/true/false, got `{other}`"),
                }
            }
            "run_dir" => self.run_dir = Some(value.to_string()),
            "checkpoint_interval" => self.search.checkpoint_interval = uint()?,
            "trace_out" => self.trace_out = Some(value.to_string()),
            "trace_ops" => {
                self.trace_ops = value.parse().context("trace_ops expects a sample rate")?
            }
            "listen" => self.listen = Some(value.to_string()),
            "connect" => self.connect = Some(value.to_string()),
            "spawn_workers" => {
                self.spawn_workers = if value == "auto" {
                    None
                } else {
                    Some(value.parse().context("spawn_workers expects a count or `auto`")?)
                }
            }
            other => bail!("unknown override `{other}`"),
        }
        Ok(())
    }

    /// Every `--set`-able key, in application order. `to_json` serialises
    /// exactly these (plus the preset name), and `from_json` replays them
    /// over `by_name` — so the codec's surface is the override surface by
    /// construction, and fields outside it (e.g. surrogate learning rate)
    /// stay pinned to the named preset on both ends.
    const OVERRIDE_KEYS: [&str; 29] = [
        "trials",
        "population",
        "epochs",
        "workers",
        "n_train",
        "n_val",
        "n_test",
        "surrogate_size",
        "surrogate_epochs",
        "imp_iterations",
        "imp_epochs",
        "warmup_epochs",
        "target_sparsity",
        "seed",
        "cache_path",
        "port",
        "batch_deadline_ms",
        "pool_size",
        "queue_depth",
        "shards",
        "threads",
        "verify_plans",
        "run_dir",
        "checkpoint_interval",
        "listen",
        "connect",
        "spawn_workers",
        "trace_out",
        "trace_ops",
    ];

    fn get(&self, key: &str) -> Option<String> {
        let s = |v: usize| Some(v.to_string());
        match key {
            "trials" => s(self.search.trials),
            "population" => s(self.search.population),
            "epochs" => s(self.search.epochs),
            "workers" => s(self.search.workers),
            "n_train" => s(self.data.n_train),
            "n_val" => s(self.data.n_val),
            "n_test" => s(self.data.n_test),
            "surrogate_size" => s(self.surrogate.dataset_size),
            "surrogate_epochs" => s(self.surrogate.epochs),
            "imp_iterations" => s(self.local.imp_iterations),
            "imp_epochs" => s(self.local.epochs_per_iteration),
            "warmup_epochs" => s(self.local.warmup_epochs),
            "target_sparsity" => Some(format!("{}", self.local.target_sparsity)),
            "seed" => Some(self.seed.to_string()),
            "cache_path" => self.cache_path.clone(),
            "port" => Some(self.serve.port.to_string()),
            "batch_deadline_ms" => Some(self.serve.batch_deadline_ms.to_string()),
            "pool_size" => s(self.serve.pool_size),
            "queue_depth" => s(self.serve.queue_depth),
            "shards" => s(self.search.shards),
            "threads" => s(self.search.threads),
            "verify_plans" => Some(if self.search.verify_plans { "1" } else { "0" }.to_string()),
            "run_dir" => self.run_dir.clone(),
            "checkpoint_interval" => s(self.search.checkpoint_interval),
            "listen" => self.listen.clone(),
            "connect" => self.connect.clone(),
            "spawn_workers" => self.spawn_workers.map(|v| v.to_string()),
            "trace_out" => self.trace_out.clone(),
            "trace_ops" => {
                if self.trace_ops == 0 {
                    None
                } else {
                    Some(self.trace_ops.to_string())
                }
            }
            _ => None,
        }
    }

    /// Serialise this preset for a sharded run's `run.json`, so worker
    /// processes reconstruct the exact experiment configuration.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("name", Json::Str(self.name.clone()))];
        for key in Self::OVERRIDE_KEYS {
            if let Some(value) = self.get(key) {
                pairs.push((key, Json::Str(value)));
            }
        }
        Json::obj(pairs)
    }

    /// Reconstruct a preset serialised by [`Preset::to_json`].
    pub fn from_json(j: &Json) -> Result<Preset> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .context("preset JSON missing `name`")?;
        let mut preset = Preset::by_name(name)?;
        for key in Self::OVERRIDE_KEYS {
            if let Some(value) = j.get(key).and_then(Json::as_str) {
                preset
                    .set(key, value)
                    .with_context(|| format!("restoring preset key `{key}`"))?;
            }
        }
        Ok(preset)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for name in ["paper", "ci", "quickstart"] {
            let p = Preset::by_name(name).unwrap();
            assert_eq!(p.name, name);
            assert!(p.search.trials >= p.search.population);
        }
        assert!(Preset::by_name("nope").is_err());
    }

    #[test]
    fn paper_preset_matches_section4() {
        let p = Preset::by_name("paper").unwrap();
        assert_eq!(p.search.trials, 500);
        assert_eq!(p.search.population, 20);
        assert_eq!(p.search.epochs, 5);
        assert_eq!(p.local.warmup_epochs, 5);
        assert_eq!(p.local.imp_iterations, 10);
        assert_eq!(p.local.epochs_per_iteration, 10);
        assert_eq!(p.local.prune_fraction, 0.2);
        assert_eq!(p.local.bits, 8);
    }

    #[test]
    fn overrides_apply() {
        let mut p = Preset::by_name("ci").unwrap();
        p.set("trials", "99").unwrap();
        p.set("target_sparsity", "0.7").unwrap();
        p.set("workers", "4").unwrap();
        p.set("cache_path", "results/eval_cache.json").unwrap();
        p.set("shards", "3").unwrap();
        p.set("threads", "2").unwrap();
        p.set("run_dir", "/tmp/run").unwrap();
        p.set("spawn_workers", "2").unwrap();
        p.set("checkpoint_interval", "5").unwrap();
        p.set("listen", "127.0.0.1:0").unwrap();
        p.set("connect", "10.0.0.2:7979").unwrap();
        assert_eq!(p.search.trials, 99);
        assert_eq!(p.local.target_sparsity, 0.7);
        assert_eq!(p.search.workers, 4);
        assert_eq!(p.cache_path.as_deref(), Some("results/eval_cache.json"));
        assert_eq!(p.search.shards, 3);
        assert_eq!(p.search.threads, 2);
        assert_eq!(p.run_dir.as_deref(), Some("/tmp/run"));
        assert_eq!(p.spawn_workers, Some(2));
        assert_eq!(p.search.checkpoint_interval, 5);
        assert_eq!(p.listen.as_deref(), Some("127.0.0.1:0"));
        assert_eq!(p.connect.as_deref(), Some("10.0.0.2:7979"));
        assert!(p.set("checkpoint_interval", "often").is_err());
        p.set("spawn_workers", "auto").unwrap();
        assert_eq!(p.spawn_workers, None);
        assert!(!p.search.verify_plans, "plan verification is opt-in");
        p.set("verify_plans", "1").unwrap();
        assert!(p.search.verify_plans);
        p.set("verify_plans", "false").unwrap();
        assert!(!p.search.verify_plans);
        assert!(p.set("verify_plans", "maybe").is_err());
        p.set("port", "0").unwrap();
        p.set("batch_deadline_ms", "25").unwrap();
        assert_eq!(p.serve.port, 0);
        assert_eq!(p.serve.batch_deadline_ms, 25);
        assert_eq!(p.serve.pool_size, 0, "pool sizing defaults to auto");
        assert_eq!(p.serve.queue_depth, 0, "queue sizing defaults to auto");
        p.set("pool_size", "3").unwrap();
        p.set("queue_depth", "9").unwrap();
        assert_eq!(p.serve.pool_size, 3);
        assert_eq!(p.serve.queue_depth, 9);
        assert!(p.set("pool_size", "many").is_err());
        assert_eq!(p.trace_out, None, "tracing is opt-in");
        assert_eq!(p.trace_ops, 0, "per-op sampling is opt-in");
        p.set("trace_out", "results/trace.json").unwrap();
        p.set("trace_ops", "16").unwrap();
        assert_eq!(p.trace_out.as_deref(), Some("results/trace.json"));
        assert_eq!(p.trace_ops, 16);
        assert!(p.set("trace_ops", "every").is_err());
        assert!(p.set("bogus", "1").is_err());
        assert!(p.set("spawn_workers", "lots").is_err());
        assert!(p.set("port", "70000").is_err(), "port must fit a u16");
    }

    /// The run.json codec: every override survives the round trip, and
    /// preset-fixed fields come back from the named base.
    #[test]
    fn preset_json_round_trips_every_override() {
        let mut p = Preset::by_name("quickstart").unwrap();
        p.set("trials", "7").unwrap();
        p.set("population", "5").unwrap();
        p.set("epochs", "3").unwrap();
        p.set("workers", "2").unwrap();
        p.set("n_train", "777").unwrap();
        p.set("surrogate_size", "256").unwrap();
        p.set("target_sparsity", "0.65").unwrap();
        p.set("seed", "99").unwrap();
        p.set("cache_path", "/tmp/c.json").unwrap();
        p.set("shards", "2").unwrap();
        p.set("threads", "4").unwrap();
        p.set("verify_plans", "1").unwrap();
        p.set("run_dir", "/tmp/rd").unwrap();
        p.set("port", "9191").unwrap();
        p.set("batch_deadline_ms", "7").unwrap();
        p.set("pool_size", "4").unwrap();
        p.set("queue_depth", "16").unwrap();
        p.set("checkpoint_interval", "3").unwrap();
        p.set("listen", "0.0.0.0:7979").unwrap();
        p.set("connect", "driver.local:7979").unwrap();
        p.set("trace_out", "/tmp/trace.json").unwrap();
        p.set("trace_ops", "8").unwrap();
        let text = p.to_json().to_string();
        let back = Preset::from_json(&crate::util::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.name, "quickstart");
        assert_eq!(back.search.trials, 7);
        assert_eq!(back.search.population, 5);
        assert_eq!(back.search.epochs, 3);
        assert_eq!(back.search.workers, 2);
        assert_eq!(back.search.shards, 2);
        assert_eq!(back.search.threads, 4);
        assert!(back.search.verify_plans, "verify_plans survives the run.json round trip");
        assert_eq!(back.data.n_train, 777);
        assert_eq!(back.data.n_val, 384, "untouched fields come from the base preset");
        assert_eq!(back.data.seed, 7, "data seed is preset-fixed");
        assert_eq!(back.surrogate.dataset_size, 256);
        assert_eq!(back.local.target_sparsity, 0.65);
        assert_eq!(back.seed, 99);
        assert_eq!(back.cache_path.as_deref(), Some("/tmp/c.json"));
        assert_eq!(back.run_dir.as_deref(), Some("/tmp/rd"));
        assert_eq!(back.serve.port, 9191);
        assert_eq!(back.serve.batch_deadline_ms, 7);
        assert_eq!(back.serve.pool_size, 4);
        assert_eq!(back.serve.queue_depth, 16);
        assert_eq!(back.search.checkpoint_interval, 3);
        assert_eq!(back.listen.as_deref(), Some("0.0.0.0:7979"));
        assert_eq!(back.connect.as_deref(), Some("driver.local:7979"));
        assert_eq!(back.trace_out.as_deref(), Some("/tmp/trace.json"));
        assert_eq!(back.trace_ops, 8, "trace knobs ride run.json like threads does");
        // garbage is rejected with context
        assert!(Preset::from_json(&crate::util::Json::parse("{}").unwrap()).is_err());
    }
}
