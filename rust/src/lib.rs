//! # SNAC-Pack — Surrogate Neural Architecture Codesign Package (reproduction)
//!
//! A full reimplementation of the SNAC-Pack system (Weitz et al., ML4PS @
//! NeurIPS 2025): multi-stage neural architecture codesign for FPGA
//! deployment, with a rule4ml-style *surrogate* resource/latency estimator
//! in the search loop instead of proxy BOPs.
//!
//! Architecture (see DESIGN.md):
//! * **Layer 3 (this crate)** — the coordination contribution: NSGA-II
//!   global search, trial scheduling, local search (iterative magnitude
//!   pruning + QAT), the surrogate trainer, the hls4ml-style synthesis
//!   simulator, and the report machinery that regenerates every table and
//!   figure of the paper.
//! * **Layer 2 (python/compile/model.py)** — the padded *supernet* covering
//!   the entire Table 1 search space, AOT-lowered once to HLO text.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for the fused
//!   masked dense layer (forward + backward).
//!
//! Python never runs at search time: [`runtime`] loads the AOT artifacts via
//! the PJRT C API and every candidate architecture is expressed as runtime
//! *inputs* (masks/gates/hyperparameter scalars) to one compiled graph.

pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod hls;
pub mod net;
pub mod nn;
pub mod objectives;
pub mod pareto;
pub mod report;
pub mod runtime;
pub mod search;
pub mod serve;
pub mod surrogate;
pub mod telemetry;
pub mod trainer;
pub mod util;
