//! Network descriptors fed to the synthesis simulator, and its report type.


use super::device::FpgaDevice;
use crate::nn::genome::{Activation, Genome};
use crate::nn::space::SearchSpace;

/// One dense(+BN)(+activation) stage as hls4ml sees it.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    /// Fan-in.
    pub n_in: usize,
    /// Fan-out.
    pub n_out: usize,
    /// Weight bit-width (ap_fixed total bits).
    pub weight_bits: u32,
    /// Activation-datapath bit-width.
    pub act_bits: u32,
    /// Non-zero multiplies after pruning/quantisation elision.
    pub nnz: usize,
    /// Nonlinearity following the dense (None for the classifier head).
    pub activation: Option<Activation>,
    /// Unfused BatchNorm affine after the dense.
    pub batch_norm: bool,
}

impl LayerSpec {
    /// Weight sparsity of this layer.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz as f64 / (self.n_in * self.n_out) as f64
    }
}

/// A whole network for synthesis.
#[derive(Debug, Clone)]
pub struct NetworkSpec {
    /// Dense stages, input → head.
    pub layers: Vec<LayerSpec>,
    /// Synthesize a stable softmax head (exp/inv BRAM tables). The legacy
    /// baseline config [12] keeps it; NAC/SNAC deployments use argmax.
    pub softmax_head: bool,
    /// Fold BatchNorm affines into the preceding Dense (hls4ml's
    /// `fuse_batch_norm` pass — free in hardware). Modern QAT flows get
    /// this; the legacy baseline synthesis kept BN as a separate 16-bit
    /// stage, which is where its DSP usage comes from (Table 3).
    pub fuse_batch_norm: bool,
}

impl NetworkSpec {
    /// Dense network from a genome at uniform precision and sparsity
    /// (global-search estimates, where no trained weights exist yet).
    pub fn from_genome(
        genome: &Genome,
        space: &SearchSpace,
        bits: u32,
        sparsity: f64,
    ) -> Self {
        let dims = genome.layer_dims(space);
        let n_layers = dims.len();
        let layers = dims
            .iter()
            .enumerate()
            .map(|(i, &(n_in, n_out))| LayerSpec {
                n_in,
                n_out,
                weight_bits: bits,
                act_bits: bits + 2, // hls4ml default: a little headroom on the datapath
                nnz: ((n_in * n_out) as f64 * (1.0 - sparsity)).round() as usize,
                activation: if i + 1 < n_layers { Some(genome.act) } else { None },
                batch_norm: genome.batch_norm && i + 1 < n_layers,
            })
            .collect();
        NetworkSpec {
            layers,
            softmax_head: false,
            fuse_batch_norm: true,
        }
    }

    /// As [`NetworkSpec::from_genome`] but with exact per-layer non-zero
    /// counts (post-IMP, post-QAT — weights whose quantised value is zero
    /// are elided by HLS constant folding).
    pub fn from_genome_with_nnz(
        genome: &Genome,
        space: &SearchSpace,
        bits: u32,
        nnz: &[usize],
    ) -> Self {
        let mut spec = Self::from_genome(genome, space, bits, 0.0);
        assert_eq!(nnz.len(), spec.layers.len(), "one nnz per dense layer");
        for (layer, &n) in spec.layers.iter_mut().zip(nnz) {
            layer.nnz = n.min(layer.n_in * layer.n_out);
        }
        spec
    }

    /// Total multiplies before pruning.
    pub fn total_macs(&self) -> usize {
        self.layers.iter().map(|l| l.n_in * l.n_out).sum()
    }

    /// Total surviving multiplies.
    pub fn total_nnz(&self) -> usize {
        self.layers.iter().map(|l| l.nnz).sum()
    }
}

/// Post-synthesis resources and timing (Table 3 row).
#[derive(Debug, Clone, Default)]
pub struct SynthReport {
    /// DSP48 slices.
    pub dsp: u64,
    /// Logic LUTs.
    pub lut: u64,
    /// Flip-flops.
    pub ff: u64,
    /// BRAM36 blocks.
    pub bram36: u64,
    /// Pipeline latency in clock cycles.
    pub latency_cc: u64,
    /// Initiation interval in clock cycles.
    pub ii_cc: u64,
    /// Clock period used for ns conversions.
    pub clock_ns: f64,
}

impl SynthReport {
    /// Latency in nanoseconds.
    pub fn latency_ns(&self) -> f64 {
        self.latency_cc as f64 * self.clock_ns
    }

    /// II in nanoseconds.
    pub fn ii_ns(&self) -> f64 {
        self.ii_cc as f64 * self.clock_ns
    }

    /// Utilisation percentages `(dsp, lut, ff, bram)` on a device.
    pub fn utilisation(&self, device: &FpgaDevice) -> [f64; 4] {
        [
            self.dsp as f64 / device.dsp as f64 * 100.0,
            self.lut as f64 / device.lut as f64 * 100.0,
            self.ff as f64 / device.ff as f64 * 100.0,
            self.bram36 as f64 / device.bram36 as f64 * 100.0,
        ]
    }

    /// The paper's "average resources" scalar: mean of the four
    /// utilisation percentages.
    pub fn avg_resources(&self, device: &FpgaDevice) -> f64 {
        self.utilisation(device).iter().sum::<f64>() / 4.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn baseline_spec() -> NetworkSpec {
        let space = SearchSpace::table1();
        NetworkSpec::from_genome(&space.baseline(), &space, 8, 0.5)
    }

    #[test]
    fn from_genome_builds_all_stages() {
        let spec = baseline_spec();
        assert_eq!(spec.layers.len(), 5); // 4 hidden + head
        assert!(spec.layers[..4].iter().all(|l| l.activation.is_some()));
        assert!(spec.layers[4].activation.is_none());
        assert!(spec.layers[..4].iter().all(|l| l.batch_norm));
        assert!(!spec.layers[4].batch_norm);
    }

    #[test]
    fn nnz_respects_sparsity() {
        let spec = baseline_spec();
        let total = spec.total_macs();
        let nnz = spec.total_nnz();
        assert!((nnz as f64 / total as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn with_nnz_overrides_counts() {
        let space = SearchSpace::table1();
        let g = space.baseline();
        let spec = NetworkSpec::from_genome_with_nnz(&g, &space, 8, &[100, 90, 80, 70, 60]);
        assert_eq!(spec.total_nnz(), 400);
    }

    #[test]
    fn utilisation_scales() {
        let d = FpgaDevice::vu13p();
        let r = SynthReport {
            dsp: 262,
            lut: 155_080,
            ff: 25_714,
            bram36: 4,
            latency_cc: 21,
            ii_cc: 1,
            clock_ns: 5.0,
        };
        let u = r.utilisation(&d);
        assert!((u[0] - 2.13).abs() < 0.05);
        assert!((u[1] - 8.97).abs() < 0.05);
        assert_eq!(r.latency_ns(), 105.0);
        assert!(r.avg_resources(&d) > 0.0);
    }
}
