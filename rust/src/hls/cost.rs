//! The analytic cost model: `io_parallel` / `latency` strategy / RF = 1.
//!
//! Modelled mechanisms (each anchored on a Table 3 observation):
//!
//! * **Multiplier mapping** — at RF = 1 every surviving weight is its own
//!   multiplier. Products at ≤ `dsp_threshold_bits` weight bits are
//!   LUT-mapped (Vivado synthesises small constant multiplies in fabric) —
//!   this is why the paper's 8-bit NAC/SNAC models report **0 DSP**.
//! * **Unfused BatchNorm** — a per-channel 16-bit scale+shift after the
//!   dense, DSP-mapped (2 DSP/channel). The baseline's 262 DSPs come from
//!   exactly this (it keeps BN as a separate layer, as [12] synthesised it).
//! * **Adder trees** — `nnz − n_out` adders at accumulator width.
//! * **Pipeline registers** — FF cost per multiplier plus per-stage output
//!   registers.
//! * **Activation tables** — tanh/sigmoid are 1024-entry ROMs (2 BRAM36
//!   per layer); ReLU is free fabric. A stable softmax head costs 4 BRAM36
//!   (exp + reciprocal tables) — the legacy baseline keeps it, NAC/SNAC
//!   deployments use argmax (0 BRAM, as Table 3's SNAC row shows).
//! * **Latency** — sum of per-stage pipeline depths (mult, log2 adder tree,
//!   activation, BN); II = 1 at RF = 1.
//!
//! Absolute constants are calibrated to land in the magnitude range of the
//! paper's Table 3 (see `table3_scale_anchor` test); EXPERIMENTS.md
//! compares shapes, not absolutes.


use super::device::FpgaDevice;
use super::network::{NetworkSpec, SynthReport};

/// Tunable constants of the synthesis model.
#[derive(Debug, Clone)]
pub struct HlsConfig {
    /// Weight bit-widths strictly above this use DSP48s for multiplies.
    pub dsp_threshold_bits: u32,
    /// LUTs per LUT-mapped multiply, per weight-bit × act-bit / this divisor.
    pub lut_mult_divisor: f64,
    /// LUTs per adder-bit in the accumulation tree.
    pub lut_per_adder_bit: f64,
    /// FFs per multiplier (pipeline balancing registers).
    pub ff_per_mult_bit: f64,
    /// FF pipeline registers per stage output bit.
    pub ff_stage_factor: f64,
    /// DSPs per unfused-BatchNorm channel (16-bit scale + shift).
    pub dsp_per_bn_channel: u64,
    /// BRAM36 per tanh/sigmoid table layer.
    pub bram_per_table: u64,
    /// BRAM36 for a stable softmax head (exp + reciprocal tables).
    pub bram_softmax: u64,
    /// Extra latency cycles for input/output handshake.
    pub io_latency_cc: u64,
}

impl Default for HlsConfig {
    fn default() -> Self {
        HlsConfig {
            dsp_threshold_bits: 9,
            lut_mult_divisor: 1.85, // 8w×10a → ~43 LUT/mult
            lut_per_adder_bit: 1.0,
            ff_per_mult_bit: 1.0,
            ff_stage_factor: 3.0,
            dsp_per_bn_channel: 2,
            bram_per_table: 2,
            bram_softmax: 4,
            io_latency_cc: 2,
        }
    }
}

fn accumulator_bits(l: &super::network::LayerSpec) -> u32 {
    // full-precision accumulation: product bits + tree growth
    l.weight_bits + l.act_bits + (l.n_in.max(2) as f64).log2().ceil() as u32
}

/// Run the synthesis model on a network for a device.
pub fn synthesize(spec: &NetworkSpec, cfg: &HlsConfig, device: &FpgaDevice) -> SynthReport {
    let mut r = SynthReport {
        clock_ns: device.clock_ns,
        ii_cc: 1, // RF = 1 fully-pipelined dataflow
        latency_cc: cfg.io_latency_cc,
        ..Default::default()
    };
    for l in &spec.layers {
        let acc_bits = accumulator_bits(l) as f64;
        let nnz = l.nnz as f64;

        // --- multipliers ---
        let dsp_mapped = l.weight_bits > cfg.dsp_threshold_bits;
        if dsp_mapped {
            r.dsp += l.nnz as u64;
        } else {
            let lut_per_mult =
                (l.weight_bits as f64 * l.act_bits as f64) / cfg.lut_mult_divisor;
            r.lut += (nnz * lut_per_mult) as u64;
        }

        // --- adder tree: nnz − n_out two-input adds at accumulator width ---
        let adds = l.nnz.saturating_sub(l.n_out) as f64;
        r.lut += (adds * acc_bits * cfg.lut_per_adder_bit) as u64;

        // --- pipeline registers ---
        r.ff += (nnz * l.weight_bits as f64 * cfg.ff_per_mult_bit / 8.0) as u64;
        r.ff += (l.n_out as f64 * acc_bits * cfg.ff_stage_factor) as u64;

        // --- BatchNorm: free when fused into the dense weights (hls4ml
        //     fuse_batch_norm); a separate 16-bit affine stage otherwise ---
        let bn_separate = l.batch_norm && !spec.fuse_batch_norm;
        if bn_separate {
            r.dsp += cfg.dsp_per_bn_channel * l.n_out as u64;
            r.ff += (l.n_out * 16 * 2) as u64;
            r.lut += (l.n_out * 16) as u64;
        }

        // --- activation ---
        let act_latency = match l.activation {
            Some(a) if a.needs_table() => {
                r.bram36 += cfg.bram_per_table;
                1 // registered ROM lookup
            }
            Some(_) => 0, // ReLU folds into the accumulator compare
            None => 0,
        };

        // --- latency: mult + adder tree + act + bn ---
        // The `latency` strategy chains ~2 tree levels per cycle at 5 ns
        // (calibrated on Table 3: baseline 21 cc over 5 dense stages).
        let fan_in = (l.nnz as f64 / l.n_out.max(1) as f64).max(1.0);
        let tree_depth = ((fan_in.log2() / 2.0).ceil()).max(1.0) as u64;
        let mult_lat = if dsp_mapped { 2 } else { 1 };
        let bn_lat = u64::from(bn_separate);
        r.latency_cc += mult_lat + tree_depth + act_latency + bn_lat;
    }
    if spec.softmax_head {
        r.bram36 += cfg.bram_softmax;
        r.latency_cc += 3; // exp lookup + normalise + compare
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::genome::{Activation, Genome};
    use crate::nn::space::SearchSpace;
    use crate::nn::NUM_LAYERS;

    fn baseline_report() -> SynthReport {
        let space = SearchSpace::table1();
        let mut spec = NetworkSpec::from_genome(&space.baseline(), &space, 8, 0.5);
        spec.softmax_head = true; // legacy [12] config
        spec.fuse_batch_norm = false;
        synthesize(&spec, &HlsConfig::default(), &FpgaDevice::vu13p())
    }

    #[test]
    fn table3_scale_anchor() {
        // Baseline [12]: pruned 50 %, 8-bit. Paper: 262 DSP, 155k LUT,
        // 25.7k FF, 4 BRAM, 21 cc. We require same order of magnitude.
        let r = baseline_report();
        assert!(r.dsp > 100 && r.dsp < 600, "dsp {}", r.dsp);
        assert!(r.lut > 60_000 && r.lut < 400_000, "lut {}", r.lut);
        assert!(r.ff > 8_000 && r.ff < 80_000, "ff {}", r.ff);
        assert_eq!(r.bram36, 4);
        assert!(r.latency_cc > 12 && r.latency_cc < 35, "lat {}", r.latency_cc);
        assert_eq!(r.ii_cc, 1);
    }

    #[test]
    fn eight_bit_models_without_bn_use_zero_dsp() {
        let space = SearchSpace::table1();
        let mut g = space.baseline();
        g.batch_norm = false;
        let spec = NetworkSpec::from_genome(&g, &space, 8, 0.5);
        let r = synthesize(&spec, &HlsConfig::default(), &FpgaDevice::vu13p());
        assert_eq!(r.dsp, 0, "8-bit LUT-mapped multiplies, no BN → no DSP");
    }

    #[test]
    fn sixteen_bit_models_use_dsp() {
        let space = SearchSpace::table1();
        let mut g = space.baseline();
        g.batch_norm = false;
        let spec = NetworkSpec::from_genome(&g, &space, 16, 0.5);
        let r = synthesize(&spec, &HlsConfig::default(), &FpgaDevice::vu13p());
        assert!(r.dsp as usize >= spec.total_nnz());
    }

    #[test]
    fn relu_model_uses_no_bram() {
        let space = SearchSpace::table1();
        let mut g = space.baseline();
        g.batch_norm = false;
        g.act = Activation::ReLU;
        let spec = NetworkSpec::from_genome(&g, &space, 8, 0.5);
        let r = synthesize(&spec, &HlsConfig::default(), &FpgaDevice::vu13p());
        assert_eq!(r.bram36, 0);
    }

    #[test]
    fn tanh_layers_cost_bram() {
        let space = SearchSpace::table1();
        let mut g = space.baseline();
        g.batch_norm = false;
        g.act = Activation::Tanh;
        let spec = NetworkSpec::from_genome(&g, &space, 8, 0.5);
        let r = synthesize(&spec, &HlsConfig::default(), &FpgaDevice::vu13p());
        // 4 hidden tanh layers × 2 BRAM = 8 (the paper's NAC row!)
        assert_eq!(r.bram36, 8);
    }

    #[test]
    fn pruning_reduces_lut_and_latency_monotonically() {
        let space = SearchSpace::table1();
        let mut g = space.baseline();
        g.batch_norm = false;
        let cfg = HlsConfig::default();
        let d = FpgaDevice::vu13p();
        let mut last_lut = u64::MAX;
        for s in [0.0, 0.25, 0.5, 0.75, 0.9] {
            let spec = NetworkSpec::from_genome(&g, &space, 8, s);
            let r = synthesize(&spec, &cfg, &d);
            assert!(r.lut < last_lut, "sparsity {s} must shrink LUT");
            last_lut = r.lut;
        }
    }

    #[test]
    fn wider_network_costs_more() {
        let space = SearchSpace::table1();
        let thin = Genome {
            n_layers: 4,
            width_idx: [0, 0, 0, 0, 0, 0, 0, 0],
            act: Activation::ReLU,
            batch_norm: false,
            lr_idx: 0,
            l1_idx: 0,
            dropout_idx: 0,
        };
        let mut wide = thin.clone();
        wide.width_idx = [2, 2, 1, 1, 1, 1, 1, 2];
        let cfg = HlsConfig::default();
        let d = FpgaDevice::vu13p();
        let rt = synthesize(&NetworkSpec::from_genome(&thin, &space, 8, 0.0), &cfg, &d);
        let rw = synthesize(&NetworkSpec::from_genome(&wide, &space, 8, 0.0), &cfg, &d);
        assert!(rw.lut > rt.lut);
        assert!(rw.ff > rt.ff);
    }

    #[test]
    fn deeper_network_has_longer_latency() {
        let space = SearchSpace::table1();
        let mut short = space.baseline();
        short.batch_norm = false;
        let mut long = short.clone();
        long.n_layers = 8;
        let cfg = HlsConfig::default();
        let d = FpgaDevice::vu13p();
        let rs = synthesize(&NetworkSpec::from_genome(&short, &space, 8, 0.0), &cfg, &d);
        let rl = synthesize(&NetworkSpec::from_genome(&long, &space, 8, 0.0), &cfg, &d);
        assert!(rl.latency_cc > rs.latency_cc);
    }

    #[test]
    fn ii_is_one_at_rf1() {
        let r = baseline_report();
        assert_eq!(r.ii_cc, 1);
    }

    #[test]
    fn all_depths_synthesize() {
        let space = SearchSpace::table1();
        let cfg = HlsConfig::default();
        let d = FpgaDevice::vu13p();
        for depth in 4..=NUM_LAYERS {
            let g = Genome {
                n_layers: depth,
                width_idx: [0; NUM_LAYERS],
                act: Activation::Sigmoid,
                batch_norm: true,
                lr_idx: 0,
                l1_idx: 0,
                dropout_idx: 0,
            };
            let r = synthesize(&NetworkSpec::from_genome(&g, &space, 8, 0.3), &cfg, &d);
            assert!(r.lut > 0 && r.latency_cc > 0);
        }
    }
}
