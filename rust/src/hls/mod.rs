//! hls4ml-style FPGA synthesis **simulator** (DESIGN.md substitution #1).
//!
//! The paper synthesises its final models with hls4ml (`io_parallel`,
//! `latency` strategy, `reuse_factor = 1`) and Vivado on a Xilinx Virtex
//! UltraScale+ VU13P. Neither tool is available here, so this module is an
//! analytic model of that exact pipeline: per-layer multiplier enumeration
//! with pruned-weight elision, bitwidth-dependent DSP-vs-LUT multiplier
//! mapping, adder trees, pipeline registers, activation-table BRAMs, and a
//! per-layer pipeline-depth latency model. It is the *ground truth* that
//! the rule4ml-style surrogate is trained to predict, and it generates
//! Table 3.

pub mod cost;
pub mod device;
pub mod network;

pub use cost::{synthesize, HlsConfig};
pub use device::FpgaDevice;
pub use network::{LayerSpec, NetworkSpec, SynthReport};
