//! FPGA device models (resource capacities for utilisation percentages).


/// An FPGA part's resource capacities.
#[derive(Debug, Clone)]
pub struct FpgaDevice {
    /// Part name.
    pub name: String,
    /// DSP48 slices.
    pub dsp: u64,
    /// Logic LUTs.
    pub lut: u64,
    /// Flip-flops.
    pub ff: u64,
    /// BRAM36 blocks.
    pub bram36: u64,
    /// Target clock period in nanoseconds.
    pub clock_ns: f64,
}

impl FpgaDevice {
    /// Xilinx Virtex UltraScale+ VU13P — the paper's target, at the 200 MHz
    /// clock implied by Table 3 (105 ns / 21 cc = 5 ns).
    pub fn vu13p() -> Self {
        FpgaDevice {
            name: "xcvu13p".to_string(),
            dsp: 12_288,
            lut: 1_728_000,
            ff: 3_456_000,
            bram36: 2_688,
            clock_ns: 5.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vu13p_percentages_match_paper_scale() {
        let d = FpgaDevice::vu13p();
        // Table 3 anchors: 262 DSP = 2.1 %, 155080 LUT = 9.0 %,
        // 25714 FF = 0.7 %
        assert!((262.0 / d.dsp as f64 * 100.0 - 2.1).abs() < 0.1);
        assert!((155_080.0 / d.lut as f64 * 100.0 - 9.0).abs() < 0.1);
        assert!((25_714.0 / d.ff as f64 * 100.0 - 0.7).abs() < 0.1);
    }
}
