//! Trial records: one row per evaluated candidate, JSON round-trippable.

use anyhow::{Context, Result};

use crate::nn::{Genome, SearchSpace};
use crate::util::Json;

/// One evaluated candidate (a point in Figures 1–4).
#[derive(Debug, Clone)]
pub struct TrialRecord {
    /// Sequential trial id.
    pub id: usize,
    /// NSGA-II generation index.
    pub generation: usize,
    /// The candidate.
    pub genome: Genome,
    /// Human-readable architecture label.
    pub label: String,
    /// Validation accuracy after the trial's training budget.
    pub accuracy: f64,
    /// BOPs at the assumed deployment point (always recorded for Table 2).
    pub bops: f64,
    /// Surrogate estimate: mean utilisation % (when a surrogate ran).
    pub est_avg_resources: Option<f64>,
    /// Surrogate estimate: latency cycles (when a surrogate ran).
    pub est_clock_cycles: Option<f64>,
    /// The minimised objective vector used by the search.
    pub objectives: Vec<f64>,
    /// Wall-clock seconds spent training+evaluating this trial.
    pub train_seconds: f64,
}

impl TrialRecord {
    /// Serialise to JSON.
    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("generation", Json::Num(self.generation as f64)),
            ("genome", self.genome.to_json()),
            ("label", Json::Str(self.label.clone())),
            ("accuracy", Json::Num(self.accuracy)),
            ("bops", Json::Num(self.bops)),
            ("est_avg_resources", opt(self.est_avg_resources)),
            ("est_clock_cycles", opt(self.est_clock_cycles)),
            ("objectives", Json::nums(self.objectives.iter().copied())),
            ("train_seconds", Json::Num(self.train_seconds)),
        ])
    }

    /// Parse back from JSON.
    pub fn from_json(j: &Json, space: &SearchSpace) -> Result<TrialRecord> {
        let genome = Genome::from_json(j.get("genome").context("missing genome")?)?;
        anyhow::ensure!(space.contains(&genome), "genome outside search space");
        // required fields read `null` back as NaN (the writer serialises
        // non-finite numbers as `null` — see util::Json); optional
        // estimates keep `as_f64`, where `null` means "not estimated"
        let f = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(Json::as_f64_or_nan)
                .with_context(|| format!("missing `{k}`"))
        };
        let optf = |k: &str| j.get(k).and_then(Json::as_f64);
        Ok(TrialRecord {
            id: f("id")? as usize,
            generation: f("generation")? as usize,
            label: genome.label(space),
            genome,
            accuracy: f("accuracy")?,
            bops: f("bops")?,
            est_avg_resources: optf("est_avg_resources"),
            est_clock_cycles: optf("est_clock_cycles"),
            objectives: j
                .get("objectives")
                .context("missing objectives")?
                .items()
                .iter()
                .filter_map(Json::as_f64_or_nan)
                .collect(),
            train_seconds: f("train_seconds")?,
        })
    }

    /// Save a whole trial database.
    pub fn save_all(records: &[TrialRecord], path: &std::path::Path) -> Result<()> {
        let arr = Json::Arr(records.iter().map(TrialRecord::to_json).collect());
        std::fs::write(path, arr.to_string())?;
        Ok(())
    }

    /// Load a trial database.
    pub fn load_all(path: &std::path::Path, space: &SearchSpace) -> Result<Vec<TrialRecord>> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        j.items()
            .iter()
            .map(|item| TrialRecord::from_json(item, space))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn record_roundtrips_through_json() {
        let space = SearchSpace::table1();
        let mut rng = Rng::new(0);
        let genome = space.sample(&mut rng);
        let rec = TrialRecord {
            id: 3,
            generation: 1,
            label: genome.label(&space),
            genome,
            accuracy: 0.6412,
            bops: 12_345.0,
            est_avg_resources: Some(3.25),
            est_clock_cycles: None,
            objectives: vec![-0.6412, 3.25],
            train_seconds: 1.5,
        };
        let parsed = TrialRecord::from_json(&rec.to_json(), &space).unwrap();
        assert_eq!(parsed.genome, rec.genome);
        assert_eq!(parsed.accuracy, rec.accuracy);
        assert_eq!(parsed.est_avg_resources, Some(3.25));
        assert_eq!(parsed.est_clock_cycles, None);
        assert_eq!(parsed.objectives, rec.objectives);

        // every None/Some estimate combination survives the round trip
        for (res, cc) in [
            (None, None),
            (Some(1.5), None),
            (None, Some(42.0)),
            (Some(1.5), Some(42.0)),
        ] {
            let mut r = rec.clone();
            r.est_avg_resources = res;
            r.est_clock_cycles = cc;
            let parsed = TrialRecord::from_json(&r.to_json(), &space).unwrap();
            assert_eq!(parsed.est_avg_resources, res);
            assert_eq!(parsed.est_clock_cycles, cc);
        }
    }

    #[test]
    fn nan_fields_round_trip_as_nan_not_missing() {
        // the writer serialises NaN as `null`; a NaN accuracy/objective
        // must read back as NaN (same shape), not drop or fail the record
        let space = SearchSpace::table1();
        let mut rng = Rng::new(7);
        let genome = space.sample(&mut rng);
        let rec = TrialRecord {
            id: 1,
            generation: 0,
            label: genome.label(&space),
            genome,
            accuracy: f64::NAN,
            bops: 10.0,
            est_avg_resources: None,
            est_clock_cycles: None,
            objectives: vec![f64::NAN, 10.0],
            train_seconds: 0.1,
        };
        let parsed = TrialRecord::from_json(&rec.to_json(), &space).unwrap();
        assert!(parsed.accuracy.is_nan());
        assert_eq!(parsed.objectives.len(), 2);
        assert!(parsed.objectives[0].is_nan());
        assert_eq!(parsed.objectives[1], 10.0);
    }

    #[test]
    fn corrupted_database_is_an_error() {
        let dir = std::env::temp_dir().join("snac_trialdb_corrupt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trials.json");
        std::fs::write(&path, "[{\"id\": 0, \"genome\": {").unwrap();
        assert!(TrialRecord::load_all(&path, &SearchSpace::table1()).is_err());
    }

    #[test]
    fn database_save_load() {
        let space = SearchSpace::table1();
        let mut rng = Rng::new(1);
        let records: Vec<TrialRecord> = (0..10)
            .map(|i| {
                let genome = space.sample(&mut rng);
                TrialRecord {
                    id: i,
                    generation: i / 4,
                    label: genome.label(&space),
                    genome,
                    accuracy: 0.6 + 0.001 * i as f64,
                    bops: 1000.0 * i as f64,
                    est_avg_resources: Some(i as f64),
                    est_clock_cycles: Some(40.0 + i as f64),
                    objectives: vec![-0.6, i as f64],
                    train_seconds: 0.1,
                }
            })
            .collect();
        let dir = std::env::temp_dir().join("snac_trialdb_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trials.json");
        TrialRecord::save_all(&records, &path).unwrap();
        let loaded = TrialRecord::load_all(&path, &space).unwrap();
        assert_eq!(loaded.len(), 10);
        assert_eq!(loaded[7].genome, records[7].genome);
        assert_eq!(loaded[7].est_clock_cycles, Some(47.0));
    }
}
