//! The end-to-end SNAC-Pack pipeline (the paper's §3 flow):
//!
//! 1. generate the jet dataset;
//! 2. train the rule4ml-style surrogate on HLS-simulator labels;
//! 3. train the baseline [12] with the trial protocol;
//! 4. global search twice — NAC objectives `{acc, BOPs}` and SNAC-Pack
//!    objectives `{acc, est-resources, est-cycles}`;
//! 5. §4 selection (accuracy ≥ baseline) from each front;
//! 6. local search (warm-up + IMP + QAT) on baseline and both winners;
//! 7. synthesis via the HLS simulator;
//! 8. emit Tables 2–3, Figures 1–4, and the trial databases.
//!
//! Every candidate evaluation — the baseline's trial-protocol training,
//! both global searches, and the three independent local-search + synthesis
//! stages — goes through the [`crate::eval`] subsystem, so one
//! `--workers` knob controls the pipeline's parallelism end to end.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::search_loop::{
    global_search, global_search_sharded, CheckpointConfig, DispatchBackend, GlobalSearchConfig,
    SearchOutcome, ShardedDispatch,
};
use super::trial_db::TrialRecord;
use crate::compress::{local_search, synthesis_nnz, LocalSearchResult};
use crate::config::Preset;
use crate::data::{Dataset, Split};
use crate::eval::{
    parallel_map, resolve_workers, EvalCache, EvalRequest, ParallelEvaluator, ShardDriver,
    ShardTimings, ShardTransport, StageSpec, SupernetEvaluator,
};
use crate::hls::{synthesize, FpgaDevice, HlsConfig, NetworkSpec, SynthReport};
use crate::nn::{bops, Genome, SearchSpace, SupernetInputs};
use crate::objectives::{ObjectiveContext, ObjectiveKind};
use crate::report::{render_table2, render_table3, write_figures, Table2Row, Table3Row};
use crate::runtime::Runtime;
use crate::surrogate::{train_surrogate, SurrogatePredictor};
use crate::trainer::{TrainConfig, Trainer};
use crate::util::Rng;

/// One fully-processed model (search winner or baseline).
pub struct ProcessedModel {
    /// Display name.
    pub name: String,
    /// The architecture.
    pub genome: Genome,
    /// Global-search-stage accuracy (val split).
    pub search_accuracy: f64,
    /// Surrogate estimates at the deployment point, if available.
    pub est: Option<(f64, f64)>,
    /// Post-local-search test accuracy.
    pub final_accuracy: f64,
    /// Achieved sparsity at the selected deployment point.
    pub sparsity: f64,
    /// Synthesis-simulator report.
    pub synth: SynthReport,
}

/// Everything the pipeline produced.
pub struct PipelineSummary {
    /// Baseline, NAC winner, SNAC winner (in that order).
    pub models: Vec<ProcessedModel>,
    /// NAC trial database.
    pub nac_records: Vec<TrialRecord>,
    /// SNAC trial database.
    pub snac_records: Vec<TrialRecord>,
    /// Rendered Table 2.
    pub table2: String,
    /// Rendered Table 3.
    pub table3: String,
    /// Wall-clock stage timings `(stage, seconds)`.
    pub timings: Vec<(String, f64)>,
}

fn timed<T>(
    timings: &mut Vec<(String, f64)>,
    stage: &str,
    f: impl FnOnce() -> Result<T>,
) -> Result<T> {
    let t0 = Instant::now();
    let out = f()?;
    let dt = t0.elapsed().as_secs_f64();
    eprintln!("[pipeline] {stage}: {dt:.1}s");
    timings.push((stage.to_string(), dt));
    Ok(out)
}

/// How the sharded stages dispatch their trial batches.
enum ShardBackend {
    /// Shared run directory, rename-based protocol (`--run-dir`).
    Fs(std::path::PathBuf),
    /// Driver-hosted TCP task queue (`--listen` / `--connect`).
    Tcp(Arc<dyn ShardTransport>),
}

impl ShardBackend {
    fn driver(
        &self,
        label: &str,
        stage: StageSpec,
        shards: usize,
        cache: EvalCache,
    ) -> Result<ShardDriver> {
        match self {
            ShardBackend::Fs(dir) => {
                ShardDriver::new(dir, label, stage, shards, cache, ShardTimings::default())
            }
            ShardBackend::Tcp(t) => ShardDriver::with_transport(
                Arc::clone(t),
                label,
                stage,
                shards,
                cache,
                ShardTimings::default(),
            ),
        }
    }

    fn dispatch(&self) -> DispatchBackend<'_> {
        match self {
            ShardBackend::Fs(dir) => DispatchBackend::RunDir(dir),
            ShardBackend::Tcp(t) => DispatchBackend::Transport(Arc::clone(t)),
        }
    }
}

/// Run the full pipeline. Writes reports under `out_dir` and returns the
/// in-memory summary.
pub fn run_pipeline(rt: &Runtime, preset: &Preset, out_dir: &Path) -> Result<PipelineSummary> {
    run_pipeline_with(rt, preset, out_dir, None)
}

/// [`run_pipeline`] with an explicit shard transport: when the CLI hosts
/// a TCP task server (`--listen`), the sharded stages dispatch over it
/// instead of a shared run directory. `None` keeps the run-directory
/// (or in-process) behaviour.
pub fn run_pipeline_with(
    rt: &Runtime,
    preset: &Preset,
    out_dir: &Path,
    transport: Option<Arc<dyn ShardTransport>>,
) -> Result<PipelineSummary> {
    std::fs::create_dir_all(out_dir)?;
    let mut timings = Vec::new();
    let space = SearchSpace::table1();
    let device = FpgaDevice::vu13p();
    let hls = HlsConfig::default();
    let workers = resolve_workers(preset.search.workers);
    eprintln!("[pipeline] evaluation workers: {workers}");
    // One snapshot file can back every stage: each loads its own protocol
    // scope, so the baseline and both searches share it safely.
    let cache_path = preset.cache_path.as_ref().map(std::path::PathBuf::from);
    if let Some(p) = &cache_path {
        eprintln!("[pipeline] evaluation cache: {}", p.display());
    }
    // Sharded dispatch: with `shards > 0` the baseline training and both
    // global searches hand their trial batches to `snac-pack worker`
    // processes — over the shared run directory, or over the driver's TCP
    // task server when one was passed in (one medium, three sequential
    // stages under distinct labels). Results are bit-identical to the
    // in-process path; only timings change. Local search + synthesis
    // stay in-process — they are three fixed models, not a generation.
    let shard_backend: Option<ShardBackend> = if preset.search.shards > 0 {
        let backend = match transport {
            Some(t) => ShardBackend::Tcp(t),
            None => {
                let dir = preset.run_dir.as_ref().context(
                    "sharded dispatch (shards > 0) needs a run directory — pass --run-dir \
                     (the CLI defaults it to <out>/shard-run)",
                )?;
                ShardBackend::Fs(std::path::PathBuf::from(dir))
            }
        };
        let medium = match &backend {
            ShardBackend::Fs(dir) => dir.display().to_string(),
            ShardBackend::Tcp(t) => t.describe(),
        };
        eprintln!(
            "[pipeline] sharded dispatch: {} shards/generation over {medium}",
            preset.search.shards
        );
        Some(backend)
    } else {
        None
    };
    let ds = timed(&mut timings, "dataset", || {
        Ok(Dataset::generate(
            preset.data.n_train,
            preset.data.n_val,
            preset.data.n_test,
            preset.data.seed,
        ))
    })?;
    let trainer = Trainer::new(rt, &ds);

    // ---- surrogate ----
    let (sur_params, sur_mse) = timed(&mut timings, "surrogate-train", || {
        train_surrogate(rt, &space, &preset.surrogate, &hls, &device)
    })?;
    eprintln!("[pipeline] surrogate final MSE (compressed space): {sur_mse:.5}");
    let surrogate = SurrogatePredictor::new(rt, sur_params);

    // ---- baseline (trial protocol, via the shared evaluation pool) ----
    let baseline_genome = space.baseline();
    let baseline_acc = timed(&mut timings, "baseline-train", || {
        let objectives = ObjectiveKind::nac_set();
        // The baseline trains with its own RNG stream (derived from the
        // master seed), so it caches under its own seed-pinned scope; a
        // re-run with the same --cache-path and configuration skips this
        // training entirely, while a different seed retrains.
        let scope = format!(
            "baseline|epochs={}|seed={}|train={}x{}",
            preset.search.epochs,
            preset.seed,
            ds.len(Split::Train),
            ds.len(Split::Val)
        );
        let request = EvalRequest {
            trial_id: 0,
            genome: baseline_genome.clone(),
            rng: Rng::new(preset.seed ^ 0xba5e_11),
        };
        let cache = EvalCache::open(cache_path.as_deref(), &space, &scope);
        let trial = if let Some(backend) = &shard_backend {
            // same protocol, dispatched through the worker fleet (a
            // single-trial generation → a single shard)
            let driver = backend.driver(
                "baseline",
                StageSpec {
                    objectives,
                    epochs: preset.search.epochs,
                },
                preset.search.shards,
                cache,
            )?;
            let mut out = None;
            driver.evaluate_stream(vec![request], |t| out = Some(t))?;
            out.expect("one baseline trial")
        } else {
            let ctx = ObjectiveContext {
                space: &space,
                device: &device,
                surrogate: None,
                bits: preset.local.bits,
                sparsity: preset.local.target_sparsity,
            };
            let evaluator = SupernetEvaluator::new(
                rt,
                &ds,
                &space,
                &objectives,
                &ctx,
                TrainConfig {
                    epochs: preset.search.epochs,
                    ..Default::default()
                },
            );
            let pool = ParallelEvaluator::with_cache(evaluator, 1, cache);
            pool.evaluate_batch(vec![request])?
                .pop()
                .expect("one baseline trial")
        };
        if trial.cached {
            eprintln!("[pipeline] baseline evaluation restored from cache");
        }
        Ok(trial.evaluation.accuracy)
    })?;
    eprintln!("[pipeline] baseline val accuracy: {baseline_acc:.4}");
    // §4: "accuracy value selected to ensure it meets or exceeds the baseline"
    let threshold = baseline_acc;

    // ---- global searches ----
    let run_search = |objectives: Vec<ObjectiveKind>,
                      use_surrogate: bool,
                      timings: &mut Vec<(String, f64)>,
                      stage: &str|
     -> Result<SearchOutcome> {
        timed(timings, stage, || {
            let cfg = GlobalSearchConfig {
                objectives,
                ctx: ObjectiveContext {
                    space: &space,
                    device: &device,
                    surrogate: use_surrogate.then_some(&surrogate),
                    bits: preset.local.bits,
                    sparsity: preset.local.target_sparsity,
                },
                nsga2: preset.nsga2(),
                trials: preset.search.trials,
                epochs: preset.search.epochs,
                seed: preset.seed,
                workers,
                accuracy_threshold: threshold,
                progress: Some(Box::new({
                    let stage = stage.to_string();
                    move |i, n, r: &TrialRecord| {
                        if i % 10 == 0 || i == n {
                            eprintln!(
                                "[{stage}] trial {i}/{n}: {} acc={:.4}",
                                r.label, r.accuracy
                            );
                        }
                    }
                })),
                cache_path: cache_path.clone(),
                // one checkpoint file per stage: the two searches run in
                // sequence over distinct budgets, so a shared path would
                // let one stage's snapshot shadow the other's
                checkpoint: (preset.search.checkpoint_interval > 0).then(|| CheckpointConfig {
                    path: out_dir.join(format!("checkpoint-{stage}.json")),
                    interval: preset.search.checkpoint_interval,
                }),
            };
            match &shard_backend {
                // workers rebuild the evaluator stack (and, for SNAC, the
                // surrogate — deterministically from the same preset seed,
                // so its estimates match the driver's bit for bit)
                Some(backend) => global_search_sharded(
                    &ds,
                    &space,
                    cfg,
                    &ShardedDispatch {
                        backend: backend.dispatch(),
                        label: stage,
                        shards: preset.search.shards,
                        timings: ShardTimings::default(),
                    },
                ),
                None => global_search(rt, &ds, &space, cfg),
            }
        })
    };
    let nac = run_search(ObjectiveKind::nac_set(), false, &mut timings, "search-nac")?;
    let snac = run_search(ObjectiveKind::snac_set(), true, &mut timings, "search-snac")?;
    for (stage, outcome) in [("search-nac", &nac), ("search-snac", &snac)] {
        eprintln!(
            "[{stage}] {} trained, {} cache hits ({} restored from snapshot)",
            outcome.evaluations, outcome.cache_hits, outcome.cache_restored
        );
    }
    TrialRecord::save_all(&nac.records, &out_dir.join("trials_nac.json"))?;
    TrialRecord::save_all(&snac.records, &out_dir.join("trials_snac.json"))?;

    let pick = |outcome: &SearchOutcome| -> (Genome, f64, Option<(f64, f64)>) {
        let idx = outcome.selected.unwrap_or_else(|| {
            // nothing cleared the threshold: take the most accurate point
            outcome
                .records
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.accuracy.total_cmp(&b.1.accuracy))
                .map(|(i, _)| i)
                .unwrap()
        });
        let r = &outcome.records[idx];
        (
            r.genome.clone(),
            r.accuracy,
            r.est_avg_resources.zip(r.est_clock_cycles),
        )
    };
    let (nac_genome, nac_acc, _) = pick(&nac);
    let (snac_genome, snac_acc, snac_est) = pick(&snac);
    eprintln!(
        "[pipeline] winners: NAC {} (acc {:.4}) | SNAC {} (acc {:.4})",
        nac_genome.label(&space),
        nac_acc,
        snac_genome.label(&space),
        snac_acc
    );

    // ---- local search + synthesis for all three ----
    // The three models are independent, so they fan out through the same
    // worker pool as trial evaluation; per-entry RNGs are seeded exactly
    // as the serial flow seeded them, so results are schedule-invariant.
    let entries: [(&str, &Genome, f64, Option<(f64, f64)>, bool); 3] = [
        ("Baseline [12]", &baseline_genome, baseline_acc, None, true),
        ("Optimal NAC", &nac_genome, nac_acc, None, false),
        ("Optimal SNAC-Pack", &snac_genome, snac_acc, snac_est, false),
    ];
    let t_local = Instant::now();
    let processed = parallel_map(
        workers,
        Vec::from(entries),
        |_, (name, genome, search_acc, est, softmax_head)| -> Result<(ProcessedModel, f64)> {
            let t0 = Instant::now();
            let mut rng = Rng::new(preset.seed ^ 0x10ca1);
            let ls: LocalSearchResult =
                local_search(&trainer, genome, &space, &preset.local, &mut rng)?;
            let inputs = SupernetInputs::compile(genome, &space);
            let eval_cfg = TrainConfig {
                qat: true,
                bits: preset.local.bits,
                ..Default::default()
            };
            let (test_acc, _) =
                trainer.evaluate(&ls.model, &inputs, &ls.masks, &eval_cfg, Split::Test)?;
            let nnz = synthesis_nnz(
                &ls.model.params,
                &ls.masks,
                &inputs,
                genome,
                &space,
                preset.local.bits,
            );
            let mut spec =
                NetworkSpec::from_genome_with_nnz(genome, &space, preset.local.bits, &nnz);
            spec.softmax_head = softmax_head;
            // the legacy [12] baseline synthesis also kept BN unfused
            spec.fuse_batch_norm = !softmax_head;
            let synth = synthesize(&spec, &hls, &device);
            Ok((
                ProcessedModel {
                    name: name.to_string(),
                    genome: genome.clone(),
                    search_accuracy: search_acc,
                    est,
                    final_accuracy: test_acc,
                    sparsity: ls.history[ls.selected].sparsity,
                    synth,
                },
                t0.elapsed().as_secs_f64(),
            ))
        },
    );
    // one summable wall-clock entry for the fan-out (the stages overlap,
    // so per-model durations go to the log, not to `timings`)
    let local_secs = t_local.elapsed().as_secs_f64();
    let mut models = Vec::new();
    for result in processed {
        let (model, secs) = result?;
        eprintln!("[pipeline] local+synth {}: {secs:.1}s in-stage", model.name);
        eprintln!(
            "[pipeline] {}: test acc {:.4}, sparsity {:.2}, LUT {}",
            model.name, model.final_accuracy, model.sparsity, model.synth.lut
        );
        models.push(model);
    }
    eprintln!("[pipeline] local+synth (all models): {local_secs:.1}s");
    timings.push(("local+synth (all models)".to_string(), local_secs));

    // ---- tables ----
    let assumed_sparsity = preset.local.target_sparsity;
    let table2_rows: Vec<Table2Row> = models
        .iter()
        .map(|m| {
            // every row gets surrogate estimates "for consistency" (paper
            // reports all metrics for all models)
            let est = m.est.map(Ok).unwrap_or_else(|| -> Result<(f64, f64)> {
                let e = surrogate.predict(
                    &m.genome,
                    &space,
                    preset.local.bits,
                    assumed_sparsity,
                )?;
                Ok((e.avg_resources(&device), e.latency_cc))
            })?;
            Ok(Table2Row {
                model: m.name.clone(),
                accuracy: m.search_accuracy,
                bops: bops::genome_bops(
                    &m.genome,
                    &space,
                    preset.local.bits,
                    preset.local.bits,
                    assumed_sparsity,
                ),
                est_avg_resources: Some(est.0),
                est_clock_cycles: Some(est.1),
            })
        })
        .collect::<Result<_>>()?;
    let table2 = render_table2(&table2_rows);
    let table3_rows: Vec<Table3Row> = models
        .iter()
        .map(|m| Table3Row {
            model: m.name.clone(),
            report: m.synth.clone(),
        })
        .collect();
    let table3 = render_table3(&table3_rows, &device);
    std::fs::write(out_dir.join("table2.md"), &table2)?;
    std::fs::write(out_dir.join("table3.md"), &table3)?;

    // ---- figures ----
    let figures = write_figures(&snac.records, &nac.records, out_dir)
        .context("writing figures")?;
    std::fs::write(out_dir.join("figures.txt"), figures)?;

    Ok(PipelineSummary {
        models,
        nac_records: nac.records,
        snac_records: snac.records,
        table2,
        table3,
        timings,
    })
}
