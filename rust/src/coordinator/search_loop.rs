//! The global-search loop: NSGA-II generations over evaluated candidates.
//!
//! Candidate scoring lives in [`crate::eval`]; this module owns the
//! generational control flow — fork per-trial RNG streams in trial-id
//! order, hand whole generations to the evaluation pool, and feed the
//! objective vectors back to NSGA-II. The pool streams each finished
//! trial back in trial-id order (no chunk barriers), and the driver
//! commits the record and fires the progress sink per completion with an
//! explicit completed-trials counter. The trial database is therefore
//! identical for every worker count under a fixed seed, in everything
//! except the recorded wall-clock timings (`train_seconds` is live
//! measurement and varies run to run).
//!
//! With a [`CheckpointConfig`] the loop additionally snapshots its full
//! generational state (committed records, master RNG, breeding population,
//! NSGA-II elite pool) to disk at generation boundaries, so a killed
//! driver resumes where it left off instead of restarting from trial 0;
//! mid-generation work survives through the persistent [`EvalCache`].

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::trial_db::TrialRecord;
use crate::data::{Dataset, Split};
use crate::eval::{
    manifest_fingerprint, EvalCache, EvalPool, EvalRequest, ParallelEvaluator, ShardDriver,
    ShardTimings, ShardTransport, StageSpec, SupernetEvaluator,
};
use crate::nn::{Genome, SearchSpace};
use crate::objectives::{ObjectiveContext, ObjectiveKind};
use crate::pareto;
use crate::runtime::Runtime;
use crate::search::{EvaluatedIndividual, Nsga2, Nsga2Config};
use crate::telemetry;
use crate::trainer::TrainConfig;
use crate::util::{Json, Rng};

/// Global-search configuration.
pub struct GlobalSearchConfig<'a> {
    /// Objective set (NAC: `{acc, bops}`; SNAC: `{acc, res, cc}`).
    pub objectives: Vec<ObjectiveKind>,
    /// Objective evaluation context (device, surrogate, deployment point).
    pub ctx: ObjectiveContext<'a>,
    /// NSGA-II parameters.
    pub nsga2: Nsga2Config,
    /// Total trials (candidate evaluations).
    pub trials: usize,
    /// Training epochs per trial.
    pub epochs: usize,
    /// Master seed.
    pub seed: u64,
    /// Evaluation workers (0 = all available parallelism). Genomes,
    /// objectives, and selection are identical for every value; only the
    /// recorded wall-clock timings change.
    pub workers: usize,
    /// §4 selection: accuracy threshold for picking off the front
    /// (the paper uses 0.638 ≈ the baseline's accuracy).
    pub accuracy_threshold: f64,
    /// Progress sink (completed trials, total, record) — e.g. a log line.
    /// Fires once per trial, in trial order, as completions stream in.
    pub progress: Option<Box<dyn FnMut(usize, usize, &TrialRecord)>>,
    /// Persist the evaluation cache to this snapshot file, restoring it
    /// on start so previously evaluated genomes are never retrained.
    /// `None` keeps the cache in-memory for this run only.
    pub cache_path: Option<PathBuf>,
    /// Snapshot the generational search state so a killed driver can
    /// resume mid-run. `None` disables checkpointing.
    pub checkpoint: Option<CheckpointConfig>,
}

/// Driver checkpointing: where and how often [`global_search_with`]
/// snapshots its generational state.
///
/// A snapshot captures everything the loop needs to restart at a
/// generation boundary — committed trial records, the master RNG (whose
/// per-trial fork points derive from it), the bred-but-unevaluated
/// population, and the NSGA-II elite pool — plus a configuration
/// fingerprint so a checkpoint from a different seed or budget is
/// ignored rather than replayed. Trials evaluated *after* the snapshot
/// but *before* the kill are not lost either: they sit in the persistent
/// evaluation cache (`--cache-path`), so the resumed driver replays them
/// as cache hits and the final trial database is bit-identical to an
/// uninterrupted run (modulo live `train_seconds`).
pub struct CheckpointConfig {
    /// Snapshot file: atomically replaced (write-temp-then-rename) on
    /// every save, removed when the search completes so a later run with
    /// the same configuration starts fresh.
    pub path: PathBuf,
    /// Snapshot every `interval` generations (`0` behaves as `1`: every
    /// generation boundary).
    pub interval: usize,
}

/// The evaluator-independent slice of the search configuration, used by
/// [`global_search_with`] to drive any [`crate::eval::EvalPool`].
pub struct SearchLoopConfig {
    /// NSGA-II parameters.
    pub nsga2: Nsga2Config,
    /// Total trials (candidate evaluations).
    pub trials: usize,
    /// Master seed.
    pub seed: u64,
    /// §4 selection threshold (objective slot 0 must be negated accuracy).
    pub accuracy_threshold: f64,
    /// Progress sink (completed trials, total, record); fires per trial,
    /// in trial order, as completions stream in.
    pub progress: Option<Box<dyn FnMut(usize, usize, &TrialRecord)>>,
}

/// Global-search result.
pub struct SearchOutcome {
    /// Every evaluated trial, in evaluation order.
    pub records: Vec<TrialRecord>,
    /// Indices (into `records`) of the final Pareto front.
    pub front: Vec<usize>,
    /// Index of the §4-selected architecture, if any cleared the threshold.
    pub selected: Option<usize>,
    /// Total search wall-clock seconds.
    pub wall_seconds: f64,
    /// Trials actually trained (cache misses).
    pub evaluations: usize,
    /// Trials served from the evaluation cache (snapshot hits included).
    pub cache_hits: usize,
    /// Cache entries restored from a `--cache-path` snapshot at start.
    pub cache_restored: usize,
}

/// The persistent-cache scope for a global search. An evaluation is only
/// reusable under the same training protocol, so the scope pins
/// everything that changes what a trial returns: the objective set, the
/// per-trial epoch budget, the dataset size, and the master seed
/// (per-trial RNG streams fork from it — a different seed must retrain
/// rather than silently replay another run's scores).
fn search_scope(objectives: &[ObjectiveKind], epochs: usize, seed: u64, ds: &Dataset) -> String {
    format!(
        "search|{objectives:?}|epochs={epochs}|seed={seed}|train={}x{}",
        ds.len(Split::Train),
        ds.len(Split::Val)
    )
}

fn open_scoped_cache(cache_path: Option<&Path>, space: &SearchSpace, scope: &str) -> EvalCache {
    let cache = EvalCache::open(cache_path, space, scope);
    if let (true, Some(path)) = (cache.restored() > 0, cache.path()) {
        eprintln!(
            "[search] restored {} cached evaluations from {}",
            cache.restored(),
            path.display()
        );
    }
    cache
}

/// Everything a checkpoint restores (the loop state at one generation
/// boundary).
struct CheckpointState {
    generation: usize,
    rng: Rng,
    population: Vec<Genome>,
    parents: Vec<EvaluatedIndividual>,
    records: Vec<TrialRecord>,
}

/// Pin a checkpoint to the exact configuration that wrote it: resuming
/// under a different seed, budget, or breeding schedule would replay a
/// foreign trial stream, so such checkpoints are ignored instead.
fn checkpoint_fingerprint(cfg: &SearchLoopConfig) -> String {
    manifest_fingerprint(&format!(
        "checkpoint|seed={}|trials={}|population={}|p_mutation={}|p_crossover={}",
        cfg.seed, cfg.trials, cfg.nsga2.population, cfg.nsga2.p_mutation, cfg.nsga2.p_crossover
    ))
}

/// Atomically snapshot the loop state (write-temp-then-rename, so a kill
/// mid-save leaves the previous checkpoint intact).
fn save_checkpoint(
    path: &Path,
    fingerprint: &str,
    generation: usize,
    rng: &Rng,
    population: &[Genome],
    parents: &[EvaluatedIndividual],
    records: &[TrialRecord],
) -> Result<()> {
    let doc = Json::obj(vec![
        ("fingerprint", Json::Str(fingerprint.to_string())),
        ("generation", Json::Num(generation as f64)),
        ("rng", rng.to_json()),
        (
            "population",
            Json::Arr(population.iter().map(Genome::to_json).collect()),
        ),
        (
            "parents",
            Json::Arr(parents.iter().map(EvaluatedIndividual::to_json).collect()),
        ),
        (
            "records",
            Json::Arr(records.iter().map(TrialRecord::to_json).collect()),
        ),
    ]);
    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint directory {}", dir.display()))?;
    }
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, doc.to_string())
        .with_context(|| format!("writing checkpoint {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("publishing checkpoint {}", path.display()))?;
    Ok(())
}

/// Load a checkpoint if one exists and matches this configuration; any
/// mismatch or corruption logs a warning and starts fresh (a stale
/// checkpoint must never poison a new run).
fn load_checkpoint(path: &Path, fingerprint: &str, space: &SearchSpace) -> Option<CheckpointState> {
    let text = std::fs::read_to_string(path).ok()?;
    match parse_checkpoint(&text, fingerprint, space) {
        Ok(state) => Some(state),
        Err(err) => {
            eprintln!(
                "[search] ignoring checkpoint {} ({err:#}) — starting fresh",
                path.display()
            );
            None
        }
    }
}

fn parse_checkpoint(text: &str, fingerprint: &str, space: &SearchSpace) -> Result<CheckpointState> {
    let j = Json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let found = j
        .get("fingerprint")
        .and_then(Json::as_str)
        .context("checkpoint missing fingerprint")?;
    anyhow::ensure!(
        found == fingerprint,
        "configuration fingerprint mismatch ({found} vs {fingerprint})"
    );
    let generation = j
        .get("generation")
        .and_then(Json::as_usize)
        .context("checkpoint missing generation")?;
    let rng = Rng::from_json(j.get("rng").context("checkpoint missing rng")?)?;
    let population: Vec<Genome> = j
        .get("population")
        .context("checkpoint missing population")?
        .items()
        .iter()
        .map(Genome::from_json)
        .collect::<Result<_>>()?;
    let parents: Vec<EvaluatedIndividual> = j
        .get("parents")
        .context("checkpoint missing parents")?
        .items()
        .iter()
        .map(EvaluatedIndividual::from_json)
        .collect::<Result<_>>()?;
    for g in population.iter().chain(parents.iter().map(|e| &e.genome)) {
        anyhow::ensure!(space.contains(g), "checkpoint genome outside search space");
    }
    let records: Vec<TrialRecord> = j
        .get("records")
        .context("checkpoint missing records")?
        .items()
        .iter()
        .map(|r| TrialRecord::from_json(r, space))
        .collect::<Result<_>>()?;
    Ok(CheckpointState {
        generation,
        rng,
        population,
        parents,
        records,
    })
}

/// Run the paper's global search stage: train-and-score evaluation over
/// the supernet runtime, parallelised and memoised per
/// [`crate::eval::ParallelEvaluator`].
pub fn global_search(
    rt: &Runtime,
    ds: &Dataset,
    space: &SearchSpace,
    cfg: GlobalSearchConfig<'_>,
) -> Result<SearchOutcome> {
    let GlobalSearchConfig {
        objectives,
        ctx,
        nsga2,
        trials,
        epochs,
        seed,
        workers,
        accuracy_threshold,
        progress,
        cache_path,
        checkpoint,
    } = cfg;
    // objective slot 0 is always (negated) accuracy by construction
    debug_assert_eq!(objectives[0], ObjectiveKind::Accuracy);
    let train = TrainConfig {
        epochs,
        ..Default::default()
    };
    let scope = search_scope(&objectives, epochs, seed, ds);
    let cache = open_scoped_cache(cache_path.as_deref(), space, &scope);
    let evaluator = SupernetEvaluator::new(rt, ds, space, &objectives, &ctx, train);
    let pool = ParallelEvaluator::with_cache(evaluator, workers, cache);
    global_search_with(
        &pool,
        space,
        SearchLoopConfig {
            nsga2,
            trials,
            seed,
            accuracy_threshold,
            progress,
            checkpoint,
        },
    )
}

/// Where a sharded search dispatches its generations.
pub struct ShardedDispatch<'a> {
    /// The medium shard tasks travel over.
    pub backend: DispatchBackend<'a>,
    /// File-name namespace for this search's shards (the pipeline runs
    /// several sharded stages over one backend, in sequence).
    pub label: &'a str,
    /// Shards per generation.
    pub shards: usize,
    /// Lease/poll/stall knobs.
    pub timings: ShardTimings,
}

/// The dispatch medium for a sharded search.
pub enum DispatchBackend<'a> {
    /// A shared run directory served by `snac-pack worker --run-dir`
    /// processes (the rename-based `FsTransport`).
    RunDir(&'a Path),
    /// An explicit [`ShardTransport`] — e.g. a driver-hosted
    /// [`crate::eval::TcpHost`] serving `snac-pack worker --connect`
    /// fleets with no shared filesystem.
    Transport(Arc<dyn ShardTransport>),
}

/// Run a global search whose trial evaluation is sharded across
/// `snac-pack worker` processes instead of in-process threads. The
/// outcome is bit-identical to [`global_search`] under the same seed and
/// budget (only wall-clock timings differ): the NSGA-II loop, RNG
/// forking, duplicate collapse, and trial-ordered emission are the exact
/// same code, only the dispatch backend changes.
///
/// `cfg.ctx` and `cfg.workers` are unused here — workers rebuild the
/// evaluation stack (runtime, dataset, surrogate) from the run manifest
/// on their side, so the driver never loads a training runtime.
pub fn global_search_sharded(
    ds: &Dataset,
    space: &SearchSpace,
    cfg: GlobalSearchConfig<'_>,
    dispatch: &ShardedDispatch<'_>,
) -> Result<SearchOutcome> {
    let GlobalSearchConfig {
        objectives,
        ctx: _,
        nsga2,
        trials,
        epochs,
        seed,
        workers: _,
        accuracy_threshold,
        progress,
        cache_path,
        checkpoint,
    } = cfg;
    debug_assert_eq!(objectives[0], ObjectiveKind::Accuracy);
    let scope = search_scope(&objectives, epochs, seed, ds);
    let cache = open_scoped_cache(cache_path.as_deref(), space, &scope);
    let stage = StageSpec { objectives, epochs };
    let driver = match &dispatch.backend {
        DispatchBackend::RunDir(run_dir) => ShardDriver::new(
            run_dir,
            dispatch.label,
            stage,
            dispatch.shards,
            cache,
            dispatch.timings.clone(),
        )?,
        DispatchBackend::Transport(transport) => ShardDriver::with_transport(
            Arc::clone(transport),
            dispatch.label,
            stage,
            dispatch.shards,
            cache,
            dispatch.timings.clone(),
        )?,
    };
    let outcome = global_search_with(
        &driver,
        space,
        SearchLoopConfig {
            nsga2,
            trials,
            seed,
            accuracy_threshold,
            progress,
            checkpoint,
        },
    )?;
    eprintln!(
        "[{}] sharded dispatch: {} shards/generation over {}, {} lease reclaims",
        dispatch.label,
        driver.shards(),
        driver.transport().describe(),
        driver.reclaims()
    );
    Ok(outcome)
}

/// Drive the NSGA-II loop over any evaluation pool — the in-process
/// [`ParallelEvaluator`] or the multi-process [`ShardDriver`]. Exposed so
/// tests and benches can exercise the search machinery with synthetic
/// evaluators (no runtime artifacts required).
pub fn global_search_with<P: EvalPool>(
    pool: &P,
    space: &SearchSpace,
    mut cfg: SearchLoopConfig,
) -> Result<SearchOutcome> {
    let start = Instant::now();
    let mut rng = Rng::new(cfg.seed);
    let mut engine = Nsga2::new(space.clone(), cfg.nsga2.clone());
    let mut records: Vec<TrialRecord> = Vec::with_capacity(cfg.trials);
    let mut population = engine.initial_population(&mut rng);
    let mut generation = 0usize;
    // Explicit completed-trials counter for the progress sink: emission is
    // in trial order, so this always equals `record.id + 1` — but the
    // count is now truthful by construction instead of an artifact of
    // commit ordering.
    let mut completed = 0usize;

    let fingerprint = cfg.checkpoint.as_ref().map(|_| checkpoint_fingerprint(&cfg));
    if let (Some(cp), Some(fp)) = (cfg.checkpoint.as_ref(), fingerprint.as_deref()) {
        if let Some(state) = load_checkpoint(&cp.path, fp, space) {
            eprintln!(
                "[search] resuming from checkpoint {} (generation {}, {} trials committed)",
                cp.path.display(),
                state.generation,
                state.records.len()
            );
            records = state.records;
            rng = state.rng;
            population = state.population;
            engine.restore(state.parents);
            generation = state.generation;
            completed = records.len();
        }
    }

    while records.len() < cfg.trials {
        // Snapshot at generation boundaries: records are committed, the
        // next generation is bred but unevaluated, and the master RNG has
        // not yet forked this generation's trial streams — exactly the
        // state a resumed driver replays. A failed save is a warning, not
        // a run-killer: the search itself needs no checkpoint to finish.
        if let (Some(cp), Some(fp)) = (cfg.checkpoint.as_ref(), fingerprint.as_deref()) {
            if generation % cp.interval.max(1) == 0 {
                if let Err(err) = save_checkpoint(
                    &cp.path,
                    fp,
                    generation,
                    &rng,
                    &population,
                    engine.parents(),
                    &records,
                ) {
                    eprintln!("[search] checkpoint save failed ({err:#}) — continuing without");
                }
            }
        }
        // Fork every trial's RNG serially, in trial-id order, from the
        // master stream — the exact per-trial streams the serial loop
        // produced — then let the pool schedule freely.
        let take = population.len().min(cfg.trials - records.len());
        let base_id = records.len();
        let requests: Vec<EvalRequest> = population
            .drain(..)
            .take(take)
            .enumerate()
            .map(|(k, genome)| EvalRequest {
                trial_id: base_id + k,
                rng: rng.fork((base_id + k) as u64),
                genome,
            })
            .collect();
        // The pool streams each finished trial back the moment it (and
        // every earlier trial) completes: workers never idle at a barrier,
        // and the progress sink fires per trial, live, on this thread.
        // Results are dispatch-invariant: RNG forks already happened
        // above, emission preserves trial order, and a duplicate genome
        // reuses exactly the evaluation its first occurrence produced.
        let mut evaluated = Vec::with_capacity(take);
        let mut gen_span = telemetry::span("generation", "search");
        gen_span.arg("generation", Json::Num(generation as f64));
        gen_span.arg("trials", Json::Num(take as f64));
        pool.evaluate_stream_dyn(requests, &mut |trial| {
            let record = TrialRecord {
                id: trial.trial_id,
                generation,
                label: trial.genome.label(space),
                accuracy: trial.evaluation.accuracy,
                bops: trial.evaluation.bops,
                est_avg_resources: trial.evaluation.est_avg_resources,
                est_clock_cycles: trial.evaluation.est_clock_cycles,
                objectives: trial.evaluation.objectives.clone(),
                // cache hits cost (essentially) nothing; recording zero
                // keeps the trial database worker-count-invariant in
                // everything but live timing
                train_seconds: if trial.cached {
                    0.0
                } else {
                    trial.evaluation.train_seconds
                },
                genome: trial.genome.clone(),
            };
            completed += 1;
            if let Some(progress) = cfg.progress.as_mut() {
                progress(completed, cfg.trials, &record);
            }
            records.push(record);
            evaluated.push(EvaluatedIndividual {
                genome: trial.genome,
                objectives: trial.evaluation.objectives,
            });
        })?;
        population = engine.next_generation(evaluated, &mut rng);
        generation += 1;
    }

    // The run completed: retire the checkpoint so a later run with the
    // same configuration starts fresh instead of short-circuiting here.
    if let Some(cp) = cfg.checkpoint.as_ref() {
        let _ = std::fs::remove_file(&cp.path);
    }

    let points: Vec<Vec<f64>> = records.iter().map(|r| r.objectives.clone()).collect();
    let front = pareto::pareto_front(&points);
    let selected = pareto::select_above_accuracy(&points, 0, cfg.accuracy_threshold);
    Ok(SearchOutcome {
        records,
        front,
        selected,
        wall_seconds: start.elapsed().as_secs_f64(),
        evaluations: pool.evaluations(),
        cache_hits: pool.cache_hits(),
        cache_restored: pool.cache().restored(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{TrialEvaluation, TrialEvaluator};
    use crate::hls::FpgaDevice;
    use crate::nn::Genome;
    use crate::util::Json;

    /// Synthetic evaluator with a real accuracy/size trade-off; accuracy
    /// mixes in the trial RNG so the tests pin the fork-per-trial-id
    /// discipline end to end.
    struct ToyEvaluator {
        space: SearchSpace,
    }

    impl TrialEvaluator for ToyEvaluator {
        fn evaluate(&self, genome: &Genome, rng: &mut Rng) -> Result<TrialEvaluation> {
            let weights = genome.num_weights(&self.space) as f64;
            let accuracy = (1.0 - (-weights / 4000.0).exp()) * (0.95 + 0.05 * rng.uniform());
            Ok(TrialEvaluation {
                accuracy,
                bops: weights,
                est_avg_resources: None,
                est_clock_cycles: None,
                objectives: vec![-accuracy, weights],
                train_seconds: 0.001,
            })
        }
    }

    fn toy_outcome(workers: usize, trials: usize, seed: u64) -> SearchOutcome {
        let space = SearchSpace::table1();
        let pool = ParallelEvaluator::new(
            ToyEvaluator {
                space: space.clone(),
            },
            workers,
        );
        global_search_with(
            &pool,
            &space,
            SearchLoopConfig {
                nsga2: Nsga2Config {
                    population: 6,
                    ..Default::default()
                },
                trials,
                seed,
                accuracy_threshold: 0.0,
                progress: None,
                checkpoint: None,
            },
        )
        .unwrap()
    }

    /// Acceptance criterion: `workers=1` and `workers=N` produce
    /// byte-identical trial databases under a fixed seed (modulo live
    /// wall-clock timing, which we zero before serialising).
    #[test]
    fn parallel_and_serial_searches_are_byte_identical() {
        let serial = toy_outcome(1, 30, 42);
        let parallel = toy_outcome(4, 30, 42);
        assert_eq!(serial.records.len(), 30);
        let db = |outcome: &SearchOutcome| -> String {
            let rows: Vec<Json> = outcome
                .records
                .iter()
                .map(|r| {
                    let mut r = r.clone();
                    r.train_seconds = 0.0;
                    r.to_json()
                })
                .collect();
            Json::Arr(rows).to_string()
        };
        assert_eq!(db(&serial), db(&parallel), "trial databases must match");
        assert_eq!(serial.front, parallel.front);
        assert_eq!(serial.selected, parallel.selected);
    }

    /// Attaching a progress sink must not change the trial stream (the
    /// pool streams completions either way), and every trial must be
    /// reported exactly once, in order, with a truthful completed count.
    /// (This is the old `progress_chunking_does_not_change_results`
    /// equivalence test, pointed at the streaming dispatch path.)
    #[test]
    fn streaming_progress_does_not_change_results() {
        use std::cell::RefCell;
        use std::rc::Rc;
        let space = SearchSpace::table1();
        let pool = ParallelEvaluator::new(
            ToyEvaluator {
                space: space.clone(),
            },
            4,
        );
        // Rc sink: progress closures run on the driver thread and need
        // not be Send — the streaming rework must preserve that.
        let reported = Rc::new(RefCell::new(Vec::new()));
        let sink = Rc::clone(&reported);
        let streamed = global_search_with(
            &pool,
            &space,
            SearchLoopConfig {
                nsga2: Nsga2Config {
                    population: 6,
                    ..Default::default()
                },
                trials: 30,
                seed: 42,
                accuracy_threshold: 0.0,
                progress: Some(Box::new(move |i, n, r| {
                    assert_eq!(n, 30);
                    assert_eq!(i, r.id + 1, "completed count stays truthful");
                    sink.borrow_mut().push(i);
                })),
                checkpoint: None,
            },
        )
        .unwrap();
        let plain = toy_outcome(4, 30, 42);
        let g1: Vec<_> = streamed.records.iter().map(|r| r.genome.clone()).collect();
        let g2: Vec<_> = plain.records.iter().map(|r| r.genome.clone()).collect();
        assert_eq!(g1, g2, "a progress sink must not change the trial stream");
        assert_eq!(*reported.borrow(), (1..=30).collect::<Vec<usize>>());
    }

    /// A second search over the same `--cache-path` snapshot retrains
    /// nothing and reproduces the identical trial database.
    #[test]
    fn persisted_cache_is_shared_across_runs() {
        let space = SearchSpace::table1();
        let dir = std::env::temp_dir().join("snac_search_cache_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("eval_cache.json");
        let _ = std::fs::remove_file(&path);

        let run = |workers: usize| {
            let pool = ParallelEvaluator::with_cache(
                ToyEvaluator {
                    space: space.clone(),
                },
                workers,
                crate::eval::EvalCache::load(&path, &space, "toy"),
            );
            global_search_with(
                &pool,
                &space,
                SearchLoopConfig {
                    nsga2: Nsga2Config {
                        population: 6,
                        ..Default::default()
                    },
                    trials: 25,
                    seed: 13,
                    accuracy_threshold: 0.0,
                    progress: None,
                    checkpoint: None,
                },
            )
            .unwrap()
        };

        let cold = run(4);
        assert!(cold.evaluations > 0);
        assert_eq!(cold.cache_restored, 0);

        // second run (even at a different worker count): zero retraining,
        // every trial a cache hit, identical records
        let warm = run(1);
        assert_eq!(warm.evaluations, 0, "no retraining on the second run");
        assert_eq!(warm.cache_restored, cold.evaluations);
        assert_eq!(warm.cache_hits, 25);
        assert_eq!(warm.records.len(), cold.records.len());
        for (a, b) in cold.records.iter().zip(&warm.records) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.generation, b.generation);
            assert_eq!(a.genome, b.genome);
            assert_eq!(a.accuracy, b.accuracy);
            assert_eq!(a.objectives, b.objectives);
        }
        assert_eq!(cold.front, warm.front);
        assert_eq!(cold.selected, warm.selected);
    }

    /// Acceptance criterion: a driver killed mid-generation resumes from
    /// its checkpoint (plus the persistent evaluation cache) and finishes
    /// with a trial database bit-identical to an uninterrupted run.
    #[test]
    fn killed_search_resumes_from_checkpoint_to_an_identical_db() {
        use std::sync::atomic::{AtomicUsize, Ordering};

        /// Simulates the driver dying mid-run: every evaluation after the
        /// budget fails, so `global_search_with` errors out partway
        /// through a generation with some of its trials already committed
        /// to the write-through cache — exactly what a kill leaves behind.
        struct DyingEvaluator {
            inner: ToyEvaluator,
            budget: AtomicUsize,
        }
        impl TrialEvaluator for DyingEvaluator {
            fn evaluate(&self, genome: &Genome, rng: &mut Rng) -> Result<TrialEvaluation> {
                anyhow::ensure!(
                    self.budget
                        .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| n.checked_sub(1))
                        .is_ok(),
                    "evaluation budget exhausted (simulated driver kill)"
                );
                self.inner.evaluate(genome, rng)
            }
        }

        let space = SearchSpace::table1();
        let dir = std::env::temp_dir().join("snac_checkpoint_resume_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let cache_path = dir.join("eval_cache.json");
        let cp_path = dir.join("checkpoint.json");
        let cfg = || SearchLoopConfig {
            nsga2: Nsga2Config {
                population: 6,
                ..Default::default()
            },
            trials: 30,
            seed: 42,
            accuracy_threshold: 0.0,
            progress: None,
            checkpoint: Some(CheckpointConfig {
                path: cp_path.clone(),
                interval: 1,
            }),
        };

        // reference: one uninterrupted run (in-memory cache, no checkpoint)
        let reference = toy_outcome(1, 30, 42);

        // run 1 dies after 13 evaluations, mid-generation
        let dying = ParallelEvaluator::with_cache(
            DyingEvaluator {
                inner: ToyEvaluator {
                    space: space.clone(),
                },
                budget: AtomicUsize::new(13),
            },
            1,
            crate::eval::EvalCache::load(&cache_path, &space, "toy"),
        );
        let err = global_search_with(&dying, &space, cfg()).unwrap_err();
        assert!(format!("{err:#}").contains("budget exhausted"), "{err:#}");
        assert!(cp_path.exists(), "the killed run left a checkpoint behind");

        // run 2: same checkpoint + cache, healthy evaluator
        let healthy = ParallelEvaluator::with_cache(
            ToyEvaluator {
                space: space.clone(),
            },
            1,
            crate::eval::EvalCache::load(&cache_path, &space, "toy"),
        );
        let resumed = global_search_with(&healthy, &space, cfg()).unwrap();

        let db = |outcome: &SearchOutcome| -> String {
            let rows: Vec<Json> = outcome
                .records
                .iter()
                .map(|r| {
                    let mut r = r.clone();
                    r.train_seconds = 0.0;
                    r.to_json()
                })
                .collect();
            Json::Arr(rows).to_string()
        };
        assert_eq!(
            db(&resumed),
            db(&reference),
            "a resumed search must reproduce the uninterrupted trial database"
        );
        assert_eq!(resumed.front, reference.front);
        assert_eq!(resumed.selected, reference.selected);
        assert!(
            resumed.evaluations < reference.evaluations,
            "resume must reuse the killed run's work ({} vs {} trained)",
            resumed.evaluations,
            reference.evaluations
        );
        assert!(
            !cp_path.exists(),
            "a completed run retires its checkpoint"
        );

        // a checkpoint from a different configuration is ignored, not
        // replayed: rerunning with another seed starts from trial 0
        std::fs::remove_file(&cache_path).unwrap();
        let fresh = ParallelEvaluator::with_cache(
            ToyEvaluator {
                space: space.clone(),
            },
            1,
            crate::eval::EvalCache::load(&cache_path, &space, "toy"),
        );
        let mut other = cfg();
        other.seed = 43;
        // plant the *old* run's checkpoint back to prove it gets rejected
        save_checkpoint(
            &cp_path,
            &checkpoint_fingerprint(&cfg()),
            1,
            &Rng::new(42),
            &[],
            &[],
            &[],
        )
        .unwrap();
        let outcome = global_search_with(&fresh, &space, other).unwrap();
        assert_eq!(outcome.records.len(), 30);
        assert_eq!(outcome.records[0].generation, 0, "fresh start, not a resume");
    }

    /// The driver records every trial (cache hits included) and keeps ids
    /// sequential and generations monotone.
    #[test]
    fn records_are_sequential_and_generations_monotone() {
        let outcome = toy_outcome(3, 25, 9);
        assert_eq!(outcome.records.len(), 25);
        for (i, r) in outcome.records.iter().enumerate() {
            assert_eq!(r.id, i);
        }
        for w in outcome.records.windows(2) {
            assert!(w[1].generation >= w[0].generation);
        }
        // the front is actually non-dominated
        let pts: Vec<Vec<f64>> = outcome
            .records
            .iter()
            .map(|r| r.objectives.clone())
            .collect();
        for &a in &outcome.front {
            for &b in &outcome.front {
                assert!(!crate::pareto::dominates(&pts[a], &pts[b]));
            }
        }
    }

    /// Acceptance: the generation-batched surrogate path produces a
    /// bit-identical trial database to the per-trial path, while
    /// executing ≤ ⌈generation/`SUR_BATCH`⌉ `surrogate_predict` calls
    /// per generation (the per-trial path pays one padded execution per
    /// unique genome).
    #[test]
    fn batched_surrogate_objectives_match_per_trial_path() {
        use crate::hls::HlsConfig;
        use crate::surrogate::{train_surrogate, SurrogatePredictor, SurrogateTrainConfig};

        let art = crate::runtime::artifact_dir().expect("no artifact manifest found");
        let rt = Runtime::load(&art).unwrap();
        let ds = Dataset::generate(640, 256, 256, 3);
        let space = SearchSpace::table1();
        let device = FpgaDevice::vu13p();
        let sur_cfg = SurrogateTrainConfig {
            dataset_size: 256,
            epochs: 10,
            ..Default::default()
        };
        let (params, _mse) =
            train_surrogate(&rt, &space, &sur_cfg, &HlsConfig::default(), &device).unwrap();

        /// Wrapper that suppresses `prepare` — exactly the pre-batching
        /// per-trial dispatch (every trial pads its own execution).
        struct PerTrial<'a>(SupernetEvaluator<'a>);
        impl TrialEvaluator for PerTrial<'_> {
            fn evaluate(&self, genome: &Genome, rng: &mut Rng) -> Result<TrialEvaluation> {
                self.0.evaluate(genome, rng)
            }
        }

        let run = |batched: bool| -> (SearchOutcome, usize, usize) {
            let sur = SurrogatePredictor::new(&rt, params.clone());
            let objectives = ObjectiveKind::snac_set();
            let ctx = ObjectiveContext {
                space: &space,
                device: &device,
                surrogate: Some(&sur),
                bits: 8,
                sparsity: 0.5,
            };
            let train = TrainConfig {
                epochs: 1,
                ..Default::default()
            };
            let evaluator = SupernetEvaluator::new(&rt, &ds, &space, &objectives, &ctx, train);
            let cfg = || SearchLoopConfig {
                nsga2: Nsga2Config {
                    population: 4,
                    ..Default::default()
                },
                trials: 8,
                seed: 42,
                accuracy_threshold: 0.0,
                progress: None,
                checkpoint: None,
            };
            let outcome = if batched {
                let pool = ParallelEvaluator::new(evaluator, 2);
                global_search_with(&pool, &space, cfg()).unwrap()
            } else {
                // serial, so two genomes that share a feature vector
                // (training hyperparameters are not surrogate features)
                // can never race past the memo and double-execute —
                // keeping the execution count deterministic
                let pool = ParallelEvaluator::new(PerTrial(evaluator), 1);
                global_search_with(&pool, &space, cfg()).unwrap()
            };
            (outcome, sur.executions(), sur.cache_len())
        };

        let (batched, batched_execs, batched_rows) = run(true);
        let (per_trial, per_trial_execs, per_trial_rows) = run(false);

        // bit-identical trial databases (live timings zeroed)
        let db = |outcome: &SearchOutcome| -> String {
            let rows: Vec<Json> = outcome
                .records
                .iter()
                .map(|r| {
                    let mut r = r.clone();
                    r.train_seconds = 0.0;
                    r.to_json()
                })
                .collect();
            Json::Arr(rows).to_string()
        };
        assert_eq!(
            db(&batched),
            db(&per_trial),
            "batched surrogate objectives must not change the trial database"
        );
        assert_eq!(batched.front, per_trial.front);
        assert_eq!(batched.selected, per_trial.selected);

        // the execution-count probe: the batched path coalesces each
        // generation into ⌈generation/SUR_BATCH⌉ executions; the
        // per-trial path pays one execution per unique genome
        let generations = batched.records.iter().map(|r| r.generation).max().unwrap() + 1;
        let population = 4usize;
        assert!(
            batched_execs <= generations * population.div_ceil(crate::nn::SUR_BATCH),
            "batched path ran {batched_execs} surrogate executions over \
             {generations} generations"
        );
        assert_eq!(batched_rows, per_trial_rows, "identical unique feature rows");
        assert_eq!(
            per_trial_execs, per_trial_rows,
            "per-trial path pays one padded execution per unique genome"
        );
        assert!(batched_execs <= per_trial_execs);
        // the estimates actually flowed into the objective vectors
        for r in &batched.records {
            assert!(r.est_avg_resources.is_some());
            assert_eq!(r.objectives.len(), 3);
        }
    }

    /// End-to-end NAC-objective search on a tiny budget (uses the real
    /// runtime + dataset; one test to amortise artifact compilation).
    /// Runs the first search with a worker pool and the replay serially,
    /// so the determinism assertion also pins worker-count invariance on
    /// the real train-and-score path.
    #[test]
    fn tiny_global_search_end_to_end() {
        // real AOT artifacts when built, else the checked-in HLO fixtures
        // interpreted by `rust/xla` — never skipped
        let art = crate::runtime::artifact_dir().expect("no artifact manifest found");
        let rt = Runtime::load(&art).unwrap();
        let ds = Dataset::generate(640, 256, 256, 3);
        let space = SearchSpace::table1();
        let device = FpgaDevice::vu13p();
        let cfg = GlobalSearchConfig {
            objectives: ObjectiveKind::nac_set(),
            ctx: ObjectiveContext {
                space: &space,
                device: &device,
                surrogate: None,
                bits: 8,
                sparsity: 0.5,
            },
            nsga2: Nsga2Config {
                population: 4,
                ..Default::default()
            },
            trials: 8,
            epochs: 1,
            seed: 42,
            workers: 4,
            accuracy_threshold: 0.0,
            progress: None,
            cache_path: None,
            checkpoint: None,
        };
        let outcome = global_search(&rt, &ds, &space, cfg).unwrap();
        assert_eq!(outcome.records.len(), 8);
        assert!(!outcome.front.is_empty());
        assert!(outcome.selected.is_some());
        // records carry coherent objective vectors
        for r in &outcome.records {
            assert_eq!(r.objectives.len(), 2);
            assert!((r.objectives[0] + r.accuracy).abs() < 1e-9);
            assert!(r.objectives[1] > 0.0);
            assert!(r.accuracy > 0.1, "acc {}", r.accuracy);
        }
        // the front is actually non-dominated
        let pts: Vec<Vec<f64>> = outcome.records.iter().map(|r| r.objectives.clone()).collect();
        for &a in &outcome.front {
            for &b in &outcome.front {
                assert!(!crate::pareto::dominates(&pts[a], &pts[b]));
            }
        }
        // determinism: same seed → same trial genomes, even across worker
        // counts (the replay runs serially)
        let cfg2 = GlobalSearchConfig {
            objectives: ObjectiveKind::nac_set(),
            ctx: ObjectiveContext {
                space: &space,
                device: &device,
                surrogate: None,
                bits: 8,
                sparsity: 0.5,
            },
            nsga2: Nsga2Config {
                population: 4,
                ..Default::default()
            },
            trials: 8,
            epochs: 1,
            seed: 42,
            workers: 1,
            accuracy_threshold: 0.0,
            progress: None,
            cache_path: None,
            checkpoint: None,
        };
        let outcome2 = global_search(&rt, &ds, &space, cfg2).unwrap();
        let g1: Vec<_> = outcome.records.iter().map(|r| r.genome.clone()).collect();
        let g2: Vec<_> = outcome2.records.iter().map(|r| r.genome.clone()).collect();
        assert_eq!(g1, g2, "search must be deterministic under a fixed seed");
    }
}
