//! The global-search loop: NSGA-II generations over trained candidates.

use std::time::Instant;

use anyhow::Result;

use super::trial_db::TrialRecord;
use crate::data::{Dataset, Split};
use crate::nn::{bops, PruneMasks, SearchSpace, SupernetInputs};
use crate::objectives::{ObjectiveContext, ObjectiveKind};
use crate::pareto;
use crate::runtime::Runtime;
use crate::search::{EvaluatedIndividual, Nsga2, Nsga2Config};
use crate::trainer::{TrainConfig, Trainer};
use crate::util::Rng;

/// Global-search configuration.
pub struct GlobalSearchConfig<'a> {
    /// Objective set (NAC: `{acc, bops}`; SNAC: `{acc, res, cc}`).
    pub objectives: Vec<ObjectiveKind>,
    /// Objective evaluation context (device, surrogate, deployment point).
    pub ctx: ObjectiveContext<'a>,
    /// NSGA-II parameters.
    pub nsga2: Nsga2Config,
    /// Total trials (candidate evaluations).
    pub trials: usize,
    /// Training epochs per trial.
    pub epochs: usize,
    /// Master seed.
    pub seed: u64,
    /// §4 selection: accuracy threshold for picking off the front
    /// (the paper uses 0.638 ≈ the baseline's accuracy).
    pub accuracy_threshold: f64,
    /// Progress sink (trial id, total, record) — e.g. a log line.
    pub progress: Option<Box<dyn FnMut(usize, usize, &TrialRecord)>>,
}

/// Global-search result.
pub struct SearchOutcome {
    /// Every evaluated trial, in evaluation order.
    pub records: Vec<TrialRecord>,
    /// Indices (into `records`) of the final Pareto front.
    pub front: Vec<usize>,
    /// Index of the §4-selected architecture, if any cleared the threshold.
    pub selected: Option<usize>,
    /// Total search wall-clock seconds.
    pub wall_seconds: f64,
}

/// Run the paper's global search stage.
pub fn global_search(
    rt: &Runtime,
    ds: &Dataset,
    space: &SearchSpace,
    mut cfg: GlobalSearchConfig<'_>,
) -> Result<SearchOutcome> {
    let start = Instant::now();
    let mut rng = Rng::new(cfg.seed);
    let mut engine = Nsga2::new(space.clone(), cfg.nsga2.clone());
    let trainer = Trainer::new(rt, ds);
    let prune = PruneMasks::ones(); // global search trains dense models
    let mut records: Vec<TrialRecord> = Vec::with_capacity(cfg.trials);
    let mut population = engine.initial_population(&mut rng);
    let mut generation = 0usize;

    while records.len() < cfg.trials {
        let mut evaluated = Vec::with_capacity(population.len());
        for genome in population.drain(..) {
            if records.len() >= cfg.trials {
                break;
            }
            let t0 = Instant::now();
            let inputs = SupernetInputs::compile(&genome, space);
            let train_cfg = TrainConfig {
                epochs: cfg.epochs,
                ..Default::default()
            };
            let mut trial_rng = rng.fork(records.len() as u64);
            let mut model = trainer.init_model(&mut trial_rng);
            trainer.train(&mut model, &inputs, &prune, &train_cfg, &mut trial_rng)?;
            let (accuracy, _val_loss) =
                trainer.evaluate(&model, &inputs, &prune, &train_cfg, Split::Val)?;
            let (objectives, est_pair) =
                cfg.ctx.evaluate(&cfg.objectives, &genome, accuracy)?;
            let record = TrialRecord {
                id: records.len(),
                generation,
                label: genome.label(space),
                accuracy,
                bops: bops::genome_bops(&genome, space, cfg.ctx.bits, cfg.ctx.bits, cfg.ctx.sparsity),
                est_avg_resources: est_pair.map(|p| p.0),
                est_clock_cycles: est_pair.map(|p| p.1),
                objectives: objectives.clone(),
                train_seconds: t0.elapsed().as_secs_f64(),
                genome: genome.clone(),
            };
            if let Some(progress) = cfg.progress.as_mut() {
                progress(record.id + 1, cfg.trials, &record);
            }
            records.push(record);
            evaluated.push(EvaluatedIndividual { genome, objectives });
        }
        population = engine.next_generation(evaluated, &mut rng);
        generation += 1;
    }

    let points: Vec<Vec<f64>> = records.iter().map(|r| r.objectives.clone()).collect();
    let front = pareto::pareto_front(&points);
    // objective slot 0 is always (negated) accuracy by construction
    debug_assert_eq!(cfg.objectives[0], ObjectiveKind::Accuracy);
    let selected = pareto::select_above_accuracy(&points, 0, cfg.accuracy_threshold);
    Ok(SearchOutcome {
        records,
        front,
        selected,
        wall_seconds: start.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hls::FpgaDevice;

    /// End-to-end NAC-objective search on a tiny budget (uses the real
    /// runtime + dataset; one test to amortise artifact compilation).
    #[test]
    fn tiny_global_search_end_to_end() {
        let art = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !art.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::load(&art).unwrap();
        let ds = Dataset::generate(640, 256, 256, 3);
        let space = SearchSpace::table1();
        let device = FpgaDevice::vu13p();
        let cfg = GlobalSearchConfig {
            objectives: ObjectiveKind::nac_set(),
            ctx: ObjectiveContext {
                space: &space,
                device: &device,
                surrogate: None,
                bits: 8,
                sparsity: 0.5,
            },
            nsga2: Nsga2Config {
                population: 4,
                ..Default::default()
            },
            trials: 8,
            epochs: 1,
            seed: 42,
            accuracy_threshold: 0.0,
            progress: None,
        };
        let outcome = global_search(&rt, &ds, &space, cfg).unwrap();
        assert_eq!(outcome.records.len(), 8);
        assert!(!outcome.front.is_empty());
        assert!(outcome.selected.is_some());
        // records carry coherent objective vectors
        for r in &outcome.records {
            assert_eq!(r.objectives.len(), 2);
            assert!((r.objectives[0] + r.accuracy).abs() < 1e-9);
            assert!(r.objectives[1] > 0.0);
            assert!(r.accuracy > 0.1, "acc {}", r.accuracy);
        }
        // the front is actually non-dominated
        let pts: Vec<Vec<f64>> = outcome.records.iter().map(|r| r.objectives.clone()).collect();
        for &a in &outcome.front {
            for &b in &outcome.front {
                assert!(!crate::pareto::dominates(&pts[a], &pts[b]));
            }
        }
        // determinism: same seed → same trial genomes
        let cfg2 = GlobalSearchConfig {
            objectives: ObjectiveKind::nac_set(),
            ctx: ObjectiveContext {
                space: &space,
                device: &device,
                surrogate: None,
                bits: 8,
                sparsity: 0.5,
            },
            nsga2: Nsga2Config {
                population: 4,
                ..Default::default()
            },
            trials: 8,
            epochs: 1,
            seed: 42,
            accuracy_threshold: 0.0,
            progress: None,
        };
        let outcome2 = global_search(&rt, &ds, &space, cfg2).unwrap();
        let g1: Vec<_> = outcome.records.iter().map(|r| r.genome.clone()).collect();
        let g2: Vec<_> = outcome2.records.iter().map(|r| r.genome.clone()).collect();
        assert_eq!(g1, g2, "search must be deterministic under a fixed seed");
    }
}
