//! The global-search loop: NSGA-II generations over evaluated candidates.
//!
//! Candidate scoring lives in [`crate::eval`]; this module owns the
//! generational control flow — fork per-trial RNG streams in trial-id
//! order, hand whole generations to the evaluation pool, commit results in
//! trial-id order, and feed the objective vectors back to NSGA-II. The
//! trial database is therefore identical for every worker count under a
//! fixed seed, in everything except the recorded wall-clock timings
//! (`train_seconds` is live measurement and varies run to run).

use std::time::Instant;

use anyhow::Result;

use super::trial_db::TrialRecord;
use crate::data::Dataset;
use crate::eval::{EvalRequest, ParallelEvaluator, SupernetEvaluator, TrialEvaluator};
use crate::nn::SearchSpace;
use crate::objectives::{ObjectiveContext, ObjectiveKind};
use crate::pareto;
use crate::runtime::Runtime;
use crate::search::{EvaluatedIndividual, Nsga2, Nsga2Config};
use crate::trainer::TrainConfig;
use crate::util::Rng;

/// Global-search configuration.
pub struct GlobalSearchConfig<'a> {
    /// Objective set (NAC: `{acc, bops}`; SNAC: `{acc, res, cc}`).
    pub objectives: Vec<ObjectiveKind>,
    /// Objective evaluation context (device, surrogate, deployment point).
    pub ctx: ObjectiveContext<'a>,
    /// NSGA-II parameters.
    pub nsga2: Nsga2Config,
    /// Total trials (candidate evaluations).
    pub trials: usize,
    /// Training epochs per trial.
    pub epochs: usize,
    /// Master seed.
    pub seed: u64,
    /// Evaluation workers (0 = all available parallelism). Genomes,
    /// objectives, and selection are identical for every value; only the
    /// recorded wall-clock timings change.
    pub workers: usize,
    /// §4 selection: accuracy threshold for picking off the front
    /// (the paper uses 0.638 ≈ the baseline's accuracy).
    pub accuracy_threshold: f64,
    /// Progress sink (trial id, total, record) — e.g. a log line.
    pub progress: Option<Box<dyn FnMut(usize, usize, &TrialRecord)>>,
}

/// The evaluator-independent slice of the search configuration, used by
/// [`global_search_with`] to drive any [`TrialEvaluator`].
pub struct SearchLoopConfig {
    /// NSGA-II parameters.
    pub nsga2: Nsga2Config,
    /// Total trials (candidate evaluations).
    pub trials: usize,
    /// Master seed.
    pub seed: u64,
    /// §4 selection threshold (objective slot 0 must be negated accuracy).
    pub accuracy_threshold: f64,
    /// Progress sink (trial id, total, record).
    pub progress: Option<Box<dyn FnMut(usize, usize, &TrialRecord)>>,
}

/// Global-search result.
pub struct SearchOutcome {
    /// Every evaluated trial, in evaluation order.
    pub records: Vec<TrialRecord>,
    /// Indices (into `records`) of the final Pareto front.
    pub front: Vec<usize>,
    /// Index of the §4-selected architecture, if any cleared the threshold.
    pub selected: Option<usize>,
    /// Total search wall-clock seconds.
    pub wall_seconds: f64,
}

/// Run the paper's global search stage: train-and-score evaluation over
/// the supernet runtime, parallelised and memoised per
/// [`crate::eval::ParallelEvaluator`].
pub fn global_search(
    rt: &Runtime,
    ds: &Dataset,
    space: &SearchSpace,
    cfg: GlobalSearchConfig<'_>,
) -> Result<SearchOutcome> {
    let GlobalSearchConfig {
        objectives,
        ctx,
        nsga2,
        trials,
        epochs,
        seed,
        workers,
        accuracy_threshold,
        progress,
    } = cfg;
    // objective slot 0 is always (negated) accuracy by construction
    debug_assert_eq!(objectives[0], ObjectiveKind::Accuracy);
    let train = TrainConfig {
        epochs,
        ..Default::default()
    };
    let evaluator = SupernetEvaluator::new(rt, ds, space, &objectives, &ctx, train);
    let pool = ParallelEvaluator::new(evaluator, workers);
    global_search_with(
        &pool,
        space,
        SearchLoopConfig {
            nsga2,
            trials,
            seed,
            accuracy_threshold,
            progress,
        },
    )
}

/// Drive the NSGA-II loop over any evaluation pool. Exposed so tests and
/// benches can exercise the search machinery with synthetic evaluators
/// (no runtime artifacts required).
pub fn global_search_with<E: TrialEvaluator>(
    pool: &ParallelEvaluator<E>,
    space: &SearchSpace,
    mut cfg: SearchLoopConfig,
) -> Result<SearchOutcome> {
    let start = Instant::now();
    let mut rng = Rng::new(cfg.seed);
    let mut engine = Nsga2::new(space.clone(), cfg.nsga2.clone());
    let mut records: Vec<TrialRecord> = Vec::with_capacity(cfg.trials);
    let mut population = engine.initial_population(&mut rng);
    let mut generation = 0usize;

    while records.len() < cfg.trials {
        // Fork every trial's RNG serially, in trial-id order, from the
        // master stream — the exact per-trial streams the serial loop
        // produced — then let the pool schedule freely.
        let take = population.len().min(cfg.trials - records.len());
        let base_id = records.len();
        let requests: Vec<EvalRequest> = population
            .drain(..)
            .take(take)
            .enumerate()
            .map(|(k, genome)| EvalRequest {
                trial_id: base_id + k,
                rng: rng.fork((base_id + k) as u64),
                genome,
            })
            .collect();
        // With a progress sink attached, feed the pool ~one worker-load at
        // a time so progress streams during the generation instead of
        // flushing at its end. The chunk boundary is a barrier, so heavy
        // per-trial cost skew idles workers there — liveness is bought
        // with a little utilisation (streaming commits would need a Send
        // progress sink; see ROADMAP). Results are chunking-invariant:
        // RNG forks already happened above, chunks preserve trial order,
        // and a duplicate genome in a later chunk hits the cache with
        // exactly the evaluation its first occurrence produced.
        let chunk_size = if cfg.progress.is_some() {
            pool.workers().max(1)
        } else {
            take.max(1)
        };
        let mut evaluated = Vec::with_capacity(take);
        let mut queued = requests.into_iter();
        loop {
            let chunk: Vec<EvalRequest> = queued.by_ref().take(chunk_size).collect();
            if chunk.is_empty() {
                break;
            }
            for trial in pool.evaluate_batch(chunk)? {
                let record = TrialRecord {
                    id: trial.trial_id,
                    generation,
                    label: trial.genome.label(space),
                    accuracy: trial.evaluation.accuracy,
                    bops: trial.evaluation.bops,
                    est_avg_resources: trial.evaluation.est_avg_resources,
                    est_clock_cycles: trial.evaluation.est_clock_cycles,
                    objectives: trial.evaluation.objectives.clone(),
                    // cache hits cost (essentially) nothing; recording zero
                    // keeps the trial database worker-count-invariant in
                    // everything but live timing
                    train_seconds: if trial.cached {
                        0.0
                    } else {
                        trial.evaluation.train_seconds
                    },
                    genome: trial.genome.clone(),
                };
                if let Some(progress) = cfg.progress.as_mut() {
                    progress(record.id + 1, cfg.trials, &record);
                }
                records.push(record);
                evaluated.push(EvaluatedIndividual {
                    genome: trial.genome,
                    objectives: trial.evaluation.objectives,
                });
            }
        }
        population = engine.next_generation(evaluated, &mut rng);
        generation += 1;
    }

    let points: Vec<Vec<f64>> = records.iter().map(|r| r.objectives.clone()).collect();
    let front = pareto::pareto_front(&points);
    let selected = pareto::select_above_accuracy(&points, 0, cfg.accuracy_threshold);
    Ok(SearchOutcome {
        records,
        front,
        selected,
        wall_seconds: start.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::TrialEvaluation;
    use crate::hls::FpgaDevice;
    use crate::nn::Genome;
    use crate::util::Json;

    /// Synthetic evaluator with a real accuracy/size trade-off; accuracy
    /// mixes in the trial RNG so the tests pin the fork-per-trial-id
    /// discipline end to end.
    struct ToyEvaluator {
        space: SearchSpace,
    }

    impl TrialEvaluator for ToyEvaluator {
        fn evaluate(&self, genome: &Genome, rng: &mut Rng) -> Result<TrialEvaluation> {
            let weights = genome.num_weights(&self.space) as f64;
            let accuracy = (1.0 - (-weights / 4000.0).exp()) * (0.95 + 0.05 * rng.uniform());
            Ok(TrialEvaluation {
                accuracy,
                bops: weights,
                est_avg_resources: None,
                est_clock_cycles: None,
                objectives: vec![-accuracy, weights],
                train_seconds: 0.001,
            })
        }
    }

    fn toy_outcome(workers: usize, trials: usize, seed: u64) -> SearchOutcome {
        let space = SearchSpace::table1();
        let pool = ParallelEvaluator::new(
            ToyEvaluator {
                space: space.clone(),
            },
            workers,
        );
        global_search_with(
            &pool,
            &space,
            SearchLoopConfig {
                nsga2: Nsga2Config {
                    population: 6,
                    ..Default::default()
                },
                trials,
                seed,
                accuracy_threshold: 0.0,
                progress: None,
            },
        )
        .unwrap()
    }

    /// Acceptance criterion: `workers=1` and `workers=N` produce
    /// byte-identical trial databases under a fixed seed (modulo live
    /// wall-clock timing, which we zero before serialising).
    #[test]
    fn parallel_and_serial_searches_are_byte_identical() {
        let serial = toy_outcome(1, 30, 42);
        let parallel = toy_outcome(4, 30, 42);
        assert_eq!(serial.records.len(), 30);
        let db = |outcome: &SearchOutcome| -> String {
            let rows: Vec<Json> = outcome
                .records
                .iter()
                .map(|r| {
                    let mut r = r.clone();
                    r.train_seconds = 0.0;
                    r.to_json()
                })
                .collect();
            Json::Arr(rows).to_string()
        };
        assert_eq!(db(&serial), db(&parallel), "trial databases must match");
        assert_eq!(serial.front, parallel.front);
        assert_eq!(serial.selected, parallel.selected);
    }

    /// Attaching a progress sink switches the driver to worker-sized
    /// chunks for liveness; the trial stream must not change, and every
    /// trial must be reported exactly once, in order.
    #[test]
    fn progress_chunking_does_not_change_results() {
        use std::cell::RefCell;
        use std::rc::Rc;
        let space = SearchSpace::table1();
        let pool = ParallelEvaluator::new(
            ToyEvaluator {
                space: space.clone(),
            },
            4,
        );
        let reported = Rc::new(RefCell::new(Vec::new()));
        let sink = Rc::clone(&reported);
        let chunked = global_search_with(
            &pool,
            &space,
            SearchLoopConfig {
                nsga2: Nsga2Config {
                    population: 6,
                    ..Default::default()
                },
                trials: 30,
                seed: 42,
                accuracy_threshold: 0.0,
                progress: Some(Box::new(move |i, _, _| sink.borrow_mut().push(i))),
            },
        )
        .unwrap();
        let plain = toy_outcome(4, 30, 42);
        let g1: Vec<_> = chunked.records.iter().map(|r| r.genome.clone()).collect();
        let g2: Vec<_> = plain.records.iter().map(|r| r.genome.clone()).collect();
        assert_eq!(g1, g2, "chunking must not change the trial stream");
        assert_eq!(*reported.borrow(), (1..=30).collect::<Vec<usize>>());
    }

    /// The driver records every trial (cache hits included) and keeps ids
    /// sequential and generations monotone.
    #[test]
    fn records_are_sequential_and_generations_monotone() {
        let outcome = toy_outcome(3, 25, 9);
        assert_eq!(outcome.records.len(), 25);
        for (i, r) in outcome.records.iter().enumerate() {
            assert_eq!(r.id, i);
        }
        for w in outcome.records.windows(2) {
            assert!(w[1].generation >= w[0].generation);
        }
        // the front is actually non-dominated
        let pts: Vec<Vec<f64>> = outcome
            .records
            .iter()
            .map(|r| r.objectives.clone())
            .collect();
        for &a in &outcome.front {
            for &b in &outcome.front {
                assert!(!crate::pareto::dominates(&pts[a], &pts[b]));
            }
        }
    }

    /// End-to-end NAC-objective search on a tiny budget (uses the real
    /// runtime + dataset; one test to amortise artifact compilation).
    /// Runs the first search with a worker pool and the replay serially,
    /// so the determinism assertion also pins worker-count invariance on
    /// the real train-and-score path.
    #[test]
    fn tiny_global_search_end_to_end() {
        let art = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !art.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::load(&art).unwrap();
        let ds = Dataset::generate(640, 256, 256, 3);
        let space = SearchSpace::table1();
        let device = FpgaDevice::vu13p();
        let cfg = GlobalSearchConfig {
            objectives: ObjectiveKind::nac_set(),
            ctx: ObjectiveContext {
                space: &space,
                device: &device,
                surrogate: None,
                bits: 8,
                sparsity: 0.5,
            },
            nsga2: Nsga2Config {
                population: 4,
                ..Default::default()
            },
            trials: 8,
            epochs: 1,
            seed: 42,
            workers: 4,
            accuracy_threshold: 0.0,
            progress: None,
        };
        let outcome = global_search(&rt, &ds, &space, cfg).unwrap();
        assert_eq!(outcome.records.len(), 8);
        assert!(!outcome.front.is_empty());
        assert!(outcome.selected.is_some());
        // records carry coherent objective vectors
        for r in &outcome.records {
            assert_eq!(r.objectives.len(), 2);
            assert!((r.objectives[0] + r.accuracy).abs() < 1e-9);
            assert!(r.objectives[1] > 0.0);
            assert!(r.accuracy > 0.1, "acc {}", r.accuracy);
        }
        // the front is actually non-dominated
        let pts: Vec<Vec<f64>> = outcome.records.iter().map(|r| r.objectives.clone()).collect();
        for &a in &outcome.front {
            for &b in &outcome.front {
                assert!(!crate::pareto::dominates(&pts[a], &pts[b]));
            }
        }
        // determinism: same seed → same trial genomes, even across worker
        // counts (the replay runs serially)
        let cfg2 = GlobalSearchConfig {
            objectives: ObjectiveKind::nac_set(),
            ctx: ObjectiveContext {
                space: &space,
                device: &device,
                surrogate: None,
                bits: 8,
                sparsity: 0.5,
            },
            nsga2: Nsga2Config {
                population: 4,
                ..Default::default()
            },
            trials: 8,
            epochs: 1,
            seed: 42,
            workers: 1,
            accuracy_threshold: 0.0,
            progress: None,
        };
        let outcome2 = global_search(&rt, &ds, &space, cfg2).unwrap();
        let g1: Vec<_> = outcome.records.iter().map(|r| r.genome.clone()).collect();
        let g2: Vec<_> = outcome2.records.iter().map(|r| r.genome.clone()).collect();
        assert_eq!(g1, g2, "search must be deterministic under a fixed seed");
    }
}
