//! The coordination layer — SNAC-Pack's system contribution.
//!
//! `global_search` drives NSGA-II generations: every candidate genome is
//! compiled to supernet inputs, trained for a few epochs against the AOT
//! `train_step` artifact, scored on the validation split, priced by the
//! configured objective set (BOPs for NAC, surrogate estimates for
//! SNAC-Pack), and fed back to the evolutionary engine. A trial database
//! records every evaluation for the report layer (Figures 1–4) and can be
//! checkpointed to JSON.
//!
//! `pipeline` (in `main.rs`) composes the full paper flow:
//! surrogate training → global search (×2 objective sets) → §4 selection →
//! local search → synthesis → Tables 2–3.

pub mod pipeline;
pub mod search_loop;
pub mod trial_db;

pub use pipeline::{run_pipeline, PipelineSummary, ProcessedModel};
pub use search_loop::{global_search, GlobalSearchConfig, SearchOutcome};
pub use trial_db::TrialRecord;
