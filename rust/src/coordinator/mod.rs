//! The coordination layer — SNAC-Pack's system contribution.
//!
//! `global_search` drives NSGA-II generations: every candidate genome is
//! handed to the [`crate::eval`] subsystem (train against the AOT
//! `train_step` artifact, score on the validation split, price with the
//! configured objective set — BOPs for NAC, surrogate estimates for
//! SNAC-Pack), concurrently across a configurable worker pool with
//! genome-keyed memoisation, and the objective vectors are fed back to
//! the evolutionary engine. A trial database records every evaluation for
//! the report layer (Figures 1–4) and can be checkpointed to JSON.
//!
//! `pipeline` (in `main.rs`) composes the full paper flow:
//! surrogate training → global search (×2 objective sets) → §4 selection →
//! local search → synthesis → Tables 2–3.

pub mod pipeline;
pub mod search_loop;
pub mod trial_db;

pub use pipeline::{run_pipeline, run_pipeline_with, PipelineSummary, ProcessedModel};
pub use search_loop::{
    global_search, global_search_sharded, global_search_with, CheckpointConfig, DispatchBackend,
    GlobalSearchConfig, SearchLoopConfig, SearchOutcome, ShardedDispatch,
};
pub use trial_db::TrialRecord;
