//! Feature and target encodings for the surrogate.
//!
//! Mirrors rule4ml's descriptor approach: fixed-size per-layer descriptors
//! (padded to `NUM_LAYERS`) plus global features. The 6 targets match
//! rule4ml's outputs: BRAM, DSP, FF, LUT, latency cycles, II — compressed
//! with `log1p` and a uniform scale so the MSE loss is well-conditioned.

use crate::hls::SynthReport;
use crate::nn::{Genome, SearchSpace, NUM_LAYERS, SUR_FEATS, SUR_OUT};

/// log1p compression scale for all six targets.
pub const TARGET_SCALE: f64 = 10.0;

/// Encode a genome (at a given deployment precision/sparsity) into the
/// `SUR_FEATS`-dim surrogate input.
pub fn genome_features(
    genome: &Genome,
    space: &SearchSpace,
    bits: u32,
    sparsity: f64,
) -> Vec<f32> {
    let dims = genome.layer_dims(space);
    let mut f = vec![0.0f32; SUR_FEATS];
    let keep = 1.0 - sparsity;
    // 8 per-layer slots × 8 features (hidden layers; the head folds into
    // the globals). Inactive layers stay all-zero — the "active" flag lets
    // the MLP tell a zero feature from a missing layer. Like rule4ml, the
    // descriptors are *engineered*: surviving-multiplier counts rather than
    // raw dims, so the network doesn't have to learn the sparsity product.
    for i in 0..NUM_LAYERS.min(dims.len().saturating_sub(1)) {
        let (n_in, n_out) = dims[i];
        let nnz = (n_in * n_out) as f64 * keep;
        let base = i * 8;
        f[base] = n_in as f32 / 128.0;
        f[base + 1] = n_out as f32 / 128.0;
        f[base + 2] = (nnz as f32).ln_1p() / 12.0;
        f[base + 3 + genome.act.index()] = 1.0; // act one-hot (3 slots)
        f[base + 6] = if genome.batch_norm { 1.0 } else { 0.0 };
        f[base + 7] = 1.0; // active flag
    }
    // globals (again engineered toward the targets: DSP-threshold flag,
    // BN channel count, table count — the mechanisms of the cost model)
    let g = NUM_LAYERS * 8;
    let total_macs: usize = dims.iter().map(|&(i, o)| i * o).sum();
    let total_nnz = total_macs as f64 * keep;
    let (head_in, head_out) = *dims.last().unwrap();
    let bn_channels: usize = if genome.batch_norm {
        genome.widths(space).iter().sum()
    } else {
        0
    };
    let n_tables = if genome.act.needs_table() {
        genome.n_layers
    } else {
        0
    };
    f[g] = genome.n_layers as f32 / 8.0;
    f[g + 1] = (total_nnz as f32).ln_1p() / 12.0;
    f[g + 2] = bits as f32 / 16.0;
    f[g + 3] = sparsity as f32;
    f[g + 4] = ((head_in * head_out) as f64 * keep) as f32 / 640.0;
    f[g + 5] = n_tables as f32 / 8.0;
    f[g + 6] = if bits > 9 { 1.0 } else { 0.0 }; // DSP-mapped multiplies
    f[g + 7] = (bn_channels as f32).ln_1p() / 8.0;
    f
}

/// Compress a synthesis report into the 6 training targets.
pub fn targets_from_report(r: &SynthReport) -> [f32; SUR_OUT] {
    [
        compress(r.bram36 as f64),
        compress(r.dsp as f64),
        compress(r.ff as f64),
        compress(r.lut as f64),
        compress(r.latency_cc as f64),
        compress(r.ii_cc as f64),
    ]
}

/// Invert [`targets_from_report`] for a prediction vector:
/// `(bram, dsp, ff, lut, latency_cc, ii_cc)` in raw units.
pub fn raw_from_targets(t: &[f32]) -> [f64; SUR_OUT] {
    let mut out = [0.0f64; SUR_OUT];
    for (o, &v) in out.iter_mut().zip(t) {
        *o = expand(v);
    }
    out
}

fn compress(v: f64) -> f32 {
    (v.ln_1p() / TARGET_SCALE) as f32
}

fn expand(v: f32) -> f64 {
    ((v as f64) * TARGET_SCALE).exp_m1().max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hls::{synthesize, FpgaDevice, HlsConfig, NetworkSpec};
    use crate::nn::Activation;
    use crate::util::Rng;

    #[test]
    fn feature_vector_has_fixed_length_and_range() {
        let space = SearchSpace::table1();
        let mut rng = Rng::new(0);
        for _ in 0..100 {
            let g = space.sample(&mut rng);
            let f = genome_features(&g, &space, 8, 0.3);
            assert_eq!(f.len(), SUR_FEATS);
            assert!(f.iter().all(|v| v.is_finite() && *v >= 0.0 && *v <= 2.5));
        }
    }

    #[test]
    fn depth_is_visible_in_features() {
        let space = SearchSpace::table1();
        let mut g = space.baseline();
        let f4 = genome_features(&g, &space, 8, 0.0);
        g.n_layers = 8;
        let f8 = genome_features(&g, &space, 8, 0.0);
        // layer-5 active flag differs
        assert_eq!(f4[4 * 8 + 7], 0.0);
        assert_eq!(f8[4 * 8 + 7], 1.0);
    }

    #[test]
    fn activation_onehot_is_exclusive() {
        let space = SearchSpace::table1();
        let mut g = space.baseline();
        for act in Activation::ALL {
            g.act = act;
            let f = genome_features(&g, &space, 8, 0.0);
            let hot: f32 = f[3..6].iter().sum();
            assert_eq!(hot, 1.0);
            assert_eq!(f[3 + act.index()], 1.0);
        }
    }

    #[test]
    fn target_roundtrip() {
        let space = SearchSpace::table1();
        let spec = NetworkSpec::from_genome(&space.baseline(), &space, 8, 0.5);
        let r = synthesize(&spec, &HlsConfig::default(), &FpgaDevice::vu13p());
        let t = targets_from_report(&r);
        let raw = raw_from_targets(&t);
        assert!((raw[1] - r.dsp as f64).abs() / (r.dsp as f64 + 1.0) < 0.01);
        assert!((raw[3] - r.lut as f64).abs() / (r.lut as f64 + 1.0) < 0.01);
        assert!((raw[4] - r.latency_cc as f64).abs() < 0.5);
    }
}
