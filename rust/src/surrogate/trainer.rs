//! Surrogate training driver: labels from the HLS simulator, SGD via the
//! AOT `surrogate_train` artifact.

use anyhow::Result;

use super::features::{genome_features, targets_from_report};
use crate::hls::{synthesize, FpgaDevice, HlsConfig, NetworkSpec};
use crate::nn::{
    SearchSpace, SHP_LEN, SUR_BATCH, SUR_FEATS, SUR_HIDDEN, SUR_OUT,
};
use crate::runtime::runtime::arg;
use crate::runtime::Runtime;
use crate::util::Rng;

/// The six weight/bias tensors of the surrogate MLP (ABI order).
#[derive(Debug, Clone)]
pub struct SurrogateParams {
    /// `(SUR_FEATS, SUR_HIDDEN)`.
    pub w1: Vec<f32>,
    /// `(SUR_HIDDEN,)`.
    pub b1: Vec<f32>,
    /// `(SUR_HIDDEN, SUR_HIDDEN)`.
    pub w2: Vec<f32>,
    /// `(SUR_HIDDEN,)`.
    pub b2: Vec<f32>,
    /// `(SUR_HIDDEN, SUR_OUT)`.
    pub w3: Vec<f32>,
    /// `(SUR_OUT,)`.
    pub b3: Vec<f32>,
}

impl SurrogateParams {
    /// He-initialised.
    pub fn init(rng: &mut Rng) -> Self {
        let mut p = SurrogateParams {
            w1: vec![0.0; SUR_FEATS * SUR_HIDDEN],
            b1: vec![0.0; SUR_HIDDEN],
            w2: vec![0.0; SUR_HIDDEN * SUR_HIDDEN],
            b2: vec![0.0; SUR_HIDDEN],
            w3: vec![0.0; SUR_HIDDEN * SUR_OUT],
            b3: vec![0.0; SUR_OUT],
        };
        rng.fill_normal(&mut p.w1, (2.0 / SUR_FEATS as f32).sqrt());
        rng.fill_normal(&mut p.w2, (2.0 / SUR_HIDDEN as f32).sqrt());
        rng.fill_normal(&mut p.w3, (2.0 / SUR_HIDDEN as f32).sqrt());
        p
    }

    fn fields(&self) -> [&[f32]; 6] {
        [&self.w1, &self.b1, &self.w2, &self.b2, &self.w3, &self.b3]
    }

    fn fields_mut(&mut self) -> [&mut Vec<f32>; 6] {
        [
            &mut self.w1,
            &mut self.b1,
            &mut self.w2,
            &mut self.b2,
            &mut self.w3,
            &mut self.b3,
        ]
    }

    /// All-zero clone (Adam state).
    pub fn zeros_like(&self) -> Self {
        let mut z = self.clone();
        for f in z.fields_mut() {
            f.fill(0.0);
        }
        z
    }
}

/// Surrogate training configuration.
#[derive(Debug, Clone)]
pub struct SurrogateTrainConfig {
    /// Number of labelled architectures to sample.
    pub dataset_size: usize,
    /// Training epochs.
    pub epochs: usize,
    /// Adam learning rate.
    pub lr: f32,
    /// Gaussian label noise (relative, in compressed space) — models the
    /// irreducible synthesis variance rule4ml also faces.
    pub label_noise: f32,
    /// RNG seed.
    pub seed: u64,
}

impl Default for SurrogateTrainConfig {
    fn default() -> Self {
        SurrogateTrainConfig {
            dataset_size: 4096,
            epochs: 150,
            lr: 1e-3,
            label_noise: 0.01,
            seed: 104,
        }
    }
}

/// Labelled surrogate dataset: (features, compressed targets).
pub fn build_dataset(
    space: &SearchSpace,
    cfg: &SurrogateTrainConfig,
    hls: &HlsConfig,
    device: &FpgaDevice,
) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(cfg.seed);
    let mut xs = Vec::with_capacity(cfg.dataset_size * SUR_FEATS);
    let mut ys = Vec::with_capacity(cfg.dataset_size * SUR_OUT);
    for _ in 0..cfg.dataset_size {
        let g = space.sample(&mut rng);
        // sample deployment points the search will actually query:
        // global search estimates at 8-bit dense; local search at 4–8 bit,
        // up to ~90 % sparse
        let bits = *rng.choose(&[4u32, 6, 8, 8, 8, 12]);
        let sparsity = rng.uniform() * 0.9;
        let spec = NetworkSpec::from_genome(&g, space, bits, sparsity);
        let report = synthesize(&spec, hls, device);
        xs.extend_from_slice(&genome_features(&g, space, bits, sparsity));
        for t in targets_from_report(&report) {
            ys.push(t + cfg.label_noise * rng.normal_f32());
        }
    }
    (xs, ys)
}

/// Train the surrogate on simulator labels. Returns the trained params and
/// the final-epoch mean MSE (compressed space).
pub fn train_surrogate(
    rt: &Runtime,
    space: &SearchSpace,
    cfg: &SurrogateTrainConfig,
    hls: &HlsConfig,
    device: &FpgaDevice,
) -> Result<(SurrogateParams, f64)> {
    let (xs, ys) = build_dataset(space, cfg, hls, device);
    let n = cfg.dataset_size;
    let mut rng = Rng::new(cfg.seed ^ 0xdead_beef);
    let mut params = SurrogateParams::init(&mut rng);
    let mut m = params.zeros_like();
    let mut v = params.zeros_like();
    let mut shp = [0.0f32; SHP_LEN];
    shp[crate::nn::SHP_BETA1] = 0.9;
    shp[crate::nn::SHP_BETA2] = 0.999;
    shp[crate::nn::SHP_EPS] = 1e-8;
    let mut t = 0i32;
    let mut last_epoch_loss = f64::NAN;
    let mut xbuf = vec![0.0f32; SUR_BATCH * SUR_FEATS];
    let mut ybuf = vec![0.0f32; SUR_BATCH * SUR_OUT];
    for epoch in 0..cfg.epochs {
        // step-decay lr schedule (lr is a runtime input of the AOT graph,
        // so the schedule lives host-side): ×0.3 at 50 % and 80 %.
        let frac = epoch as f64 / cfg.epochs.max(1) as f64;
        shp[crate::nn::SHP_LR] = cfg.lr
            * if frac < 0.5 {
                1.0
            } else if frac < 0.8 {
                0.3
            } else {
                0.09
            };
        let perm = rng.permutation(n);
        let mut loss_sum = 0.0;
        let mut batches = 0;
        for chunk in perm.chunks(SUR_BATCH) {
            // tail chunk: wrap around (training only, harmless)
            for (slot, &src) in chunk.iter().chain(perm.iter()).take(SUR_BATCH).enumerate()
            {
                xbuf[slot * SUR_FEATS..(slot + 1) * SUR_FEATS]
                    .copy_from_slice(&xs[src * SUR_FEATS..(src + 1) * SUR_FEATS]);
                ybuf[slot * SUR_OUT..(slot + 1) * SUR_OUT]
                    .copy_from_slice(&ys[src * SUR_OUT..(src + 1) * SUR_OUT]);
            }
            t += 1;
            shp[crate::nn::SHP_BETA1_POW] = 0.9f32.powi(t);
            shp[crate::nn::SHP_BETA2_POW] = 0.999f32.powi(t);
            let out = rt.run(
                "surrogate_train",
                &[
                    arg("sw1", &params.w1),
                    arg("sb1", &params.b1),
                    arg("sw2", &params.w2),
                    arg("sb2", &params.b2),
                    arg("sw3", &params.w3),
                    arg("sb3", &params.b3),
                    arg("m_sw1", &m.w1),
                    arg("m_sb1", &m.b1),
                    arg("m_sw2", &m.w2),
                    arg("m_sb2", &m.b2),
                    arg("m_sw3", &m.w3),
                    arg("m_sb3", &m.b3),
                    arg("v_sw1", &v.w1),
                    arg("v_sb1", &v.b1),
                    arg("v_sw2", &v.w2),
                    arg("v_sb2", &v.b2),
                    arg("v_sw3", &v.w3),
                    arg("v_sb3", &v.b3),
                    arg("x", &xbuf),
                    arg("y", &ybuf),
                    arg("shp", &shp),
                ],
            )?;
            let mut it = out.into_iter();
            for f in params.fields_mut() {
                *f = it.next().unwrap();
            }
            for f in m.fields_mut() {
                *f = it.next().unwrap();
            }
            for f in v.fields_mut() {
                *f = it.next().unwrap();
            }
            loss_sum += it.next().unwrap()[0] as f64;
            batches += 1;
        }
        last_epoch_loss = loss_sum / batches.max(1) as f64;
    }
    let _ = params.fields(); // keep accessor used
    Ok((params, last_epoch_loss))
}
