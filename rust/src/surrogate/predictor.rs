//! Batched, cached surrogate inference used by the search objectives.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::Result;

use super::features::{genome_features, raw_from_targets};
use super::trainer::SurrogateParams;
use crate::hls::FpgaDevice;
use crate::nn::{Genome, SearchSpace, SUR_BATCH, SUR_FEATS, SUR_OUT};
use crate::runtime::runtime::arg;
use crate::runtime::Runtime;

/// Raw (uncompressed) surrogate outputs for one architecture.
#[derive(Debug, Clone, Copy)]
pub struct ResourceEstimate {
    /// BRAM36 blocks.
    pub bram: f64,
    /// DSP slices.
    pub dsp: f64,
    /// Flip-flops.
    pub ff: f64,
    /// LUTs.
    pub lut: f64,
    /// Latency in clock cycles.
    pub latency_cc: f64,
    /// Initiation interval in clock cycles.
    pub ii_cc: f64,
}

impl ResourceEstimate {
    /// The paper's "estimated average resources": mean of the four
    /// utilisation percentages on a device.
    pub fn avg_resources(&self, device: &FpgaDevice) -> f64 {
        (self.dsp / device.dsp as f64
            + self.lut / device.lut as f64
            + self.ff / device.ff as f64
            + self.bram / device.bram36 as f64)
            * 100.0
            / 4.0
    }
}

/// Trained surrogate + prediction cache.
///
/// The predictor is shared by reference across the evaluation worker
/// threads (`eval::ParallelEvaluator`), so the memo cache is behind a
/// `Mutex` — contention is negligible next to a `surrogate_predict` call.
pub struct SurrogatePredictor<'a> {
    rt: &'a Runtime,
    params: SurrogateParams,
    /// memoised by feature-vector bits (genomes repeat across generations)
    cache: Mutex<HashMap<Vec<u32>, ResourceEstimate>>,
}

impl<'a> SurrogatePredictor<'a> {
    /// Wrap trained parameters.
    pub fn new(rt: &'a Runtime, params: SurrogateParams) -> Self {
        SurrogatePredictor {
            rt,
            params,
            cache: Mutex::new(HashMap::new()),
        }
    }

    /// Predict resources for one genome at a deployment point.
    pub fn predict(
        &self,
        genome: &Genome,
        space: &SearchSpace,
        bits: u32,
        sparsity: f64,
    ) -> Result<ResourceEstimate> {
        let feats = genome_features(genome, space, bits, sparsity);
        let key: Vec<u32> = feats.iter().map(|f| f.to_bits()).collect();
        if let Some(hit) = self.cache.lock().unwrap().get(&key) {
            return Ok(*hit);
        }
        let est = self.predict_batch(&[feats])?[0];
        self.cache.lock().unwrap().insert(key, est);
        Ok(est)
    }

    /// Predict a batch of feature vectors (padded to `SUR_BATCH` rows).
    pub fn predict_batch(&self, feats: &[Vec<f32>]) -> Result<Vec<ResourceEstimate>> {
        let mut out = Vec::with_capacity(feats.len());
        for chunk in feats.chunks(SUR_BATCH) {
            let mut xbuf = vec![0.0f32; SUR_BATCH * SUR_FEATS];
            for (i, f) in chunk.iter().enumerate() {
                xbuf[i * SUR_FEATS..(i + 1) * SUR_FEATS].copy_from_slice(f);
            }
            let p = &self.params;
            let result = self.rt.run(
                "surrogate_predict",
                &[
                    arg("sw1", &p.w1),
                    arg("sb1", &p.b1),
                    arg("sw2", &p.w2),
                    arg("sb2", &p.b2),
                    arg("sw3", &p.w3),
                    arg("sb3", &p.b3),
                    arg("x", &xbuf),
                ],
            )?;
            let pred = &result[0];
            for i in 0..chunk.len() {
                let raw = raw_from_targets(&pred[i * SUR_OUT..(i + 1) * SUR_OUT]);
                out.push(ResourceEstimate {
                    bram: raw[0],
                    dsp: raw[1],
                    ff: raw[2],
                    lut: raw[3],
                    latency_cc: raw[4],
                    ii_cc: raw[5],
                });
            }
        }
        Ok(out)
    }

    /// Number of memoised predictions (diagnostics).
    pub fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}
