//! Batched, cached surrogate inference used by the search objectives and
//! the `serve` estimation service.
//!
//! [`SurrogatePredictor::predict_batch`] is the single choke point every
//! caller funnels through: it memo-checks all rows in one cache pass,
//! collapses duplicate feature vectors to one interpreter row, packs the
//! survivors into `SUR_BATCH`-row `surrogate_predict` executions (one
//! reused padded buffer, zeroed tail), and commits the fresh rows back to
//! the memo in a second single pass. The per-genome [`predict`] path is a
//! one-row batch, and the generation-level prefetch
//! (`objectives::ObjectiveContext::prefetch`) plus the micro-batching
//! `serve::SurrogateEngine` both ride the same code — so estimates are
//! bit-identical whichever path asked for them.
//!
//! [`predict`]: SurrogatePredictor::predict

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use super::features::{genome_features, raw_from_targets};
use super::trainer::SurrogateParams;
use crate::hls::FpgaDevice;
use crate::nn::{Genome, SearchSpace, SUR_BATCH, SUR_FEATS, SUR_OUT};
use crate::runtime::runtime::arg;
use crate::runtime::Runtime;

/// Raw (uncompressed) surrogate outputs for one architecture.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResourceEstimate {
    /// BRAM36 blocks.
    pub bram: f64,
    /// DSP slices.
    pub dsp: f64,
    /// Flip-flops.
    pub ff: f64,
    /// LUTs.
    pub lut: f64,
    /// Latency in clock cycles.
    pub latency_cc: f64,
    /// Initiation interval in clock cycles.
    pub ii_cc: f64,
}

impl ResourceEstimate {
    /// The paper's "estimated average resources": mean of the four
    /// utilisation percentages on a device.
    pub fn avg_resources(&self, device: &FpgaDevice) -> f64 {
        (self.dsp / device.dsp as f64
            + self.lut / device.lut as f64
            + self.ff / device.ff as f64
            + self.bram / device.bram36 as f64)
            * 100.0
            / 4.0
    }
}

/// Memo key for one feature vector: the exact f32 bit patterns.
pub(crate) fn feature_key(feats: &[f32]) -> Vec<u32> {
    feats.iter().map(|f| f.to_bits()).collect()
}

/// Upper bound on memoised rows. A search's working set (unique genomes
/// per run) is orders of magnitude smaller, so this only matters for a
/// long-lived `snac-pack serve` process fed arbitrary feature vectors,
/// where the memo would otherwise grow without bound. Eviction is
/// deliberately coarse — a full clear when the cap would be exceeded —
/// costing only re-prediction of rows still in use; at ~400 bytes/row
/// the table stays around 100 MB.
const MEMO_CAP: usize = 256 * 1024;

/// Trained surrogate + prediction cache.
///
/// The predictor is shared by reference across the evaluation worker
/// threads (`eval::ParallelEvaluator`) and the `serve` connection
/// handlers, so the memo cache is behind a `Mutex` — contention is
/// negligible next to a `surrogate_predict` call, and `predict_batch`
/// takes the lock exactly twice per call (one memo-check pass, one
/// commit pass), never per row.
pub struct SurrogatePredictor<'a> {
    rt: &'a Runtime,
    params: SurrogateParams,
    /// memoised by feature-vector bits (genomes repeat across generations)
    cache: Mutex<HashMap<Vec<u32>, ResourceEstimate>>,
    /// `surrogate_predict` executions so far — the probe the batched
    /// objectives path is asserted against (≤ ⌈generation/`SUR_BATCH`⌉
    /// per generation).
    executions: AtomicUsize,
    /// Memo size bound ([`MEMO_CAP`]; overridable in tests).
    memo_cap: usize,
}

impl<'a> SurrogatePredictor<'a> {
    /// Wrap trained parameters.
    pub fn new(rt: &'a Runtime, params: SurrogateParams) -> Self {
        SurrogatePredictor {
            rt,
            params,
            cache: Mutex::new(HashMap::new()),
            executions: AtomicUsize::new(0),
            memo_cap: MEMO_CAP,
        }
    }

    /// Shrink the memo bound (tests exercise the eviction path without
    /// a quarter-million rows).
    #[cfg(test)]
    pub(crate) fn set_memo_cap(&mut self, cap: usize) {
        self.memo_cap = cap;
    }

    /// Predict resources for one genome at a deployment point.
    pub fn predict(
        &self,
        genome: &Genome,
        space: &SearchSpace,
        bits: u32,
        sparsity: f64,
    ) -> Result<ResourceEstimate> {
        let feats = genome_features(genome, space, bits, sparsity);
        Ok(self.predict_batch(std::slice::from_ref(&feats))?[0])
    }

    /// Predict a whole generation of genomes at one deployment point in
    /// ⌈unique/`SUR_BATCH`⌉ executions (duplicates and memoised genomes
    /// cost zero rows).
    pub fn predict_genomes(
        &self,
        genomes: &[Genome],
        space: &SearchSpace,
        bits: u32,
        sparsity: f64,
    ) -> Result<Vec<ResourceEstimate>> {
        let feats: Vec<Vec<f32>> = genomes
            .iter()
            .map(|g| genome_features(g, space, bits, sparsity))
            .collect();
        self.predict_batch(&feats)
    }

    /// The memoised estimate for a feature vector, if one exists.
    pub fn cached(&self, feats: &[f32]) -> Option<ResourceEstimate> {
        self.cached_by_key(&feature_key(feats))
    }

    /// Memo lookup by a precomputed [`feature_key`] (the serve engine
    /// polls per wake-up and avoids re-hashing the floats).
    pub(crate) fn cached_by_key(&self, key: &[u32]) -> Option<ResourceEstimate> {
        self.cache.lock().unwrap().get(key).copied()
    }

    /// Predict a batch of feature vectors (each `SUR_FEATS` long).
    ///
    /// Memoised rows are never re-executed, duplicate rows within the
    /// call collapse to one interpreter row, and the unique misses are
    /// packed into `SUR_BATCH`-row executions through one reused padded
    /// buffer. Outputs are positional: `out[i]` is the estimate for
    /// `feats[i]`, bit-identical to a single-row `predict` of the same
    /// vector.
    pub fn predict_batch(&self, feats: &[Vec<f32>]) -> Result<Vec<ResourceEstimate>> {
        let keys: Vec<Vec<u32>> = feats.iter().map(|f| feature_key(f)).collect();
        let mut out: Vec<Option<ResourceEstimate>> = vec![None; feats.len()];
        // slot in `unique` that will resolve each not-yet-memoised row
        let mut slot_of: HashMap<&[u32], usize> = HashMap::new();
        // first-occurrence indices into `feats` of the rows to execute
        let mut unique: Vec<usize> = Vec::new();
        {
            // single lock pass: memo check + intra-batch dedup together
            let cache = self.cache.lock().unwrap();
            for (i, key) in keys.iter().enumerate() {
                if let Some(hit) = cache.get(key) {
                    out[i] = Some(*hit);
                } else if !slot_of.contains_key(key.as_slice()) {
                    slot_of.insert(key.as_slice(), unique.len());
                    unique.push(i);
                }
            }
        }

        // one padded buffer reused across chunks; the tail rows of a
        // short final chunk are re-zeroed so a previous chunk's rows
        // never leak into the padding
        let mut span = crate::telemetry::span("predict_batch", "surrogate");
        span.arg("rows", crate::util::Json::Num(feats.len() as f64));
        span.arg("unique", crate::util::Json::Num(unique.len() as f64));
        let mut fresh: Vec<ResourceEstimate> = Vec::with_capacity(unique.len());
        let mut xbuf = vec![0.0f32; SUR_BATCH * SUR_FEATS];
        for chunk in unique.chunks(SUR_BATCH) {
            for (slot, &fi) in chunk.iter().enumerate() {
                xbuf[slot * SUR_FEATS..(slot + 1) * SUR_FEATS].copy_from_slice(&feats[fi]);
            }
            xbuf[chunk.len() * SUR_FEATS..].fill(0.0);
            let p = &self.params;
            let result = self.rt.run(
                "surrogate_predict",
                &[
                    arg("sw1", &p.w1),
                    arg("sb1", &p.b1),
                    arg("sw2", &p.w2),
                    arg("sb2", &p.b2),
                    arg("sw3", &p.w3),
                    arg("sb3", &p.b3),
                    arg("x", &xbuf),
                ],
            )?;
            self.executions.fetch_add(1, Ordering::Relaxed);
            let pred = &result[0];
            for i in 0..chunk.len() {
                let raw = raw_from_targets(&pred[i * SUR_OUT..(i + 1) * SUR_OUT]);
                fresh.push(ResourceEstimate {
                    bram: raw[0],
                    dsp: raw[1],
                    ff: raw[2],
                    lut: raw[3],
                    latency_cc: raw[4],
                    ii_cc: raw[5],
                });
            }
        }

        if !unique.is_empty() {
            // second (and last) lock pass: commit the fresh rows
            let mut cache = self.cache.lock().unwrap();
            if cache.len() + unique.len() > self.memo_cap {
                cache.clear();
            }
            for (slot, &fi) in unique.iter().enumerate() {
                cache.insert(keys[fi].clone(), fresh[slot]);
            }
        }
        Ok(out
            .into_iter()
            .enumerate()
            .map(|(i, hit)| hit.unwrap_or_else(|| fresh[slot_of[keys[i].as_slice()]]))
            .collect())
    }

    /// Number of memoised predictions (diagnostics).
    pub fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Total `surrogate_predict` interpreter executions so far.
    pub fn executions(&self) -> usize {
        self.executions.load(Ordering::Relaxed)
    }
}

/// Shared fixtures for the predictor/engine/serve test modules: the
/// fixture-backed runtime, an untrained (but deterministic) predictor —
/// prediction *values* are arbitrary; tests assert identity/counting
/// properties — and pairwise-distinct feature rows.
#[cfg(test)]
pub(crate) mod test_support {
    use super::{SurrogateParams, SurrogatePredictor};
    use crate::nn::SearchSpace;
    use crate::runtime::Runtime;
    use crate::surrogate::genome_features;
    use crate::util::Rng;

    pub(crate) fn runtime() -> Runtime {
        let dir = crate::runtime::artifact_dir().expect("no artifact manifest found");
        Runtime::load(&dir).expect("runtime load")
    }

    pub(crate) fn predictor(rt: &Runtime) -> SurrogatePredictor<'_> {
        let mut rng = Rng::new(42);
        SurrogatePredictor::new(rt, SurrogateParams::init(&mut rng))
    }

    pub(crate) fn feature_rows(n: usize, seed: u64) -> Vec<Vec<f32>> {
        let space = SearchSpace::table1();
        let mut rng = Rng::new(seed);
        let mut out: Vec<Vec<f32>> = Vec::new();
        while out.len() < n {
            let f = genome_features(&space.sample(&mut rng), &space, 8, 0.5);
            if !out.contains(&f) {
                out.push(f);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::{feature_rows as rows, predictor, runtime};
    use super::*;

    /// Tail padding: batch lengths 1, `SUR_BATCH`, and `SUR_BATCH + 1`
    /// all produce rows bit-identical to single-row prediction, in ⌈n/
    /// `SUR_BATCH`⌉ executions.
    #[test]
    fn predict_batch_tail_padding_matches_single_row() {
        let rt = runtime();
        // one-row reference predictions from an independent predictor
        let reference = predictor(&rt);
        let all = rows(SUR_BATCH + 1, 3);
        for n in [1usize, SUR_BATCH, SUR_BATCH + 1] {
            let sur = predictor(&rt);
            let batch = sur.predict_batch(&all[..n]).unwrap();
            assert_eq!(batch.len(), n);
            assert_eq!(sur.executions(), n.div_ceil(SUR_BATCH));
            // spot-check head, tail, and a chunk-boundary row
            for &i in &[0, n - 1, (n - 1).min(SUR_BATCH - 1)] {
                let single = reference.predict_batch(&all[i..i + 1]).unwrap()[0];
                assert_eq!(batch[i], single);
            }
        }
    }

    /// Duplicate rows within one call cost one interpreter row, not `k`.
    #[test]
    fn predict_batch_dedups_identical_rows() {
        let rt = runtime();
        let sur = predictor(&rt);
        let distinct = rows(3, 7);
        let feats = [
            distinct[0].clone(),
            distinct[1].clone(),
            distinct[0].clone(),
            distinct[2].clone(),
            distinct[0].clone(),
        ];
        let out = sur.predict_batch(&feats).unwrap();
        assert_eq!(out.len(), 5);
        assert_eq!(sur.executions(), 1);
        assert_eq!(sur.cache_len(), 3, "only unique rows are memoised");
        assert_eq!(out[0], out[2]);
        assert_eq!(out[0], out[4]);
    }

    /// Already-memoised rows are skipped inside `predict_batch`: a batch
    /// that is fully covered by the memo executes nothing, and a partial
    /// overlap executes only the misses.
    #[test]
    fn predict_batch_skips_memoised_rows() {
        let rt = runtime();
        let sur = predictor(&rt);
        let all = rows(6, 11);
        let first = sur.predict_batch(&all[..4]).unwrap();
        assert_eq!(sur.executions(), 1);

        // full overlap: zero executions, identical values
        let again = sur.predict_batch(&all[..4]).unwrap();
        assert_eq!(sur.executions(), 1, "memoised batch re-executes nothing");
        assert_eq!(first, again);

        // partial overlap: one more execution, memoised rows keep their
        // original values
        let mixed = sur.predict_batch(&all).unwrap();
        assert_eq!(sur.executions(), 2);
        assert_eq!(sur.cache_len(), 6);
        assert_eq!(first, mixed[..4]);
    }

    /// The memo stays bounded: when a commit would exceed the cap the
    /// table is cleared (coarse eviction), and evicted rows simply
    /// re-execute with identical values — a long-lived `serve` process
    /// cannot grow memory without bound.
    #[test]
    fn memo_cap_bounds_the_cache_and_evicted_rows_reexecute() {
        let rt = runtime();
        let mut sur = predictor(&rt);
        sur.set_memo_cap(4);
        let sur = sur;
        let all = rows(6, 21);
        let first = sur.predict_batch(&all[..4]).unwrap();
        assert_eq!(sur.cache_len(), 4);
        // committing two more rows would exceed the cap: coarse clear
        sur.predict_batch(&all[4..]).unwrap();
        assert_eq!(sur.cache_len(), 2);
        assert_eq!(sur.executions(), 2);
        // evicted rows re-execute and reproduce the identical estimates
        let again = sur.predict_batch(&all[..4]).unwrap();
        assert_eq!(sur.executions(), 3);
        assert_eq!(first, again);
    }

    /// `predict` is a one-row batch: it shares the memo with
    /// `predict_batch` and never re-executes a covered genome.
    #[test]
    fn predict_shares_the_batch_memo() {
        let rt = runtime();
        let sur = predictor(&rt);
        let space = SearchSpace::table1();
        let genome = space.baseline();
        let single = sur.predict(&genome, &space, 8, 0.5).unwrap();
        assert_eq!(sur.executions(), 1);
        let batched = sur.predict_genomes(&[genome.clone()], &space, 8, 0.5).unwrap()[0];
        assert_eq!(sur.executions(), 1, "memo hit — no second execution");
        assert_eq!(single, batched);
        // a different deployment point is a different feature vector
        sur.predict(&genome, &space, 4, 0.0).unwrap();
        assert_eq!(sur.executions(), 2);
    }
}
