//! Markdown renderings of the paper's Tables 2 and 3, with the paper's
//! reference values printed alongside for direct comparison.

use crate::hls::{FpgaDevice, SynthReport};

/// One row of Table 2 (global-search comparison).
#[derive(Debug, Clone)]
pub struct Table2Row {
    /// Model name (Baseline / Optimal NAC / Optimal SNAC-Pack).
    pub model: String,
    /// Test accuracy (fraction).
    pub accuracy: f64,
    /// BOPs at the assumed deployment point.
    pub bops: f64,
    /// Estimated average resources (mean utilisation %).
    pub est_avg_resources: Option<f64>,
    /// Estimated clock cycles.
    pub est_clock_cycles: Option<f64>,
}

/// Render Table 2.
pub fn render_table2(rows: &[Table2Row]) -> String {
    let mut out = String::new();
    out.push_str("# Table 2 — global-search comparison\n\n");
    out.push_str("| Model | Accuracy [%] | BOPs | Est. average resources | Est. clock cycles |\n");
    out.push_str("|---|---|---|---|---|\n");
    for r in rows {
        out.push_str(&format!(
            "| {} | {:.2} | {:.0} | {} | {} |\n",
            r.model,
            r.accuracy * 100.0,
            r.bops,
            r.est_avg_resources
                .map(|v| format!("{v:.2}"))
                .unwrap_or_else(|| "—".into()),
            r.est_clock_cycles
                .map(|v| format!("{v:.2}"))
                .unwrap_or_else(|| "—".into()),
        ));
    }
    out.push_str(
        "\nPaper (Table 2): Baseline 63.77 % / 25,916 BOPs / 7.10 / 183.74; \
         Optimal NAC 63.81 % / 7,904 / 3.60 / 62.69; \
         Optimal SNAC-Pack 63.84 % / 8,352 / 3.12 / 72.24.\n\
         Shape targets: all accuracies within ~1 pt of each other; \
         NAC & SNAC ≪ baseline in cost; SNAC best avg-resources; NAC best BOPs/cycles.\n",
    );
    out
}

/// One row of Table 3 (post-synthesis).
#[derive(Debug, Clone)]
pub struct Table3Row {
    /// Model name.
    pub model: String,
    /// Synthesis-simulator report.
    pub report: SynthReport,
}

/// Render Table 3.
pub fn render_table3(rows: &[Table3Row], device: &FpgaDevice) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "# Table 3 — synthesis on {} ({} ns clock)\n\n",
        device.name, device.clock_ns
    ));
    out.push_str("| Model | Lat. [ns] (cc) | II [ns] (cc) | DSP | LUT | FF | BRAM |\n");
    out.push_str("|---|---|---|---|---|---|---|\n");
    for r in rows {
        let u = r.report.utilisation(device);
        out.push_str(&format!(
            "| {} | {:.0} ({}) | {:.0} ({}) | {} ({:.2}%) | {} ({:.2}%) | {} ({:.2}%) | {} ({:.2}%) |\n",
            r.model,
            r.report.latency_ns(),
            r.report.latency_cc,
            r.report.ii_ns(),
            r.report.ii_cc,
            r.report.dsp,
            u[0],
            r.report.lut,
            u[1],
            r.report.ff,
            u[2],
            r.report.bram36,
            u[3],
        ));
    }
    out.push_str(
        "\nPaper (Table 3): Baseline 105 ns (21 cc), 262 DSP (2.1 %), 155,080 LUT (9.0 %), \
         25,714 FF (0.7 %), 4 BRAM; Optimal NAC 0 DSP, 54,075 LUT (3.13 %), 12,016 FF, 8 BRAM; \
         Optimal SNAC-Pack 0 DSP, 57,728 LUT (3.34 %), 12,605 FF, 0 BRAM.\n\
         Shape targets: optimised models use 0 DSP and ~⅓ of baseline LUT/FF; \
         BRAM tracks activation choice (tables) — 0 for an all-ReLU SNAC winner.\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_renders_all_rows() {
        let rows = vec![
            Table2Row {
                model: "Baseline".into(),
                accuracy: 0.6377,
                bops: 25_916.0,
                est_avg_resources: Some(7.10),
                est_clock_cycles: Some(183.74),
            },
            Table2Row {
                model: "Optimal SNAC-Pack".into(),
                accuracy: 0.6384,
                bops: 8_352.0,
                est_avg_resources: None,
                est_clock_cycles: None,
            },
        ];
        let text = render_table2(&rows);
        assert!(text.contains("| Baseline | 63.77 | 25916 | 7.10 | 183.74 |"));
        assert!(text.contains("| Optimal SNAC-Pack | 63.84 | 8352 | — | — |"));
        assert!(text.contains("Paper (Table 2)"));
    }

    #[test]
    fn table3_renders_utilisation() {
        let device = FpgaDevice::vu13p();
        let rows = vec![Table3Row {
            model: "Baseline".into(),
            report: SynthReport {
                dsp: 262,
                lut: 155_080,
                ff: 25_714,
                bram36: 4,
                latency_cc: 21,
                ii_cc: 1,
                clock_ns: 5.0,
            },
        }];
        let text = render_table3(&rows, &device);
        assert!(text.contains("105 (21)"));
        assert!(text.contains("262 (2.13%)"));
        assert!(text.contains("155080 (8.97%)"));
    }
}
