//! Report layer: regenerates every table and figure of the paper.
//!
//! * Figures 1–4 — CSV point clouds (+ Pareto flags) and ASCII scatter
//!   renderings of the trial database;
//! * Table 2 — global-search comparison (accuracy / BOPs / est. resources /
//!   est. clock cycles) for Baseline, NAC, SNAC-Pack;
//! * Table 3 — post-synthesis resources/latency from the HLS simulator.

pub mod figures;
pub mod scatter;
pub mod tables;

pub use figures::write_figures;
pub use scatter::Scatter;
pub use tables::{render_table2, render_table3, Table2Row, Table3Row};

use anyhow::Result;
use std::path::Path;

/// Write rows of comma-separated values with a header line.
pub fn write_csv(path: &Path, header: &str, rows: &[Vec<String>]) -> Result<()> {
    let mut out = String::with_capacity(rows.len() * 32 + header.len() + 1);
    out.push_str(header);
    out.push('\n');
    for row in rows {
        out.push_str(&row.join(","));
        out.push('\n');
    }
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, out)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_writes_header_and_rows() {
        let dir = std::env::temp_dir().join("snac_report_test");
        let path = dir.join("t.csv");
        write_csv(
            &path,
            "a,b",
            &[vec!["1".into(), "2".into()], vec!["3".into(), "4".into()]],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n3,4\n");
    }
}
