//! Figures 1–4: CSV point clouds + ASCII scatters from a trial database.

use std::path::Path;

use anyhow::Result;

use super::scatter::Scatter;
use super::write_csv;
use crate::coordinator::TrialRecord;
use crate::pareto;

/// Figure spec: which record fields go on which axis.
struct FigSpec {
    /// Output stem, e.g. `fig1`.
    stem: &'static str,
    title: &'static str,
    x: &'static str,
    y: &'static str,
    log_x: bool,
    get: fn(&TrialRecord) -> Option<(f64, f64)>,
    /// objectives used for the front overlay (minimised)
    front_objs: fn(&TrialRecord) -> Option<Vec<f64>>,
}

const FIGS_SNAC: [FigSpec; 3] = [
    FigSpec {
        stem: "fig1",
        title: "Figure 1 — SNAC-Pack: est. average resources vs est. clock cycles",
        x: "est_clock_cycles",
        y: "est_avg_resources",
        log_x: false,
        get: |r| Some((r.est_clock_cycles?, r.est_avg_resources?)),
        front_objs: |r| Some(vec![r.est_clock_cycles?, r.est_avg_resources?]),
    },
    FigSpec {
        stem: "fig2",
        title: "Figure 2 — SNAC-Pack: est. average resources vs accuracy",
        x: "est_avg_resources",
        y: "accuracy",
        log_x: false,
        get: |r| Some((r.est_avg_resources?, r.accuracy)),
        front_objs: |r| Some(vec![r.est_avg_resources?, -r.accuracy]),
    },
    FigSpec {
        stem: "fig3",
        title: "Figure 3 — SNAC-Pack: est. clock cycles vs accuracy",
        x: "est_clock_cycles",
        y: "accuracy",
        log_x: false,
        get: |r| Some((r.est_clock_cycles?, r.accuracy)),
        front_objs: |r| Some(vec![r.est_clock_cycles?, -r.accuracy]),
    },
];

const FIG_NAC: FigSpec = FigSpec {
    stem: "fig4",
    title: "Figure 4 — NAC: BOPs vs accuracy",
    x: "bops",
    y: "accuracy",
    log_x: true,
    get: |r| Some((r.bops, r.accuracy)),
    front_objs: |r| Some(vec![r.bops, -r.accuracy]),
};

fn emit(spec: &FigSpec, records: &[TrialRecord], dir: &Path) -> Result<String> {
    // pairwise front over the two plotted quantities (matches the paper's
    // per-figure fronts, which are 2-D projections)
    let pts: Vec<(usize, Vec<f64>)> = records
        .iter()
        .enumerate()
        .filter_map(|(i, r)| Some((i, (spec.front_objs)(r)?)))
        .collect();
    let objs: Vec<Vec<f64>> = pts.iter().map(|(_, o)| o.clone()).collect();
    let front_local = pareto::pareto_front(&objs);
    let front: std::collections::HashSet<usize> =
        front_local.iter().map(|&k| pts[k].0).collect();

    let mut rows = Vec::new();
    let mut plot = Scatter::new(spec.title, spec.x, spec.y);
    if spec.log_x {
        plot = plot.log_x();
    }
    for (i, r) in records.iter().enumerate() {
        let Some((x, y)) = (spec.get)(r) else { continue };
        let on_front = front.contains(&i);
        rows.push(vec![
            r.id.to_string(),
            r.label.clone(),
            format!("{x}"),
            format!("{y}"),
            (on_front as u8).to_string(),
        ]);
        plot.push(x, y, on_front);
    }
    write_csv(
        &dir.join(format!("{}.csv", spec.stem)),
        &format!("trial,label,{},{},pareto", spec.x, spec.y),
        &rows,
    )?;
    let text = plot.render(72, 20);
    std::fs::write(dir.join(format!("{}.txt", spec.stem)), &text)?;
    Ok(text)
}

/// Write Figures 1–3 from the SNAC trial DB and Figure 4 from the NAC
/// trial DB. Returns the concatenated ASCII renderings.
pub fn write_figures(
    snac_records: &[TrialRecord],
    nac_records: &[TrialRecord],
    dir: &Path,
) -> Result<String> {
    std::fs::create_dir_all(dir)?;
    let mut all = String::new();
    for spec in &FIGS_SNAC {
        all.push_str(&emit(spec, snac_records, dir)?);
        all.push('\n');
    }
    all.push_str(&emit(&FIG_NAC, nac_records, dir)?);
    Ok(all)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::SearchSpace;
    use crate::util::Rng;

    fn fake_records(n: usize, with_est: bool) -> Vec<TrialRecord> {
        let space = SearchSpace::table1();
        let mut rng = Rng::new(0);
        (0..n)
            .map(|i| {
                let genome = space.sample(&mut rng);
                TrialRecord {
                    id: i,
                    generation: 0,
                    label: genome.label(&space),
                    genome,
                    accuracy: 0.5 + 0.1 * rng.uniform(),
                    bops: 1e4 * (1.0 + rng.uniform()),
                    est_avg_resources: with_est.then(|| 2.0 + rng.uniform()),
                    est_clock_cycles: with_est.then(|| 30.0 + 40.0 * rng.uniform()),
                    objectives: vec![],
                    train_seconds: 0.0,
                }
            })
            .collect()
    }

    #[test]
    fn writes_all_four_figures() {
        let dir = std::env::temp_dir().join("snac_fig_test");
        let _ = std::fs::remove_dir_all(&dir);
        let snac = fake_records(40, true);
        let nac = fake_records(40, false);
        let text = write_figures(&snac, &nac, &dir).unwrap();
        for stem in ["fig1", "fig2", "fig3", "fig4"] {
            assert!(dir.join(format!("{stem}.csv")).exists(), "{stem}.csv");
            assert!(dir.join(format!("{stem}.txt")).exists(), "{stem}.txt");
        }
        assert!(text.contains("Figure 1"));
        assert!(text.contains("Figure 4"));
        // fig1 csv has a pareto column with at least one front point
        let csv = std::fs::read_to_string(dir.join("fig1.csv")).unwrap();
        assert!(csv.lines().skip(1).any(|l| l.ends_with(",1")));
    }

    #[test]
    fn records_without_estimates_skip_snac_figures() {
        let dir = std::env::temp_dir().join("snac_fig_test2");
        let _ = std::fs::remove_dir_all(&dir);
        let nac_only = fake_records(10, false);
        let text = write_figures(&nac_only, &nac_only, &dir).unwrap();
        // figs 1-3 have no points but must not crash
        assert!(text.contains("no points"));
    }
}
