//! ASCII scatter plots — the terminal rendering of Figures 1–4.

/// A small fixed-grid scatter renderer. Points marked `*`; Pareto-front
/// members marked `o`; axes are linear or log10.

pub struct Scatter {
    title: String,
    x_label: String,
    y_label: String,
    log_x: bool,
    log_y: bool,
    points: Vec<(f64, f64, bool)>, // (x, y, on_front)
}

impl Scatter {
    /// New plot.
    pub fn new(title: &str, x_label: &str, y_label: &str) -> Self {
        Scatter {
            title: title.to_string(),
            x_label: x_label.to_string(),
            y_label: y_label.to_string(),
            log_x: false,
            log_y: false,
            points: Vec::new(),
        }
    }

    /// Use log10 on the x axis.
    pub fn log_x(mut self) -> Self {
        self.log_x = true;
        self
    }

    /// Use log10 on the y axis.
    pub fn log_y(mut self) -> Self {
        self.log_y = true;
        self
    }

    /// Add a point; `front` marks Pareto membership.
    pub fn push(&mut self, x: f64, y: f64, front: bool) {
        self.points.push((x, y, front));
    }

    fn transform(v: f64, log: bool) -> f64 {
        if log {
            v.max(1e-12).log10()
        } else {
            v
        }
    }

    /// Render to text (width×height character grid plus legend).
    pub fn render(&self, width: usize, height: usize) -> String {
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        if self.points.is_empty() {
            out.push_str("(no points)\n");
            return out;
        }
        let tx: Vec<f64> = self
            .points
            .iter()
            .map(|p| Self::transform(p.0, self.log_x))
            .collect();
        let ty: Vec<f64> = self
            .points
            .iter()
            .map(|p| Self::transform(p.1, self.log_y))
            .collect();
        let (x0, x1) = min_max(&tx);
        let (y0, y1) = min_max(&ty);
        let xr = (x1 - x0).max(1e-12);
        let yr = (y1 - y0).max(1e-12);
        let mut grid = vec![vec![' '; width]; height];
        // draw dominated points first so front markers stay visible
        let mut order: Vec<usize> = (0..self.points.len()).collect();
        order.sort_by_key(|&i| self.points[i].2 as u8);
        for i in order {
            let col = (((tx[i] - x0) / xr) * (width - 1) as f64).round() as usize;
            let row = height - 1 - (((ty[i] - y0) / yr) * (height - 1) as f64).round() as usize;
            grid[row][col] = if self.points[i].2 { 'o' } else { '*' };
        }
        let fmt = |v: f64, log: bool| -> String {
            let raw = if log { 10f64.powf(v) } else { v };
            if raw.abs() >= 1000.0 {
                format!("{raw:.0}")
            } else {
                format!("{raw:.3}")
            }
        };
        out.push_str(&format!(
            "y: {} [{} .. {}]{}\n",
            self.y_label,
            fmt(y0, self.log_y),
            fmt(y1, self.log_y),
            if self.log_y { " (log)" } else { "" }
        ));
        for row in grid {
            out.push('|');
            out.extend(row);
            out.push('\n');
        }
        out.push('+');
        out.push_str(&"-".repeat(width));
        out.push('\n');
        out.push_str(&format!(
            "x: {} [{} .. {}]{}   * trial   o Pareto front\n",
            self.x_label,
            fmt(x0, self.log_x),
            fmt(x1, self.log_x),
            if self.log_x { " (log)" } else { "" }
        ));
        out
    }
}

fn min_max(v: &[f64]) -> (f64, f64) {
    v.iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &x| {
            (lo.min(x), hi.max(x))
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_points_and_front_markers() {
        let mut s = Scatter::new("t", "x", "y");
        s.push(1.0, 1.0, false);
        s.push(2.0, 2.0, true);
        let text = s.render(20, 10);
        assert!(text.contains('*'));
        assert!(text.contains('o'));
        assert!(text.contains("Pareto front"));
    }

    #[test]
    fn log_axes_render() {
        let mut s = Scatter::new("t", "bops", "acc").log_x();
        s.push(100.0, 0.5, false);
        s.push(100_000.0, 0.6, true);
        let text = s.render(30, 8);
        assert!(text.contains("(log)"));
    }

    #[test]
    fn empty_plot_is_safe() {
        let s = Scatter::new("t", "x", "y");
        assert!(s.render(10, 5).contains("no points"));
    }

    #[test]
    fn single_point_no_panic() {
        let mut s = Scatter::new("t", "x", "y");
        s.push(3.0, 4.0, true);
        let _ = s.render(10, 5);
    }
}
