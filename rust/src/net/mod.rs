//! Shared std-only HTTP/1.1 framing.
//!
//! Extracted from `serve/http.rs` so the estimation service (`serve/`)
//! and the TCP shard transport (`eval/tcp.rs`) speak one wire format:
//! a blocking request reader, a response writer, and a one-shot client.
//! One request per connection (`Connection: close`), bodies framed by
//! `Content-Length` — exactly what a JSON endpoint needs and nothing
//! more. The request reader is generic over any [`Read`] source, so the
//! framing parser is fuzzable without sockets (`tests/net_robustness.rs`
//! drives it with truncated, oversized, and split-read inputs).

use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

/// Largest request body the server will read (a full `/estimate/batch`
/// of a few thousand genomes — or a shard task file of forked RNG
/// states — fits in well under this).
pub const MAX_BODY: usize = 8 << 20;

/// Largest request line + header block the server will read. Bounding
/// the whole pre-body region (rather than per line) also caps header
/// count, so a client streaming endless bytes cannot grow server
/// memory or pin a connection thread.
pub const MAX_HEAD: usize = 64 << 10;

/// Read timeout the convenience [`request`] client uses; callers with a
/// liveness requirement (shard workers probing a possibly-dead driver)
/// pass their own via [`request_with_timeout`].
pub const DEFAULT_CLIENT_TIMEOUT: Duration = Duration::from_secs(60);

/// One parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    /// Upper-case method (`GET`, `POST`, …).
    pub method: String,
    /// Path without query string.
    pub path: String,
    /// Raw body (empty when no `Content-Length`).
    pub body: String,
}

/// Typed client-side failures (carried inside `anyhow::Error`; downcast
/// to branch on them).
#[derive(Debug)]
pub enum NetError {
    /// The peer accepted (or never completed) the exchange but went
    /// quiet past the configured timeout. Workers downcast to this to
    /// tell a dead driver from a malformed response.
    Timeout {
        /// The address the request was sent to.
        addr: String,
        /// How long the client waited before giving up.
        waited: Duration,
    },
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::Timeout { addr, waited } => {
                write!(f, "request to {addr} timed out after {waited:.1?}")
            }
        }
    }
}

impl std::error::Error for NetError {}

/// Read one request from a connection. Fails on malformed framing, an
/// over-long body, or a source that goes quiet mid-request (on a socket
/// the caller sets the stream's read timeout). Generic over the byte
/// source so the parser is testable against in-memory and split reads.
pub fn read_request<R: Read>(stream: R) -> Result<Request> {
    // hard cap on the pre-body region: an over-long request line or
    // header block exhausts the budget (read_line hits EOF) and fails
    // the request instead of ballooning `line` without bound
    let mut reader = BufReader::new(stream.take(MAX_HEAD as u64));
    let mut line = String::new();
    reader.read_line(&mut line).context("reading request line")?;
    let mut parts = line.split_whitespace();
    let method = parts.next().context("empty request line")?.to_ascii_uppercase();
    let target = parts.next().context("request line has no path")?;
    let path = target.split('?').next().unwrap_or(target).to_string();

    let mut content_length = 0usize;
    loop {
        let mut header = String::new();
        reader.read_line(&mut header).context("reading header")?;
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().context("unparseable Content-Length")?;
            }
        }
    }
    if content_length > MAX_BODY {
        bail!("request body of {content_length} bytes exceeds the {MAX_BODY}-byte limit");
    }
    // headers consumed: widen the read budget to admit exactly the body
    // (bytes the BufReader already buffered are paid for, so this is
    // never under-generous)
    reader.get_mut().set_limit(content_length as u64);
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).context("reading request body")?;
    Ok(Request {
        method,
        path,
        body: String::from_utf8(body).context("request body is not UTF-8")?,
    })
}

/// Reason phrase for the status codes the services emit.
fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        _ => "Unknown",
    }
}

/// Write a full JSON response and flush.
pub fn write_response<W: Write>(stream: &mut W, status: u16, body: &str) -> Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        reason(status),
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// One-shot HTTP client: send `method path` with an optional JSON body
/// to `addr` (e.g. `127.0.0.1:7878`) and return `(status, body)`. Reads
/// time out after [`DEFAULT_CLIENT_TIMEOUT`].
pub fn request(addr: &str, method: &str, path: &str, body: Option<&str>) -> Result<(u16, String)> {
    request_with_timeout(addr, method, path, body, DEFAULT_CLIENT_TIMEOUT)
}

/// [`request`] with an explicit timeout bounding connect, write, and
/// read. A peer that goes quiet past the deadline fails with a typed
/// [`NetError::Timeout`] instead of hanging the caller forever — shard
/// workers rely on this to survive a dead driver.
pub fn request_with_timeout(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
    timeout: Duration,
) -> Result<(u16, String)> {
    // a zero timeout means "disable timeouts" to the socket API — clamp
    // so the caller's intent (fail fast) is preserved
    let timeout = timeout.max(Duration::from_millis(1));
    let t0 = Instant::now();
    let timed = |e: std::io::Error, what: &'static str| -> anyhow::Error {
        if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) {
            anyhow::Error::new(NetError::Timeout {
                addr: addr.to_string(),
                waited: t0.elapsed(),
            })
        } else {
            anyhow::Error::new(e).context(what)
        }
    };
    let sock = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving {addr}"))?
        .next()
        .with_context(|| format!("{addr} resolves to no address"))?;
    let mut stream =
        TcpStream::connect_timeout(&sock, timeout).map_err(|e| timed(e, "connecting"))?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    let body = body.unwrap_or("");
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream
        .write_all(head.as_bytes())
        .map_err(|e| timed(e, "writing request head"))?;
    stream
        .write_all(body.as_bytes())
        .map_err(|e| timed(e, "writing request body"))?;
    stream.flush().map_err(|e| timed(e, "flushing request"))?;

    let mut response = String::new();
    stream
        .read_to_string(&mut response)
        .map_err(|e| timed(e, "reading response"))?;
    let (head, payload) = response
        .split_once("\r\n\r\n")
        .context("response has no header/body separator")?;
    let status_line = head.lines().next().context("empty response")?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .context("status line has no code")?
        .parse()
        .context("unparseable status code")?;
    Ok((status, payload.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn request_parses_from_any_reader() {
        let raw = b"POST /estimate?x=1 HTTP/1.1\r\nHost: h\r\nContent-Length: 4\r\n\r\nbody";
        let req = read_request(Cursor::new(raw.to_vec())).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/estimate");
        assert_eq!(req.body, "body");

        // no Content-Length: empty body
        let req = read_request(Cursor::new(b"GET / HTTP/1.1\r\n\r\n".to_vec())).unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
    }

    #[test]
    fn oversized_and_truncated_requests_are_typed_errors() {
        let big = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1);
        let err = read_request(Cursor::new(big.into_bytes())).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds"), "{err:#}");

        // promised body never arrives
        let err = read_request(Cursor::new(
            b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc".to_vec(),
        ))
        .unwrap_err();
        assert!(format!("{err:#}").contains("request body"), "{err:#}");
    }

    #[test]
    fn quiet_peer_times_out_with_a_typed_error() {
        // a listener that accepts and never responds
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let hold = std::thread::spawn(move || listener.accept());
        let err = request_with_timeout(
            &addr,
            "GET",
            "/healthz",
            None,
            Duration::from_millis(50),
        )
        .unwrap_err();
        let net = err
            .downcast_ref::<NetError>()
            .expect("typed NetError, not a stringly error");
        let NetError::Timeout { addr: got, waited } = net;
        assert_eq!(*got, addr);
        assert!(*waited >= Duration::from_millis(50));
        drop(hold.join());
    }
}
