//! Shared std-only HTTP/1.1 framing.
//!
//! Extracted from `serve/http.rs` so the estimation service (`serve/`)
//! and the TCP shard transport (`eval/tcp.rs`) speak one wire format.
//! Connections are persistent: [`RequestReader`] parses many requests
//! per socket (honoring `Connection: keep-alive`/`close`), responses
//! carry explicit `Content-Length` framing, and [`HttpClient`] reuses
//! one connection across requests with an overall per-request deadline.
//! Bodies are framed by `Content-Length` only — exactly what a JSON
//! endpoint needs and nothing more. The request reader is generic over
//! any [`Read`] source, so the framing parser is fuzzable without
//! sockets (`tests/net_robustness.rs` drives it with truncated,
//! pipelined, oversized, and split-read inputs).

use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

/// Largest request or response body either side will read (a full
/// `/estimate/batch` of a few thousand genomes — or a shard task file
/// of forked RNG states — fits in well under this).
pub const MAX_BODY: usize = 8 << 20;

/// Largest request line + header block either side will read. Bounding
/// the whole pre-body region (rather than per line) also caps header
/// count, so a peer streaming endless bytes cannot grow memory or pin
/// a connection thread.
pub const MAX_HEAD: usize = 64 << 10;

/// Deadline the convenience [`request`] client uses; callers with a
/// liveness requirement (shard workers probing a possibly-dead driver)
/// pass their own via [`request_with_timeout`] or [`HttpClient`].
pub const DEFAULT_CLIENT_TIMEOUT: Duration = Duration::from_secs(60);

/// One parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    /// Upper-case method (`GET`, `POST`, …).
    pub method: String,
    /// Path without query string.
    pub path: String,
    /// Raw body (empty when no `Content-Length`).
    pub body: String,
    /// Whether the peer asked to keep the connection open after this
    /// request (HTTP/1.1 default; `Connection: close` or HTTP/1.0 turn
    /// it off).
    pub keep_alive: bool,
    /// Token from an `Authorization: Bearer …` header, if any.
    pub bearer: Option<String>,
    /// Trace ID from an `X-Snac-Trace` header, if any — cross-process
    /// span propagation for the shard transport (`telemetry`).
    pub trace: Option<String>,
}

/// Typed framing failures (carried inside `anyhow::Error`; downcast to
/// branch on them).
#[derive(Debug)]
pub enum NetError {
    /// The peer accepted (or never completed) the exchange but went
    /// quiet past the configured deadline. Workers downcast to this to
    /// tell a dead driver from a malformed response.
    Timeout {
        /// The address the request was sent to.
        addr: String,
        /// How long the client waited before giving up.
        waited: Duration,
    },
    /// The peer closed the connection cleanly at a request boundary —
    /// the normal end of a persistent connection, not a fault.
    Closed,
    /// Nothing arrived within the socket's read timeout while waiting
    /// for the *start* of a request — the keep-alive idle timeout.
    Idle,
    /// The source ended (EOF or went quiet) *inside* a request or
    /// response — a truncated exchange, never silently accepted.
    Truncated {
        /// Which framing region was cut short.
        what: &'static str,
    },
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::Timeout { addr, waited } => {
                write!(f, "request to {addr} timed out after {waited:.1?}")
            }
            NetError::Closed => write!(f, "peer closed the connection between requests"),
            NetError::Idle => write!(f, "connection idle past the keep-alive timeout"),
            NetError::Truncated { what } => {
                write!(f, "connection truncated inside the {what}")
            }
        }
    }
}

impl std::error::Error for NetError {}

/// True when a reader error only means the peer is done with the
/// connection (clean close, or idle past the keep-alive timeout) —
/// servers drop the socket without logging or replying.
pub fn quiet_close(err: &anyhow::Error) -> bool {
    matches!(
        err.downcast_ref::<NetError>(),
        Some(NetError::Closed | NetError::Idle)
    )
}

/// How a capped line read ended.
enum LineRead {
    /// A complete line (terminator stripped).
    Line(String),
    /// The source ended before the line terminator arrived.
    Ended {
        /// Whether any byte of this line had already arrived.
        started: bool,
        /// Ended by a read timeout (the source went quiet) rather than
        /// EOF.
        timed_out: bool,
    },
}

/// Read one `\n`-terminated line, consuming at most `*budget` bytes
/// across calls. EOF and read timeouts are reported as [`LineRead::Ended`]
/// so callers can distinguish a clean between-requests close from a
/// truncated exchange; exhausting the budget is a hard error.
fn read_line_capped<R: Read>(
    reader: &mut BufReader<R>,
    budget: &mut usize,
    what: &'static str,
) -> Result<LineRead> {
    let mut line: Vec<u8> = Vec::new();
    loop {
        if *budget == 0 {
            bail!("{what} exceeds the {MAX_HEAD}-byte head cap");
        }
        let (used, done) = {
            let buf = match reader.fill_buf() {
                Ok(buf) => buf,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                    return Ok(LineRead::Ended { started: !line.is_empty(), timed_out: true });
                }
                Err(e) => return Err(anyhow::Error::new(e).context(format!("reading {what}"))),
            };
            if buf.is_empty() {
                return Ok(LineRead::Ended { started: !line.is_empty(), timed_out: false });
            }
            let take = buf.len().min(*budget);
            match buf[..take].iter().position(|&b| b == b'\n') {
                Some(i) => {
                    line.extend_from_slice(&buf[..i]);
                    (i + 1, true)
                }
                None => {
                    line.extend_from_slice(&buf[..take]);
                    (take, false)
                }
            }
        };
        reader.consume(used);
        *budget -= used;
        if done {
            if line.last() == Some(&b'\r') {
                line.pop();
            }
            let text =
                String::from_utf8(line).with_context(|| format!("{what} is not UTF-8"))?;
            return Ok(LineRead::Line(text));
        }
    }
}

/// How an exact-length body read ended short.
enum FrameEnd {
    /// EOF before the promised byte count arrived.
    Eof,
    /// The source went quiet past its read timeout mid-body.
    TimedOut,
    /// A real I/O failure.
    Io(std::io::Error),
}

/// Read exactly `n` bytes, classifying every way the framing contract
/// can break so callers map it to the right typed error.
fn read_exact_framed<R: Read>(reader: &mut R, n: usize) -> std::result::Result<Vec<u8>, FrameEnd> {
    let mut buf = vec![0u8; n];
    let mut filled = 0usize;
    while filled < n {
        match reader.read(&mut buf[filled..]) {
            Ok(0) => return Err(FrameEnd::Eof),
            Ok(k) => filled += k,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                return Err(FrameEnd::TimedOut)
            }
            Err(e) => return Err(FrameEnd::Io(e)),
        }
    }
    Ok(buf)
}

/// Record a `Content-Length` value, rejecting a second conflicting one
/// (duplicate-but-equal headers are tolerated; last-wins smuggling is
/// not).
fn note_content_length(slot: &mut Option<usize>, value: &str) -> Result<()> {
    let n: usize = value.trim().parse().context("unparseable Content-Length")?;
    match *slot {
        Some(prev) if prev != n => {
            bail!("conflicting Content-Length headers ({prev} then {n})")
        }
        _ => {
            *slot = Some(n);
            Ok(())
        }
    }
}

/// Connection-lifetime request parser: feeds many requests off one byte
/// source. On a socket, set the stream's read timeout to the desired
/// keep-alive idle timeout before constructing — going quiet *between*
/// requests surfaces as [`NetError::Idle`], a clean close as
/// [`NetError::Closed`], and an EOF or stall *inside* a request as
/// [`NetError::Truncated`].
pub struct RequestReader<R: Read> {
    reader: BufReader<R>,
}

impl<R: Read> RequestReader<R> {
    /// Wrap a byte source (socket, cursor, split reader, …).
    pub fn new(source: R) -> Self {
        RequestReader { reader: BufReader::new(source) }
    }

    /// Parse the next request. Fails on malformed framing, an over-long
    /// head or body, or a source that ends mid-request; see the type
    /// docs for how connection endings are classified.
    pub fn next_request(&mut self) -> Result<Request> {
        // hard cap on this request's pre-body region: an over-long
        // request line or header block exhausts the budget and fails
        // the request instead of ballooning memory without bound
        let mut budget = MAX_HEAD;
        let line = loop {
            match read_line_capped(&mut self.reader, &mut budget, "request line")? {
                // tolerate stray blank lines between pipelined requests
                LineRead::Line(l) if l.is_empty() => continue,
                LineRead::Line(l) => break l,
                LineRead::Ended { started: false, timed_out } => {
                    return Err(anyhow::Error::new(if timed_out {
                        NetError::Idle
                    } else {
                        NetError::Closed
                    }));
                }
                LineRead::Ended { started: true, .. } => {
                    return Err(anyhow::Error::new(NetError::Truncated { what: "request line" }));
                }
            }
        };
        let mut parts = line.split_whitespace();
        let method = parts.next().context("empty request line")?.to_ascii_uppercase();
        let target = parts.next().context("request line has no path")?;
        let path = target.split('?').next().unwrap_or(target).to_string();
        // HTTP/1.1 defaults to keep-alive; 1.0 (or a missing version) to close
        let mut keep_alive = parts.next().is_some_and(|v| v.eq_ignore_ascii_case("HTTP/1.1"));

        let mut content_length: Option<usize> = None;
        let mut bearer: Option<String> = None;
        let mut trace: Option<String> = None;
        loop {
            let header = match read_line_capped(&mut self.reader, &mut budget, "headers")? {
                LineRead::Line(l) => l,
                // EOF mid-headers is truncation, never end-of-headers
                LineRead::Ended { .. } => {
                    return Err(anyhow::Error::new(NetError::Truncated { what: "headers" }));
                }
            };
            if header.is_empty() {
                break;
            }
            let Some((name, value)) = header.split_once(':') else { continue };
            let (name, value) = (name.trim(), value.trim());
            if name.eq_ignore_ascii_case("content-length") {
                note_content_length(&mut content_length, value)?;
            } else if name.eq_ignore_ascii_case("connection") {
                if value.eq_ignore_ascii_case("close") {
                    keep_alive = false;
                } else if value.eq_ignore_ascii_case("keep-alive") {
                    keep_alive = true;
                }
            } else if name.eq_ignore_ascii_case("authorization") {
                if let Some((scheme, token)) = value.split_once(' ') {
                    if scheme.eq_ignore_ascii_case("bearer") {
                        bearer = Some(token.trim().to_string());
                    }
                }
            } else if name.eq_ignore_ascii_case("x-snac-trace") && !value.is_empty() {
                trace = Some(value.to_string());
            }
        }

        let content_length = content_length.unwrap_or(0);
        if content_length > MAX_BODY {
            bail!("request body of {content_length} bytes exceeds the {MAX_BODY}-byte limit");
        }
        let body = match read_exact_framed(&mut self.reader, content_length) {
            Ok(b) => b,
            Err(FrameEnd::Eof | FrameEnd::TimedOut) => {
                return Err(anyhow::Error::new(NetError::Truncated { what: "request body" }));
            }
            Err(FrameEnd::Io(e)) => {
                return Err(anyhow::Error::new(e).context("reading request body"))
            }
        };
        Ok(Request {
            method,
            path,
            body: String::from_utf8(body).context("request body is not UTF-8")?,
            keep_alive,
            bearer,
            trace,
        })
    }
}

/// Read a single request from a one-request source (compatibility shim
/// over [`RequestReader`] — fuzz tests and simple callers).
pub fn read_request<R: Read>(stream: R) -> Result<Request> {
    RequestReader::new(stream).next_request()
}

/// Reason phrase for the status codes the services emit.
fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        403 => "Forbidden",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write a full JSON response and flush. `keep_alive` picks the
/// `Connection` header; the caller decides whether the socket actually
/// stays open.
pub fn write_response<W: Write>(
    stream: &mut W,
    status: u16,
    body: &str,
    keep_alive: bool,
) -> Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        reason(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// A [`TcpStream`] whose reads and writes all count against one
/// deadline: before every socket operation the remaining time is
/// re-armed as the socket timeout, so a peer trickling one byte per
/// interval cannot hold the caller past the overall deadline.
struct DeadlineStream {
    stream: TcpStream,
    end: Instant,
}

impl DeadlineStream {
    fn arm(&self) -> std::io::Result<Duration> {
        let now = Instant::now();
        if now >= self.end {
            return Err(std::io::Error::new(
                ErrorKind::TimedOut,
                "overall request deadline exceeded",
            ));
        }
        Ok(self.end - now)
    }
}

impl Read for DeadlineStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let left = self.arm()?;
        self.stream.set_read_timeout(Some(left))?;
        self.stream.read(buf)
    }
}

impl Write for DeadlineStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let left = self.arm()?;
        self.stream.set_write_timeout(Some(left))?;
        self.stream.write(buf)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.stream.flush()
    }
}

/// Map an I/O failure from the client path to a typed timeout when the
/// socket (or the overall deadline) ran out of time.
fn client_io_error(
    e: std::io::Error,
    what: &'static str,
    addr: &str,
    t0: Instant,
) -> anyhow::Error {
    if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) {
        anyhow::Error::new(NetError::Timeout { addr: addr.to_string(), waited: t0.elapsed() })
    } else {
        anyhow::Error::new(e).context(what)
    }
}

/// Persistent HTTP client: keeps one connection open across requests
/// (`Connection: keep-alive`), frames responses by their
/// `Content-Length` (never read-to-EOF), and bounds every request by an
/// overall deadline across connect, write, and read. If a reused
/// connection turns out to have been closed by the server's idle
/// timeout, the request is retried exactly once on a fresh connection
/// (timeouts are never retried — the wait is already spent).
pub struct HttpClient {
    addr: String,
    timeout: Duration,
    bearer: Option<String>,
    trace: Option<String>,
    one_shot: bool,
    conn: Option<BufReader<DeadlineStream>>,
}

impl HttpClient {
    /// A keep-alive client for `addr` (e.g. `127.0.0.1:7878`) with a
    /// per-request deadline.
    pub fn new(addr: impl Into<String>, timeout: Duration) -> Self {
        HttpClient {
            addr: addr.into(),
            timeout,
            bearer: None,
            trace: None,
            one_shot: false,
            conn: None,
        }
    }

    /// Attach an `Authorization: Bearer …` token to every request.
    pub fn bearer(mut self, token: impl Into<String>) -> Self {
        self.bearer = Some(token.into());
        self
    }

    /// Attach an `X-Snac-Trace` header to every request so the peer can
    /// stitch this client's spans into one cross-process trace.
    pub fn set_trace(&mut self, id: impl Into<String>) {
        self.trace = Some(id.into());
    }

    /// The server address this client talks to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    fn open(addr: &str, deadline: Duration, t0: Instant) -> Result<BufReader<DeadlineStream>> {
        let sock = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving {addr}"))?
            .next()
            .with_context(|| format!("{addr} resolves to no address"))?;
        let stream = TcpStream::connect_timeout(&sock, deadline)
            .map_err(|e| client_io_error(e, "connecting", addr, t0))?;
        // each request is one small write; don't wait for coalescing
        let _ = stream.set_nodelay(true);
        Ok(BufReader::new(DeadlineStream { stream, end: t0 + deadline }))
    }

    /// Send `method path` with an optional JSON body and return
    /// `(status, body)`. A peer that goes quiet past the deadline fails
    /// with a typed [`NetError::Timeout`] instead of hanging the caller
    /// forever — shard workers rely on this to survive a dead driver.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<(u16, String)> {
        let reused = self.conn.is_some();
        match self.try_request(method, path, body) {
            Ok(out) => Ok(out),
            Err(e) => {
                self.conn = None;
                // a reused connection may have been idle-closed by the
                // server between requests; one fresh retry covers that
                // race without retrying genuine fresh-connection errors
                let timed_out = matches!(
                    e.downcast_ref::<NetError>(),
                    Some(NetError::Timeout { .. })
                );
                if !reused || timed_out {
                    return Err(e);
                }
                let out = self.try_request(method, path, body);
                if out.is_err() {
                    self.conn = None;
                }
                out
            }
        }
    }

    fn try_request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<(u16, String)> {
        // a zero timeout means "disable timeouts" to the socket API —
        // clamp so the caller's intent (fail fast) is preserved
        let deadline = self.timeout.max(Duration::from_millis(1));
        let t0 = Instant::now();
        if self.conn.is_none() {
            self.conn = Some(Self::open(&self.addr, deadline, t0)?);
        }
        let Some(conn) = self.conn.as_mut() else {
            bail!("no connection to {}", self.addr);
        };
        conn.get_mut().end = t0 + deadline;

        let body = body.unwrap_or("");
        let mut auth = match &self.bearer {
            Some(token) => format!("Authorization: Bearer {token}\r\n"),
            None => String::new(),
        };
        if let Some(id) = &self.trace {
            auth.push_str(&format!("X-Snac-Trace: {id}\r\n"));
        }
        let head = format!(
            "{method} {path} HTTP/1.1\r\nHost: {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n{auth}Connection: {}\r\n\r\n",
            self.addr,
            body.len(),
            if self.one_shot { "close" } else { "keep-alive" },
        );
        let stream = conn.get_mut();
        stream
            .write_all(head.as_bytes())
            .and_then(|()| stream.write_all(body.as_bytes()))
            .and_then(|()| stream.flush())
            .map_err(|e| client_io_error(e, "writing request", &self.addr, t0))?;

        let (status, payload, server_keep) = read_response(conn, &self.addr, t0)?;
        if self.one_shot || !server_keep {
            self.conn = None;
        }
        Ok((status, payload))
    }
}

/// Parse one framed response: status line, headers, exactly
/// `Content-Length` body bytes. Returns `(status, body, keep_alive)`.
fn read_response(
    reader: &mut BufReader<DeadlineStream>,
    addr: &str,
    t0: Instant,
) -> Result<(u16, String, bool)> {
    let mut budget = MAX_HEAD;
    let timeout = |t0: Instant| NetError::Timeout { addr: addr.to_string(), waited: t0.elapsed() };
    let status_line = match read_line_capped(reader, &mut budget, "response status line")? {
        LineRead::Line(l) => l,
        LineRead::Ended { timed_out: true, .. } => return Err(anyhow::Error::new(timeout(t0))),
        LineRead::Ended { started: false, .. } => return Err(anyhow::Error::new(NetError::Closed)),
        LineRead::Ended { started: true, .. } => {
            return Err(anyhow::Error::new(NetError::Truncated { what: "response status line" }));
        }
    };
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .context("status line has no code")?
        .parse()
        .context("unparseable status code")?;
    let mut keep_alive = status_line
        .split_whitespace()
        .next()
        .is_some_and(|v| v.eq_ignore_ascii_case("HTTP/1.1"));

    let mut content_length: Option<usize> = None;
    loop {
        let header = match read_line_capped(reader, &mut budget, "response headers")? {
            LineRead::Line(l) => l,
            LineRead::Ended { timed_out: true, .. } => {
                return Err(anyhow::Error::new(timeout(t0)))
            }
            LineRead::Ended { .. } => {
                return Err(anyhow::Error::new(NetError::Truncated { what: "response headers" }));
            }
        };
        if header.is_empty() {
            break;
        }
        let Some((name, value)) = header.split_once(':') else { continue };
        let (name, value) = (name.trim(), value.trim());
        if name.eq_ignore_ascii_case("content-length") {
            note_content_length(&mut content_length, value)?;
        } else if name.eq_ignore_ascii_case("connection") {
            if value.eq_ignore_ascii_case("close") {
                keep_alive = false;
            } else if value.eq_ignore_ascii_case("keep-alive") {
                keep_alive = true;
            }
        }
    }

    // read-to-EOF parsing is gone: persistent connections need explicit
    // framing, and every server in this workspace emits it
    let Some(n) = content_length else {
        bail!("response has no Content-Length (persistent connections require framed responses)");
    };
    if n > MAX_BODY {
        bail!("response body of {n} bytes exceeds the {MAX_BODY}-byte limit");
    }
    let payload = match read_exact_framed(reader, n) {
        Ok(b) => b,
        Err(FrameEnd::TimedOut) => return Err(anyhow::Error::new(timeout(t0))),
        Err(FrameEnd::Eof) => {
            return Err(anyhow::Error::new(NetError::Truncated { what: "response body" }));
        }
        Err(FrameEnd::Io(e)) => return Err(anyhow::Error::new(e).context("reading response body")),
    };
    Ok((
        status,
        String::from_utf8(payload).context("response body is not UTF-8")?,
        keep_alive,
    ))
}

/// One-shot HTTP client: send `method path` with an optional JSON body
/// to `addr` (e.g. `127.0.0.1:7878`) and return `(status, body)`. The
/// whole exchange is bounded by [`DEFAULT_CLIENT_TIMEOUT`].
pub fn request(addr: &str, method: &str, path: &str, body: Option<&str>) -> Result<(u16, String)> {
    request_with_timeout(addr, method, path, body, DEFAULT_CLIENT_TIMEOUT)
}

/// [`request`] with an explicit overall deadline across connect, write,
/// and read — not a per-socket-read timeout, so a peer trickling bytes
/// cannot stretch the wait. Sends `Connection: close` (one request per
/// connection); use [`HttpClient`] for keep-alive.
pub fn request_with_timeout(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
    timeout: Duration,
) -> Result<(u16, String)> {
    let mut client = HttpClient {
        addr: addr.to_string(),
        timeout,
        bearer: None,
        trace: None,
        one_shot: true,
        conn: None,
    };
    client.request(method, path, body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn request_parses_from_any_reader() {
        let raw = b"POST /estimate?x=1 HTTP/1.1\r\nHost: h\r\nContent-Length: 4\r\n\r\nbody";
        let req = read_request(Cursor::new(raw.to_vec())).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/estimate");
        assert_eq!(req.body, "body");
        assert!(req.keep_alive, "HTTP/1.1 defaults to keep-alive");
        assert!(req.bearer.is_none());

        // no Content-Length: empty body; Connection: close honored
        let req =
            read_request(Cursor::new(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n".to_vec()))
                .unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
        assert!(!req.keep_alive);

        // bearer tokens parse regardless of scheme case
        let raw = b"POST /shard/claim HTTP/1.1\r\nAuthorization: bearer tok-123\r\n\r\n";
        let req = read_request(Cursor::new(raw.to_vec())).unwrap();
        assert_eq!(req.bearer.as_deref(), Some("tok-123"));
        assert!(req.trace.is_none());

        // trace IDs ride a dedicated header, case-insensitively
        let raw = b"POST /shard/claim HTTP/1.1\r\nx-snac-trace: 1a2b-3c4d\r\n\r\n";
        let req = read_request(Cursor::new(raw.to_vec())).unwrap();
        assert_eq!(req.trace.as_deref(), Some("1a2b-3c4d"));
    }

    #[test]
    fn reader_serves_many_requests_per_connection() {
        let raw = b"POST /a HTTP/1.1\r\nContent-Length: 2\r\n\r\nhiGET /b HTTP/1.1\r\n\r\nGET /c HTTP/1.1\r\nConnection: close\r\n\r\n";
        let mut reader = RequestReader::new(Cursor::new(raw.to_vec()));
        let a = reader.next_request().unwrap();
        assert_eq!((a.path.as_str(), a.body.as_str(), a.keep_alive), ("/a", "hi", true));
        let b = reader.next_request().unwrap();
        assert_eq!((b.path.as_str(), b.keep_alive), ("/b", true));
        let c = reader.next_request().unwrap();
        assert_eq!((c.path.as_str(), c.keep_alive), ("/c", false));
        let end = reader.next_request().unwrap_err();
        assert!(
            matches!(end.downcast_ref::<NetError>(), Some(NetError::Closed)),
            "clean EOF at a request boundary must be NetError::Closed, got {end:#}"
        );
    }

    #[test]
    fn truncation_inside_headers_is_a_typed_error() {
        // regression: EOF mid-headers used to read as end-of-headers and
        // silently serve the truncated request as a body-less one
        let err = read_request(Cursor::new(b"GET / HTTP/1.1\r\nHost: h".to_vec())).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<NetError>(),
                Some(NetError::Truncated { what: "headers" })
            ),
            "EOF mid-headers must be a typed truncation, got {err:#}"
        );

        // EOF mid-request-line is the same class of fault
        let err = read_request(Cursor::new(b"GET / HT".to_vec())).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<NetError>(), Some(NetError::Truncated { .. })),
            "{err:#}"
        );
    }

    #[test]
    fn conflicting_content_length_headers_are_rejected() {
        // regression: last-wins parsing accepted smuggled lengths
        let raw = b"POST / HTTP/1.1\r\nContent-Length: 4\r\nContent-Length: 2\r\n\r\nbody";
        let err = read_request(Cursor::new(raw.to_vec())).unwrap_err();
        assert!(format!("{err:#}").contains("conflicting Content-Length"), "{err:#}");

        // duplicate-but-equal headers are tolerated
        let raw = b"POST / HTTP/1.1\r\nContent-Length: 4\r\nContent-Length: 4\r\n\r\nbody";
        let req = read_request(Cursor::new(raw.to_vec())).unwrap();
        assert_eq!(req.body, "body");
    }

    #[test]
    fn oversized_and_truncated_requests_are_typed_errors() {
        let big = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1);
        let err = read_request(Cursor::new(big.into_bytes())).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds"), "{err:#}");

        // promised body never arrives
        let err = read_request(Cursor::new(
            b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc".to_vec(),
        ))
        .unwrap_err();
        assert!(format!("{err:#}").contains("request body"), "{err:#}");
    }

    #[test]
    fn quiet_peer_times_out_with_a_typed_error() {
        // a listener that accepts and never responds
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let hold = std::thread::spawn(move || listener.accept());
        let err = request_with_timeout(
            &addr,
            "GET",
            "/healthz",
            None,
            Duration::from_millis(50),
        )
        .unwrap_err();
        let net = err
            .downcast_ref::<NetError>()
            .expect("typed NetError, not a stringly error");
        assert!(matches!(net, NetError::Timeout { .. }), "{net}");
        if let NetError::Timeout { addr: got, waited } = net {
            assert_eq!(*got, addr);
            assert!(*waited >= Duration::from_millis(50));
        }
        drop(hold.join());
    }

    #[test]
    fn trickling_peer_cannot_stretch_the_deadline() {
        // regression: the timeout used to re-arm per socket read, so a
        // peer dripping one byte per interval held the client far past
        // the configured wait
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let trickle = std::thread::spawn(move || {
            let Ok((mut s, _)) = listener.accept() else { return };
            for _ in 0..300 {
                if s.write_all(b"x").is_err() {
                    break;
                }
                let _ = s.flush();
                std::thread::sleep(Duration::from_millis(10));
            }
        });
        let t0 = Instant::now();
        let err =
            request_with_timeout(&addr, "GET", "/", None, Duration::from_millis(100)).unwrap_err();
        let elapsed = t0.elapsed();
        assert!(
            matches!(err.downcast_ref::<NetError>(), Some(NetError::Timeout { .. })),
            "trickled bytes must still end in a typed timeout, got {err:#}"
        );
        assert!(
            elapsed < Duration::from_millis(1500),
            "deadline must bound the whole exchange, waited {elapsed:?}"
        );
        drop(trickle.join());
    }

    #[test]
    fn client_honors_response_framing_without_a_server_close() {
        // regression: the client used to read to EOF, which hangs the
        // moment the server keeps the connection open after responding
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let Ok((stream, _)) = listener.accept() else { return };
            let mut reader = RequestReader::new(&stream);
            let _ = reader.next_request();
            let mut w = &stream;
            let _ = write_response(&mut w, 200, "{\"ok\":true}", true);
            // hold the connection open well past the client's deadline
            std::thread::sleep(Duration::from_millis(1500));
        });
        let t0 = Instant::now();
        let (status, body) =
            request_with_timeout(&addr, "GET", "/healthz", None, Duration::from_millis(1000))
                .expect("framed response must parse without waiting for EOF");
        assert_eq!(status, 200);
        assert_eq!(body, "{\"ok\":true}");
        assert!(
            t0.elapsed() < Duration::from_millis(900),
            "client must return as soon as the framed body arrives"
        );
        drop(server.join());
    }

    #[test]
    fn keep_alive_client_reuses_one_connection() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let mut accepted = 0usize;
            let Ok((stream, _)) = listener.accept() else { return 0 };
            accepted += 1;
            let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
            let mut reader = RequestReader::new(&stream);
            loop {
                match reader.next_request() {
                    Ok(req) => {
                        let mut w = &stream;
                        let body = format!("{{\"echo\":\"{}\"}}", req.path);
                        if write_response(&mut w, 200, &body, req.keep_alive).is_err()
                            || !req.keep_alive
                        {
                            break;
                        }
                    }
                    Err(_) => break,
                }
            }
            accepted
        });
        let mut client = HttpClient::new(addr, Duration::from_secs(5));
        for path in ["/a", "/b", "/c"] {
            let (status, body) = client.request("GET", path, None).unwrap();
            assert_eq!(status, 200);
            assert_eq!(body, format!("{{\"echo\":\"{path}\"}}"));
        }
        drop(client);
        assert_eq!(server.join().unwrap(), 1, "three requests over one connection");
    }
}
