//! Serving observability: lock-free counters and latency histograms
//! behind `GET /metrics`.
//!
//! Everything here is a relaxed atomic — connection workers record into
//! the histograms on the request path with no shared lock, and the
//! `/metrics` endpoint renders a consistent-enough snapshot (each value
//! is individually atomic; the report as a whole is not a transaction,
//! which is the standard contract for scrape-style metrics).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Duration;

use crate::serve::SurrogateEngine;
use crate::util::Json;

/// Latency bucket upper bounds in microseconds; one overflow bucket is
/// appended. Spans 50µs (memo hit on loopback) to 250ms (a cold flush
/// behind a long batching deadline).
const BUCKET_US: [u64; 12] =
    [50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000, 250_000];

/// One fixed-bucket latency histogram.
pub struct Histogram {
    counts: [AtomicU64; BUCKET_US.len() + 1],
    sum_us: AtomicU64,
}

impl Histogram {
    fn new() -> Self {
        Histogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
        }
    }

    /// Record one request latency.
    pub fn observe(&self, elapsed: Duration) {
        let us = u64::try_from(elapsed.as_micros()).unwrap_or(u64::MAX);
        let idx = BUCKET_US.iter().position(|&b| us <= b).unwrap_or(BUCKET_US.len());
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Mean latency in milliseconds (0 when empty).
    pub fn mean_ms(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64 / 1_000.0
    }

    /// Conservative quantile in milliseconds: the upper bound of the
    /// bucket holding the q-th observation (the overflow bucket reports
    /// four times the last bound). 0 when empty.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        let snapshot: Vec<u64> = self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let total: u64 = snapshot.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, &n) in snapshot.iter().enumerate() {
            seen += n;
            if seen >= rank {
                let bound_us = BUCKET_US.get(i).copied().unwrap_or(BUCKET_US[BUCKET_US.len() - 1] * 4);
                return bound_us as f64 / 1_000.0;
            }
        }
        BUCKET_US[BUCKET_US.len() - 1] as f64 * 4.0 / 1_000.0
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count() as f64)),
            ("mean_ms", Json::Num(self.mean_ms())),
            ("p50_ms", Json::Num(self.quantile_ms(0.50))),
            ("p99_ms", Json::Num(self.quantile_ms(0.99))),
        ])
    }
}

/// The endpoints tracked individually; everything else lands in `other`.
const ENDPOINTS: [&str; 6] =
    ["/healthz", "/metrics", "/estimate", "/estimate/batch", "/shutdown", "other"];

/// Decrements a gauge when dropped — pairs an increment with every exit
/// path of a connection handler.
pub struct GaugeGuard<'a>(&'a AtomicUsize);

impl Drop for GaugeGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

/// All serving metrics, shared by reference across connection workers.
pub struct ServeMetrics {
    endpoints: [Histogram; ENDPOINTS.len()],
    /// Connections currently being served by a worker.
    in_flight: AtomicUsize,
    /// Connections accepted but not yet picked up by a worker.
    queued: AtomicUsize,
    accepted: AtomicU64,
    shed: AtomicU64,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeMetrics {
    /// Fresh, all-zero metrics.
    pub fn new() -> Self {
        ServeMetrics {
            endpoints: std::array::from_fn(|_| Histogram::new()),
            in_flight: AtomicUsize::new(0),
            queued: AtomicUsize::new(0),
            accepted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        }
    }

    fn endpoint(&self, path: &str) -> &Histogram {
        let idx = ENDPOINTS.iter().position(|&e| e == path).unwrap_or(ENDPOINTS.len() - 1);
        &self.endpoints[idx]
    }

    /// Record one served request's latency against its endpoint.
    pub fn observe(&self, path: &str, elapsed: Duration) {
        self.endpoint(path).observe(elapsed);
    }

    /// A connection entered the admission queue.
    pub fn enqueued(&self) {
        self.accepted.fetch_add(1, Ordering::Relaxed);
        self.queued.fetch_add(1, Ordering::Relaxed);
    }

    /// A worker took a connection off the queue; the guard holds the
    /// in-flight gauge up until the connection finishes.
    pub fn serving(&self) -> GaugeGuard<'_> {
        self.queued.fetch_sub(1, Ordering::Relaxed);
        self.in_flight.fetch_add(1, Ordering::Relaxed);
        GaugeGuard(&self.in_flight)
    }

    /// A connection was refused with a fast 503 (queue full).
    pub fn note_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Load-shed count so far.
    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Total requests observed across all endpoints.
    pub fn requests(&self) -> u64 {
        self.endpoints.iter().map(Histogram::count).sum()
    }

    /// Render the full `/metrics` document.
    pub fn render(&self, engine: &SurrogateEngine<'_>) -> Json {
        let endpoints = ENDPOINTS
            .iter()
            .zip(&self.endpoints)
            .map(|(&name, hist)| (name, hist.to_json()))
            .collect();
        let flushes = engine.flushes();
        let rows_flushed = engine.rows_flushed();
        let requested = engine.rows_requested();
        let hits = engine.memo_hits();
        Json::obj(vec![
            ("requests", Json::Num(self.requests() as f64)),
            ("endpoints", Json::obj(endpoints)),
            (
                "connections",
                Json::obj(vec![
                    ("accepted", Json::Num(self.accepted.load(Ordering::Relaxed) as f64)),
                    ("in_flight", Json::Num(self.in_flight.load(Ordering::Relaxed) as f64)),
                    ("queued", Json::Num(self.queued.load(Ordering::Relaxed) as f64)),
                    ("shed", Json::Num(self.shed_count() as f64)),
                ]),
            ),
            (
                "engine",
                Json::obj(vec![
                    ("flushes", Json::Num(flushes as f64)),
                    ("rows_flushed", Json::Num(rows_flushed as f64)),
                    (
                        "mean_flush_rows",
                        Json::Num(if flushes == 0 {
                            0.0
                        } else {
                            rows_flushed as f64 / flushes as f64
                        }),
                    ),
                    ("max_flush_rows", Json::Num(engine.max_flush_rows() as f64)),
                    ("rows_requested", Json::Num(requested as f64)),
                    ("memo_hits", Json::Num(hits as f64)),
                    (
                        "memo_hit_rate",
                        Json::Num(if requested == 0 { 0.0 } else { hits as f64 / requested as f64 }),
                    ),
                    ("surrogate_executions", Json::Num(engine.predictor().executions() as f64)),
                    ("memo_rows", Json::Num(engine.predictor().cache_len() as f64)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_are_conservative_bucket_bounds() {
        let h = Histogram::new();
        assert_eq!(h.quantile_ms(0.5), 0.0, "empty histogram reports zero");
        for _ in 0..99 {
            h.observe(Duration::from_micros(80)); // second bucket (≤100µs)
        }
        h.observe(Duration::from_millis(40)); // ≤50ms bucket
        assert_eq!(h.count(), 100);
        assert_eq!(h.quantile_ms(0.5), 0.1, "p50 lands in the ≤100µs bucket");
        assert_eq!(h.quantile_ms(0.99), 0.1);
        assert_eq!(h.quantile_ms(1.0), 50.0, "max lands in the ≤50ms bucket");
        assert!(h.mean_ms() > 0.0);

        // overflow bucket: far past the last bound
        let h = Histogram::new();
        h.observe(Duration::from_secs(2));
        assert_eq!(h.quantile_ms(0.5), 1_000.0, "overflow reports 4x the last bound");
    }

    #[test]
    fn gauges_and_counters_track_connection_lifecycles() {
        let m = ServeMetrics::new();
        m.enqueued();
        m.enqueued();
        let guard = m.serving();
        assert_eq!(m.queued.load(Ordering::Relaxed), 1);
        assert_eq!(m.in_flight.load(Ordering::Relaxed), 1);
        drop(guard);
        assert_eq!(m.in_flight.load(Ordering::Relaxed), 0);
        m.note_shed();
        assert_eq!(m.shed_count(), 1);
        m.observe("/estimate", Duration::from_micros(300));
        m.observe("/nope", Duration::from_micros(300)); // lands in `other`
        assert_eq!(m.requests(), 2);
        assert_eq!(m.endpoint("/estimate").count(), 1);
        assert_eq!(m.endpoint("anything-unknown").count(), 1);
    }
}
