//! Serving observability: the serve-side instrument set behind
//! `GET /metrics`, built on the unified [`crate::telemetry::registry`].
//!
//! `ServeMetrics` owns a [`Registry`] instance and records through `Arc`
//! handles — connection workers hit relaxed atomics on the request path
//! with no shared lock, exactly as before the registry refactor, and the
//! rendered `/metrics` document keeps its original schema. The registry
//! itself can additionally be attached to the trace exporter
//! ([`crate::telemetry::attach_registry`]), so a traced serving run's
//! `trace.json` snapshots the same instruments `/metrics` serves.

use std::sync::Arc;
use std::time::Duration;

use crate::serve::SurrogateEngine;
use crate::telemetry::registry::{Counter, Gauge, GaugeGuard, Histogram, Registry};
use crate::util::Json;

/// The endpoints tracked individually; everything else lands in `other`.
const ENDPOINTS: [&str; 6] =
    ["/healthz", "/metrics", "/estimate", "/estimate/batch", "/shutdown", "other"];

/// All serving metrics, shared by reference across connection workers.
pub struct ServeMetrics {
    registry: Arc<Registry>,
    endpoints: [Arc<Histogram>; ENDPOINTS.len()],
    /// Connections currently being served by a worker.
    in_flight: Arc<Gauge>,
    /// Connections accepted but not yet picked up by a worker.
    queued: Arc<Gauge>,
    accepted: Arc<Counter>,
    shed: Arc<Counter>,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeMetrics {
    /// Fresh, all-zero metrics on a private registry instance.
    pub fn new() -> Self {
        let registry = Arc::new(Registry::new());
        let endpoints = std::array::from_fn(|i| registry.histogram(ENDPOINTS[i]));
        ServeMetrics {
            endpoints,
            in_flight: registry.gauge("in_flight"),
            queued: registry.gauge("queued"),
            accepted: registry.counter("accepted"),
            shed: registry.counter("shed"),
            registry,
        }
    }

    /// The backing registry (attach it to the trace exporter so the
    /// Chrome-trace metadata carries the same instrument snapshot).
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    fn endpoint(&self, path: &str) -> &Histogram {
        let idx = ENDPOINTS.iter().position(|&e| e == path).unwrap_or(ENDPOINTS.len() - 1);
        &self.endpoints[idx]
    }

    /// Record one served request's latency against its endpoint.
    pub fn observe(&self, path: &str, elapsed: Duration) {
        self.endpoint(path).observe(elapsed);
    }

    /// A connection entered the admission queue.
    pub fn enqueued(&self) {
        self.accepted.inc();
        self.queued.inc();
    }

    /// A worker took a connection off the queue; the guard holds the
    /// in-flight gauge up until the connection finishes.
    pub fn serving(&self) -> GaugeGuard<'_> {
        self.queued.dec();
        self.in_flight.guard()
    }

    /// A connection was refused with a fast 503 (queue full).
    pub fn note_shed(&self) {
        self.shed.inc();
    }

    /// Load-shed count so far.
    pub fn shed_count(&self) -> u64 {
        self.shed.get()
    }

    /// Total requests observed across all endpoints.
    pub fn requests(&self) -> u64 {
        self.endpoints.iter().map(|h| h.count()).sum()
    }

    /// Render the full `/metrics` document.
    pub fn render(&self, engine: &SurrogateEngine<'_>) -> Json {
        let endpoints = ENDPOINTS
            .iter()
            .zip(&self.endpoints)
            .map(|(&name, hist)| (name, hist.to_json()))
            .collect();
        let flushes = engine.flushes();
        let rows_flushed = engine.rows_flushed();
        let requested = engine.rows_requested();
        let hits = engine.memo_hits();
        Json::obj(vec![
            ("requests", Json::Num(self.requests() as f64)),
            ("endpoints", Json::obj(endpoints)),
            (
                "connections",
                Json::obj(vec![
                    ("accepted", Json::Num(self.accepted.get() as f64)),
                    ("in_flight", Json::Num(self.in_flight.get() as f64)),
                    ("queued", Json::Num(self.queued.get() as f64)),
                    ("shed", Json::Num(self.shed_count() as f64)),
                ]),
            ),
            (
                "engine",
                Json::obj(vec![
                    ("flushes", Json::Num(flushes as f64)),
                    ("rows_flushed", Json::Num(rows_flushed as f64)),
                    (
                        "mean_flush_rows",
                        Json::Num(if flushes == 0 {
                            0.0
                        } else {
                            rows_flushed as f64 / flushes as f64
                        }),
                    ),
                    ("max_flush_rows", Json::Num(engine.max_flush_rows() as f64)),
                    ("rows_requested", Json::Num(requested as f64)),
                    ("memo_hits", Json::Num(hits as f64)),
                    (
                        "memo_hit_rate",
                        Json::Num(if requested == 0 { 0.0 } else { hits as f64 / requested as f64 }),
                    ),
                    ("surrogate_executions", Json::Num(engine.predictor().executions() as f64)),
                    ("memo_rows", Json::Num(engine.predictor().cache_len() as f64)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gauges_and_counters_track_connection_lifecycles() {
        let m = ServeMetrics::new();
        m.enqueued();
        m.enqueued();
        let guard = m.serving();
        assert_eq!(m.queued.get(), 1);
        assert_eq!(m.in_flight.get(), 1);
        drop(guard);
        assert_eq!(m.in_flight.get(), 0);
        m.note_shed();
        assert_eq!(m.shed_count(), 1);
        m.observe("/estimate", Duration::from_micros(300));
        m.observe("/nope", Duration::from_micros(300)); // lands in `other`
        assert_eq!(m.requests(), 2);
        assert_eq!(m.endpoint("/estimate").count(), 1);
        assert_eq!(m.endpoint("anything-unknown").count(), 1);
    }

    /// The registry view and the direct handles agree — `/metrics` and
    /// the trace exporter read one source of truth.
    #[test]
    fn registry_snapshot_matches_the_handles() {
        let m = ServeMetrics::new();
        m.enqueued();
        m.observe("/estimate", Duration::from_micros(80));
        let snap = m.registry().to_json();
        assert_eq!(
            snap.get("counters").and_then(|c| c.get("accepted")).and_then(Json::as_usize),
            Some(1)
        );
        assert_eq!(
            snap.get("gauges").and_then(|g| g.get("queued")).and_then(Json::as_usize),
            Some(1)
        );
        assert_eq!(
            snap.get("histograms")
                .and_then(|h| h.get("/estimate"))
                .and_then(|e| e.get("count"))
                .and_then(Json::as_usize),
            Some(1)
        );
    }
}
