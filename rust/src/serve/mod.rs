//! The estimation service: SNAC-Pack's trained surrogate as a
//! first-class serving surface.
//!
//! The search consumes surrogate estimates in-process; this subsystem
//! exposes the same predictor to everything else — CI smoke clients,
//! external tooling, future design-space dashboards — as a std-only
//! HTTP/1.1 JSON service (`snac-pack serve`):
//!
//! * `GET  /healthz` — liveness + batching/cache counters;
//! * `GET  /metrics` — request-latency histograms per endpoint,
//!   connection gauges, flush sizes, memo hit rate, shed count;
//! * `POST /estimate` — one genome (or raw feature vector) →
//!   [`ResourceEstimate`] + `avg_resources` on the serving device;
//! * `POST /estimate/batch` — `{"requests": [...]}` → `{"results": [...]}`;
//! * `POST /shutdown` — drain and exit cleanly.
//!
//! Connections are persistent (`Connection: keep-alive`, with an idle
//! timeout) and served by a **fixed-size worker pool** fed from a
//! **bounded admission queue**: the accept loop never spawns, and when
//! every worker is busy and the queue is full it sheds the connection
//! with a fast `503` instead of letting latency grow without bound
//! ([`ServeTuning`] holds the knobs). Workers block on the shared
//! [`SurrogateEngine`] (`serve/engine.rs`), which coalesces all
//! concurrent requests into full `SUR_BATCH`-row interpreter executions
//! and shares the predictor's memo cache — so the service returns
//! bit-identical numbers to an in-process
//! [`SurrogatePredictor`](crate::surrogate::SurrogatePredictor) call
//! for the same inputs, at batch throughput under concurrency.
//!
//! One sizing caveat worth knowing: a keep-alive connection owns its
//! worker until it closes or idles out, so `pool_size` bounds the
//! number of *concurrently connected* keep-alive clients, not just
//! concurrent requests. Size the pool for the client fleet, or have
//! clients close when done (the one-shot [`http::request`] path does).
//!
//! Request schema (`POST /estimate`; batch wraps a list of these):
//!
//! ```json
//! {"genome": {"n_layers": 4, "width_idx": [0,0,0,0,0,0,0,0], "act": 0,
//!             "batch_norm": true, "lr_idx": 0, "l1_idx": 0, "dropout_idx": 0},
//!  "bits": 8, "sparsity": 0.5}
//! ```
//!
//! `bits`/`sparsity` default to the preset's deployment point; a raw
//! `{"features": [72 floats]}` body bypasses genome encoding entirely.

pub mod engine;
pub mod metrics;
/// HTTP framing now lives in the shared [`crate::net`] module (the TCP
/// shard transport speaks the same wire format); re-exported here so
/// `serve::http::request` keeps working for clients and tests.
pub use crate::net as http;

use std::collections::VecDeque;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

pub use engine::{EngineConfig, SurrogateEngine};
pub use metrics::ServeMetrics;

use crate::eval::lock_unpoisoned;
use crate::hls::FpgaDevice;
use crate::nn::{Genome, SearchSpace, NUM_LAYERS, SUR_BATCH, SUR_FEATS};
use crate::surrogate::{genome_features, ResourceEstimate};
use crate::util::Json;

/// Everything a connection handler needs, shared by reference across
/// the connection threads.
pub struct ServeContext<'a> {
    /// The micro-batching engine (a flusher thread must be running —
    /// [`serve`] owns that).
    pub engine: &'a SurrogateEngine<'a>,
    /// Search space genomes are validated against.
    pub space: &'a SearchSpace,
    /// Device utilisation percentages are computed for.
    pub device: &'a FpgaDevice,
    /// Default deployment precision when a request omits `bits`.
    pub bits: u32,
    /// Default deployment sparsity when a request omits `sparsity`.
    pub sparsity: f64,
    /// Runtime platform name (health diagnostics).
    pub platform: String,
    /// Request/connection observability, rendered by `GET /metrics`.
    pub metrics: ServeMetrics,
}

impl ServeContext<'_> {
    /// Decode one estimate-request object into a surrogate feature
    /// vector (either a validated genome at a deployment point, or a raw
    /// `SUR_FEATS`-long feature list).
    fn features_of(&self, j: &Json) -> Result<Vec<f32>> {
        if let Some(f) = j.get("features") {
            let items = f.items();
            let vals: Vec<f32> = items.iter().filter_map(Json::as_f64).map(|v| v as f32).collect();
            anyhow::ensure!(
                vals.len() == items.len() && vals.len() == SUR_FEATS,
                "`features` must be {SUR_FEATS} numbers, got {}",
                items.len()
            );
            return Ok(vals);
        }
        let g = j.get("genome").context("request needs a `genome` object or a `features` array")?;
        // the shared trial-db codec is deliberately lenient (it clamps
        // `act` and zero-fills a short `width_idx`); a *request* with
        // such values must 400 rather than silently describe a different
        // architecture, so check the raw JSON before decoding
        let act = g
            .get("act")
            .and_then(Json::as_f64)
            .context("genome `act` must be a number")?;
        anyhow::ensure!(
            act.fract() == 0.0 && (0.0..=2.0).contains(&act),
            "genome `act` must be an integer in 0..=2, got {act}"
        );
        let widths = g.get("width_idx").context("genome missing `width_idx`")?.items().len();
        anyhow::ensure!(
            widths == NUM_LAYERS,
            "genome `width_idx` must have {NUM_LAYERS} entries, got {widths}"
        );
        let genome = Genome::from_json(g).context("parsing `genome`")?;
        validate_genome(&genome, self.space)?;
        // validate the raw value before any narrowing conversion: a
        // fractional or out-of-range `bits` must 400, not silently round
        // or wrap to a different deployment point
        let bits = match j.get("bits") {
            None => self.bits,
            Some(b) => {
                let v = b.as_f64().context("`bits` must be a number")?;
                anyhow::ensure!(
                    v.fract() == 0.0 && (1.0..=32.0).contains(&v),
                    "`bits` must be an integer in 1..=32, got {v}"
                );
                v as u32
            }
        };
        let sparsity = j
            .get("sparsity")
            .map(|s| s.as_f64().context("`sparsity` must be a number"))
            .transpose()?
            .unwrap_or(self.sparsity);
        anyhow::ensure!(
            (0.0..=1.0).contains(&sparsity),
            "`sparsity` must be in [0, 1], got {sparsity}"
        );
        Ok(genome_features(&genome, self.space, bits, sparsity))
    }

    /// Decode an `/estimate/batch` body into its feature vectors.
    fn batch_features(&self, j: &Json) -> Result<Vec<Vec<f32>>> {
        let reqs = j.get("requests").context("batch body needs a `requests` array")?;
        anyhow::ensure!(matches!(reqs, Json::Arr(_)), "`requests` must be an array");
        reqs.items().iter().map(|r| self.features_of(r)).collect()
    }

    /// Serialise one estimate for the wire.
    fn estimate_json(&self, est: &ResourceEstimate) -> Json {
        Json::obj(vec![
            ("bram", Json::Num(est.bram)),
            ("dsp", Json::Num(est.dsp)),
            ("ff", Json::Num(est.ff)),
            ("lut", Json::Num(est.lut)),
            ("latency_cc", Json::Num(est.latency_cc)),
            ("ii_cc", Json::Num(est.ii_cc)),
            ("avg_resources", Json::Num(est.avg_resources(self.device))),
        ])
    }
}

/// Reject genomes whose indices fall outside the serving search space
/// before they can panic a feature encoder.
fn validate_genome(g: &Genome, space: &SearchSpace) -> Result<()> {
    anyhow::ensure!(
        space.depth_choices.contains(&g.n_layers),
        "genome depth {} is outside the search space {:?}",
        g.n_layers,
        space.depth_choices
    );
    for i in 0..NUM_LAYERS {
        anyhow::ensure!(
            g.width_idx[i] < space.width_choices[i].len(),
            "genome width_idx[{i}] = {} is out of range (layer has {} choices)",
            g.width_idx[i],
            space.width_choices[i].len()
        );
    }
    anyhow::ensure!(g.lr_idx < space.lr_choices.len(), "lr_idx out of range");
    anyhow::ensure!(g.l1_idx < space.l1_choices.len(), "l1_idx out of range");
    anyhow::ensure!(g.dropout_idx < space.dropout_choices.len(), "dropout_idx out of range");
    Ok(())
}

/// Outcome of one request: status, JSON body, and whether the server
/// should stop accepting after responding.
struct Handled {
    status: u16,
    body: Json,
    shutdown: bool,
}

fn ok(body: Json) -> Handled {
    Handled {
        status: 200,
        body,
        shutdown: false,
    }
}

fn error(status: u16, msg: impl std::fmt::Display) -> Handled {
    Handled {
        status,
        body: Json::obj(vec![("error", Json::Str(msg.to_string()))]),
        shutdown: false,
    }
}

/// Route one parsed request. Pure except for the engine call, so the
/// endpoint semantics are unit-testable without sockets.
fn handle(ctx: &ServeContext<'_>, req: &http::Request) -> Handled {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => ok(Json::obj(vec![
            ("status", Json::Str("ok".to_string())),
            ("platform", Json::Str(ctx.platform.clone())),
            ("device", Json::Str(ctx.device.name.clone())),
            ("sur_batch", Json::Num(SUR_BATCH as f64)),
            (
                "plan_verifier",
                Json::Str(if xla::verify_plans() { "on" } else { "off" }.to_string()),
            ),
            ("flushes", Json::Num(ctx.engine.flushes() as f64)),
            ("rows_flushed", Json::Num(ctx.engine.rows_flushed() as f64)),
            (
                "surrogate_executions",
                Json::Num(ctx.engine.predictor().executions() as f64),
            ),
            ("memo_rows", Json::Num(ctx.engine.predictor().cache_len() as f64)),
        ])),
        ("POST", "/estimate") => {
            let parsed = Json::parse(&req.body)
                .map_err(anyhow::Error::msg)
                .and_then(|j| ctx.features_of(&j));
            match parsed {
                Err(e) => error(400, format!("{e:#}")),
                Ok(feats) => match ctx.engine.estimate(&feats) {
                    Ok(est) => ok(ctx.estimate_json(&est)),
                    Err(e) => error(500, format!("{e:#}")),
                },
            }
        }
        ("POST", "/estimate/batch") => {
            let parsed = Json::parse(&req.body)
                .map_err(anyhow::Error::msg)
                .and_then(|j| ctx.batch_features(&j));
            match parsed {
                Err(e) => error(400, format!("{e:#}")),
                Ok(feats) => match ctx.engine.estimate_many(&feats) {
                    Ok(ests) => ok(Json::obj(vec![(
                        "results",
                        Json::Arr(ests.iter().map(|e| ctx.estimate_json(e)).collect()),
                    )])),
                    Err(e) => error(500, format!("{e:#}")),
                },
            }
        }
        ("GET", "/metrics") => ok(ctx.metrics.render(ctx.engine)),
        ("POST", "/shutdown") => Handled {
            status: 200,
            body: Json::obj(vec![("status", Json::Str("shutting down".to_string()))]),
            shutdown: true,
        },
        (_, "/healthz") | (_, "/metrics") | (_, "/estimate") | (_, "/estimate/batch")
        | (_, "/shutdown") => error(405, format!("method {} not allowed here", req.method)),
        (_, path) => error(404, format!("no such endpoint `{path}`")),
    }
}

/// Concurrency and keep-alive knobs for [`serve`].
#[derive(Debug, Clone)]
pub struct ServeTuning {
    /// Connection worker threads (`--pool-size`; 0 = auto-size to the
    /// machine's available parallelism, clamped to a sane band).
    pub pool_size: usize,
    /// Accepted connections allowed to wait for a worker before the
    /// accept loop sheds with `503` (`--queue-depth`; 0 = 4x the pool).
    pub queue_depth: usize,
    /// How long a keep-alive connection may sit idle between requests
    /// before the server closes it.
    pub idle_timeout: Duration,
}

impl Default for ServeTuning {
    fn default() -> Self {
        ServeTuning { pool_size: 0, queue_depth: 0, idle_timeout: Duration::from_secs(30) }
    }
}

impl ServeTuning {
    /// The worker count after auto-sizing.
    pub fn resolved_pool(&self) -> usize {
        if self.pool_size > 0 {
            return self.pool_size;
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).clamp(2, 32)
    }

    /// The queue capacity after auto-sizing.
    pub fn resolved_depth(&self) -> usize {
        if self.queue_depth > 0 {
            return self.queue_depth;
        }
        4 * self.resolved_pool()
    }
}

/// The bounded admission queue between the accept loop and the worker
/// pool.
struct ConnQueue {
    inner: Mutex<ConnQueueState>,
    ready: Condvar,
    capacity: usize,
}

struct ConnQueueState {
    waiting: VecDeque<TcpStream>,
    closed: bool,
}

impl ConnQueue {
    fn new(capacity: usize) -> Self {
        ConnQueue {
            inner: Mutex::new(ConnQueueState { waiting: VecDeque::new(), closed: false }),
            ready: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Admit a connection, or hand it back when the queue is full (the
    /// caller sheds it) or closed.
    fn push(&self, stream: TcpStream) -> Option<TcpStream> {
        let mut st = lock_unpoisoned(&self.inner);
        if st.closed || st.waiting.len() >= self.capacity {
            return Some(stream);
        }
        st.waiting.push_back(stream);
        drop(st);
        self.ready.notify_one();
        None
    }

    /// Block for the next connection; `None` once the queue is closed
    /// *and* drained (workers finish queued connections on shutdown).
    fn pop(&self) -> Option<TcpStream> {
        let mut st = lock_unpoisoned(&self.inner);
        loop {
            if let Some(stream) = st.waiting.pop_front() {
                return Some(stream);
            }
            if st.closed {
                return None;
            }
            st = self.ready.wait(st).unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }

    fn close(&self) {
        lock_unpoisoned(&self.inner).closed = true;
        self.ready.notify_all();
    }
}

/// Serve one connection for its whole life: many requests per socket
/// until the peer closes, asks for `Connection: close`, idles out, or
/// the server is stopping.
fn handle_connection(
    ctx: &ServeContext<'_>,
    stream: TcpStream,
    stop: &AtomicBool,
    idle_timeout: Duration,
) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(idle_timeout.max(Duration::from_millis(1))));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
    let _ = stream.set_nodelay(true);
    let mut reader = http::RequestReader::new(&stream);
    loop {
        let (handled, requested_keep) = match reader.next_request() {
            Ok(req) => {
                let t0 = Instant::now();
                let handled = handle(ctx, &req);
                ctx.metrics.observe(&req.path, t0.elapsed());
                (handled, req.keep_alive)
            }
            Err(e) => {
                // a clean close or idle expiry between requests is the
                // normal end of a keep-alive connection; a framing fault
                // gets a best-effort 400 (the peer may already be gone)
                if !http::quiet_close(&e) {
                    let body =
                        Json::obj(vec![("error", Json::Str(format!("{e:#}")))]).to_string();
                    let mut w = &stream;
                    let _ = http::write_response(&mut w, 400, &body, false);
                }
                return;
            }
        };
        if handled.shutdown {
            stop.store(true, Ordering::SeqCst);
        }
        let keep = requested_keep && !handled.shutdown && !stop.load(Ordering::SeqCst);
        let mut w = &stream;
        if http::write_response(&mut w, handled.status, &handled.body.to_string(), keep).is_err()
            || !keep
        {
            return;
        }
    }
}

/// A worker: pull connections off the admission queue until it closes.
fn worker_loop(ctx: &ServeContext<'_>, queue: &ConnQueue, stop: &AtomicBool, idle: Duration) {
    while let Some(stream) = queue.pop() {
        let _guard = ctx.metrics.serving();
        handle_connection(ctx, stream, stop, idle);
    }
}

/// Refuse a connection with a fast `503` — the admission queue is full
/// and letting it wait would only grow tail latency unbounded.
fn shed(ctx: &ServeContext<'_>, stream: TcpStream) {
    ctx.metrics.note_shed();
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
    let body = Json::obj(vec![(
        "error",
        Json::Str("server saturated: worker pool and admission queue are full; retry".to_string()),
    )])
    .to_string();
    let mut w = &stream;
    let _ = http::write_response(&mut w, 503, &body, false);
}

/// Run the service on an already-bound listener until a client POSTs
/// `/shutdown`. Owns the whole lifecycle: spawns the engine's flusher
/// and a fixed-size worker pool, admits connections through a bounded
/// queue (shedding with `503` when full), and drains the queue and the
/// engine on the way out. Returns once every admitted connection has
/// been served.
pub fn serve(ctx: &ServeContext<'_>, listener: TcpListener, tuning: &ServeTuning) -> Result<()> {
    listener.set_nonblocking(true).context("setting the listener non-blocking")?;
    let stop = AtomicBool::new(false);
    let stop = &stop;
    let queue = ConnQueue::new(tuning.resolved_depth());
    let queue = &queue;
    let idle = tuning.idle_timeout;
    std::thread::scope(|s| -> Result<()> {
        s.spawn(|| ctx.engine.run_flusher());
        let workers: Vec<_> = (0..tuning.resolved_pool())
            .map(|_| s.spawn(|| worker_loop(ctx, queue, stop, idle)))
            .collect();
        // transient accept() errors (ECONNABORTED from a client RST in
        // the backlog, EMFILE under a connection burst, EINTR) must not
        // take the whole service down; only a persistently failing
        // listener is fatal
        let mut accept_errors = 0usize;
        const MAX_CONSECUTIVE_ACCEPT_ERRORS: usize = 100;
        let result = loop {
            if stop.load(Ordering::SeqCst) {
                break Ok(());
            }
            match listener.accept() {
                Ok((stream, _peer)) => {
                    accept_errors = 0;
                    match queue.push(stream) {
                        None => ctx.metrics.enqueued(),
                        Some(refused) => shed(ctx, refused),
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => {
                    accept_errors += 1;
                    if accept_errors >= MAX_CONSECUTIVE_ACCEPT_ERRORS {
                        break Err(anyhow::Error::from(e)
                            .context("accept failing persistently — listener unusable"));
                    }
                    eprintln!(
                        "[serve] accept error ({accept_errors}/{MAX_CONSECUTIVE_ACCEPT_ERRORS}, \
                         retrying): {e}"
                    );
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        };
        // drain: workers finish every admitted connection before the
        // engine stops, so queued requests still get real answers
        queue.close();
        for w in workers {
            let _ = w.join();
        }
        ctx.engine.shutdown();
        result
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::surrogate::predictor::test_support::{predictor, runtime};
    use crate::util::Rng;

    fn genome_request(g: &Genome, bits: u32, sparsity: f64) -> String {
        Json::obj(vec![
            ("genome", g.to_json()),
            ("bits", Json::Num(bits as f64)),
            ("sparsity", Json::Num(sparsity)),
        ])
        .to_string()
    }

    fn f64_field(j: &Json, k: &str) -> f64 {
        j.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN)
    }

    /// Full loopback round trip: concurrent mixed single/batch/raw-
    /// feature requests against a live server return estimates exactly
    /// equal to a direct `SurrogatePredictor` call, and `/shutdown`
    /// stops the server cleanly.
    #[test]
    fn server_matches_the_inprocess_predictor() {
        let rt = runtime();
        let sur = predictor(&rt);
        let engine = SurrogateEngine::new(
            &sur,
            EngineConfig {
                deadline: Duration::from_millis(5),
                max_rows: SUR_BATCH,
            },
        );
        let space = SearchSpace::table1();
        let device = FpgaDevice::vu13p();
        let ctx = ServeContext {
            engine: &engine,
            space: &space,
            device: &device,
            bits: 8,
            sparsity: 0.5,
            platform: rt.platform(),
            metrics: ServeMetrics::new(),
        };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();

        // independent reference predictor with the same params
        let reference = predictor(&rt);
        let mut rng = Rng::new(7);
        let genomes: Vec<Genome> = (0..6).map(|_| space.sample(&mut rng)).collect();

        let ctx_ref = &ctx;
        let addr_ref = addr.as_str();
        let tuning = ServeTuning::default();
        std::thread::scope(|s| {
            let server = s.spawn(|| serve(ctx_ref, listener, &tuning));

            // health first (also waits out any accept-loop startup)
            let (status, body) = http::request(addr_ref, "GET", "/healthz", None).unwrap();
            assert_eq!(status, 200, "{body}");
            let health = Json::parse(&body).unwrap();
            assert_eq!(health.get("status").and_then(Json::as_str), Some("ok"));
            assert_eq!(f64_field(&health, "sur_batch") as usize, SUR_BATCH);
            // test builds carry debug_assertions, so the static plan
            // verifier is unconditionally on
            assert_eq!(health.get("plan_verifier").and_then(Json::as_str), Some("on"));

            // concurrent single-genome estimates
            let singles: Vec<_> = genomes
                .iter()
                .map(|g| {
                    s.spawn(move || {
                        http::request(
                            addr_ref,
                            "POST",
                            "/estimate",
                            Some(&genome_request(g, 8, 0.5)),
                        )
                        .unwrap()
                    })
                })
                .collect();
            // ... racing a batch estimate of the same genomes plus a raw
            // feature-vector request
            let batch_body = Json::obj(vec![(
                "requests",
                Json::Arr(
                    genomes.iter().map(|g| Json::obj(vec![("genome", g.to_json())])).collect(),
                ),
            )])
            .to_string();
            let batch = s.spawn(move || {
                http::request(addr_ref, "POST", "/estimate/batch", Some(&batch_body)).unwrap()
            });
            let raw_feats = genome_features(&genomes[0], &space, 8, 0.5);
            let raw_body = Json::obj(vec![(
                "features",
                Json::nums(raw_feats.iter().map(|&v| v as f64)),
            )])
            .to_string();
            let raw = s.spawn(move || {
                http::request(addr_ref, "POST", "/estimate", Some(&raw_body)).unwrap()
            });

            for (g, handle) in genomes.iter().zip(singles) {
                let (status, body) = handle.join().unwrap();
                assert_eq!(status, 200, "{body}");
                let j = Json::parse(&body).unwrap();
                let want = reference.predict(g, &space, 8, 0.5).unwrap();
                assert_eq!(f64_field(&j, "lut"), want.lut);
                assert_eq!(f64_field(&j, "latency_cc"), want.latency_cc);
                assert_eq!(f64_field(&j, "avg_resources"), want.avg_resources(&device));
            }
            let (status, body) = batch.join().unwrap();
            assert_eq!(status, 200, "{body}");
            let results = Json::parse(&body).unwrap();
            let results = results.get("results").unwrap().items();
            assert_eq!(results.len(), genomes.len());
            for (g, j) in genomes.iter().zip(results) {
                let want = reference.predict(g, &space, 8, 0.5).unwrap();
                assert_eq!(f64_field(j, "dsp"), want.dsp);
                assert_eq!(f64_field(j, "ff"), want.ff);
            }
            let (status, body) = raw.join().unwrap();
            assert_eq!(status, 200, "{body}");
            let j = Json::parse(&body).unwrap();
            let want = reference.predict(&genomes[0], &space, 8, 0.5).unwrap();
            assert_eq!(f64_field(&j, "bram"), want.bram);

            // a keep-alive client sees identical numbers over one
            // persistent connection
            let mut ka = http::HttpClient::new(addr_ref.to_string(), Duration::from_secs(30));
            for g in &genomes {
                let (status, body) =
                    ka.request("POST", "/estimate", Some(&genome_request(g, 8, 0.5))).unwrap();
                assert_eq!(status, 200, "{body}");
                let j = Json::parse(&body).unwrap();
                let want = reference.predict(g, &space, 8, 0.5).unwrap();
                assert_eq!(f64_field(&j, "lut"), want.lut);
                assert_eq!(f64_field(&j, "ii_cc"), want.ii_cc);
            }
            drop(ka);

            // /metrics reflects the traffic served so far
            let (status, body) = http::request(addr_ref, "GET", "/metrics", None).unwrap();
            assert_eq!(status, 200, "{body}");
            let m = Json::parse(&body).unwrap();
            assert!(f64_field(&m, "requests") >= 2.0 * genomes.len() as f64, "{body}");
            let est = m.get("endpoints").and_then(|e| e.get("/estimate")).unwrap().clone();
            assert!(f64_field(&est, "count") >= genomes.len() as f64, "{body}");
            assert!(f64_field(&est, "p99_ms") >= f64_field(&est, "p50_ms"), "{body}");
            let eng = m.get("engine").unwrap().clone();
            // the keep-alive pass re-requested rows the first pass
            // computed, so some submissions were pure memo hits
            assert!(f64_field(&eng, "memo_hit_rate") > 0.0, "{body}");
            assert!(f64_field(&eng, "rows_requested") >= f64_field(&eng, "rows_flushed"), "{body}");

            // clean shutdown
            let (status, _) = http::request(addr_ref, "POST", "/shutdown", None).unwrap();
            assert_eq!(status, 200);
            server.join().unwrap().unwrap();
        });
        // the engine coalesced: far fewer executions than requests
        assert!(sur.executions() >= 1);
        assert!(
            sur.executions() <= 2 * genomes.len(),
            "executions stay bounded by unique rows, got {}",
            sur.executions()
        );
    }

    /// Endpoint error semantics (no sockets needed for these framings).
    #[test]
    fn handler_rejects_bad_requests() {
        let rt = runtime();
        let sur = predictor(&rt);
        let engine = SurrogateEngine::new(&sur, EngineConfig::default());
        let space = SearchSpace::table1();
        let device = FpgaDevice::vu13p();
        let ctx = ServeContext {
            engine: &engine,
            space: &space,
            device: &device,
            bits: 8,
            sparsity: 0.5,
            platform: "test".to_string(),
            metrics: ServeMetrics::new(),
        };
        let post = |path: &str, body: &str| {
            handle(
                &ctx,
                &http::Request {
                    method: "POST".to_string(),
                    path: path.to_string(),
                    body: body.to_string(),
                    keep_alive: true,
                    bearer: None,
                    trace: None,
                },
            )
        };
        // malformed JSON, missing keys, wrong feature arity
        assert_eq!(post("/estimate", "{nope").status, 400);
        assert_eq!(post("/estimate", "{}").status, 400);
        assert_eq!(post("/estimate", r#"{"features": [1, 2, 3]}"#).status, 400);
        assert_eq!(post("/estimate/batch", r#"{"requests": 3}"#).status, 400);
        // an out-of-space genome is a 400, not a panic
        let mut g = space.baseline();
        g.width_idx[0] = 99;
        assert_eq!(post("/estimate", &genome_request(&g, 8, 0.5)).status, 400);
        let mut g = space.baseline();
        g.n_layers = 99;
        assert_eq!(post("/estimate", &genome_request(&g, 8, 0.5)).status, 400);
        // bad deployment points: out-of-range, wrapping, and fractional
        // bits must all 400 rather than silently serve another precision
        let g = space.baseline();
        assert_eq!(post("/estimate", &genome_request(&g, 0, 0.5)).status, 400);
        assert_eq!(post("/estimate", &genome_request(&g, 8, 1.5)).status, 400);
        let wrap = Json::obj(vec![
            ("genome", g.to_json()),
            ("bits", Json::Num(4_294_967_304.0)), // would wrap to 8 as u32
        ])
        .to_string();
        assert_eq!(post("/estimate", &wrap).status, 400);
        let fractional = Json::obj(vec![
            ("genome", g.to_json()),
            ("bits", Json::Num(8.7)), // would round to 9 via as_usize
        ])
        .to_string();
        assert_eq!(post("/estimate", &fractional).status, 400);
        // the lenient trial-db genome codec must not leak into requests:
        // an out-of-range `act` (from_json would clamp it to Sigmoid) and
        // a short `width_idx` (would zero-fill) are 400s, not silently
        // different architectures
        let mut bad_act = g.to_json();
        if let Json::Obj(m) = &mut bad_act {
            m.insert("act".to_string(), Json::Num(7.0));
        }
        let body = Json::obj(vec![("genome", bad_act)]).to_string();
        assert_eq!(post("/estimate", &body).status, 400);
        let mut short_widths = g.to_json();
        if let Json::Obj(m) = &mut short_widths {
            m.insert("width_idx".to_string(), Json::nums([0.0, 0.0].into_iter()));
        }
        let body = Json::obj(vec![("genome", short_widths)]).to_string();
        assert_eq!(post("/estimate", &body).status, 400);
        // unknown path / wrong method
        let miss = handle(
            &ctx,
            &http::Request {
                method: "GET".to_string(),
                path: "/nope".to_string(),
                body: String::new(),
                keep_alive: true,
                bearer: None,
                trace: None,
            },
        );
        assert_eq!(miss.status, 404);
        let wrong = handle(
            &ctx,
            &http::Request {
                method: "POST".to_string(),
                path: "/metrics".to_string(),
                body: String::new(),
                keep_alive: true,
                bearer: None,
                trace: None,
            },
        );
        assert_eq!(wrong.status, 405);
        let wrong = handle(
            &ctx,
            &http::Request {
                method: "GET".to_string(),
                path: "/estimate".to_string(),
                body: String::new(),
                keep_alive: true,
                bearer: None,
                trace: None,
            },
        );
        assert_eq!(wrong.status, 405);
        // an empty batch is fine and needs no flusher
        let empty = post("/estimate/batch", r#"{"requests": []}"#);
        assert_eq!(empty.status, 200);
        assert_eq!(empty.body.get("results").unwrap().items().len(), 0);
    }

    /// Admission control: with one worker and a one-deep queue, a third
    /// concurrent connection is shed with a fast `503` while the two
    /// admitted requests complete with estimates bit-identical to the
    /// reference predictor — saturation degrades availability, never
    /// correctness.
    #[test]
    fn saturated_queue_sheds_503_while_admitted_requests_complete() {
        let rt = runtime();
        let sur = predictor(&rt);
        // a long batching deadline pins the lone worker on the first
        // request while the queue fills behind it
        let engine = SurrogateEngine::new(
            &sur,
            EngineConfig { deadline: Duration::from_millis(1500), max_rows: SUR_BATCH },
        );
        let space = SearchSpace::table1();
        let device = FpgaDevice::vu13p();
        let ctx = ServeContext {
            engine: &engine,
            space: &space,
            device: &device,
            bits: 8,
            sparsity: 0.5,
            platform: rt.platform(),
            metrics: ServeMetrics::new(),
        };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let reference = predictor(&rt);
        let mut rng = Rng::new(11);
        let g1 = space.sample(&mut rng);
        let g2 = space.sample(&mut rng);
        let tuning =
            ServeTuning { pool_size: 1, queue_depth: 1, idle_timeout: Duration::from_secs(5) };

        let ctx_ref = &ctx;
        let addr_ref = addr.as_str();
        std::thread::scope(|s| {
            let server = s.spawn(|| serve(ctx_ref, listener, &tuning));

            // A occupies the lone worker for ~the batching deadline
            let body_a = genome_request(&g1, 8, 0.5);
            let a = s.spawn(move || {
                http::request(addr_ref, "POST", "/estimate", Some(&body_a)).unwrap()
            });
            std::thread::sleep(Duration::from_millis(400));
            // B fills the one queue slot
            let body_b = genome_request(&g2, 8, 0.5);
            let b = s.spawn(move || {
                http::request(addr_ref, "POST", "/estimate", Some(&body_b)).unwrap()
            });
            std::thread::sleep(Duration::from_millis(400));

            // C finds pool and queue full: fast 503, not a slow wait
            let t0 = Instant::now();
            let (status, body) = http::request(addr_ref, "GET", "/healthz", None).unwrap();
            assert_eq!(status, 503, "{body}");
            assert!(body.contains("saturated"), "{body}");
            assert!(
                t0.elapsed() < Duration::from_millis(1000),
                "load shedding must be immediate, took {:?}",
                t0.elapsed()
            );

            // the admitted requests still complete, bit-identical
            let (status, body) = a.join().unwrap();
            assert_eq!(status, 200, "{body}");
            let want = reference.predict(&g1, &space, 8, 0.5).unwrap();
            assert_eq!(f64_field(&Json::parse(&body).unwrap(), "lut"), want.lut);
            let (status, body) = b.join().unwrap();
            assert_eq!(status, 200, "{body}");
            let want = reference.predict(&g2, &space, 8, 0.5).unwrap();
            assert_eq!(f64_field(&Json::parse(&body).unwrap(), "lut"), want.lut);

            // the shed is visible on /metrics
            let (status, body) = http::request(addr_ref, "GET", "/metrics", None).unwrap();
            assert_eq!(status, 200, "{body}");
            let m = Json::parse(&body).unwrap();
            let conns = m.get("connections").unwrap().clone();
            assert!(f64_field(&conns, "shed") >= 1.0, "{body}");

            let (status, _) = http::request(addr_ref, "POST", "/shutdown", None).unwrap();
            assert_eq!(status, 200);
            server.join().unwrap().unwrap();
        });
    }
}
