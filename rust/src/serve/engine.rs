//! The micro-batching surrogate engine: coalesce concurrent estimate
//! requests into full `SUR_BATCH`-row interpreter executions.
//!
//! Callers (HTTP connection handlers, or anything else holding a
//! [`SurrogateEngine`]) submit one feature vector at a time and block
//! until their estimate is ready. The engine accumulates the pending
//! unique rows and a dedicated **flusher** (one thread running
//! [`run_flusher`](SurrogateEngine::run_flusher)) executes them through
//! [`SurrogatePredictor::predict_batch`] when either
//!
//! * the pending set reaches `max_rows` (flush-on-full), or
//! * the oldest pending row has waited `deadline` (flush-on-deadline).
//!
//! Requests whose feature vector is already memoised return immediately
//! without touching the batch; duplicate vectors submitted concurrently
//! collapse to one pending row whose result every waiter shares. Results
//! land in the predictor's memo cache (the same cache the search's
//! per-generation prefetch fills), so the engine and the search never
//! compute the same row twice between them — and because every path
//! bottoms out in `predict_batch`, the estimates are bit-identical to a
//! direct `SurrogatePredictor` call for the same inputs.

use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::eval::lock_unpoisoned;
use crate::nn::SUR_FEATS;
use crate::surrogate::predictor::feature_key;
use crate::surrogate::{ResourceEstimate, SurrogatePredictor};

/// Batching knobs.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Maximum time the first row of a batch waits before a partial
    /// flush (`--batch-deadline-ms`).
    pub deadline: Duration,
    /// Flush as soon as this many unique rows pend (defaults to
    /// `SUR_BATCH`, the interpreter's native batch).
    pub max_rows: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            deadline: Duration::from_millis(2),
            max_rows: crate::nn::SUR_BATCH,
        }
    }
}

/// What the flusher + waiting requesters share under one mutex.
struct EngineState {
    /// Unique feature rows accumulating toward the next flush.
    rows: Vec<Vec<f32>>,
    /// Keys of `rows` (intra-flush dedup).
    pending: HashSet<Vec<u32>>,
    /// Keys taken by the currently executing flush.
    in_flight: HashSet<Vec<u32>>,
    /// When the oldest pending row arrived (deadline anchor).
    first_at: Option<Instant>,
    /// Error of the most recent flush (`None` after a successful one),
    /// so waiters can attribute a missing memo row to their flush
    /// failing vs. eviction at the memo cap.
    last_error: Option<String>,
    /// Once set, new submissions are refused; the flusher drains the
    /// pending rows and exits.
    stopping: bool,
}

/// A micro-batching front over a shared [`SurrogatePredictor`].
///
/// Exactly one thread must run [`run_flusher`] while requests are being
/// submitted (the `serve` subsystem spawns it inside its connection
/// scope); without a flusher, [`estimate`] would block forever.
///
/// [`run_flusher`]: SurrogateEngine::run_flusher
/// [`estimate`]: SurrogateEngine::estimate
pub struct SurrogateEngine<'a> {
    predictor: &'a SurrogatePredictor<'a>,
    cfg: EngineConfig,
    state: Mutex<EngineState>,
    /// Wakes the flusher (new rows, or shutdown).
    submitted: Condvar,
    /// Wakes the requesters (a flush completed, or shutdown).
    completed: Condvar,
    flushes: AtomicUsize,
    rows_flushed: AtomicUsize,
    /// Rows ever submitted through [`estimate_many`](Self::estimate_many)
    /// (memo hits included) — the denominator of the memo hit rate.
    rows_requested: AtomicUsize,
    /// Rows answered straight from the memo at submit time, costing no
    /// batch slot.
    memo_hits: AtomicUsize,
    /// Largest single flush so far (how close traffic gets to the
    /// interpreter's native batch).
    max_flush_rows: AtomicUsize,
}

impl<'a> SurrogateEngine<'a> {
    /// New engine over a predictor.
    pub fn new(predictor: &'a SurrogatePredictor<'a>, cfg: EngineConfig) -> Self {
        SurrogateEngine {
            predictor,
            cfg,
            state: Mutex::new(EngineState {
                rows: Vec::new(),
                pending: HashSet::new(),
                in_flight: HashSet::new(),
                first_at: None,
                last_error: None,
                stopping: false,
            }),
            submitted: Condvar::new(),
            completed: Condvar::new(),
            flushes: AtomicUsize::new(0),
            rows_flushed: AtomicUsize::new(0),
            rows_requested: AtomicUsize::new(0),
            memo_hits: AtomicUsize::new(0),
            max_flush_rows: AtomicUsize::new(0),
        }
    }

    /// The predictor behind this engine (health diagnostics).
    pub fn predictor(&self) -> &SurrogatePredictor<'a> {
        self.predictor
    }

    /// Batches executed so far.
    pub fn flushes(&self) -> usize {
        self.flushes.load(Ordering::Relaxed)
    }

    /// Unique rows executed across all flushes so far.
    pub fn rows_flushed(&self) -> usize {
        self.rows_flushed.load(Ordering::Relaxed)
    }

    /// Rows ever submitted (memo hits included).
    pub fn rows_requested(&self) -> usize {
        self.rows_requested.load(Ordering::Relaxed)
    }

    /// Rows answered straight from the memo at submit time.
    pub fn memo_hits(&self) -> usize {
        self.memo_hits.load(Ordering::Relaxed)
    }

    /// Largest single flush so far.
    pub fn max_flush_rows(&self) -> usize {
        self.max_flush_rows.load(Ordering::Relaxed)
    }

    /// Estimate one feature vector, blocking until its flush completes
    /// (immediately on a memo hit).
    pub fn estimate(&self, feats: &[f32]) -> Result<ResourceEstimate> {
        Ok(self.estimate_many(std::slice::from_ref(&feats.to_vec()))?[0])
    }

    /// Estimate a batch of feature vectors in one submission: all rows
    /// join the pending set together (deduplicated against each other,
    /// the memo, and whatever else is pending), then the caller blocks
    /// until every row has resolved.
    pub fn estimate_many(&self, feats: &[Vec<f32>]) -> Result<Vec<ResourceEstimate>> {
        for f in feats {
            anyhow::ensure!(
                f.len() == SUR_FEATS,
                "feature vector has {} values, expected {SUR_FEATS}",
                f.len()
            );
        }
        let keys: Vec<Vec<u32>> = feats.iter().map(|f| feature_key(f)).collect();
        let mut out: Vec<Option<ResourceEstimate>> = vec![None; feats.len()];
        self.rows_requested.fetch_add(feats.len(), Ordering::Relaxed);

        // ---- submit ----
        {
            let mut st = lock_unpoisoned(&self.state);
            anyhow::ensure!(!st.stopping, "surrogate engine is shut down");
            let mut added = false;
            for (i, key) in keys.iter().enumerate() {
                // memo first (lock order is always state → memo): covered
                // rows never touch the batch
                if let Some(hit) = self.predictor.cached_by_key(key) {
                    out[i] = Some(hit);
                    self.memo_hits.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                // rows someone else already queued (or that are mid-
                // flush) are shared, not re-added
                if st.pending.contains(key) || st.in_flight.contains(key) {
                    continue;
                }
                st.pending.insert(key.clone());
                st.rows.push(feats[i].clone());
                st.first_at.get_or_insert_with(Instant::now);
                added = true;
            }
            if added {
                self.submitted.notify_one();
            }
        }

        // ---- await ----
        let mut st = lock_unpoisoned(&self.state);
        let mut resubmits = 0usize;
        loop {
            let mut waiting = false;
            for (i, key) in keys.iter().enumerate() {
                if out[i].is_some() {
                    continue;
                }
                if let Some(hit) = self.predictor.cached_by_key(key) {
                    out[i] = Some(hit);
                } else if st.pending.contains(key) || st.in_flight.contains(key) {
                    waiting = true;
                } else if st.stopping {
                    // the flusher may already have drained and exited; a
                    // resubmitted row would never flush
                    anyhow::bail!("surrogate estimate failed: engine shut down");
                } else if let Some(msg) = st.last_error.clone() {
                    // the row's flush failed (successful flushes clear
                    // the error, so this is at worst one flush stale)
                    anyhow::bail!("surrogate estimate failed: {msg}");
                } else {
                    // the row was committed but evicted at the memo cap
                    // before this waiter woke — resubmit it (bounded, so
                    // cap thrashing cannot loop forever)
                    anyhow::ensure!(
                        resubmits < 8,
                        "surrogate estimate evicted {resubmits} times — memo cap thrashing"
                    );
                    resubmits += 1;
                    st.pending.insert(key.clone());
                    st.rows.push(feats[i].clone());
                    st.first_at.get_or_insert_with(Instant::now);
                    self.submitted.notify_one();
                    waiting = true;
                }
            }
            if !waiting {
                // every row either hit the memo or was awaited above, so
                // an unresolved slot is a typed error, not a panic
                return out
                    .into_iter()
                    .map(|e| e.context("surrogate estimate left a row unresolved"))
                    .collect();
            }
            st = self.completed.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// The flusher loop: run this on a dedicated thread for the life of
    /// the engine. Returns once [`shutdown`](Self::shutdown) is called
    /// and the pending rows have drained.
    pub fn run_flusher(&self) {
        let mut st = lock_unpoisoned(&self.state);
        loop {
            if st.rows.is_empty() {
                if st.stopping {
                    break;
                }
                st = self.submitted.wait(st).unwrap_or_else(PoisonError::into_inner);
                continue;
            }
            let age = st.first_at.map(|t| t.elapsed()).unwrap_or(Duration::ZERO);
            if st.rows.len() < self.cfg.max_rows && age < self.cfg.deadline && !st.stopping {
                let remaining = self.cfg.deadline - age;
                let (guard, _) = self
                    .submitted
                    .wait_timeout(st, remaining)
                    .unwrap_or_else(PoisonError::into_inner);
                st = guard;
                continue;
            }
            // ---- flush: take the batch, execute it unlocked ----
            let rows = std::mem::take(&mut st.rows);
            st.in_flight = std::mem::take(&mut st.pending);
            st.first_at = None;
            drop(st);
            let mut span = crate::telemetry::span("flush", "serve");
            span.arg("rows", crate::util::Json::Num(rows.len() as f64));
            let result = self.predictor.predict_batch(&rows);
            drop(span);
            st = lock_unpoisoned(&self.state);
            st.in_flight.clear();
            self.flushes.fetch_add(1, Ordering::Relaxed);
            self.rows_flushed.fetch_add(rows.len(), Ordering::Relaxed);
            self.max_flush_rows.fetch_max(rows.len(), Ordering::Relaxed);
            // a success clears the error so waiters can tell "my flush
            // failed" apart from "my row was evicted at the memo cap"
            st.last_error = match result {
                Ok(_) => None,
                Err(e) => Some(format!("{e:#}")),
            };
            self.completed.notify_all();
        }
        drop(st);
        // anyone still blocked learns the engine stopped
        self.completed.notify_all();
    }

    /// Stop accepting new requests and let the flusher drain and exit.
    /// Safe to call more than once.
    pub fn shutdown(&self) {
        let mut st = lock_unpoisoned(&self.state);
        st.stopping = true;
        drop(st);
        self.submitted.notify_all();
        self.completed.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::surrogate::predictor::test_support::{feature_rows as rows, predictor, runtime};

    /// Flush-on-full: with a long deadline and `max_rows = k`, `k`
    /// concurrent callers coalesce into exactly one execution — none of
    /// them waits for the deadline.
    #[test]
    fn concurrent_requests_flush_on_full() {
        let rt = runtime();
        let sur = predictor(&rt);
        let k = 6usize;
        let engine = SurrogateEngine::new(
            &sur,
            EngineConfig {
                deadline: Duration::from_secs(60),
                max_rows: k,
            },
        );
        let feats = rows(k, 3);
        let reference = predictor(&rt);
        let expected = reference.predict_batch(&feats).unwrap();
        let eng = &engine;
        std::thread::scope(|s| {
            s.spawn(move || eng.run_flusher());
            let results: Vec<_> = feats
                .iter()
                .map(|f| s.spawn(move || eng.estimate(f).unwrap()))
                .collect();
            for (i, h) in results.into_iter().enumerate() {
                assert_eq!(h.join().unwrap(), expected[i]);
            }
            eng.shutdown();
        });
        assert_eq!(engine.flushes(), 1, "k requests coalesced into one flush");
        assert_eq!(engine.rows_flushed(), k);
        assert_eq!(sur.executions(), 1);
    }

    /// Flush-on-deadline: a single request on an otherwise idle engine
    /// is served after the deadline rather than waiting for a full batch.
    #[test]
    fn lone_request_flushes_on_deadline() {
        let rt = runtime();
        let sur = predictor(&rt);
        let engine = SurrogateEngine::new(
            &sur,
            EngineConfig {
                deadline: Duration::from_millis(20),
                max_rows: crate::nn::SUR_BATCH,
            },
        );
        let feats = rows(1, 5);
        let reference = predictor(&rt);
        let expected = reference.predict_batch(&feats).unwrap()[0];
        std::thread::scope(|s| {
            s.spawn(|| engine.run_flusher());
            let got = engine.estimate(&feats[0]).unwrap();
            assert_eq!(got, expected);
            engine.shutdown();
        });
        assert_eq!(engine.flushes(), 1);
        assert_eq!(engine.rows_flushed(), 1);
    }

    /// Duplicate submissions share one pending row, and memo hits skip
    /// the batch entirely.
    #[test]
    fn duplicates_and_memo_hits_cost_no_extra_rows() {
        let rt = runtime();
        let sur = predictor(&rt);
        let engine = SurrogateEngine::new(
            &sur,
            EngineConfig {
                deadline: Duration::from_millis(5),
                max_rows: crate::nn::SUR_BATCH,
            },
        );
        let distinct = rows(3, 9);
        let batch = [
            distinct[0].clone(),
            distinct[1].clone(),
            distinct[0].clone(),
            distinct[2].clone(),
        ];
        std::thread::scope(|s| {
            s.spawn(|| engine.run_flusher());
            let out = engine.estimate_many(&batch).unwrap();
            assert_eq!(out.len(), 4);
            assert_eq!(out[0], out[2]);
            // a repeat is a pure memo hit: no new rows, no new flush
            let flushes = engine.flushes();
            let again = engine.estimate(&distinct[1]).unwrap();
            assert_eq!(again, out[1]);
            assert_eq!(engine.flushes(), flushes);
            engine.shutdown();
        });
        assert_eq!(engine.rows_flushed(), 3, "duplicates collapsed");
    }

    /// Input validation and post-shutdown behaviour are typed errors,
    /// not hangs.
    #[test]
    fn bad_input_and_shutdown_are_errors() {
        let rt = runtime();
        let sur = predictor(&rt);
        let engine = SurrogateEngine::new(&sur, EngineConfig::default());
        let err = engine.estimate(&[1.0, 2.0]).unwrap_err();
        assert!(format!("{err:#}").contains("feature vector"));
        engine.shutdown();
        let feats = rows(1, 2);
        let err = engine.estimate(&feats[0]).unwrap_err();
        assert!(format!("{err:#}").contains("shut down"));
    }
}
