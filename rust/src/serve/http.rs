//! Minimal HTTP/1.1 framing for the estimation service.
//!
//! Std-only (the image has no crate network access): a blocking
//! request reader, a response writer, and a tiny one-shot client used by
//! `examples/estimate_client.rs`, the integration tests, and the bench.
//! One request per connection (`Connection: close`), bodies framed by
//! `Content-Length` — exactly what a JSON estimation endpoint needs and
//! nothing more.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, Context, Result};

/// Largest request body the server will read (a full `/estimate/batch`
/// of a few thousand genomes fits in well under this).
const MAX_BODY: usize = 8 << 20;

/// Largest request line + header block the server will read. Bounding
/// the whole pre-body region (rather than per line) also caps header
/// count, so a client streaming endless bytes cannot grow server
/// memory or pin a connection thread.
const MAX_HEAD: usize = 64 << 10;

/// One parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    /// Upper-case method (`GET`, `POST`, …).
    pub method: String,
    /// Path without query string.
    pub path: String,
    /// Raw body (empty when no `Content-Length`).
    pub body: String,
}

/// Read one request from a connection. Fails on malformed framing, an
/// over-long body, or a client that goes quiet mid-request (the caller
/// sets the stream's read timeout).
pub fn read_request(stream: &mut TcpStream) -> Result<Request> {
    // hard cap on the pre-body region: an over-long request line or
    // header block exhausts the budget (read_line hits EOF) and fails
    // the request instead of ballooning `line` without bound
    let mut reader = BufReader::new(stream.take(MAX_HEAD as u64));
    let mut line = String::new();
    reader.read_line(&mut line).context("reading request line")?;
    let mut parts = line.split_whitespace();
    let method = parts.next().context("empty request line")?.to_ascii_uppercase();
    let target = parts.next().context("request line has no path")?;
    let path = target.split('?').next().unwrap_or(target).to_string();

    let mut content_length = 0usize;
    loop {
        let mut header = String::new();
        reader.read_line(&mut header).context("reading header")?;
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().context("unparseable Content-Length")?;
            }
        }
    }
    if content_length > MAX_BODY {
        bail!("request body of {content_length} bytes exceeds the {MAX_BODY}-byte limit");
    }
    // headers consumed: widen the read budget to admit exactly the body
    // (bytes the BufReader already buffered are paid for, so this is
    // never under-generous)
    reader.get_mut().set_limit(content_length as u64);
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).context("reading request body")?;
    Ok(Request {
        method,
        path,
        body: String::from_utf8(body).context("request body is not UTF-8")?,
    })
}

/// Reason phrase for the status codes the service emits.
fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        _ => "Unknown",
    }
}

/// Write a full JSON response and flush.
pub fn write_response(stream: &mut TcpStream, status: u16, body: &str) -> Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        reason(status),
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// One-shot HTTP client: send `method path` with an optional JSON body
/// to `addr` (e.g. `127.0.0.1:7878`) and return `(status, body)`.
pub fn request(addr: &str, method: &str, path: &str, body: Option<&str>) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(60)))?;
    stream.set_write_timeout(Some(Duration::from_secs(60)))?;
    let body = body.unwrap_or("");
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;

    let mut response = String::new();
    stream.read_to_string(&mut response).context("reading response")?;
    let (head, payload) = response
        .split_once("\r\n\r\n")
        .context("response has no header/body separator")?;
    let status_line = head.lines().next().context("empty response")?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .context("status line has no code")?
        .parse()
        .context("unparseable status code")?;
    Ok((status, payload.to_string()))
}
