//! Supernet training driver: the hot loop that feeds the AOT `train_step`
//! artifact and maintains optimiser/BN state on the host.

pub mod supernet;

pub use supernet::{EpochMetrics, TrainConfig, TrainedModel, Trainer};
