//! Training/evaluation of one candidate architecture inside the supernet.
//!
//! Every candidate is trained against the SAME compiled `train_step` HLO:
//! the genome only changes the mask/gate/hyperparameter *inputs*
//! (`nn::SupernetInputs`). The trainer owns the Adam state, the Adam
//! bias-correction schedule (β^t is computed host-side and passed in `hp`),
//! and the BatchNorm running statistics used by `eval_step`.

use anyhow::{Context, Result};

use crate::data::{Dataset, Split};
use crate::nn::{
    self, PruneMasks, SupernetInputs, SupernetParams, EVAL_BATCH, HP_LEN, NUM_LAYERS,
    OUT_DIM, PAD,
};
use crate::runtime::runtime::arg;
use crate::runtime::Runtime;
use crate::util::Rng;

/// Training-run configuration (per candidate).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Number of epochs over the train split.
    pub epochs: usize,
    /// Quantisation-aware training enabled.
    pub qat: bool,
    /// QAT bit-width.
    pub bits: u32,
    /// Adam β1.
    pub beta1: f32,
    /// Adam β2.
    pub beta2: f32,
    /// Adam ε.
    pub eps: f32,
    /// BN running-stat EMA momentum (fraction of the *new* batch stat).
    pub bn_momentum: f32,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 5, // paper: 5 epochs per global-search trial
            qat: false,
            bits: 8,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            bn_momentum: 0.1,
        }
    }
}

/// Per-epoch training metrics.
#[derive(Debug, Clone)]
pub struct EpochMetrics {
    /// Mean train loss over the epoch.
    pub loss: f64,
    /// Train accuracy over the epoch.
    pub accuracy: f64,
}

/// A trained candidate: parameters + BN statistics + history.
#[derive(Debug, Clone)]
pub struct TrainedModel {
    /// Final supernet parameters.
    pub params: SupernetParams,
    /// BN running means `(L, PAD)` for eval.
    pub run_mean: Vec<f32>,
    /// BN running variances `(L, PAD)` for eval.
    pub run_var: Vec<f32>,
    /// Loss/accuracy per epoch.
    pub history: Vec<EpochMetrics>,
    /// Total train steps taken (continues across resume calls).
    pub steps: u64,
    /// Adam first-moment state (kept for resume during local search).
    pub adam_m: SupernetParams,
    /// Adam second-moment state.
    pub adam_v: SupernetParams,
}

/// The training driver. Holds only borrowed context; all heavy state lives
/// in [`TrainedModel`] so local search can resume training after pruning.
pub struct Trainer<'a> {
    rt: &'a Runtime,
    ds: &'a Dataset,
}

impl<'a> Trainer<'a> {
    /// New trainer over a runtime and dataset.
    pub fn new(rt: &'a Runtime, ds: &'a Dataset) -> Self {
        Trainer { rt, ds }
    }

    /// Fresh state for a candidate (He init, zero Adam, identity BN stats).
    pub fn init_model(&self, rng: &mut Rng) -> TrainedModel {
        TrainedModel {
            params: SupernetParams::init(rng),
            run_mean: vec![0.0; NUM_LAYERS * PAD],
            run_var: vec![1.0; NUM_LAYERS * PAD],
            history: Vec::new(),
            steps: 0,
            adam_m: SupernetParams::zeros(),
            adam_v: SupernetParams::zeros(),
        }
    }

    /// Train `model` in place for `cfg.epochs` epochs.
    pub fn train(
        &self,
        model: &mut TrainedModel,
        inputs: &SupernetInputs,
        prune: &PruneMasks,
        cfg: &TrainConfig,
        rng: &mut Rng,
    ) -> Result<()> {
        let qat_gate = if cfg.qat { 1.0 } else { 0.0 };
        let mut hp = [0.0f32; HP_LEN];
        hp[nn::HP_BN_GATE] = inputs.bn_gate;
        hp[nn::HP_DROPOUT] = inputs.dropout;
        hp[nn::HP_QAT_GATE] = qat_gate;
        hp[nn::HP_BITS] = cfg.bits as f32;
        hp[nn::HP_LR] = inputs.lr;
        hp[nn::HP_L1] = inputs.l1;
        hp[nn::HP_BETA1] = cfg.beta1;
        hp[nn::HP_BETA2] = cfg.beta2;
        hp[nn::HP_EPS] = cfg.eps;
        hp[nn::HP_BN_MOM] = cfg.bn_momentum;

        for _epoch in 0..cfg.epochs {
            let batches = self.ds.train_epoch(rng);
            let mut loss_sum = 0.0f64;
            let mut correct_sum = 0.0f64;
            let mut rows = 0usize;
            for batch in &batches {
                model.steps += 1;
                let t = model.steps as i32;
                hp[nn::HP_BETA1_POW] = cfg.beta1.powi(t);
                hp[nn::HP_BETA2_POW] = cfg.beta2.powi(t);
                // dropout seed: deterministic per step, < 2^24 for exact f32
                hp[nn::HP_SEED] = (model.steps % (1 << 24)) as f32;

                let p = &model.params;
                let m = &model.adam_m;
                let v = &model.adam_v;
                let out = self.rt.run(
                    "train_step",
                    &[
                        arg("w0", &p.w0),
                        arg("wh", &p.wh),
                        arg("b", &p.b),
                        arg("gamma", &p.gamma),
                        arg("beta", &p.beta),
                        arg("wo", &p.wo),
                        arg("bo", &p.bo),
                        arg("m_w0", &m.w0),
                        arg("m_wh", &m.wh),
                        arg("m_b", &m.b),
                        arg("m_gamma", &m.gamma),
                        arg("m_beta", &m.beta),
                        arg("m_wo", &m.wo),
                        arg("m_bo", &m.bo),
                        arg("v_w0", &v.w0),
                        arg("v_wh", &v.wh),
                        arg("v_b", &v.b),
                        arg("v_gamma", &v.gamma),
                        arg("v_beta", &v.beta),
                        arg("v_wo", &v.wo),
                        arg("v_bo", &v.bo),
                        arg("unit", &inputs.unit),
                        arg("p0", &prune.p0),
                        arg("ph", &prune.ph),
                        arg("po", &prune.po),
                        arg("gates", &inputs.gates),
                        arg("act_sel", &inputs.act_sel),
                        arg("hp", &hp),
                        arg("run_mean", &model.run_mean),
                        arg("run_var", &model.run_var),
                        arg("x", &batch.x),
                        arg("y1h", &batch.y1h),
                    ],
                )?;
                let mut it = out.into_iter();
                let mut take = |what: &'static str| {
                    it.next().with_context(|| {
                        format!("train_step returned too few outputs (missing {what})")
                    })
                };
                // 7 params, 7 m, 7 v — same field order as PARAM_SHAPES
                for field in model.params.fields_mut() {
                    *field = take("a parameter tensor")?;
                }
                for field in model.adam_m.fields_mut() {
                    *field = take("an Adam first-moment tensor")?;
                }
                for field in model.adam_v.fields_mut() {
                    *field = take("an Adam second-moment tensor")?;
                }
                let loss = f64::from(
                    *take("the loss scalar")?
                        .first()
                        .context("train_step loss output is empty")?,
                );
                let correct = f64::from(
                    *take("the correct-count scalar")?
                        .first()
                        .context("train_step correct-count output is empty")?,
                );
                // BN running statistics: EMA computed in-graph
                model.run_mean = take("the BN running means")?;
                model.run_var = take("the BN running variances")?;
                loss_sum += loss;
                correct_sum += correct;
                rows += batch.rows;
            }
            model.history.push(EpochMetrics {
                loss: loss_sum / batches.len().max(1) as f64,
                accuracy: correct_sum / rows.max(1) as f64,
            });
        }
        Ok(())
    }

    /// Accuracy and mean CE loss on a split (eval mode: running BN stats,
    /// no dropout; padded tail rows are discounted host-side).
    pub fn evaluate(
        &self,
        model: &TrainedModel,
        inputs: &SupernetInputs,
        prune: &PruneMasks,
        cfg: &TrainConfig,
        split: Split,
    ) -> Result<(f64, f64)> {
        let qat_gate = if cfg.qat { 1.0 } else { 0.0 };
        let ehp = [inputs.bn_gate, qat_gate, cfg.bits as f32];
        let p = &model.params;
        let mut correct = 0usize;
        let mut loss_sum = 0.0f64;
        let mut rows_total = 0usize;
        for tile in self.ds.eval_tiles(split, EVAL_BATCH) {
            let out = self.rt.run(
                "eval_step",
                &[
                    arg("w0", &p.w0),
                    arg("wh", &p.wh),
                    arg("b", &p.b),
                    arg("gamma", &p.gamma),
                    arg("beta", &p.beta),
                    arg("wo", &p.wo),
                    arg("bo", &p.bo),
                    arg("unit", &inputs.unit),
                    arg("p0", &prune.p0),
                    arg("ph", &prune.ph),
                    arg("po", &prune.po),
                    arg("gates", &inputs.gates),
                    arg("act_sel", &inputs.act_sel),
                    arg("ehp", &ehp),
                    arg("run_mean", &model.run_mean),
                    arg("run_var", &model.run_var),
                    arg("x", &tile.x),
                    arg("y1h", &tile.y1h),
                ],
            )?;
            let logits = &out[2];
            for r in 0..tile.rows {
                let row = &logits[r * OUT_DIM..(r + 1) * OUT_DIM];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .context("eval_step returned an empty logits row")?;
                let label = tile.y1h[r * OUT_DIM..(r + 1) * OUT_DIM]
                    .iter()
                    .position(|&v| v == 1.0)
                    .context("eval tile row carries no one-hot label")?;
                if pred == label {
                    correct += 1;
                }
                // numerically-stable CE from logits (host side, f64)
                let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v)) as f64;
                let lse = max
                    + row
                        .iter()
                        .map(|&v| ((v as f64) - max).exp())
                        .sum::<f64>()
                        .ln();
                loss_sum += lse - row[label] as f64;
            }
            rows_total += tile.rows;
        }
        Ok((
            correct as f64 / rows_total.max(1) as f64,
            loss_sum / rows_total.max(1) as f64,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::SearchSpace;

    /// One shared end-to-end integration test (runtime compiles are slow on
    /// this box, so a single test covers train → eval → prune-resume).
    /// Runs against real AOT artifacts when built, else the checked-in HLO
    /// fixtures interpreted by `rust/xla` — never skipped.
    #[test]
    fn trains_evaluates_and_resumes_end_to_end() {
        let dir = crate::runtime::artifact_dir().expect("no artifact manifest found");
        let rt = Runtime::load(&dir).unwrap();
        let ds = Dataset::generate(1280, 256, 256, 11);
        let space = SearchSpace::table1();
        let genome = space.baseline();
        let inputs = SupernetInputs::compile(&genome, &space);
        let prune = PruneMasks::ones();
        let trainer = Trainer::new(&rt, &ds);
        let cfg = TrainConfig {
            epochs: 3,
            ..Default::default()
        };
        let mut rng = Rng::new(0);
        let mut model = trainer.init_model(&mut rng);
        trainer
            .train(&mut model, &inputs, &prune, &cfg, &mut rng)
            .unwrap();
        assert_eq!(model.history.len(), 3);
        let first = model.history.first().unwrap().loss;
        let last = model.history.last().unwrap().loss;
        assert!(last < first, "loss should fall: {first} → {last}");
        assert!(
            last < 1.55,
            "3 epochs should beat the 5-class random loss 1.609, got {last}"
        );

        // eval mode beats chance on held-out data
        let (acc, loss) = trainer
            .evaluate(&model, &inputs, &prune, &cfg, Split::Test)
            .unwrap();
        assert!(acc > 0.30, "test accuracy {acc} should beat 0.2 chance");
        assert!(loss < 1.6, "test loss {loss}");

        // prune 20% and resume with QAT — the IMP inner loop
        let mut masks = PruneMasks::ones();
        masks.prune_step(&model.params, &inputs, 0.2);
        let qat_cfg = TrainConfig {
            epochs: 1,
            qat: true,
            bits: 8,
            ..Default::default()
        };
        trainer
            .train(&mut model, &inputs, &masks, &qat_cfg, &mut rng)
            .unwrap();
        // pruned coordinates stay exactly zero through resumed training
        for (w, m) in model.params.w0.iter().zip(&masks.p0) {
            if *m == 0.0 {
                assert_eq!(*w, 0.0);
            }
        }
        let (acc_q, _) = trainer
            .evaluate(&model, &inputs, &masks, &qat_cfg, Split::Test)
            .unwrap();
        assert!(acc_q > 0.30, "pruned+QAT accuracy {acc_q}");
    }
}
