//! `snac-pack` — the Layer-3 coordinator CLI.
//!
//! Python never runs here: all compute executes through the AOT-compiled
//! HLO artifacts in `artifacts/` (build them once with `make artifacts`).
//!
//! ```text
//! snac-pack pipeline  --preset ci --out results          # full paper flow
//! snac-pack search    --preset ci --objectives acc,bops  # one global search
//! snac-pack surrogate --preset ci                        # surrogate train/eval
//! snac-pack synth                                        # Table-3 style synthesis demo
//! snac-pack info                                         # runtime/artifact info
//! ```

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use snac_pack::config::Preset;
use snac_pack::coordinator::{self, GlobalSearchConfig, TrialRecord};
use snac_pack::data::Dataset;
use snac_pack::hls::{synthesize, FpgaDevice, HlsConfig, NetworkSpec};
use snac_pack::nn::SearchSpace;
use snac_pack::objectives::{ObjectiveContext, ObjectiveKind};
use snac_pack::runtime::Runtime;
use snac_pack::surrogate::{train_surrogate, SurrogatePredictor};

/// Parsed command line.
struct Cli {
    command: String,
    preset: Preset,
    out: PathBuf,
    /// `--artifacts DIR` override; `None` resolves lazily (only for
    /// commands that actually load the runtime, so e.g. `synth` never
    /// prints the fixture-fallback notice).
    artifacts: Option<PathBuf>,
    objectives: Vec<ObjectiveKind>,
}

impl Cli {
    /// The artifact directory this invocation should load.
    fn artifacts_dir(&self) -> PathBuf {
        match &self.artifacts {
            Some(dir) => dir.clone(),
            None => snac_pack::runtime::resolve_artifact_dir(Path::new("artifacts")),
        }
    }
}

fn parse_cli() -> Result<Cli> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(command) = args.first().cloned() else {
        bail!(
            "usage: snac-pack <pipeline|search|surrogate|synth|info> \
             [--preset paper|ci|quickstart] [--out DIR] [--artifacts DIR] \
             [--objectives acc,bops] [--workers N] [--cache-path FILE] \
             [--set key=value ...]\n\
             --preset picks the base regardless of position; \
             --workers/--cache-path/--set overrides then apply left to right\n\
             --cache-path persists the evaluation cache across runs: a \
             re-run never retrains a previously evaluated genome"
        );
    };
    let mut preset = Preset::by_name("ci")?;
    let mut out = PathBuf::from("results");
    // default (no --artifacts): resolved lazily by Cli::artifacts_dir —
    // ./artifacts when present, else whatever this build can load (real
    // AOT artifacts, falling back to the checked-in HLO fixtures the
    // rust/xla interpreter executes)
    let mut artifacts: Option<PathBuf> = None;
    let mut objectives = ObjectiveKind::nac_set();
    // --preset resolves first so `--workers 8 --preset paper` keeps the 8:
    // the preset is the base, every other flag is an override on top.
    let mut i = 1;
    while i < args.len() {
        if args[i] == "--preset" {
            let name = args.get(i + 1).context("flag --preset needs a value")?;
            preset = Preset::by_name(name)?;
        }
        i += 2;
    }
    let mut i = 1;
    while i < args.len() {
        let flag = &args[i];
        let value = || -> Result<&String> {
            args.get(i + 1)
                .with_context(|| format!("flag {flag} needs a value"))
        };
        match flag.as_str() {
            "--preset" => {} // consumed in the first pass
            "--out" => out = PathBuf::from(value()?),
            "--artifacts" => artifacts = Some(PathBuf::from(value()?)),
            "--objectives" => objectives = ObjectiveKind::parse_set(value()?)?,
            "--workers" => preset
                .set("workers", value()?)
                .context("--workers expects a count")?,
            "--cache-path" => preset
                .set("cache_path", value()?)
                .context("--cache-path expects a file path")?,
            "--set" => {
                let kv = value()?;
                let (k, v) = kv
                    .split_once('=')
                    .with_context(|| format!("--set expects key=value, got {kv}"))?;
                preset.set(k, v)?;
            }
            other => bail!("unknown flag `{other}`"),
        }
        i += 2;
    }
    Ok(Cli {
        command,
        preset,
        out,
        artifacts,
        objectives,
    })
}

fn main() -> Result<()> {
    let cli = parse_cli()?;
    match cli.command.as_str() {
        "info" => {
            let rt = Runtime::load(&cli.artifacts_dir())?;
            println!("platform: {}", rt.platform());
            for (name, spec) in &rt.manifest().artifacts {
                println!(
                    "artifact {name}: {} inputs / {} outputs ({})",
                    spec.inputs.len(),
                    spec.outputs.len(),
                    spec.file
                );
            }
        }
        "pipeline" => {
            let rt = Runtime::load(&cli.artifacts_dir())?;
            let summary = coordinator::run_pipeline(&rt, &cli.preset, &cli.out)?;
            println!("{}", summary.table2);
            println!("{}", summary.table3);
            println!("stage timings:");
            for (stage, secs) in &summary.timings {
                println!("  {stage:<28} {secs:>8.1}s");
            }
            println!("reports written to {}", cli.out.display());
        }
        "search" => {
            let rt = Runtime::load(&cli.artifacts_dir())?;
            let space = SearchSpace::table1();
            let device = FpgaDevice::vu13p();
            let ds = Dataset::generate(
                cli.preset.data.n_train,
                cli.preset.data.n_val,
                cli.preset.data.n_test,
                cli.preset.data.seed,
            );
            let sur = if ObjectiveKind::needs_surrogate(&cli.objectives) {
                let (p, mse) = train_surrogate(
                    &rt,
                    &space,
                    &cli.preset.surrogate,
                    &HlsConfig::default(),
                    &device,
                )?;
                eprintln!("surrogate MSE: {mse:.5}");
                Some(SurrogatePredictor::new(&rt, p))
            } else {
                None
            };
            let outcome = coordinator::global_search(
                &rt,
                &ds,
                &space,
                GlobalSearchConfig {
                    objectives: cli.objectives.clone(),
                    ctx: ObjectiveContext {
                        space: &space,
                        device: &device,
                        surrogate: sur.as_ref(),
                        bits: cli.preset.local.bits,
                        sparsity: cli.preset.local.target_sparsity,
                    },
                    nsga2: cli.preset.nsga2(),
                    trials: cli.preset.search.trials,
                    epochs: cli.preset.search.epochs,
                    seed: cli.preset.seed,
                    workers: cli.preset.search.workers,
                    accuracy_threshold: 0.0,
                    progress: Some(Box::new(|i, n, r: &TrialRecord| {
                        eprintln!("trial {i}/{n}: {} acc={:.4}", r.label, r.accuracy);
                    })),
                    cache_path: cli.preset.cache_path.as_ref().map(PathBuf::from),
                },
            )?;
            std::fs::create_dir_all(&cli.out)?;
            TrialRecord::save_all(&outcome.records, &cli.out.join("trials.json"))?;
            println!(
                "{} trials in {:.1}s ({:.2} trials/s, {} workers); front size {}; \
                 trials.json written to {}",
                outcome.records.len(),
                outcome.wall_seconds,
                outcome.records.len() as f64 / outcome.wall_seconds.max(1e-9),
                snac_pack::eval::resolve_workers(cli.preset.search.workers),
                outcome.front.len(),
                cli.out.display()
            );
            println!(
                "cache: {} trained, {} cache hits, {} restored from snapshot",
                outcome.evaluations, outcome.cache_hits, outcome.cache_restored
            );
            for &i in &outcome.front {
                let r = &outcome.records[i];
                println!("  front: {} acc={:.4} obj={:?}", r.label, r.accuracy, r.objectives);
            }
        }
        "surrogate" => {
            let rt = Runtime::load(&cli.artifacts_dir())?;
            let space = SearchSpace::table1();
            let device = FpgaDevice::vu13p();
            let hls = HlsConfig::default();
            let (params, mse) =
                train_surrogate(&rt, &space, &cli.preset.surrogate, &hls, &device)?;
            println!("surrogate trained: final MSE {mse:.5} (compressed space)");
            // held-out sanity: compare predictions against the simulator
            let sur = SurrogatePredictor::new(&rt, params);
            let mut rng = snac_pack::util::Rng::new(999);
            let mut rel_err = [0.0f64; 2];
            let n = 64;
            for _ in 0..n {
                let g = space.sample(&mut rng);
                let est = sur.predict(&g, &space, 8, 0.5)?;
                let spec = NetworkSpec::from_genome(&g, &space, 8, 0.5);
                let truth = synthesize(&spec, &hls, &device);
                rel_err[0] +=
                    ((est.lut - truth.lut as f64) / (truth.lut as f64 + 1.0)).abs();
                rel_err[1] += ((est.latency_cc - truth.latency_cc as f64)
                    / (truth.latency_cc as f64 + 1.0))
                    .abs();
            }
            println!(
                "held-out mean relative error: LUT {:.1}%, latency {:.1}%",
                rel_err[0] / n as f64 * 100.0,
                rel_err[1] / n as f64 * 100.0
            );
        }
        "synth" => {
            // Table-3-style synthesis of the baseline at several sparsities
            let space = SearchSpace::table1();
            let device = FpgaDevice::vu13p();
            let hls = HlsConfig::default();
            println!("baseline [12] synthesis sweep on {}:", device.name);
            println!("sparsity  DSP    LUT      FF     BRAM  lat(cc)");
            for s in [0.0, 0.25, 0.5, 0.75] {
                let mut spec = NetworkSpec::from_genome(&space.baseline(), &space, 8, s);
                spec.softmax_head = true;
                spec.fuse_batch_norm = false; // legacy [12] synthesis
                let r = synthesize(&spec, &hls, &device);
                println!(
                    "{s:>7.2}  {:>4}  {:>6}  {:>6}  {:>4}  {:>6}",
                    r.dsp, r.lut, r.ff, r.bram36, r.latency_cc
                );
            }
        }
        other => bail!("unknown command `{other}`"),
    }
    Ok(())
}
