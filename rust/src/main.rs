//! `snac-pack` — the Layer-3 coordinator CLI.
//!
//! Python never runs here: all compute executes through the AOT-compiled
//! HLO artifacts in `artifacts/` (build them once with `make artifacts`).
//!
//! ```text
//! snac-pack pipeline  --preset ci --out results          # full paper flow
//! snac-pack search    --preset ci --objectives acc,bops  # one global search
//! snac-pack search    --shards 4 --run-dir /tmp/run      # multi-process dispatch
//! snac-pack search    --shards 4 --listen 0.0.0.0:7979   # TCP dispatch, no shared fs
//! snac-pack worker    --run-dir /tmp/run                 # serve shards for a driver
//! snac-pack worker    --connect HOST:7979                # join a TCP driver
//! snac-pack serve     --preset ci --port 7878            # surrogate estimation service
//! snac-pack surrogate --preset ci                        # surrogate train/eval
//! snac-pack synth                                        # Table-3 style synthesis demo
//! snac-pack info                                         # runtime/artifact info
//! ```

use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use snac_pack::config::Preset;
use snac_pack::coordinator::{
    self, CheckpointConfig, DispatchBackend, GlobalSearchConfig, ShardedDispatch, TrialRecord,
};
use snac_pack::data::Dataset;
use snac_pack::eval::{
    parallel_map, resolve_workers, run_worker_on, FsTransport, RunDir, ShardTimings,
    ShardTransport, SupernetEvaluator, TcpHost, TcpWorker, TrialEvaluator, WorkerOptions,
};
use snac_pack::hls::{synthesize, FpgaDevice, HlsConfig, NetworkSpec};
use snac_pack::nn::{Genome, SearchSpace};
use snac_pack::objectives::{ObjectiveContext, ObjectiveKind};
use snac_pack::runtime::Runtime;
use snac_pack::serve::{self, EngineConfig, ServeContext, ServeMetrics, ServeTuning, SurrogateEngine};
use snac_pack::surrogate::{train_surrogate, SurrogateParams, SurrogatePredictor};
use snac_pack::telemetry;
use snac_pack::trainer::TrainConfig;
use snac_pack::util::Json;

/// Parsed command line.
struct Cli {
    command: String,
    preset: Preset,
    out: PathBuf,
    /// `--artifacts DIR` override; `None` resolves lazily (only for
    /// commands that actually load the runtime, so e.g. `synth` never
    /// prints the fixture-fallback notice).
    artifacts: Option<PathBuf>,
    objectives: Vec<ObjectiveKind>,
    /// Raw `--workers` value when one was passed (the `worker`
    /// subcommand overrides the manifest's preset with it).
    workers_flag: Option<usize>,
    /// `--token TOK`: the shared bearer token gating `/shard/*` on a TCP
    /// run. The driver mints one when the flag is absent and prints it;
    /// `worker --connect` requires it. Deliberately not a preset key —
    /// the manifest is served unauthenticated, so the token must travel
    /// out-of-band.
    token: Option<String>,
}

impl Cli {
    /// The artifact directory this invocation should load.
    fn artifacts_dir(&self) -> PathBuf {
        match &self.artifacts {
            Some(dir) => dir.clone(),
            None => snac_pack::runtime::resolve_artifact_dir(Path::new("artifacts")),
        }
    }
}

fn parse_cli() -> Result<Cli> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(command) = args.first().cloned() else {
        bail!(
            "usage: snac-pack <pipeline|search|worker|serve|surrogate|synth|info> \
             [--preset paper|ci|quickstart] [--out DIR] [--artifacts DIR] \
             [--objectives acc,bops] [--workers N] [--threads N] \
             [--verify-plans 0|1] [--cache-path FILE] \
             [--shards N] [--run-dir DIR] [--listen HOST:PORT] \
             [--connect HOST:PORT] [--token TOK] [--checkpoint-interval N] \
             [--port N] [--batch-deadline-ms N] [--pool-size N] \
             [--queue-depth N] [--trace-out PATH] [--trace-ops N] \
             [--set key=value ...]\n\
             --preset picks the base regardless of position; \
             --workers/--cache-path/--set overrides then apply left to right\n\
             --threads N runs the interpreter's dot-general kernels on N \
             threads (0 = all cores, 1 = serial default); results are \
             bit-identical for every value\n\
             --verify-plans 1 statically verifies every compiled execution \
             plan (bounds/liveness/partition/dataflow) before it runs; \
             always on in debug builds, also via SNAC_XLA_VERIFY=1\n\
             --cache-path persists the evaluation cache across runs: a \
             re-run never retrains a previously evaluated genome\n\
             --shards N dispatches each generation to N shard files served \
             by `snac-pack worker` processes over --run-dir (auto-spawned \
             locally unless --set spawn_workers=0); results are \
             bit-identical to the in-process run\n\
             --listen HOST:PORT serves the shard queue over TCP instead of \
             a shared run directory; workers on any machine join with \
             `snac-pack worker --connect HOST:PORT --token TOK` (HOST:0 \
             binds an ephemeral port, printed on startup; the driver \
             mints TOK unless --token pins it, and prints the exact join \
             command)\n\
             --checkpoint-interval N snapshots the search state every N \
             generations so a killed driver resumes mid-run with a \
             bit-identical trial database (0 = off)\n\
             --trace-out PATH records structured spans across every layer \
             (generations, trials, shards, surrogate flushes) and writes \
             a Chrome-trace trace.json + JSONL flight log at exit; shard \
             workers of a traced run stitch their spans into the same \
             trace. Purely observational: the trial database is \
             bit-identical with tracing on or off\n\
             --trace-ops N additionally times every Nth interpreter plan \
             step (0 = off; sampled so kernels stay fast)\n\
             serve exposes the trained surrogate as an HTTP estimation \
             service on 127.0.0.1:--port (0 = ephemeral), micro-batching \
             concurrent requests with a --batch-deadline-ms flush \
             deadline; --pool-size bounds the connection workers and \
             --queue-depth the admission queue (0 = auto for both; a \
             full queue sheds with a fast 503), with live counters on \
             GET /metrics"
        );
    };
    let mut preset = Preset::by_name("ci")?;
    let mut out = PathBuf::from("results");
    // default (no --artifacts): resolved lazily by Cli::artifacts_dir —
    // ./artifacts when present, else whatever this build can load (real
    // AOT artifacts, falling back to the checked-in HLO fixtures the
    // rust/xla interpreter executes)
    let mut artifacts: Option<PathBuf> = None;
    let mut objectives = ObjectiveKind::nac_set();
    let mut workers_flag = None;
    let mut token = None;
    // --preset resolves first so `--workers 8 --preset paper` keeps the 8:
    // the preset is the base, every other flag is an override on top.
    let mut i = 1;
    while i < args.len() {
        if args[i] == "--preset" {
            let name = args.get(i + 1).context("flag --preset needs a value")?;
            preset = Preset::by_name(name)?;
        }
        i += 2;
    }
    let mut i = 1;
    while i < args.len() {
        let flag = &args[i];
        let value = || -> Result<&String> {
            args.get(i + 1)
                .with_context(|| format!("flag {flag} needs a value"))
        };
        match flag.as_str() {
            "--preset" => {} // consumed in the first pass
            "--out" => out = PathBuf::from(value()?),
            "--artifacts" => artifacts = Some(PathBuf::from(value()?)),
            "--objectives" => objectives = ObjectiveKind::parse_set(value()?)?,
            "--workers" => {
                let v = value()?;
                preset
                    .set("workers", v)
                    .context("--workers expects a count")?;
                workers_flag = v.parse().ok();
            }
            "--threads" => preset
                .set("threads", value()?)
                .context("--threads expects a count")?,
            "--verify-plans" => preset
                .set("verify_plans", value()?)
                .context("--verify-plans expects 0/1/true/false")?,
            "--cache-path" => preset
                .set("cache_path", value()?)
                .context("--cache-path expects a file path")?,
            "--shards" => preset
                .set("shards", value()?)
                .context("--shards expects a count")?,
            "--run-dir" => preset
                .set("run_dir", value()?)
                .context("--run-dir expects a directory path")?,
            "--listen" => preset
                .set("listen", value()?)
                .context("--listen expects HOST:PORT")?,
            "--connect" => preset
                .set("connect", value()?)
                .context("--connect expects HOST:PORT")?,
            "--token" => token = Some(value()?.clone()),
            "--checkpoint-interval" => preset
                .set("checkpoint_interval", value()?)
                .context("--checkpoint-interval expects a generation count")?,
            "--port" => preset
                .set("port", value()?)
                .context("--port expects a TCP port")?,
            "--batch-deadline-ms" => preset
                .set("batch_deadline_ms", value()?)
                .context("--batch-deadline-ms expects milliseconds")?,
            "--pool-size" => preset
                .set("pool_size", value()?)
                .context("--pool-size expects a worker count (0 = auto)")?,
            "--queue-depth" => preset
                .set("queue_depth", value()?)
                .context("--queue-depth expects a connection count (0 = auto)")?,
            "--trace-out" => preset
                .set("trace_out", value()?)
                .context("--trace-out expects a file path")?,
            "--trace-ops" => preset
                .set("trace_ops", value()?)
                .context("--trace-ops expects a sample rate (0 = off)")?,
            "--set" => {
                let kv = value()?;
                let (k, v) = kv
                    .split_once('=')
                    .with_context(|| format!("--set expects key=value, got {kv}"))?;
                preset.set(k, v)?;
            }
            other => bail!("unknown flag `{other}`"),
        }
        i += 2;
    }
    Ok(Cli {
        command,
        preset,
        out,
        artifacts,
        objectives,
        workers_flag,
        token,
    })
}

/// The medium a sharded driver dispatches over: a shared run directory
/// (rename-based file protocol) or an in-process TCP task server.
enum FleetBackend {
    Fs(RunDir),
    Tcp(Arc<TcpHost>),
}

/// A fleet of locally spawned `snac-pack worker` processes serving one
/// driver. Created before a sharded run; on drop — success or error —
/// it requests shutdown and reaps the children, so workers never
/// outlive their driver. With `--listen` the fleet hosts a TCP task
/// server instead of a run directory, and external workers on other
/// machines may join alongside (or instead of) the local children.
struct ShardFleet {
    backend: FleetBackend,
    children: Vec<std::process::Child>,
}

impl ShardFleet {
    /// Prepare the dispatch medium (run directory + `run.json`, or a TCP
    /// task server with the manifest served over HTTP) and spawn the
    /// local workers. `preset.spawn_workers`: `None` = one worker per
    /// shard; `Some(0)` = none (externally managed workers). For a TCP
    /// run, `token` pins the shared bearer token (`--token`); `None`
    /// mints a fresh per-run one, printed with the join command.
    fn launch(preset: &Preset, artifacts: &Path, token: Option<&str>) -> Result<ShardFleet> {
        // absolute artifacts path: externally started workers may run
        // from any cwd, so a relative fixture-fallback path must not
        // leak into the manifest verbatim
        let artifacts = artifacts
            .canonicalize()
            .unwrap_or_else(|_| artifacts.to_path_buf());
        let mut manifest_pairs = vec![
            ("preset", preset.to_json()),
            ("artifacts", Json::Str(artifacts.display().to_string())),
        ];
        // a traced driver stamps its trace ID so every worker's spans
        // stitch into one logical run
        if let Some(id) = telemetry::trace_id() {
            manifest_pairs.push(("trace", Json::Str(id)));
        }
        let manifest = Json::obj(manifest_pairs);

        let (backend, join_args, medium) = if let Some(bind) = preset.listen.as_deref() {
            let minted;
            let token = match token {
                Some(t) => t,
                None => {
                    // pid+millis, the run_tag scheme: unguessable tokens
                    // are not the goal (use --token for that) — keeping
                    // a stray worker from a *previous* run out is
                    let millis = std::time::SystemTime::now()
                        .duration_since(std::time::UNIX_EPOCH)
                        .map(|d| d.as_millis())
                        .unwrap_or(0);
                    minted = format!("{:x}-{millis:x}", std::process::id());
                    &minted
                }
            };
            let host = Arc::new(TcpHost::listen(bind, Some(manifest.to_string()), token)?);
            // external workers (and the TCP-fleet test) scrape these two
            // lines: the token first, then the bound address on its own
            // line — HOST:0 binds an ephemeral port
            eprintln!("[driver] run token: {token}");
            eprintln!("[driver] task server listening on tcp://{}", host.addr());
            let addr = host.addr().to_string();
            let join = format!("snac-pack worker --connect {addr} --token {token}");
            (
                FleetBackend::Tcp(host),
                vec![
                    "--connect".to_string(),
                    addr,
                    "--token".to_string(),
                    token.to_string(),
                ],
                join,
            )
        } else {
            let run_dir = PathBuf::from(preset.run_dir.as_ref().context(
                "sharded dispatch needs --run-dir DIR (shared filesystem) or \
                 --listen HOST:PORT (TCP)",
            )?);
            let dir = RunDir::new(&run_dir);
            dir.ensure()?;
            // Clear leftovers from a previous run on this directory before
            // any worker exists: a stale shutdown sentinel would stop the
            // fresh workers immediately, and stale queue/result files would
            // burn worker time on shards no driver is waiting for (this
            // run's shard names carry a fresh per-run tag, so stale files
            // could never be *consumed* — only wastefully served).
            dir.clear_shutdown();
            for proto_dir in [dir.queue(), dir.claims(), dir.results(), dir.tmp()] {
                for entry in std::fs::read_dir(&proto_dir).into_iter().flatten().flatten() {
                    let _ = std::fs::remove_file(entry.path());
                }
            }
            // atomic publish (tmp + rename): an externally started worker
            // polling for run.json can never read a torn manifest, and the
            // stale one from a previous run is gone before any worker of
            // this run could load it
            let _ = std::fs::remove_file(dir.manifest_path());
            dir.publish(&dir.manifest_path(), &manifest.to_string())?;
            let join = format!("snac-pack worker --run-dir {}", run_dir.display());
            (
                FleetBackend::Fs(dir),
                vec!["--run-dir".to_string(), run_dir.display().to_string()],
                join,
            )
        };

        let spawn = preset.spawn_workers.unwrap_or(preset.search.shards);
        let mut children = Vec::new();
        if spawn > 0 {
            // split the configured evaluation parallelism across the
            // spawned processes instead of oversubscribing every core
            // `spawn` times (determinism is unaffected either way)
            let per_worker = (resolve_workers(preset.search.workers) / spawn).max(1);
            let exe = std::env::current_exe().context("locating the snac-pack binary")?;
            for _ in 0..spawn {
                children.push(
                    std::process::Command::new(&exe)
                        .arg("worker")
                        .args(&join_args)
                        .arg("--workers")
                        .arg(per_worker.to_string())
                        .spawn()
                        .context("spawning a local worker process")?,
                );
            }
            eprintln!(
                "[driver] spawned {spawn} local worker(s), {per_worker} eval thread(s) each"
            );
        } else {
            eprintln!(
                "[driver] expecting externally managed workers: start them with `{medium}`"
            );
        }
        Ok(ShardFleet { backend, children })
    }

    /// The dispatch transport when this fleet hosts a TCP task server;
    /// `None` means the driver talks the run-directory file protocol.
    fn transport(&self) -> Option<Arc<dyn ShardTransport>> {
        match &self.backend {
            FleetBackend::Fs(_) => None,
            FleetBackend::Tcp(host) => {
                let t: Arc<dyn ShardTransport> = Arc::clone(host);
                Some(t)
            }
        }
    }
}

impl Drop for ShardFleet {
    fn drop(&mut self) {
        match &self.backend {
            FleetBackend::Fs(dir) => {
                let _ = dir.request_shutdown();
            }
            FleetBackend::Tcp(host) => {
                let _ = host.request_shutdown();
            }
        }
        for child in &mut self.children {
            let _ = child.wait();
        }
    }
}

/// The `worker` subcommand over a shared run directory: wait for the
/// driver's `run.json`, then serve shards until shutdown.
fn worker_main(run_dir: &Path, workers_flag: Option<usize>) -> Result<()> {
    let manifest_path = run_dir.join("run.json");
    // externally started workers may race the driver's manifest write:
    // wait for it briefly instead of failing on startup order
    for _ in 0..600 {
        if manifest_path.exists() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    let text = std::fs::read_to_string(&manifest_path).with_context(|| {
        format!(
            "reading {} — is a driver running with --shards over this directory?",
            manifest_path.display()
        )
    })?;
    worker_serve(Arc::new(FsTransport::new(run_dir)?), &text, workers_flag)
}

/// The `worker --connect` subcommand: fetch the run manifest from a TCP
/// driver, then serve shards over the wire until shutdown. No shared
/// filesystem is needed — only the driver's artifacts path must also
/// resolve on this machine, and `--token` must carry the run token the
/// driver printed at launch.
fn worker_connect(addr: &str, workers_flag: Option<usize>, token: &str) -> Result<()> {
    let transport = Arc::new(TcpWorker::connect(addr, Duration::from_secs(10), token));
    // externally started workers may race the driver's startup: poll for
    // the manifest briefly instead of failing on connection order
    let mut text = None;
    for _ in 0..600 {
        if let Ok(Some(m)) = transport.manifest() {
            text = Some(m);
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    let text = text.with_context(|| {
        format!("no run manifest served at {addr} — is a driver running with --listen?")
    })?;
    worker_serve(transport, &text, workers_flag)
}

/// Shared worker body: rebuild the evaluation stack from the run
/// manifest and serve shards over `transport` until the driver requests
/// shutdown. Identical for both transports — the protocol core decides
/// shard order and the driver merges in dispatch order, so results are
/// bit-identical however the shards travelled.
fn worker_serve(
    transport: Arc<dyn ShardTransport>,
    text: &str,
    workers_flag: Option<usize>,
) -> Result<()> {
    let wid = std::process::id();
    let manifest =
        Json::parse(text).map_err(|e| anyhow::anyhow!("parsing the run manifest: {e}"))?;
    let preset = Preset::from_json(manifest.get("preset").context("run.json missing `preset`")?)?;
    let artifacts = PathBuf::from(
        manifest
            .get("artifacts")
            .and_then(Json::as_str)
            .context("run.json missing `artifacts`")?,
    );

    // worker processes inherit the driver's kernel threading and plan
    // verification through the manifest, so a sharded run behaves like
    // the in-process one
    xla::set_dot_threads(preset.search.threads);
    xla::set_verify_plans(preset.search.verify_plans);
    // a traced run: adopt the driver's trace ID so this worker's spans
    // (drained into each result publication) stitch into the driver's
    // trace, and echo it on every shard request
    if preset.trace_out.is_some() {
        let id = telemetry::init(
            manifest.get("trace").and_then(Json::as_str).map(str::to_string),
        );
        transport.set_trace(&id);
        xla::set_op_trace(preset.trace_ops, Some(telemetry::xla_op_sink));
        eprintln!("[worker {wid}] tracing under run {id}");
    }
    let rt = Runtime::load(&artifacts)?;
    let space = SearchSpace::table1();
    let device = FpgaDevice::vu13p();
    let hls = HlsConfig::default();
    let ds = Dataset::generate(
        preset.data.n_train,
        preset.data.n_val,
        preset.data.n_test,
        preset.data.seed,
    );
    let workers = workers_flag.unwrap_or(preset.search.workers);
    eprintln!(
        "[worker {wid}] serving {} with {} eval thread(s)",
        transport.describe(),
        resolve_workers(workers)
    );

    // every result this worker publishes echoes the fingerprint of the
    // manifest its evaluator stack was built from — the driver rejects
    // results computed under a stale manifest instead of merging them
    let opts = WorkerOptions {
        manifest: Some(snac_pack::eval::manifest_fingerprint(text)),
        ..Default::default()
    };
    // trained lazily, once, when a stage's objective set first needs it —
    // deterministically from the preset seed, so every worker (and the
    // driver's reporting pass) derives the identical surrogate
    let mut sur_params: Option<SurrogateParams> = None;
    let summary = run_worker_on(transport, &opts, |stage, requests| {
        let needs = ObjectiveKind::needs_surrogate(&stage.objectives);
        if needs && sur_params.is_none() {
            match train_surrogate(&rt, &space, &preset.surrogate, &hls, &device) {
                Ok((params, mse)) => {
                    eprintln!("[worker {wid}] surrogate trained (MSE {mse:.5})");
                    sur_params = Some(params);
                }
                Err(e) => {
                    let msg = format!("surrogate training failed: {e:#}");
                    return requests.iter().map(|_| Err(anyhow::anyhow!("{msg}"))).collect();
                }
            }
        }
        let predictor = match &sur_params {
            Some(params) if needs => Some(SurrogatePredictor::new(&rt, params.clone())),
            _ => None,
        };
        let ctx = ObjectiveContext {
            space: &space,
            device: &device,
            surrogate: predictor.as_ref(),
            bits: preset.local.bits,
            sparsity: preset.local.target_sparsity,
        };
        let evaluator = SupernetEvaluator::new(
            &rt,
            &ds,
            &space,
            &stage.objectives,
            &ctx,
            TrainConfig {
                epochs: stage.epochs,
                ..Default::default()
            },
        );
        // mirror the in-process pool's generation staging: one batched
        // surrogate prefetch for the whole shard (⌈N/SUR_BATCH⌉
        // executions) instead of one padded execution per trial. Best-
        // effort like the pool's: on failure the per-trial path below
        // surfaces the same error per request.
        let genomes: Vec<Genome> = requests.iter().map(|r| r.genome.clone()).collect();
        if let Err(e) = evaluator.prepare(&genomes) {
            eprintln!("[worker {wid}] shard staging failed, falling back to per-trial: {e:#}");
        }
        // the driver already collapsed duplicates and cache hits out of
        // the shard, so a plain ordered fan-out suffices; per-request
        // errors travel back to the driver individually
        parallel_map(workers, requests.to_vec(), |_, req| {
            let mut rng = req.rng.clone();
            evaluator.evaluate(&req.genome, &mut rng)
        })
    })?;
    eprintln!(
        "[worker {wid}] shutdown: served {} shard(s), {} trial(s)",
        summary.shards, summary.trials
    );
    Ok(())
}

fn main() -> Result<()> {
    let mut cli = parse_cli()?;
    // sharded file-protocol runs need a concrete run directory before
    // the preset is shared with the pipeline and the worker manifest;
    // --listen dispatches over TCP and needs no directory at all
    if cli.preset.search.shards > 0
        && cli.preset.run_dir.is_none()
        && cli.preset.listen.is_none()
    {
        cli.preset.run_dir = Some(cli.out.join("shard-run").display().to_string());
    }
    let cli = cli;
    // global interpreter knobs: dot-general threading and static plan
    // verification; both are bit-identical in their results at every
    // setting, so it is safe to default them from the preset for every
    // subcommand (`worker` re-applies the manifest's values in
    // worker_main)
    xla::set_dot_threads(cli.preset.search.threads);
    xla::set_verify_plans(cli.preset.search.verify_plans);
    // driver-side tracing: mint the run's trace ID up front so a sharded
    // fleet's manifest carries it. Workers adopt theirs from the manifest
    // in worker_serve; serve is excluded (long-running, and /metrics
    // already covers it).
    if let (Some(path), "pipeline" | "search") =
        (cli.preset.trace_out.as_deref(), cli.command.as_str())
    {
        let id = telemetry::init(None);
        xla::set_op_trace(cli.preset.trace_ops, Some(telemetry::xla_op_sink));
        eprintln!("[trace] run {id} -> {path}");
    }
    match cli.command.as_str() {
        "worker" => {
            if let Some(addr) = cli.preset.connect.clone() {
                let token = cli.token.as_deref().context(
                    "worker --connect needs --token TOK — use the run token the \
                     driver printed at launch (`[driver] run token: ...`)",
                )?;
                worker_connect(&addr, cli.workers_flag, token)?;
            } else {
                let run_dir = cli.preset.run_dir.clone().context(
                    "the worker subcommand needs --run-dir DIR (shared filesystem) \
                     or --connect HOST:PORT (TCP driver)",
                )?;
                worker_main(Path::new(&run_dir), cli.workers_flag)?;
            }
        }
        "info" => {
            let rt = Runtime::load(&cli.artifacts_dir())?;
            println!("platform: {}", rt.platform());
            for (name, spec) in &rt.manifest().artifacts {
                println!(
                    "artifact {name}: {} inputs / {} outputs ({})",
                    spec.inputs.len(),
                    spec.outputs.len(),
                    spec.file
                );
            }
        }
        "pipeline" => {
            let artifacts = cli.artifacts_dir();
            let rt = Runtime::load(&artifacts)?;
            // dropped (= shutdown + reap) when this arm finishes, success
            // or error — workers never outlive the driver
            let fleet = (cli.preset.search.shards > 0)
                .then(|| ShardFleet::launch(&cli.preset, &artifacts, cli.token.as_deref()))
                .transpose()?;
            let transport = fleet.as_ref().and_then(|f| f.transport());
            let summary =
                coordinator::run_pipeline_with(&rt, &cli.preset, &cli.out, transport)?;
            println!("{}", summary.table2);
            println!("{}", summary.table3);
            println!("stage timings:");
            for (stage, secs) in &summary.timings {
                println!("  {stage:<28} {secs:>8.1}s");
            }
            println!("reports written to {}", cli.out.display());
        }
        "search" => {
            let artifacts = cli.artifacts_dir();
            let sharded = cli.preset.search.shards > 0;
            // sharded drivers never evaluate, so they skip the (interpreter)
            // runtime load entirely — workers load their own; a cheap
            // manifest check still catches a bad --artifacts up front
            let rt = if sharded {
                anyhow::ensure!(
                    artifacts.join("manifest.json").exists(),
                    "no manifest.json under {} — workers could not load a runtime",
                    artifacts.display()
                );
                None
            } else {
                Some(Runtime::load(&artifacts)?)
            };
            let space = SearchSpace::table1();
            let device = FpgaDevice::vu13p();
            let ds = Dataset::generate(
                cli.preset.data.n_train,
                cli.preset.data.n_val,
                cli.preset.data.n_test,
                cli.preset.data.seed,
            );
            let fleet = sharded
                .then(|| ShardFleet::launch(&cli.preset, &artifacts, cli.token.as_deref()))
                .transpose()?;
            // in sharded mode the workers train the surrogate themselves
            // (deterministically, from the same preset seed), so the
            // driver skips it
            let sur = if !sharded && ObjectiveKind::needs_surrogate(&cli.objectives) {
                let rt = rt.as_ref().context("runtime loaded for non-sharded search")?;
                let (p, mse) = train_surrogate(
                    rt,
                    &space,
                    &cli.preset.surrogate,
                    &HlsConfig::default(),
                    &device,
                )?;
                eprintln!("surrogate MSE: {mse:.5}");
                Some(SurrogatePredictor::new(rt, p))
            } else {
                None
            };
            let cfg = GlobalSearchConfig {
                objectives: cli.objectives.clone(),
                ctx: ObjectiveContext {
                    space: &space,
                    device: &device,
                    surrogate: sur.as_ref(),
                    bits: cli.preset.local.bits,
                    sparsity: cli.preset.local.target_sparsity,
                },
                nsga2: cli.preset.nsga2(),
                trials: cli.preset.search.trials,
                epochs: cli.preset.search.epochs,
                seed: cli.preset.seed,
                workers: cli.preset.search.workers,
                accuracy_threshold: 0.0,
                progress: Some(Box::new(|i, n, r: &TrialRecord| {
                    eprintln!("trial {i}/{n}: {} acc={:.4}", r.label, r.accuracy);
                })),
                cache_path: cli.preset.cache_path.as_ref().map(PathBuf::from),
                checkpoint: (cli.preset.search.checkpoint_interval > 0).then(|| {
                    CheckpointConfig {
                        path: cli.out.join("checkpoint-search.json"),
                        interval: cli.preset.search.checkpoint_interval,
                    }
                }),
            };
            let outcome = if sharded {
                let run_dir = cli.preset.run_dir.as_ref().map(PathBuf::from);
                let backend = match (fleet.as_ref().and_then(|f| f.transport()), &run_dir) {
                    (Some(t), _) => DispatchBackend::Transport(t),
                    (None, Some(dir)) => DispatchBackend::RunDir(dir),
                    (None, None) => bail!(
                        "sharded dispatch needs --run-dir DIR or --listen HOST:PORT"
                    ),
                };
                coordinator::global_search_sharded(
                    &ds,
                    &space,
                    cfg,
                    &ShardedDispatch {
                        backend,
                        label: "search",
                        shards: cli.preset.search.shards,
                        timings: ShardTimings::default(),
                    },
                )?
            } else {
                let rt = rt.as_ref().context("runtime loaded for non-sharded search")?;
                coordinator::global_search(rt, &ds, &space, cfg)?
            };
            drop(fleet);
            std::fs::create_dir_all(&cli.out)?;
            TrialRecord::save_all(&outcome.records, &cli.out.join("trials.json"))?;
            println!(
                "{} trials in {:.1}s ({:.2} trials/s, {} workers); front size {}; \
                 trials.json written to {}",
                outcome.records.len(),
                outcome.wall_seconds,
                outcome.records.len() as f64 / outcome.wall_seconds.max(1e-9),
                snac_pack::eval::resolve_workers(cli.preset.search.workers),
                outcome.front.len(),
                cli.out.display()
            );
            println!(
                "cache: {} trained, {} cache hits, {} restored from snapshot",
                outcome.evaluations, outcome.cache_hits, outcome.cache_restored
            );
            for &i in &outcome.front {
                let r = &outcome.records[i];
                println!("  front: {} acc={:.4} obj={:?}", r.label, r.accuracy, r.objectives);
            }
        }
        "serve" => {
            // The estimation service: train the surrogate once (exactly
            // the search's protocol, so served numbers match search-time
            // estimates), then expose it over HTTP with the
            // micro-batching engine coalescing concurrent requests.
            let rt = Runtime::load(&cli.artifacts_dir())?;
            let space = SearchSpace::table1();
            let device = FpgaDevice::vu13p();
            let (params, mse) = train_surrogate(
                &rt,
                &space,
                &cli.preset.surrogate,
                &HlsConfig::default(),
                &device,
            )?;
            eprintln!("[serve] surrogate trained (MSE {mse:.5})");
            let predictor = SurrogatePredictor::new(&rt, params);
            let engine = SurrogateEngine::new(
                &predictor,
                EngineConfig {
                    deadline: Duration::from_millis(cli.preset.serve.batch_deadline_ms),
                    ..Default::default()
                },
            );
            let listener = TcpListener::bind(("127.0.0.1", cli.preset.serve.port))
                .with_context(|| format!("binding 127.0.0.1:{}", cli.preset.serve.port))?;
            let addr = listener.local_addr()?;
            let ctx = ServeContext {
                engine: &engine,
                space: &space,
                device: &device,
                bits: cli.preset.local.bits,
                sparsity: cli.preset.local.target_sparsity,
                platform: rt.platform(),
                metrics: ServeMetrics::new(),
            };
            let tuning = ServeTuning {
                pool_size: cli.preset.serve.pool_size,
                queue_depth: cli.preset.serve.queue_depth,
                ..Default::default()
            };
            // the smoke client scrapes this line for the ephemeral port —
            // flush it through before blocking in the accept loop
            println!("snac-pack serve: listening on http://{addr}");
            use std::io::Write as _;
            std::io::stdout().flush().ok();
            eprintln!(
                "[serve] endpoints: GET /healthz | GET /metrics | POST /estimate | \
                 POST /estimate/batch | POST /shutdown \
                 (batch deadline {}ms, {} workers, queue depth {}, device {})",
                cli.preset.serve.batch_deadline_ms,
                tuning.resolved_pool(),
                tuning.resolved_depth(),
                device.name
            );
            serve::serve(&ctx, listener, &tuning)?;
            eprintln!(
                "[serve] shutdown: {} requests ({} shed), {} flushes, {} rows, \
                 {} interpreter executions",
                ctx.metrics.requests(),
                ctx.metrics.shed_count(),
                engine.flushes(),
                engine.rows_flushed(),
                predictor.executions()
            );
        }
        "surrogate" => {
            let rt = Runtime::load(&cli.artifacts_dir())?;
            let space = SearchSpace::table1();
            let device = FpgaDevice::vu13p();
            let hls = HlsConfig::default();
            let (params, mse) =
                train_surrogate(&rt, &space, &cli.preset.surrogate, &hls, &device)?;
            println!("surrogate trained: final MSE {mse:.5} (compressed space)");
            // held-out sanity: compare predictions against the simulator
            let sur = SurrogatePredictor::new(&rt, params);
            let mut rng = snac_pack::util::Rng::new(999);
            let mut rel_err = [0.0f64; 2];
            let n = 64;
            for _ in 0..n {
                let g = space.sample(&mut rng);
                let est = sur.predict(&g, &space, 8, 0.5)?;
                let spec = NetworkSpec::from_genome(&g, &space, 8, 0.5);
                let truth = synthesize(&spec, &hls, &device);
                rel_err[0] +=
                    ((est.lut - truth.lut as f64) / (truth.lut as f64 + 1.0)).abs();
                rel_err[1] += ((est.latency_cc - truth.latency_cc as f64)
                    / (truth.latency_cc as f64 + 1.0))
                    .abs();
            }
            println!(
                "held-out mean relative error: LUT {:.1}%, latency {:.1}%",
                rel_err[0] / n as f64 * 100.0,
                rel_err[1] / n as f64 * 100.0
            );
        }
        "synth" => {
            // Table-3-style synthesis of the baseline at several sparsities
            let space = SearchSpace::table1();
            let device = FpgaDevice::vu13p();
            let hls = HlsConfig::default();
            println!("baseline [12] synthesis sweep on {}:", device.name);
            println!("sparsity  DSP    LUT      FF     BRAM  lat(cc)");
            for s in [0.0, 0.25, 0.5, 0.75] {
                let mut spec = NetworkSpec::from_genome(&space.baseline(), &space, 8, s);
                spec.softmax_head = true;
                spec.fuse_batch_norm = false; // legacy [12] synthesis
                let r = synthesize(&spec, &hls, &device);
                println!(
                    "{s:>7.2}  {:>4}  {:>6}  {:>6}  {:>4}  {:>6}",
                    r.dsp, r.lut, r.ff, r.bram36, r.latency_cc
                );
            }
        }
        other => bail!("unknown command `{other}`"),
    }
    if let (true, Some(path)) = (telemetry::enabled(), cli.preset.trace_out.as_deref()) {
        let path = Path::new(path);
        if let Some(parent) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
            let _ = std::fs::create_dir_all(parent);
        }
        match telemetry::export(path) {
            Ok(summary) => {
                eprintln!("[trace] wrote {} (+ .jsonl flight log)", path.display());
                eprint!("{summary}");
            }
            Err(e) => eprintln!("[trace] export failed: {e}"),
        }
    }
    Ok(())
}
