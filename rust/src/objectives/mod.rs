//! Search objectives — the paper's central axis of comparison.
//!
//! * **NAC** optimises `{accuracy, BOPs}` (the proxy the paper argues
//!   against);
//! * **SNAC-Pack** optimises `{accuracy, estimated average resources,
//!   estimated clock cycles}` via the rule4ml-style surrogate.
//!
//! All objectives are converted to *minimisation* (accuracy is negated)
//! before entering NSGA-II / Pareto machinery.

use anyhow::Result;

use crate::hls::FpgaDevice;
use crate::nn::{bops, Genome, SearchSpace};
use crate::surrogate::SurrogatePredictor;

/// One optimisation objective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ObjectiveKind {
    /// Validation accuracy (entered negated).
    Accuracy,
    /// Bit operations at the assumed deployment precision.
    Bops,
    /// Surrogate-estimated mean utilisation % over DSP/LUT/FF/BRAM.
    EstAvgResources,
    /// Surrogate-estimated latency in clock cycles.
    EstClockCycles,
}

impl ObjectiveKind {
    /// Display name (report headers).
    pub fn name(self) -> &'static str {
        match self {
            ObjectiveKind::Accuracy => "accuracy",
            ObjectiveKind::Bops => "bops",
            ObjectiveKind::EstAvgResources => "est_avg_resources",
            ObjectiveKind::EstClockCycles => "est_clock_cycles",
        }
    }

    /// The paper's NAC objective set.
    pub fn nac_set() -> Vec<ObjectiveKind> {
        vec![ObjectiveKind::Accuracy, ObjectiveKind::Bops]
    }

    /// The paper's SNAC-Pack objective set.
    pub fn snac_set() -> Vec<ObjectiveKind> {
        vec![
            ObjectiveKind::Accuracy,
            ObjectiveKind::EstAvgResources,
            ObjectiveKind::EstClockCycles,
        ]
    }

    /// True if any objective in `kinds` needs the trained surrogate
    /// (used by callers to decide whether to train one before searching).
    pub fn needs_surrogate(kinds: &[ObjectiveKind]) -> bool {
        kinds
            .iter()
            .any(|k| matches!(k, ObjectiveKind::EstAvgResources | ObjectiveKind::EstClockCycles))
    }

    /// Parse a comma-separated list (CLI).
    pub fn parse_set(s: &str) -> Result<Vec<ObjectiveKind>> {
        s.split(',')
            .map(|tok| match tok.trim() {
                "accuracy" | "acc" => Ok(ObjectiveKind::Accuracy),
                "bops" => Ok(ObjectiveKind::Bops),
                "est_avg_resources" | "resources" => Ok(ObjectiveKind::EstAvgResources),
                "est_clock_cycles" | "cycles" => Ok(ObjectiveKind::EstClockCycles),
                other => anyhow::bail!("unknown objective `{other}`"),
            })
            .collect()
    }
}

/// Static context shared by objective evaluations.
///
/// One context is shared by reference across every evaluation worker
/// (`eval::ParallelEvaluator`); it is immutable here, and the surrogate
/// predictor's memo cache is internally synchronised, so evaluation may
/// run concurrently without coordination.
pub struct ObjectiveContext<'a> {
    /// Search space (for layer dims).
    pub space: &'a SearchSpace,
    /// Target device (utilisation percentages).
    pub device: &'a FpgaDevice,
    /// The trained surrogate; required for the Est* objectives.
    pub surrogate: Option<&'a SurrogatePredictor<'a>>,
    /// Deployment precision assumed during global search (paper: 8-bit QAT
    /// downstream).
    pub bits: u32,
    /// Deployment sparsity assumed during global search (paper's local
    /// search prunes to ~50 %).
    pub sparsity: f64,
}

impl<'a> ObjectiveContext<'a> {
    /// Batch-prefetch surrogate estimates for a whole generation.
    ///
    /// When `kinds` needs the surrogate, this predicts every genome's
    /// feature vector at this context's deployment point in
    /// ⌈unique/`SUR_BATCH`⌉ interpreter executions (duplicates and
    /// already-memoised genomes cost zero rows — see
    /// [`SurrogatePredictor::predict_batch`]), priming the predictor's
    /// memo so the per-trial [`evaluate`](Self::evaluate) calls that
    /// follow are pure cache hits. Estimates are bit-identical to the
    /// per-trial path, so objectives (and the trial database) do not
    /// change — only the execution count does. Returns the number of
    /// genomes prefetched (0 when no surrogate objective is configured).
    pub fn prefetch(&self, kinds: &[ObjectiveKind], genomes: &[Genome]) -> Result<usize> {
        if genomes.is_empty() || !ObjectiveKind::needs_surrogate(kinds) {
            return Ok(0);
        }
        // a missing surrogate stays a per-trial error (same message,
        // same failing trials) rather than failing the whole batch here
        let Some(sur) = self.surrogate else {
            return Ok(0);
        };
        sur.predict_genomes(genomes, self.space, self.bits, self.sparsity)?;
        Ok(genomes.len())
    }

    /// Evaluate `kinds` for a genome with measured validation `accuracy`.
    /// Returns the minimised objective vector, plus the raw
    /// `(est_avg_resources, est_clock_cycles)` pair when a surrogate ran.
    pub fn evaluate(
        &self,
        kinds: &[ObjectiveKind],
        genome: &Genome,
        accuracy: f64,
    ) -> Result<(Vec<f64>, Option<(f64, f64)>)> {
        let mut est_pair = None;
        let mut out = Vec::with_capacity(kinds.len());
        for kind in kinds {
            out.push(match kind {
                ObjectiveKind::Accuracy => -accuracy,
                ObjectiveKind::Bops => {
                    bops::genome_bops(genome, self.space, self.bits, self.bits, self.sparsity)
                }
                ObjectiveKind::EstAvgResources | ObjectiveKind::EstClockCycles => {
                    let sur = self.surrogate.ok_or_else(|| {
                        anyhow::anyhow!("objective {} needs a trained surrogate", kind.name())
                    })?;
                    let est = sur.predict(genome, self.space, self.bits, self.sparsity)?;
                    let pair = (est.avg_resources(self.device), est.latency_cc);
                    est_pair = Some(pair);
                    match kind {
                        ObjectiveKind::EstAvgResources => pair.0,
                        _ => pair.1,
                    }
                }
            });
        }
        Ok((out, est_pair))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn objective_sets_match_paper() {
        assert_eq!(ObjectiveKind::nac_set().len(), 2);
        assert_eq!(ObjectiveKind::snac_set().len(), 3);
        assert_eq!(ObjectiveKind::snac_set()[0], ObjectiveKind::Accuracy);
    }

    #[test]
    fn needs_surrogate_flags_estimate_objectives() {
        assert!(!ObjectiveKind::needs_surrogate(&ObjectiveKind::nac_set()));
        assert!(ObjectiveKind::needs_surrogate(&ObjectiveKind::snac_set()));
        assert!(ObjectiveKind::needs_surrogate(&[
            ObjectiveKind::Accuracy,
            ObjectiveKind::EstClockCycles,
        ]));
    }

    #[test]
    fn parse_round_trips() {
        let set = ObjectiveKind::parse_set("accuracy, bops").unwrap();
        assert_eq!(set, ObjectiveKind::nac_set());
        let set = ObjectiveKind::parse_set("acc,resources,cycles").unwrap();
        assert_eq!(set, ObjectiveKind::snac_set());
        assert!(ObjectiveKind::parse_set("nope").is_err());
    }

    #[test]
    fn accuracy_is_negated_and_bops_positive() {
        let space = SearchSpace::table1();
        let device = FpgaDevice::vu13p();
        let ctx = ObjectiveContext {
            space: &space,
            device: &device,
            surrogate: None,
            bits: 8,
            sparsity: 0.0,
        };
        let (obj, est) = ctx
            .evaluate(&ObjectiveKind::nac_set(), &space.baseline(), 0.64)
            .unwrap();
        assert_eq!(obj[0], -0.64);
        assert!(obj[1] > 0.0);
        assert!(est.is_none());
    }

    /// `prefetch` is a no-op without surrogate objectives, and a missing
    /// surrogate defers its error to the per-trial `evaluate` (same
    /// failure, same message) instead of failing the batch stage.
    #[test]
    fn prefetch_without_surrogate_is_a_noop() {
        let space = SearchSpace::table1();
        let device = FpgaDevice::vu13p();
        let ctx = ObjectiveContext {
            space: &space,
            device: &device,
            surrogate: None,
            bits: 8,
            sparsity: 0.0,
        };
        let genomes = [space.baseline()];
        assert_eq!(ctx.prefetch(&ObjectiveKind::nac_set(), &genomes).unwrap(), 0);
        assert_eq!(ctx.prefetch(&ObjectiveKind::snac_set(), &genomes).unwrap(), 0);
        assert_eq!(ctx.prefetch(&ObjectiveKind::snac_set(), &[]).unwrap(), 0);
    }

    #[test]
    fn surrogate_objectives_without_surrogate_error() {
        let space = SearchSpace::table1();
        let device = FpgaDevice::vu13p();
        let ctx = ObjectiveContext {
            space: &space,
            device: &device,
            surrogate: None,
            bits: 8,
            sparsity: 0.0,
        };
        assert!(ctx
            .evaluate(&ObjectiveKind::snac_set(), &space.baseline(), 0.6)
            .is_err());
    }
}
