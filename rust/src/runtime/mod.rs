//! PJRT runtime: loads the AOT HLO-text artifacts and executes them.
//!
//! This is the only place the `xla` crate appears. The flow mirrors
//! /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO *text* is the interchange format (the
//! bundled xla_extension 0.5.1 rejects jax ≥ 0.5 serialized protos — see
//! python/compile/aot.py).
//!
//! Python never runs here; after `make artifacts` the binary is fully
//! self-contained.

pub mod manifest;
#[allow(clippy::module_inception)]
pub mod runtime;

pub use manifest::{ArtifactSpec, Manifest};
pub use runtime::{Runtime, TensorArg};
