//! PJRT runtime: loads the AOT HLO-text artifacts and executes them.
//!
//! This is the only place the `xla` crate appears. The flow mirrors
//! /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO *text* is the interchange format (the
//! bundled xla_extension 0.5.1 rejects jax ≥ 0.5 serialized protos — see
//! python/compile/aot.py).
//!
//! Python never runs here; after `make artifacts` the binary is fully
//! self-contained.

pub mod manifest;
#[allow(clippy::module_inception)]
pub mod runtime;

pub use manifest::{ArtifactSpec, Manifest};
pub use runtime::{Runtime, TensorArg};

use std::path::{Path, PathBuf};

/// Locate an artifact directory this build can load, in preference order:
///
/// 1. `rust/artifacts/` — real AOT artifacts produced by `make artifacts`
///    (the JAX lowering); always wins when present.
/// 2. `rust/xla/tests/fixtures/` — the checked-in hand-authored HLO
///    fixtures executed by the `rust/xla` interpreter, so the runtime path
///    works out of a fresh clone with no Python at all.
///
/// Returns `None` only when neither contains a `manifest.json` (e.g. a
/// stripped release tree), so callers can emit a precise error.
///
/// Note the preference is unconditional: a tree with real AOT artifacts is
/// expected to also link the real PJRT bindings (rust/xla/README.md) — the
/// interpreter rejects ops outside its documented set at `Runtime::load`
/// rather than falling back to fixtures, so real-artifact breakage is loud
/// instead of silently masked by simplified fixtures.
pub fn artifact_dir() -> Option<PathBuf> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    [root.join("artifacts"), root.join("xla/tests/fixtures")]
        .into_iter()
        .find(|d| d.join("manifest.json").exists())
}

/// CLI-style resolution: `preferred` (conventionally `./artifacts`) when it
/// holds a manifest, else whatever this build can load via
/// [`artifact_dir`], else `preferred` unchanged so the eventual
/// `Runtime::load` error still names the conventional path.
///
/// Falling back is *announced* on stderr: the fixture artifacts are a
/// simplified supernet (see rust/xla/tests/fixtures/README.md), so a user
/// who forgot `make artifacts` must be able to see their numbers came from
/// interpreted fixtures, not the real AOT graphs.
pub fn resolve_artifact_dir(preferred: &Path) -> PathBuf {
    if preferred.join("manifest.json").exists() {
        return preferred.to_path_buf();
    }
    match artifact_dir() {
        Some(dir) => {
            eprintln!(
                "[runtime] no manifest in {}; loading artifacts from {} \
                 (checked-in fixtures run through the rust/xla interpreter — \
                 run `make artifacts` for the real AOT graphs)",
                preferred.display(),
                dir.display()
            );
            dir
        }
        None => preferred.to_path_buf(),
    }
}
