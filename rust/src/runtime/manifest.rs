//! The AOT ABI manifest (`artifacts/manifest.json`).
//!
//! `python/compile/aot.py` records the ordered input/output names and
//! shapes of every artifact; this module parses it and cross-checks the
//! constants against `nn::abi` so a drifted Python build fails fast at
//! load time instead of producing garbage numerics.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::nn;
use crate::util::Json;

/// One artifact's ABI.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// HLO text filename relative to the artifact dir.
    pub file: String,
    /// Ordered `(name, shape)` inputs.
    pub inputs: Vec<(String, Vec<usize>)>,
    /// Ordered output names.
    pub outputs: Vec<String>,
}

impl ArtifactSpec {
    /// Total input element count.
    pub fn input_elems(&self) -> usize {
        self.inputs
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum()
    }

    /// Index of a named input.
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|(n, _)| n == name)
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// ABI version tag.
    pub abi_version: usize,
    /// Artifact name → spec.
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn get_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(Json::as_usize)
        .with_context(|| format!("manifest missing numeric `{key}`"))
}

impl Manifest {
    /// Load and validate `manifest.json` from an artifact directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))?;

        // --- constant cross-check (the ABI contract) ---
        let c = j.get("constants").context("manifest missing `constants`")?;
        let checks: [(&str, usize); 10] = [
            ("pad", nn::PAD),
            ("num_layers", nn::NUM_LAYERS),
            ("in_dim", nn::IN_DIM),
            ("out_dim", nn::OUT_DIM),
            ("batch", nn::BATCH),
            ("eval_batch", nn::EVAL_BATCH),
            ("hp_len", nn::HP_LEN),
            ("sur_feats", nn::SUR_FEATS),
            ("sur_out", nn::SUR_OUT),
            ("sur_batch", nn::SUR_BATCH),
        ];
        for (key, expected) in checks {
            let got = get_usize(c, key)?;
            if got != expected {
                bail!(
                    "ABI drift: manifest `{key}` = {got} but this binary was \
                     built for {expected}; re-run `make artifacts`"
                );
            }
        }

        // --- artifact specs ---
        let mut artifacts = BTreeMap::new();
        let arts = j.get("artifacts").context("manifest missing `artifacts`")?;
        if let Json::Obj(m) = arts {
            for (name, spec) in m {
                let file = spec
                    .get("file")
                    .and_then(Json::as_str)
                    .context("artifact missing `file`")?
                    .to_string();
                let mut inputs = Vec::new();
                for inp in spec.get("inputs").context("missing inputs")?.items() {
                    let n = inp
                        .get("name")
                        .and_then(Json::as_str)
                        .context("input missing name")?;
                    let shape: Vec<usize> = inp
                        .get("shape")
                        .context("input missing shape")?
                        .items()
                        .iter()
                        .filter_map(Json::as_usize)
                        .collect();
                    inputs.push((n.to_string(), shape));
                }
                let outputs = spec
                    .get("outputs")
                    .context("missing outputs")?
                    .items()
                    .iter()
                    .filter_map(Json::as_str)
                    .map(str::to_string)
                    .collect();
                artifacts.insert(name.clone(), ArtifactSpec { file, inputs, outputs });
            }
        }
        for required in ["train_step", "eval_step", "surrogate_train", "surrogate_predict"] {
            if !artifacts.contains_key(required) {
                bail!("manifest missing required artifact `{required}`");
            }
        }
        Ok(Manifest {
            abi_version: get_usize(&j, "abi_version")?,
            artifacts,
        })
    }

    /// Spec of a named artifact.
    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("unknown artifact `{name}`"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_manifest() {
        // real AOT artifacts when built, else the checked-in HLO fixtures
        // (same ABI) — never skipped
        let dir = crate::runtime::artifact_dir().expect("no artifact manifest found");
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.abi_version, 1);
        let ts = m.spec("train_step").unwrap();
        assert_eq!(ts.inputs.len(), 32);
        assert_eq!(ts.inputs[0].0, "w0");
        assert_eq!(ts.inputs[0].1, vec![nn::IN_DIM, nn::PAD]);
        assert_eq!(ts.outputs.len(), 25);
        assert_eq!(ts.input_index("x"), Some(30));
    }

    #[test]
    fn missing_dir_is_a_clean_error() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
