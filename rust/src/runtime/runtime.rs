//! The executor: compiled artifacts + shape-checked execution.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::manifest::Manifest;

/// A borrowed input tensor (shape checked against the manifest).
pub struct TensorArg<'a> {
    /// Input name (must match the manifest, in order).
    pub name: &'a str,
    /// Row-major f32 data.
    pub data: &'a [f32],
}

/// Convenience constructor used all over the trainer.
pub fn arg<'a>(name: &'a str, data: &'a [f32]) -> TensorArg<'a> {
    TensorArg { name, data }
}

/// Loaded PJRT runtime: one compiled executable per artifact.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: BTreeMap<String, xla::PjRtLoadedExecutable>,
}


impl Runtime {
    /// Load every artifact in `dir` (validated against `manifest.json`)
    /// and compile it on the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut exes = BTreeMap::new();
        for (name, spec) in &manifest.artifacts {
            let path = dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling artifact `{name}`"))?;
            exes.insert(name.clone(), exe);
        }
        Ok(Runtime {
            client,
            manifest,
            exes,
        })
    }

    /// The ABI manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute an artifact. `args` must match the manifest's input order,
    /// names, and element counts exactly; outputs are returned as flat
    /// `Vec<f32>`s in the manifest's output order.
    pub fn run(&self, name: &str, args: &[TensorArg<'_>]) -> Result<Vec<Vec<f32>>> {
        let spec = self.manifest.spec(name)?;
        if args.len() != spec.inputs.len() {
            bail!(
                "artifact `{name}` expects {} inputs, got {}",
                spec.inputs.len(),
                args.len()
            );
        }
        // Inputs go up as PjRtBuffers we own (freed on drop). This matters:
        // the crate's Literal-based `execute` path leaks its device-side
        // input copies in the C wrapper (`release()` with no post-Execute
        // free), which OOMs a 20k-step search. `execute_b` borrows our
        // buffers instead.
        let mut buffers = Vec::with_capacity(args.len());
        for (a, (want_name, shape)) in args.iter().zip(&spec.inputs) {
            if a.name != want_name {
                bail!("artifact `{name}`: input `{}` out of order (expected `{want_name}`)", a.name);
            }
            let want: usize = shape.iter().product();
            if a.data.len() != want {
                bail!(
                    "artifact `{name}`: input `{}` has {} elements, expected {} {shape:?}",
                    a.name,
                    a.data.len(),
                    want
                );
            }
            buffers.push(
                self.client
                    .buffer_from_host_buffer::<f32>(a.data, shape, None)
                    .with_context(|| format!("uploading `{}`", a.name))?,
            );
        }
        // `spec()` above proves `name` is in the manifest, and `load`
        // compiles every manifest artifact — but keep this a typed error
        // (not a panic) so a future partial-load path fails with context.
        let exe = self.exes.get(name).with_context(|| {
            format!(
                "artifact `{name}` is in the manifest but was never compiled \
                 (loaded: {:?})",
                self.exes.keys().collect::<Vec<_>>()
            )
        })?;
        let result = exe
            .execute_b(&buffers)
            .with_context(|| format!("executing `{name}`"))?;
        // single replica; the graph was lowered with return_tuple=True
        let replica = result
            .into_iter()
            .next()
            .with_context(|| format!("artifact `{name}` returned no replica outputs"))?;
        let out = replica
            .into_iter()
            .next()
            .with_context(|| format!("artifact `{name}` returned no output buffer"))?;
        let tuple = out
            .to_literal_sync()
            .with_context(|| format!("downloading result of `{name}`"))?;
        let leaves = tuple
            .to_tuple()
            .with_context(|| format!("untupling result of `{name}`"))?;
        if leaves.len() != spec.outputs.len() {
            bail!(
                "artifact `{name}` returned {} outputs, manifest says {}",
                leaves.len(),
                spec.outputs.len()
            );
        }
        leaves
            .into_iter()
            .map(|l| l.to_vec::<f32>().context("downloading output"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn;

    /// Real AOT artifacts when built, else the checked-in HLO fixtures —
    /// never skipped: a clone with neither is a broken clone.
    fn runtime() -> Runtime {
        let dir = crate::runtime::artifact_dir()
            .expect("no artifacts/ and no xla/tests/fixtures/ manifest — fixtures are checked in, so this tree is incomplete");
        Runtime::load(&dir).expect("runtime load")
    }

    #[test]
    fn loads_from_fixtures_and_reports_platform() {
        let rt = runtime();
        assert!(!rt.platform().is_empty());
        assert!(rt.manifest().artifacts.contains_key("surrogate_predict"));
    }

    #[test]
    fn surrogate_predict_runs_and_is_linear_at_zero_weights() {
        let rt = runtime();
        let z1 = vec![0.0f32; nn::SUR_FEATS * nn::SUR_HIDDEN];
        let zb1 = vec![0.0f32; nn::SUR_HIDDEN];
        let z2 = vec![0.0f32; nn::SUR_HIDDEN * nn::SUR_HIDDEN];
        let zb2 = vec![0.0f32; nn::SUR_HIDDEN];
        let z3 = vec![0.0f32; nn::SUR_HIDDEN * nn::SUR_OUT];
        let mut zb3 = vec![0.0f32; nn::SUR_OUT];
        zb3.copy_from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let x = vec![0.5f32; nn::SUR_BATCH * nn::SUR_FEATS];
        let out = rt
            .run(
                "surrogate_predict",
                &[
                    arg("sw1", &z1),
                    arg("sb1", &zb1),
                    arg("sw2", &z2),
                    arg("sb2", &zb2),
                    arg("sw3", &z3),
                    arg("sb3", &zb3),
                    arg("x", &x),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        let pred = &out[0];
        assert_eq!(pred.len(), nn::SUR_BATCH * nn::SUR_OUT);
        // all-zero weights → prediction == output bias everywhere
        for row in pred.chunks(nn::SUR_OUT) {
            assert_eq!(row, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        }
    }

    #[test]
    fn unknown_artifact_is_a_typed_error_with_the_name() {
        let rt = runtime();
        let err = rt.run("nonexistent", &[]).unwrap_err();
        assert!(format!("{err:#}").contains("nonexistent"));
    }

    #[test]
    fn wrong_input_order_is_rejected() {
        let rt = runtime();
        let z = vec![0.0f32; 4];
        let err = rt
            .run("surrogate_predict", &[arg("sb1", &z)])
            .unwrap_err();
        assert!(format!("{err:#}").contains("expects"));
    }

    #[test]
    fn wrong_element_count_is_rejected() {
        let rt = runtime();
        let short = vec![0.0f32; 3];
        let args: Vec<TensorArg> = ["sw1", "sb1", "sw2", "sb2", "sw3", "sb3", "x"]
            .iter()
            .map(|n| arg(n, &short))
            .collect();
        let err = rt.run("surrogate_predict", &args).unwrap_err();
        assert!(format!("{err:#}").contains("elements"));
    }
}
