//! The Table 1 search space and genome sampling / variation operators.


use super::abi::NUM_LAYERS;
use super::genome::{Activation, Genome};
use crate::util::Rng;

/// The comprehensive MLP parameter space of the paper's Table 1.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    /// Depth choices ({4..8} in the paper).
    pub depth_choices: Vec<usize>,
    /// Hidden-unit choices per layer position.
    pub width_choices: [Vec<usize>; NUM_LAYERS],
    /// Learning-rate choices.
    pub lr_choices: Vec<f32>,
    /// L1 regularisation choices.
    pub l1_choices: Vec<f32>,
    /// Dropout-rate choices.
    pub dropout_choices: Vec<f32>,
}

impl SearchSpace {
    /// The exact space of the paper's Table 1.
    pub fn table1() -> Self {
        SearchSpace {
            depth_choices: vec![4, 5, 6, 7, 8],
            width_choices: [
                vec![64, 120, 128], // layer 1
                vec![32, 60, 64],   // layer 2
                vec![16, 32],       // layer 3
                vec![32, 64],       // layer 4
                vec![32, 64],       // layer 5
                vec![32, 64],       // layer 6
                vec![16, 32],       // layer 7
                vec![32, 44, 64],   // layer 8
            ],
            lr_choices: vec![0.0010, 0.0015, 0.0020],
            l1_choices: vec![0.0, 1e-6, 1e-5, 1e-4],
            dropout_choices: vec![0.0, 0.05, 0.1],
        }
    }

    /// Number of distinct architectures (ignoring training hyperparameters).
    pub fn architecture_count(&self) -> u64 {
        let mut total = 0u64;
        for &d in &self.depth_choices {
            let mut combos = 1u64;
            for i in 0..d {
                combos *= self.width_choices[i].len() as u64;
            }
            combos *= Activation::ALL.len() as u64 * 2; // act × bn
            total += combos;
        }
        total
    }

    /// Uniform random genome.
    pub fn sample(&self, rng: &mut Rng) -> Genome {
        let mut width_idx = [0usize; NUM_LAYERS];
        for (i, w) in width_idx.iter_mut().enumerate() {
            *w = rng.below(self.width_choices[i].len());
        }
        Genome {
            n_layers: *rng.choose(&self.depth_choices),
            width_idx,
            act: *rng.choose(&Activation::ALL),
            batch_norm: rng.chance(0.5),
            lr_idx: rng.below(self.lr_choices.len()),
            l1_idx: rng.below(self.l1_choices.len()),
            dropout_idx: rng.below(self.dropout_choices.len()),
        }
    }

    /// Uniform (gene-wise) crossover of two parents.
    pub fn crossover(&self, a: &Genome, b: &Genome, rng: &mut Rng) -> Genome {
        let mut child = a.clone();
        if rng.chance(0.5) {
            child.n_layers = b.n_layers;
        }
        for i in 0..NUM_LAYERS {
            if rng.chance(0.5) {
                child.width_idx[i] = b.width_idx[i];
            }
        }
        if rng.chance(0.5) {
            child.act = b.act;
        }
        if rng.chance(0.5) {
            child.batch_norm = b.batch_norm;
        }
        if rng.chance(0.5) {
            child.lr_idx = b.lr_idx;
        }
        if rng.chance(0.5) {
            child.l1_idx = b.l1_idx;
        }
        if rng.chance(0.5) {
            child.dropout_idx = b.dropout_idx;
        }
        child
    }

    /// Per-gene reset mutation with probability `p_gene`.
    pub fn mutate(&self, g: &mut Genome, p_gene: f64, rng: &mut Rng) {
        if rng.chance(p_gene) {
            g.n_layers = *rng.choose(&self.depth_choices);
        }
        for i in 0..NUM_LAYERS {
            if rng.chance(p_gene) {
                g.width_idx[i] = rng.below(self.width_choices[i].len());
            }
        }
        if rng.chance(p_gene) {
            g.act = *rng.choose(&Activation::ALL);
        }
        if rng.chance(p_gene) {
            g.batch_norm = !g.batch_norm;
        }
        if rng.chance(p_gene) {
            g.lr_idx = rng.below(self.lr_choices.len());
        }
        if rng.chance(p_gene) {
            g.l1_idx = rng.below(self.l1_choices.len());
        }
        if rng.chance(p_gene) {
            g.dropout_idx = rng.below(self.dropout_choices.len());
        }
    }

    /// Validate that a genome's indices are all within this space.
    pub fn contains(&self, g: &Genome) -> bool {
        self.depth_choices.contains(&g.n_layers)
            && g.width_idx
                .iter()
                .enumerate()
                .all(|(i, &w)| w < self.width_choices[i].len())
            && g.lr_idx < self.lr_choices.len()
            && g.l1_idx < self.l1_choices.len()
            && g.dropout_idx < self.dropout_choices.len()
    }

    /// The paper's comparative baseline [12]: a fixed 24→64→32→32→5 ReLU MLP
    /// with BatchNorm (Odagiu et al.'s 8-constituent MLP), expressed in this
    /// space's encoding. Trained by the same trainer for Table 2/3.
    pub fn baseline(&self) -> Genome {
        Genome {
            n_layers: 4,
            // widths 64, 32, 32(closest: idx over [16,32] → 32), 32
            width_idx: [0, 0, 1, 0, 0, 0, 0, 0],
            act: Activation::ReLU,
            batch_norm: true,
            lr_idx: 0,
            l1_idx: 0,
            dropout_idx: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_cardinalities() {
        let s = SearchSpace::table1();
        assert_eq!(s.depth_choices, vec![4, 5, 6, 7, 8]);
        assert_eq!(s.width_choices[0], vec![64, 120, 128]);
        assert_eq!(s.width_choices[7], vec![32, 44, 64]);
        assert_eq!(s.lr_choices.len(), 3);
        assert_eq!(s.l1_choices.len(), 4);
        assert_eq!(s.dropout_choices.len(), 3);
    }

    #[test]
    fn sampled_genomes_are_contained() {
        let s = SearchSpace::table1();
        let mut rng = Rng::new(1);
        for _ in 0..500 {
            let g = s.sample(&mut rng);
            assert!(s.contains(&g));
        }
    }

    #[test]
    fn crossover_and_mutation_stay_in_space() {
        let s = SearchSpace::table1();
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let a = s.sample(&mut rng);
            let b = s.sample(&mut rng);
            let mut c = s.crossover(&a, &b, &mut rng);
            s.mutate(&mut c, 0.3, &mut rng);
            assert!(s.contains(&c));
        }
    }

    #[test]
    fn sampling_covers_depths() {
        let s = SearchSpace::table1();
        let mut rng = Rng::new(3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..300 {
            seen.insert(s.sample(&mut rng).n_layers);
        }
        assert_eq!(seen.len(), 5, "all depths sampled");
    }

    #[test]
    fn baseline_matches_odagiu_dims() {
        let s = SearchSpace::table1();
        let b = s.baseline();
        assert_eq!(
            b.layer_dims(&s),
            vec![(24, 64), (64, 32), (32, 32), (32, 32), (32, 5)]
        );
    }

    #[test]
    fn architecture_count_is_exact() {
        let s = SearchSpace::table1();
        // Σ_depth Π_{i<depth} |widths_i| × 3 activations × 2 BN
        // = (36 + 72 + 144 + 288 + 864) × 6 = 8424
        assert_eq!(s.architecture_count(), 8424);
    }
}
