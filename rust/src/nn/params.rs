//! Flat parameter/optimiser-state stores for the supernet.
//!
//! Everything lives in plain `Vec<f32>` in the exact row-major layouts the
//! AOT graphs expect, so literal packing in `runtime::executor` is a
//! straight memcpy — no reshaping on the hot path.

use super::abi::{IN_DIM, NUM_LAYERS, OUT_DIM, PAD};
use crate::util::Rng;

/// Sizes of the 7 supernet parameter tensors, ABI order.
pub const PARAM_SHAPES: [(&str, usize); 7] = [
    ("w0", IN_DIM * PAD),
    ("wh", (NUM_LAYERS - 1) * PAD * PAD),
    ("b", NUM_LAYERS * PAD),
    ("gamma", NUM_LAYERS * PAD),
    ("beta", NUM_LAYERS * PAD),
    ("wo", PAD * OUT_DIM),
    ("bo", OUT_DIM),
];

/// The supernet parameter set (or an Adam moment set — same layout).
#[derive(Debug, Clone, PartialEq)]
pub struct SupernetParams {
    /// `(IN_DIM, PAD)` input-layer weights.
    pub w0: Vec<f32>,
    /// `(NUM_LAYERS-1, PAD, PAD)` hidden-layer weights.
    pub wh: Vec<f32>,
    /// `(NUM_LAYERS, PAD)` biases.
    pub b: Vec<f32>,
    /// `(NUM_LAYERS, PAD)` BatchNorm gamma.
    pub gamma: Vec<f32>,
    /// `(NUM_LAYERS, PAD)` BatchNorm beta.
    pub beta: Vec<f32>,
    /// `(PAD, OUT_DIM)` classifier weights.
    pub wo: Vec<f32>,
    /// `(OUT_DIM,)` classifier bias.
    pub bo: Vec<f32>,
}

impl SupernetParams {
    /// All-zero state (Adam moments).
    pub fn zeros() -> Self {
        SupernetParams {
            w0: vec![0.0; IN_DIM * PAD],
            wh: vec![0.0; (NUM_LAYERS - 1) * PAD * PAD],
            b: vec![0.0; NUM_LAYERS * PAD],
            gamma: vec![0.0; NUM_LAYERS * PAD],
            beta: vec![0.0; NUM_LAYERS * PAD],
            wo: vec![0.0; PAD * OUT_DIM],
            bo: vec![0.0; OUT_DIM],
        }
    }

    /// He-initialised weights, identity BatchNorm, zero biases.
    pub fn init(rng: &mut Rng) -> Self {
        let mut p = Self::zeros();
        rng.fill_normal(&mut p.w0, (2.0 / IN_DIM as f32).sqrt());
        rng.fill_normal(&mut p.wh, (2.0 / PAD as f32).sqrt());
        rng.fill_normal(&mut p.wo, (2.0 / PAD as f32).sqrt());
        p.gamma.fill(1.0);
        p
    }

    /// The 7 tensors as slices, ABI order.
    pub fn fields(&self) -> [&[f32]; 7] {
        [
            &self.w0, &self.wh, &self.b, &self.gamma, &self.beta, &self.wo, &self.bo,
        ]
    }

    /// The 7 tensors as mutable slices, ABI order.
    pub fn fields_mut(&mut self) -> [&mut Vec<f32>; 7] {
        [
            &mut self.w0,
            &mut self.wh,
            &mut self.b,
            &mut self.gamma,
            &mut self.beta,
            &mut self.wo,
            &mut self.bo,
        ]
    }

    /// Total number of scalars.
    pub fn len(&self) -> usize {
        self.fields().iter().map(|f| f.len()).sum()
    }

    /// True when empty (never, but clippy insists on pairing with `len`).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_abi() {
        let p = SupernetParams::zeros();
        for ((name, size), field) in PARAM_SHAPES.iter().zip(p.fields()) {
            assert_eq!(field.len(), *size, "{name}");
        }
    }

    #[test]
    fn init_statistics() {
        let mut rng = Rng::new(0);
        let p = SupernetParams::init(&mut rng);
        let mean: f32 = p.wh.iter().sum::<f32>() / p.wh.len() as f32;
        let var: f32 = p.wh.iter().map(|x| x * x).sum::<f32>() / p.wh.len() as f32;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 2.0 / PAD as f32).abs() < 0.002, "var {var}");
        assert!(p.gamma.iter().all(|&g| g == 1.0));
        assert!(p.b.iter().all(|&b| b == 0.0));
    }

    #[test]
    fn init_is_deterministic() {
        let a = SupernetParams::init(&mut Rng::new(5));
        let b = SupernetParams::init(&mut Rng::new(5));
        assert_eq!(a, b);
    }
}
