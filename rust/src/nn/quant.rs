//! Host-side mirror of the graph's symmetric fake quantiser.
//!
//! Used after local search to count which weights the HLS backend will
//! elide (quantised-to-zero) — matching `fake_quant` in
//! `python/compile/kernels/fused_dense.py` exactly.

/// Quantise a copy of `w` to `bits` (symmetric, per-tensor max-abs scale).
pub fn fake_quant(w: &[f32], bits: u32) -> Vec<f32> {
    let levels = ((1u64 << (bits - 1)) - 1) as f32;
    let max_abs = w.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-8);
    let delta = max_abs / levels;
    w.iter()
        .map(|&v| (v / delta).round().clamp(-levels - 1.0, levels) * delta)
        .collect()
}

/// Count entries whose quantised value is exactly zero.
pub fn quantised_zeros(w: &[f32], bits: u32) -> usize {
    let levels = ((1u64 << (bits - 1)) - 1) as f32;
    let max_abs = w.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-8);
    let delta = max_abs / levels;
    w.iter().filter(|&&v| (v / delta).round() == 0.0).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_size_respected() {
        let w: Vec<f32> = (0..1000).map(|i| (i as f32 - 500.0) / 100.0).collect();
        let q = fake_quant(&w, 4);
        let mut uniq: Vec<i64> = q.iter().map(|&v| (v * 1e6) as i64).collect();
        uniq.sort_unstable();
        uniq.dedup();
        assert!(uniq.len() <= 16, "4-bit grid has ≤16 levels, got {}", uniq.len());
    }

    #[test]
    fn zeros_counted() {
        let w = [1.0f32, 0.0, 0.001, -0.001, -1.0];
        // at 8 bits, delta = 1/127; |0.001| rounds to 0
        assert_eq!(quantised_zeros(&w, 8), 3);
    }

    #[test]
    fn matches_python_reference_values() {
        // cross-checked against compile.kernels.ref.fake_quant_ref:
        // delta = 1/127; 0.5→64/127, -0.25→-32/127, 1.0→127/127
        let w = [0.5f32, -0.25, 0.1, 1.0];
        let q = fake_quant(&w, 8);
        assert!((q[0] - 64.0 / 127.0).abs() < 1e-6, "{}", q[0]);
        assert!((q[1] + 32.0 / 127.0).abs() < 1e-6, "{}", q[1]);
        assert!((q[2] - 13.0 / 127.0).abs() < 1e-6, "{}", q[2]);
        assert_eq!(q[3], 1.0);
    }
}
