//! Genome → supernet runtime inputs.
//!
//! This is the bridge that makes the whole AOT design work: a candidate
//! architecture is *compiled* into the mask/gate/hyperparameter tensors the
//! fixed train/eval HLO graphs consume (see `python/compile/model.py`).

use super::abi::{NUM_LAYERS, PAD};
use super::genome::Genome;
use super::space::SearchSpace;

/// Dense (row-major) runtime inputs selecting one candidate inside the
/// padded supernet.
#[derive(Debug, Clone, PartialEq)]
pub struct SupernetInputs {
    /// `(NUM_LAYERS, PAD)` unit mask — 1 for active hidden units.
    pub unit: Vec<f32>,
    /// `(NUM_LAYERS,)` layer gates — 1 for active layers.
    pub gates: Vec<f32>,
    /// `(3,)` activation one-hot (ReLU/tanh/sigmoid).
    pub act_sel: Vec<f32>,
    /// BatchNorm gate (1.0 = on).
    pub bn_gate: f32,
    /// Dropout rate.
    pub dropout: f32,
    /// Learning rate.
    pub lr: f32,
    /// L1 strength.
    pub l1: f32,
}

impl SupernetInputs {
    /// Compile a genome against the search space.
    pub fn compile(genome: &Genome, space: &SearchSpace) -> Self {
        let widths = genome.widths(space);
        let mut unit = vec![0.0f32; NUM_LAYERS * PAD];
        let mut gates = vec![0.0f32; NUM_LAYERS];
        for (i, &w) in widths.iter().enumerate() {
            debug_assert!(w <= PAD);
            for u in 0..w {
                unit[i * PAD + u] = 1.0;
            }
            gates[i] = 1.0;
        }
        let mut act_sel = vec![0.0f32; 3];
        act_sel[genome.act.index()] = 1.0;
        SupernetInputs {
            unit,
            gates,
            act_sel,
            bn_gate: if genome.batch_norm { 1.0 } else { 0.0 },
            dropout: genome.dropout(space),
            lr: genome.lr(space),
            l1: genome.l1(space),
        }
    }

    /// Active width of layer `i` (number of set units).
    pub fn active_width(&self, i: usize) -> usize {
        self.unit[i * PAD..(i + 1) * PAD]
            .iter()
            .filter(|&&u| u != 0.0)
            .count()
    }

    /// Number of active layers.
    pub fn depth(&self) -> usize {
        self.gates.iter().filter(|&&g| g != 0.0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::genome::Activation;

    fn genome(n_layers: usize) -> Genome {
        Genome {
            n_layers,
            width_idx: [1, 2, 0, 1, 0, 1, 0, 2],
            act: Activation::Tanh,
            batch_norm: false,
            lr_idx: 1,
            l1_idx: 2,
            dropout_idx: 1,
        }
    }

    #[test]
    fn masks_match_widths() {
        let space = SearchSpace::table1();
        let g = genome(6);
        let inputs = SupernetInputs::compile(&g, &space);
        let widths = g.widths(&space);
        for (i, &w) in widths.iter().enumerate() {
            assert_eq!(inputs.active_width(i), w, "layer {i}");
            // contiguity: prefix of ones then zeros
            let row = &inputs.unit[i * PAD..(i + 1) * PAD];
            assert!(row[..w].iter().all(|&u| u == 1.0));
            assert!(row[w..].iter().all(|&u| u == 0.0));
        }
        // inactive layers fully zero
        for i in 6..NUM_LAYERS {
            assert_eq!(inputs.active_width(i), 0);
            assert_eq!(inputs.gates[i], 0.0);
        }
        assert_eq!(inputs.depth(), 6);
    }

    #[test]
    fn hyperparameters_resolve() {
        let space = SearchSpace::table1();
        let inputs = SupernetInputs::compile(&genome(4), &space);
        assert_eq!(inputs.lr, 0.0015);
        assert_eq!(inputs.l1, 1e-5);
        assert_eq!(inputs.dropout, 0.05);
        assert_eq!(inputs.bn_gate, 0.0);
        assert_eq!(inputs.act_sel, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn depth_bounds() {
        let space = SearchSpace::table1();
        for d in 4..=8 {
            let inputs = SupernetInputs::compile(&genome(d), &space);
            assert_eq!(inputs.depth(), d);
        }
    }
}
