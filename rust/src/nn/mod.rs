//! Neural-architecture domain types: the Table 1 search space, genomes,
//! the genome→supernet mask compiler, parameter stores, pruning masks, and
//! the BOPs proxy metric.

pub mod abi;
pub mod bops;
pub mod genome;
pub mod masks;
pub mod params;
pub mod prune;
pub mod quant;
pub mod space;

pub use abi::*;
pub use genome::{Activation, Genome};
pub use masks::SupernetInputs;
pub use params::SupernetParams;
pub use prune::PruneMasks;
pub use space::SearchSpace;
