//! Shape constants shared with the AOT-compiled JAX graphs.
//!
//! These mirror `python/compile/model.py`; `runtime::artifacts` validates
//! them against `artifacts/manifest.json` at load time so any drift between
//! the Python build and this crate fails fast.

/// Padded hidden width of the supernet (max Table 1 width).
pub const PAD: usize = 128;
/// Maximum depth of the Table 1 space.
pub const NUM_LAYERS: usize = 8;
/// Input features: 8 constituents × (pT, η, φ).
pub const IN_DIM: usize = 24;
/// Output classes: q, g, W, Z, t.
pub const OUT_DIM: usize = 5;
/// Training batch size (paper: 128).
pub const BATCH: usize = 128;
/// Evaluation tile size; Rust pads the tail batch.
pub const EVAL_BATCH: usize = 512;

/// BatchNorm epsilon baked into the graph.
pub const BN_EPS: f32 = 1e-3;

// ---- `hp` vector layout for the train_step artifact ----
pub const HP_BN_GATE: usize = 0;
pub const HP_DROPOUT: usize = 1;
pub const HP_QAT_GATE: usize = 2;
pub const HP_BITS: usize = 3;
pub const HP_LR: usize = 4;
pub const HP_L1: usize = 5;
pub const HP_BETA1: usize = 6;
pub const HP_BETA2: usize = 7;
pub const HP_EPS: usize = 8;
pub const HP_BETA1_POW: usize = 9;
pub const HP_BETA2_POW: usize = 10;
pub const HP_SEED: usize = 11;
pub const HP_BN_MOM: usize = 12;
pub const HP_LEN: usize = 13;

// ---- `ehp` vector layout for the eval_step artifact ----
pub const EHP_BN_GATE: usize = 0;
pub const EHP_QAT_GATE: usize = 1;
pub const EHP_BITS: usize = 2;
pub const EHP_LEN: usize = 3;

// ---- surrogate shapes ----
pub const SUR_FEATS: usize = 72;
pub const SUR_HIDDEN: usize = 128;
pub const SUR_OUT: usize = 6;
pub const SUR_BATCH: usize = 256;

// ---- surrogate `shp` layout ----
pub const SHP_LR: usize = 0;
pub const SHP_BETA1: usize = 1;
pub const SHP_BETA2: usize = 2;
pub const SHP_EPS: usize = 3;
pub const SHP_BETA1_POW: usize = 4;
pub const SHP_BETA2_POW: usize = 5;
pub const SHP_LEN: usize = 6;
