//! Bit operations (BOPs) — the proxy metric NAC optimises and SNAC-Pack
//! replaces with surrogate estimates (the paper's central comparison).
//!
//! We use the standard accounting of Baskin et al. (adopted by the NAC
//! paper): for a dense layer with `n` inputs, `m` outputs, weight bits
//! `b_w`, activation bits `b_a` and weight sparsity `s`:
//!
//! ```text
//! BOPs = m·n·( (1−s)·b_w·b_a + b_a + b_w + log2(n) )
//! ```
//!
//! Absolute values depend on accounting conventions, so EXPERIMENTS.md
//! compares *ratios* (baseline vs NAC vs SNAC-Pack) against Table 2.

use super::genome::Genome;
use super::space::SearchSpace;

/// BOPs of one dense layer.
pub fn layer_bops(n_in: usize, n_out: usize, bw: u32, ba: u32, sparsity: f64) -> f64 {
    let n = n_in as f64;
    let m = n_out as f64;
    m * n * ((1.0 - sparsity) * (bw as f64) * (ba as f64) + ba as f64 + bw as f64 + n.log2())
}

/// BOPs of a whole genome at uniform precision/sparsity.
pub fn genome_bops(g: &Genome, space: &SearchSpace, bw: u32, ba: u32, sparsity: f64) -> f64 {
    g.layer_dims(space)
        .iter()
        .map(|&(i, o)| layer_bops(i, o, bw, ba, sparsity))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::genome::Activation;

    #[test]
    fn layer_bops_formula() {
        // 16 in, 8 out, 8w8a, dense
        let b = layer_bops(16, 8, 8, 8, 0.0);
        assert_eq!(b, 8.0 * 16.0 * (64.0 + 8.0 + 8.0 + 4.0));
    }

    #[test]
    fn sparsity_reduces_bops() {
        let dense = layer_bops(64, 64, 8, 8, 0.0);
        let half = layer_bops(64, 64, 8, 8, 0.5);
        assert!(half < dense);
        assert!(half > 0.4 * dense);
    }

    #[test]
    fn lower_precision_reduces_bops() {
        assert!(layer_bops(64, 64, 4, 8, 0.0) < layer_bops(64, 64, 8, 8, 0.0));
    }

    #[test]
    fn baseline_exceeds_small_net() {
        let space = SearchSpace::table1();
        let baseline = space.baseline();
        let small = Genome {
            n_layers: 4,
            width_idx: [0, 0, 0, 0, 0, 0, 0, 0],
            act: Activation::ReLU,
            batch_norm: false,
            lr_idx: 0,
            l1_idx: 0,
            dropout_idx: 0,
        };
        // baseline widths 64-32-32-32 vs 64-32-16-32 → strictly more BOPs
        assert!(
            genome_bops(&baseline, &space, 8, 8, 0.0)
                > genome_bops(&small, &space, 8, 8, 0.0)
        );
    }
}
