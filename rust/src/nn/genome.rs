//! Genome: one point of the Table 1 search space.

use anyhow::{Context, Result};

use super::abi::{IN_DIM, NUM_LAYERS, OUT_DIM};
use super::space::SearchSpace;
use crate::util::Json;

/// Activation function choice (Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Activation {
    ReLU,
    Tanh,
    Sigmoid,
}

impl Activation {
    /// All choices, index-aligned with the supernet's one-hot selector.
    pub const ALL: [Activation; 3] = [Activation::ReLU, Activation::Tanh, Activation::Sigmoid];

    /// Index into the one-hot selector.
    pub fn index(self) -> usize {
        match self {
            Activation::ReLU => 0,
            Activation::Tanh => 1,
            Activation::Sigmoid => 2,
        }
    }

    /// Whether hls4ml implements this with a BRAM lookup table.
    pub fn needs_table(self) -> bool {
        !matches!(self, Activation::ReLU)
    }
}

/// A sampled MLP architecture + training hyperparameters (Table 1 point).
///
/// Width/lr/l1/dropout are stored as *indices* into the [`SearchSpace`]
/// choice lists so crossover/mutation stay within the discrete space.
/// `Hash`/`Eq` make a genome directly usable as an evaluation-cache key
/// (see `eval::ParallelEvaluator`).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Genome {
    /// Depth, 4..=8 (Table 1 "Number of layers").
    pub n_layers: usize,
    /// Per-layer index into `SearchSpace::width_choices[i]`.
    pub width_idx: [usize; NUM_LAYERS],
    /// Activation used throughout the network.
    pub act: Activation,
    /// BatchNorm after every hidden dense layer.
    pub batch_norm: bool,
    /// Index into `SearchSpace::lr_choices`.
    pub lr_idx: usize,
    /// Index into `SearchSpace::l1_choices`.
    pub l1_idx: usize,
    /// Index into `SearchSpace::dropout_choices`.
    pub dropout_idx: usize,
}

impl Genome {
    /// Hidden widths of the *active* layers.
    pub fn widths(&self, space: &SearchSpace) -> Vec<usize> {
        (0..self.n_layers)
            .map(|i| space.width_choices[i][self.width_idx[i]])
            .collect()
    }

    /// All dense layer shapes `(n_in, n_out)` including the classifier head.
    pub fn layer_dims(&self, space: &SearchSpace) -> Vec<(usize, usize)> {
        let widths = self.widths(space);
        let mut dims = Vec::with_capacity(self.n_layers + 1);
        let mut prev = IN_DIM;
        for &w in &widths {
            dims.push((prev, w));
            prev = w;
        }
        dims.push((prev, OUT_DIM));
        dims
    }

    /// Total weight count (no biases), the classic "parameters" number.
    pub fn num_weights(&self, space: &SearchSpace) -> usize {
        self.layer_dims(space).iter().map(|&(i, o)| i * o).sum()
    }

    /// Learning rate value.
    pub fn lr(&self, space: &SearchSpace) -> f32 {
        space.lr_choices[self.lr_idx]
    }

    /// L1 regularisation strength.
    pub fn l1(&self, space: &SearchSpace) -> f32 {
        space.l1_choices[self.l1_idx]
    }

    /// Dropout rate.
    pub fn dropout(&self, space: &SearchSpace) -> f32 {
        space.dropout_choices[self.dropout_idx]
    }

    /// Serialise to JSON (the shared trial-db / eval-cache genome codec).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n_layers", Json::Num(self.n_layers as f64)),
            (
                "width_idx",
                Json::nums(self.width_idx.iter().map(|&w| w as f64)),
            ),
            ("act", Json::Num(self.act.index() as f64)),
            ("batch_norm", Json::Bool(self.batch_norm)),
            ("lr_idx", Json::Num(self.lr_idx as f64)),
            ("l1_idx", Json::Num(self.l1_idx as f64)),
            ("dropout_idx", Json::Num(self.dropout_idx as f64)),
        ])
    }

    /// Parse back from JSON.
    pub fn from_json(j: &Json) -> Result<Genome> {
        let num = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("genome missing `{k}`"))
        };
        let mut width_idx = [0usize; NUM_LAYERS];
        for (i, item) in j
            .get("width_idx")
            .context("genome missing width_idx")?
            .items()
            .iter()
            .enumerate()
            .take(NUM_LAYERS)
        {
            width_idx[i] = item.as_usize().context("bad width idx")?;
        }
        Ok(Genome {
            n_layers: num("n_layers")?,
            width_idx,
            act: Activation::ALL[num("act")?.min(2)],
            batch_norm: j
                .get("batch_norm")
                .and_then(Json::as_bool)
                .context("genome missing batch_norm")?,
            lr_idx: num("lr_idx")?,
            l1_idx: num("l1_idx")?,
            dropout_idx: num("dropout_idx")?,
        })
    }

    /// Compact human-readable id, e.g. `d5-64.32.16.32.32-relu-bn`.
    pub fn label(&self, space: &SearchSpace) -> String {
        let widths: Vec<String> = self.widths(space).iter().map(|w| w.to_string()).collect();
        format!(
            "d{}-{}-{}{}",
            self.n_layers,
            widths.join("."),
            match self.act {
                Activation::ReLU => "relu",
                Activation::Tanh => "tanh",
                Activation::Sigmoid => "sig",
            },
            if self.batch_norm { "-bn" } else { "" }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn space() -> SearchSpace {
        SearchSpace::table1()
    }

    fn genome() -> Genome {
        Genome {
            n_layers: 5,
            width_idx: [0; NUM_LAYERS],
            act: Activation::ReLU,
            batch_norm: true,
            lr_idx: 0,
            l1_idx: 0,
            dropout_idx: 0,
        }
    }

    #[test]
    fn layer_dims_chain() {
        let g = genome();
        let dims = g.layer_dims(&space());
        assert_eq!(dims.len(), 6); // 5 hidden + head
        assert_eq!(dims[0].0, IN_DIM);
        assert_eq!(dims.last().unwrap().1, OUT_DIM);
        for w in dims.windows(2) {
            assert_eq!(w[0].1, w[1].0, "consecutive dims must chain");
        }
    }

    #[test]
    fn widths_respect_depth() {
        let mut g = genome();
        g.n_layers = 4;
        assert_eq!(g.widths(&space()).len(), 4);
        g.n_layers = 8;
        assert_eq!(g.widths(&space()).len(), 8);
    }

    #[test]
    fn num_weights_matches_dims() {
        let g = genome();
        let s = space();
        let manual: usize = g.layer_dims(&s).iter().map(|&(a, b)| a * b).sum();
        assert_eq!(g.num_weights(&s), manual);
    }

    #[test]
    fn label_is_stable() {
        let g = genome();
        assert_eq!(g.label(&space()), "d5-64.32.16.32.32-relu-bn");
    }

    #[test]
    fn json_roundtrips() {
        let mut g = genome();
        g.act = Activation::Tanh;
        g.width_idx[2] = 1;
        let parsed = Genome::from_json(&g.to_json()).unwrap();
        assert_eq!(parsed, g);
        // reparsing the emitted text also round-trips (on-disk form)
        let text = g.to_json().to_string();
        let parsed = Genome::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed, g);
    }

    #[test]
    fn json_rejects_missing_fields() {
        assert!(Genome::from_json(&Json::obj(vec![])).is_err());
        let mut j = genome().to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("batch_norm");
        }
        assert!(Genome::from_json(&j).is_err());
    }
}
