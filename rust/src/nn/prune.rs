//! Pruning masks for iterative magnitude pruning (IMP, local search).
//!
//! Masks share the weight tensors' layouts (`p0`/`ph`/`po` ↔ `w0`/`wh`/`wo`)
//! and are multiplied into the weights inside the AOT graph. The magnitude
//! threshold is computed *globally* over the architecture's active
//! coordinates, matching the paper's "20 % pruned per iteration" of the
//! surviving weights (Frankle & Carbin style).

use super::abi::{IN_DIM, NUM_LAYERS, OUT_DIM, PAD};
use super::masks::SupernetInputs;
use super::params::SupernetParams;

/// {0,1} masks over the three weight tensors.
#[derive(Debug, Clone, PartialEq)]
pub struct PruneMasks {
    /// `(IN_DIM, PAD)`.
    pub p0: Vec<f32>,
    /// `(NUM_LAYERS-1, PAD, PAD)`.
    pub ph: Vec<f32>,
    /// `(PAD, OUT_DIM)`.
    pub po: Vec<f32>,
}

impl PruneMasks {
    /// No pruning.
    pub fn ones() -> Self {
        PruneMasks {
            p0: vec![1.0; IN_DIM * PAD],
            ph: vec![1.0; (NUM_LAYERS - 1) * PAD * PAD],
            po: vec![1.0; PAD * OUT_DIM],
        }
    }

    /// Iterate over (mask, weight) pairs restricted to coordinates that are
    /// *active* for the given architecture (unit-masked columns of active
    /// layers). Only those coordinates count toward sparsity and threshold
    /// selection — the padded supernet's dead weights are irrelevant.
    fn active_coords<'a>(
        &'a self,
        inputs: &'a SupernetInputs,
    ) -> impl Iterator<Item = usize> + 'a {
        // encode (tensor, offset) as a single global index:
        //   [0, len(p0)) → p0, [len(p0), +len(ph)) → ph, then po
        let p0_len = self.p0.len();
        let ph_len = self.ph.len();
        let depth = inputs.depth();
        let l0 = (0..IN_DIM * PAD).filter(move |i| {
            let col = i % PAD;
            inputs.unit[col] != 0.0 // layer 0 unit mask
        });
        let lh = (0..ph_len).filter(move |i| {
            let layer = i / (PAD * PAD) + 1; // ph[k] serves layer k+1
            let col = i % PAD;
            let row = (i / PAD) % PAD;
            layer < depth
                && inputs.unit[layer * PAD + col] != 0.0
                // rows beyond the previous layer's width never carry signal
                && inputs.unit[(layer - 1) * PAD + row] != 0.0
        });
        let last = depth - 1;
        let lo = (0..PAD * OUT_DIM)
            .filter(move |i| inputs.unit[last * PAD + i / OUT_DIM] != 0.0);
        l0.chain(lh.map(move |i| p0_len + i))
            .chain(lo.map(move |i| p0_len + ph_len + i))
    }

    fn get(&self, gi: usize) -> f32 {
        if gi < self.p0.len() {
            self.p0[gi]
        } else if gi < self.p0.len() + self.ph.len() {
            self.ph[gi - self.p0.len()]
        } else {
            self.po[gi - self.p0.len() - self.ph.len()]
        }
    }

    fn set_zero(&mut self, gi: usize) {
        if gi < self.p0.len() {
            self.p0[gi] = 0.0;
        } else if gi < self.p0.len() + self.ph.len() {
            let k = gi - self.p0.len();
            self.ph[k] = 0.0;
        } else {
            let k = gi - self.p0.len() - self.ph.len();
            self.po[k] = 0.0;
        }
    }

    fn weight_at(params: &SupernetParams, gi: usize, p0_len: usize, ph_len: usize) -> f32 {
        if gi < p0_len {
            params.w0[gi]
        } else if gi < p0_len + ph_len {
            params.wh[gi - p0_len]
        } else {
            params.wo[gi - p0_len - ph_len]
        }
    }

    /// Prune `fraction` of the currently-surviving active weights by global
    /// magnitude. Returns the number of weights newly pruned.
    pub fn prune_step(
        &mut self,
        params: &SupernetParams,
        inputs: &SupernetInputs,
        fraction: f64,
    ) -> usize {
        let p0_len = self.p0.len();
        let ph_len = self.ph.len();
        let mut survivors: Vec<(f32, usize)> = self
            .active_coords(inputs)
            .filter(|&gi| self.get(gi) != 0.0)
            .map(|gi| (Self::weight_at(params, gi, p0_len, ph_len).abs(), gi))
            .collect();
        let k = (survivors.len() as f64 * fraction).floor() as usize;
        if k == 0 {
            return 0;
        }
        // partial selection: k smallest magnitudes
        survivors.select_nth_unstable_by(k - 1, |a, b| a.0.total_cmp(&b.0));
        for &(_, gi) in &survivors[..k] {
            self.set_zero(gi);
        }
        k
    }

    /// Sparsity over the architecture's active coordinates.
    pub fn sparsity(&self, inputs: &SupernetInputs) -> f64 {
        let (mut total, mut zeros) = (0usize, 0usize);
        for gi in self.active_coords(inputs) {
            total += 1;
            if self.get(gi) == 0.0 {
                zeros += 1;
            }
        }
        if total == 0 {
            0.0
        } else {
            zeros as f64 / total as f64
        }
    }

    /// Count of surviving (active, unpruned) weights.
    pub fn active_nonzeros(&self, inputs: &SupernetInputs) -> usize {
        self.active_coords(inputs)
            .filter(|&gi| self.get(gi) != 0.0)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::genome::{Activation, Genome};
    use crate::nn::space::SearchSpace;
    use crate::util::Rng;

    fn setup() -> (SupernetInputs, SupernetParams) {
        let space = SearchSpace::table1();
        let g = Genome {
            n_layers: 5,
            width_idx: [0; NUM_LAYERS],
            act: Activation::ReLU,
            batch_norm: false,
            lr_idx: 0,
            l1_idx: 0,
            dropout_idx: 0,
        };
        let inputs = SupernetInputs::compile(&g, &space);
        let params = SupernetParams::init(&mut Rng::new(0));
        (inputs, params)
    }

    #[test]
    fn active_count_matches_architecture() {
        let (inputs, _) = setup();
        let masks = PruneMasks::ones();
        // widths 64,32,16,32,32; dims (24,64)(64,32)(32,16)(16,32)(32,32)(32,5)
        let expected = 24 * 64 + 64 * 32 + 32 * 16 + 16 * 32 + 32 * 32 + 32 * 5;
        assert_eq!(masks.active_nonzeros(&inputs), expected);
    }

    #[test]
    fn prune_fraction_is_respected() {
        let (inputs, params) = setup();
        let mut masks = PruneMasks::ones();
        let before = masks.active_nonzeros(&inputs);
        let pruned = masks.prune_step(&params, &inputs, 0.2);
        assert_eq!(pruned, (before as f64 * 0.2).floor() as usize);
        assert_eq!(masks.active_nonzeros(&inputs), before - pruned);
        assert!((masks.sparsity(&inputs) - 0.2).abs() < 0.01);
    }

    #[test]
    fn iterative_pruning_compounds() {
        let (inputs, params) = setup();
        let mut masks = PruneMasks::ones();
        for _ in 0..10 {
            masks.prune_step(&params, &inputs, 0.2);
        }
        let s = masks.sparsity(&inputs);
        // 1 - 0.8^10 ≈ 0.8926
        assert!((s - 0.8926).abs() < 0.01, "sparsity {s}");
    }

    #[test]
    fn pruning_removes_smallest_magnitudes() {
        let (inputs, params) = setup();
        let mut masks = PruneMasks::ones();
        masks.prune_step(&params, &inputs, 0.3);
        // the largest surviving |w| among pruned coords must be <= the
        // smallest |w| among survivors (global threshold property)
        let p0_len = masks.p0.len();
        let ph_len = masks.ph.len();
        let mut max_pruned = 0.0f32;
        let mut min_kept = f32::INFINITY;
        for gi in masks.active_coords(&inputs).collect::<Vec<_>>() {
            let w = PruneMasks::weight_at(&params, gi, p0_len, ph_len).abs();
            if masks.get(gi) == 0.0 {
                max_pruned = max_pruned.max(w);
            } else {
                min_kept = min_kept.min(w);
            }
        }
        assert!(max_pruned <= min_kept + 1e-6, "{max_pruned} vs {min_kept}");
    }

    #[test]
    fn inactive_coords_never_pruned() {
        let (inputs, params) = setup();
        let mut masks = PruneMasks::ones();
        masks.prune_step(&params, &inputs, 0.5);
        // layer 6+ (inactive) must remain all-ones
        let start = 5 * PAD * PAD; // ph index of layer 6 == ph[5]... (layer idx 6 => ph[5])
        assert!(masks.ph[start..].iter().all(|&m| m == 1.0));
    }
}
