//! Seeded differential harness: randomized shapes and dim-specs run
//! through both the compiled execution plans (`execute_b`) and the
//! retained naive reference evaluator (`execute_b_reference`), asserting
//! **bit-exact** equality — including the threaded dot-general at
//! `threads ∈ {1, 2, 4}` — plus arena-reuse regression tests.
//!
//! Everything is deterministic: a fixed-seed xorshift PRNG drives shape
//! and value generation, so a failure reproduces exactly.

use xla::{HloModuleProto, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Fixed-seed xorshift64 — no external crates, fully reproducible.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Rng {
        Rng(seed.max(1))
    }
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }
    fn below(&mut self, n: usize) -> usize {
        (self.next() % n as u64) as usize
    }
    /// Small exact-in-f32 values with a healthy share of exact zeros so
    /// the dot-general zero-skip fast path is exercised.
    fn val(&mut self) -> f32 {
        match self.below(4) {
            0 => 0.0,
            _ => (self.below(33) as f32 - 16.0) * 0.25,
        }
    }
    fn fill(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.val()).collect()
    }
}

fn compile(text: &str) -> (PjRtClient, PjRtLoadedExecutable) {
    let proto = HloModuleProto::from_text(text).expect("parse");
    let client = PjRtClient::cpu().expect("client");
    let exe = client
        .compile(&XlaComputation::from_proto(&proto))
        .unwrap_or_else(|e| panic!("compile failed: {e}\n{text}"));
    (client, exe)
}

fn buffers(client: &PjRtClient, args: &[(Vec<f32>, Vec<usize>)]) -> Vec<PjRtBuffer> {
    args.iter()
        .map(|(data, dims)| {
            client
                .buffer_from_host_buffer::<f32>(data, dims, None)
                .expect("buffer")
        })
        .collect()
}

/// Flatten a (possibly tuple) result to the raw bit patterns of every
/// leaf, so comparisons are exact even around -0.0.
fn result_bits(out: Vec<Vec<PjRtBuffer>>) -> Vec<u32> {
    fn walk(lit: xla::Literal, bits: &mut Vec<u32>) {
        if let Ok(v) = lit.to_vec::<f32>() {
            bits.extend(v.iter().map(|x| x.to_bits()));
            return;
        }
        for leaf in lit.to_tuple().expect("array or tuple literal") {
            walk(leaf, bits);
        }
    }
    let mut bits = Vec::new();
    walk(out[0][0].to_literal_sync().expect("literal"), &mut bits);
    bits
}

/// Execute planned and reference paths on identical inputs and assert
/// bit-identical results.
fn assert_bit_exact(text: &str, args: &[(Vec<f32>, Vec<usize>)], what: &str) {
    let (client, exe) = compile(text);
    let bufs = buffers(&client, args);
    let planned = result_bits(exe.execute_b(&bufs).expect("planned execute"));
    let reference = result_bits(exe.execute_b_reference(&bufs).expect("reference execute"));
    assert_eq!(planned, reference, "planned vs reference mismatch: {what}\n{text}");
}

fn shape(dims: &[usize]) -> String {
    let strs: Vec<String> = dims.iter().map(|d| d.to_string()).collect();
    format!("f32[{}]", strs.join(","))
}

#[test]
fn dot_general_randomized_bit_exact_at_1_2_4_threads() {
    let mut rng = Rng::new(0x5eed_d07);
    // (m, k, n) triples: tiny, ROW_TILE remainders, and sizes big enough
    // to cross the COL_BLOCK boundary and engage real threads
    let mut cases: Vec<(usize, usize, usize)> = vec![
        (1, 1, 1),
        (5, 3, 7),
        (4, 8, 513),
        (9, 7, 700),
        (128, 64, 64),
    ];
    for _ in 0..10 {
        cases.push((1 + rng.below(9), 1 + rng.below(9), 1 + rng.below(9)));
    }
    for &(m, k, n) in &cases {
        let variants = [
            // standard [m,k]·[k,n]
            (
                vec![m, k],
                vec![k, n],
                "lhs_contracting_dims={1}, rhs_contracting_dims={0}",
                vec![m, n],
            ),
            // transposed lhs [k,m]·[k,n]
            (
                vec![k, m],
                vec![k, n],
                "lhs_contracting_dims={0}, rhs_contracting_dims={0}",
                vec![m, n],
            ),
            // rhs free dim leading: [m,k]·[n,k] — non-contiguous rhs walk
            (
                vec![m, k],
                vec![n, k],
                "lhs_contracting_dims={1}, rhs_contracting_dims={1}",
                vec![m, n],
            ),
        ];
        for (adims, bdims, spec, odims) in variants {
            let text = format!(
                "HloModule t\n\nENTRY %main (a: {sa}, b: {sb}) -> {so} {{\n  \
                 %a = {sa} parameter(0)\n  %b = {sb} parameter(1)\n  \
                 ROOT %d = {so} dot(%a, %b), {spec}\n}}\n",
                sa = shape(&adims),
                sb = shape(&bdims),
                so = shape(&odims),
            );
            let na: usize = adims.iter().product();
            let nb: usize = bdims.iter().product();
            let args = vec![(rng.fill(na), adims), (rng.fill(nb), bdims)];
            let (client, exe) = compile(&text);
            let bufs = buffers(&client, &args);
            let reference = result_bits(exe.execute_b_reference(&bufs).expect("reference"));
            for threads in [1usize, 2, 4] {
                xla::set_dot_threads(threads);
                let planned = result_bits(exe.execute_b(&bufs).expect("planned"));
                assert_eq!(
                    planned, reference,
                    "dot [{m},{k}]x[{k},{n}] spec `{spec}` at threads={threads}"
                );
            }
            xla::set_dot_threads(1);
        }
    }
}

#[test]
fn batched_dot_general_randomized_bit_exact() {
    let mut rng = Rng::new(0xbadc_0de);
    for _ in 0..12 {
        let (b, m, k, n) = (
            1 + rng.below(4),
            1 + rng.below(7),
            1 + rng.below(7),
            1 + rng.below(7),
        );
        let adims = vec![b, m, k];
        let bdims = vec![b, k, n];
        let odims = vec![b, m, n];
        let text = format!(
            "HloModule t\n\nENTRY %main (a: {sa}, b: {sb}) -> {so} {{\n  \
             %a = {sa} parameter(0)\n  %b = {sb} parameter(1)\n  \
             ROOT %d = {so} dot(%a, %b), lhs_batch_dims={{0}}, rhs_batch_dims={{0}}, \
             lhs_contracting_dims={{2}}, rhs_contracting_dims={{1}}\n}}\n",
            sa = shape(&adims),
            sb = shape(&bdims),
            so = shape(&odims),
        );
        let na: usize = adims.iter().product();
        let nb: usize = bdims.iter().product();
        let args = vec![(rng.fill(na), adims), (rng.fill(nb), bdims)];
        for threads in [1usize, 2, 4] {
            xla::set_dot_threads(threads);
            assert_bit_exact(&text, &args, &format!("batched dot b={b} threads={threads}"));
        }
        xla::set_dot_threads(1);
    }
}

#[test]
fn elementwise_chains_randomized_bit_exact() {
    let mut rng = Rng::new(0xe1e);
    for _ in 0..20 {
        let n = 1 + rng.below(40);
        let text = format!(
            "HloModule t\n\nENTRY %main (a: f32[{n}], b: f32[{n}]) -> f32[{n}] {{\n  \
             %a = f32[{n}] parameter(0)\n  %b = f32[{n}] parameter(1)\n  \
             %s = f32[{n}] add(%a, %b)\n  %m = f32[{n}] multiply(%s, %b)\n  \
             %t = f32[{n}] subtract(%m, %a)\n  %e = f32[{n}] exponential(%t)\n  \
             %mx = f32[{n}] maximum(%e, %a)\n  \
             %p = pred[{n}] compare(%mx, %b), direction=GT\n  \
             %pf = f32[{n}] convert(%p)\n  \
             ROOT %r = f32[{n}] select(%p, %mx, %pf)\n}}\n"
        );
        let args = vec![(rng.fill(n), vec![n]), (rng.fill(n), vec![n])];
        assert_bit_exact(&text, &args, &format!("elementwise chain n={n}"));
    }
}

#[test]
fn broadcast_transpose_slice_randomized_bit_exact() {
    let mut rng = Rng::new(0x90a7);
    for _ in 0..25 {
        // broadcast a rank-1/2 operand into a rank-2/3 output along a
        // random strictly-increasing dim mapping
        let out_rank = 2 + rng.below(2);
        let odims: Vec<usize> = (0..out_rank).map(|_| 1 + rng.below(5)).collect();
        let op_rank = 1 + rng.below(out_rank);
        // choose op_rank distinct output dims, increasing
        let mut picks: Vec<usize> = (0..out_rank).collect();
        while picks.len() > op_rank {
            let i = rng.below(picks.len());
            picks.remove(i);
        }
        let adims: Vec<usize> = picks.iter().map(|&d| odims[d]).collect();
        let dim_list: Vec<String> = picks.iter().map(|d| d.to_string()).collect();
        let na: usize = adims.iter().product();
        let text = format!(
            "HloModule t\n\nENTRY %main (a: {sa}) -> {so} {{\n  \
             %a = {sa} parameter(0)\n  \
             ROOT %r = {so} broadcast(%a), dimensions={{{dl}}}\n}}\n",
            sa = shape(&adims),
            so = shape(&odims),
            dl = dim_list.join(","),
        );
        let args = vec![(rng.fill(na), adims.clone())];
        assert_bit_exact(&text, &args, &format!("broadcast {adims:?}->{odims:?}"));
    }
    for _ in 0..25 {
        // random rank-2/3 transpose
        let rank = 2 + rng.below(2);
        let adims: Vec<usize> = (0..rank).map(|_| 1 + rng.below(5)).collect();
        let mut perm: Vec<usize> = (0..rank).collect();
        for i in (1..rank).rev() {
            perm.swap(i, rng.below(i + 1));
        }
        let odims: Vec<usize> = perm.iter().map(|&p| adims[p]).collect();
        let perm_list: Vec<String> = perm.iter().map(|p| p.to_string()).collect();
        let na: usize = adims.iter().product();
        let text = format!(
            "HloModule t\n\nENTRY %main (a: {sa}) -> {so} {{\n  \
             %a = {sa} parameter(0)\n  \
             ROOT %r = {so} transpose(%a), dimensions={{{pl}}}\n}}\n",
            sa = shape(&adims),
            so = shape(&odims),
            pl = perm_list.join(","),
        );
        let args = vec![(rng.fill(na), adims.clone())];
        assert_bit_exact(&text, &args, &format!("transpose {adims:?} perm {perm:?}"));
    }
    for _ in 0..25 {
        // random strided slice of a rank-2 operand (may be empty)
        let adims = vec![1 + rng.below(7), 1 + rng.below(7)];
        let mut spec = Vec::new();
        let mut odims = Vec::new();
        for &size in &adims {
            let start = rng.below(size + 1);
            let limit = start + rng.below(size - start + 1);
            let stride = 1 + rng.below(3);
            odims.push((limit - start).div_ceil(stride));
            spec.push(format!("[{start}:{limit}:{stride}]"));
        }
        let na: usize = adims.iter().product();
        let text = format!(
            "HloModule t\n\nENTRY %main (a: {sa}) -> {so} {{\n  \
             %a = {sa} parameter(0)\n  \
             ROOT %r = {so} slice(%a), slice={{{sp}}}\n}}\n",
            sa = shape(&adims),
            so = shape(&odims),
            sp = spec.join(", "),
        );
        let args = vec![(rng.fill(na), adims.clone())];
        assert_bit_exact(&text, &args, &format!("slice {adims:?} spec {spec:?}"));
    }
}

#[test]
fn concat_iota_reshape_randomized_bit_exact() {
    let mut rng = Rng::new(0xc047);
    for _ in 0..15 {
        let rank = 2;
        let common = 1 + rng.below(4);
        let dim = rng.below(rank);
        let sizes = [1 + rng.below(4), 1 + rng.below(4), 1 + rng.below(4)];
        let part_dims = |s: usize| -> Vec<usize> {
            if dim == 0 {
                vec![s, common]
            } else {
                vec![common, s]
            }
        };
        let mut odims = part_dims(sizes[0]);
        odims[dim] = sizes.iter().sum();
        let (d0, d1, d2) = (
            part_dims(sizes[0]),
            part_dims(sizes[1]),
            part_dims(sizes[2]),
        );
        let text = format!(
            "HloModule t\n\nENTRY %main (a: {s0}, b: {s1}, c: {s2}) -> {so} {{\n  \
             %a = {s0} parameter(0)\n  %b = {s1} parameter(1)\n  %c = {s2} parameter(2)\n  \
             ROOT %r = {so} concatenate(%a, %b, %c), dimensions={{{dim}}}\n}}\n",
            s0 = shape(&d0),
            s1 = shape(&d1),
            s2 = shape(&d2),
            so = shape(&odims),
        );
        let args = vec![
            (rng.fill(d0.iter().product()), d0.clone()),
            (rng.fill(d1.iter().product()), d1.clone()),
            (rng.fill(d2.iter().product()), d2.clone()),
        ];
        assert_bit_exact(&text, &args, &format!("concat dim {dim} sizes {sizes:?}"));
    }
    for _ in 0..10 {
        let dims = vec![1 + rng.below(4), 1 + rng.below(4), 1 + rng.below(4)];
        let dim = rng.below(3);
        let n: usize = dims.iter().product();
        let text = format!(
            "HloModule t\n\nENTRY %main (a: {sa}) -> {sa} {{\n  \
             %a = {sa} parameter(0)\n  %i = {sa} iota(), iota_dimension={dim}\n  \
             ROOT %r = {sa} add(%a, %i)\n}}\n",
            sa = shape(&dims),
        );
        let args = vec![(rng.fill(n), dims.clone())];
        assert_bit_exact(&text, &args, &format!("iota dim {dim} of {dims:?}"));
    }
    for _ in 0..10 {
        let (a, b) = (1 + rng.below(6), 1 + rng.below(6));
        let n = a * b;
        let text = format!(
            "HloModule t\n\nENTRY %main (a: f32[{a},{b}]) -> f32[{n}] {{\n  \
             %a = f32[{a},{b}] parameter(0)\n  \
             %f = f32[{n}] reshape(%a)\n  \
             ROOT %r = f32[{n}] add(%f, %f)\n}}\n"
        );
        let args = vec![(rng.fill(n), vec![a, b])];
        assert_bit_exact(&text, &args, &format!("reshape [{a},{b}]"));
    }
}

#[test]
fn reduce_randomized_bit_exact_fast_and_general_paths() {
    let mut rng = Rng::new(0x4ed);
    let regions = "%add_f32 (p0: f32[], p1: f32[]) -> f32[] {\n  \
                   %p0 = f32[] parameter(0)\n  %p1 = f32[] parameter(1)\n  \
                   ROOT %r = f32[] add(%p0, %p1)\n}\n\n\
                   %max_f32 (q0: f32[], q1: f32[]) -> f32[] {\n  \
                   %q0 = f32[] parameter(0)\n  %q1 = f32[] parameter(1)\n  \
                   ROOT %m = f32[] maximum(%q0, %q1)\n}\n\n\
                   %sub_rev (r0: f32[], r1: f32[]) -> f32[] {\n  \
                   %r0 = f32[] parameter(0)\n  %r1 = f32[] parameter(1)\n  \
                   ROOT %s = f32[] subtract(%r1, %r0)\n}\n\n";
    for _ in 0..20 {
        let dims = vec![1 + rng.below(5), 1 + rng.below(5), 1 + rng.below(5)];
        let n: usize = dims.iter().product();
        // random non-empty subset of dims to reduce
        let mut red: Vec<usize> = (0..3).filter(|_| rng.below(2) == 1).collect();
        if red.is_empty() {
            red.push(rng.below(3));
        }
        let kept_dims: Vec<usize> = (0..3usize)
            .filter(|d| !red.contains(d))
            .map(|d| dims[d])
            .collect();
        let red_list: Vec<String> = red.iter().map(|d| d.to_string()).collect();
        // `subtract(%p1, %p0)` is non-commutative swapped: general path
        for region in ["add_f32", "max_f32", "sub_rev"] {
            let text = format!(
                "HloModule t\n\n{regions}ENTRY %main (a: {sa}) -> {so} {{\n  \
                 %a = {sa} parameter(0)\n  %z = f32[] constant(0.5)\n  \
                 ROOT %r = {so} reduce(%a, %z), dimensions={{{rl}}}, to_apply=%{region}\n}}\n",
                sa = shape(&dims),
                so = shape(&kept_dims),
                rl = red_list.join(","),
            );
            let args = vec![(rng.fill(n), dims.clone())];
            assert_bit_exact(&text, &args, &format!("reduce {region} dims {red:?} of {dims:?}"));
        }
    }
}

#[test]
fn tuple_roots_bit_exact() {
    let mut rng = Rng::new(0x70b1e);
    let text = "HloModule t\n\nENTRY %main (a: f32[3,4], b: f32[4,2]) -> (f32[3,2], f32[3,4]) {\n  \
                %a = f32[3,4] parameter(0)\n  %b = f32[4,2] parameter(1)\n  \
                %d = f32[3,2] dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n  \
                %s = f32[3,4] add(%a, %a)\n  \
                ROOT %t = (f32[3,2], f32[3,4]) tuple(%d, %s)\n}\n";
    let args = vec![(rng.fill(12), vec![3, 4]), (rng.fill(8), vec![4, 2])];
    assert_bit_exact(text, &args, "tuple root");
}

#[test]
fn arena_reuse_two_back_to_back_executions() {
    // chain with intermediates whose last uses free them mid-run: the
    // second execution must be served almost entirely from the pool and
    // produce bit-identical results
    let text = "HloModule t\n\nENTRY %main (a: f32[256], b: f32[256]) -> f32[256] {\n  \
                %a = f32[256] parameter(0)\n  %b = f32[256] parameter(1)\n  \
                %s = f32[256] add(%a, %b)\n  \
                %m = f32[256] multiply(%s, %b)\n  \
                %t = f32[256] subtract(%m, %a)\n  \
                ROOT %r = f32[256] multiply(%t, %m)\n}\n";
    let mut rng = Rng::new(0xa4e4a);
    let args = vec![(rng.fill(256), vec![256]), (rng.fill(256), vec![256])];
    let (client, exe) = compile(text);
    let bufs = buffers(&client, &args);

    let first = result_bits(exe.execute_b(&bufs).expect("first run"));
    let (fresh1, _reused1) = exe.arena_alloc_stats();
    assert!(fresh1 > 0, "first run allocates fresh buffers");

    let second = result_bits(exe.execute_b(&bufs).expect("second run"));
    let (fresh2, reused2) = exe.arena_alloc_stats();
    assert_eq!(first, second, "recycled buffers must not change results");
    assert!(
        fresh2 - fresh1 <= 1,
        "second run reuses pooled intermediates (fresh {fresh1} -> {fresh2})"
    );
    assert!(reused2 > 0, "second run reused at least one pooled buffer");

    let third = result_bits(exe.execute_b(&bufs).expect("third run"));
    assert_eq!(first, third);
}

#[test]
fn intermediates_freed_eagerly_within_one_execution() {
    // %s dies once %m is computed, so %t's buffer must come from the
    // arena even on the very first execution
    let text = "HloModule t\n\nENTRY %main (a: f32[64]) -> f32[64] {\n  \
                %a = f32[64] parameter(0)\n  \
                %s = f32[64] add(%a, %a)\n  \
                %m = f32[64] multiply(%s, %s)\n  \
                %t = f32[64] add(%m, %a)\n  \
                ROOT %r = f32[64] multiply(%t, %m)\n}\n";
    let mut rng = Rng::new(0xf4ee);
    let args = vec![(rng.fill(64), vec![64])];
    let (client, exe) = compile(text);
    let bufs = buffers(&client, &args);
    let planned = result_bits(exe.execute_b(&bufs).expect("planned"));
    let (_, reused) = exe.arena_alloc_stats();
    assert!(reused >= 1, "dead %s must be recycled for %t within one run");
    let reference = result_bits(exe.execute_b_reference(&bufs).expect("reference"));
    assert_eq!(planned, reference);
}
