//! Property-style robustness harness: feed randomized *malformed* HLO
//! text through the full parse → compile → verify pipeline and assert the
//! whole stack degrades to typed errors — it must never panic, whatever
//! garbage comes in.
//!
//! Deterministic by construction: a fixed-seed xorshift PRNG drives every
//! mutation, so any failure names the exact (seed, round) pair and
//! reproduces bit-for-bit. Mutations are length-preserving single-byte
//! replacements (from a small HLO-flavored alphabet), byte swaps, line
//! drops, line duplications and truncations — shapes in the corpus keep
//! at most two digits per dimension, so a mutated module can never
//! request a pathologically large allocation.

use xla::{HloModuleProto, PjRtClient, XlaComputation};

/// Fixed-seed xorshift64 — no external crates, fully reproducible.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Rng {
        Rng(seed.max(1))
    }
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }
    fn below(&mut self, n: usize) -> usize {
        (self.next() % n as u64) as usize
    }
}

/// Valid modules to corrupt: between them they cover parameters, dot,
/// transpose, reduce regions, reshape aliasing, tuples, broadcast,
/// compare/select and constants.
const CORPUS: [&str; 2] = [
    "HloModule robust_a\n\n%add (p0: f32[], p1: f32[]) -> f32[] {\n  \
     %p0 = f32[] parameter(0)\n  \
     %p1 = f32[] parameter(1)\n  \
     ROOT %s = f32[] add(%p0, %p1)\n}\n\n\
     ENTRY %main (x: f32[4,3], w: f32[3,5]) -> (f32[5,4], f32[4]) {\n  \
     %x = f32[4,3]{1,0} parameter(0)\n  \
     %w = f32[3,5]{1,0} parameter(1)\n  \
     %d = f32[4,5]{1,0} dot(f32[4,3] %x, f32[3,5] %w), \
     lhs_contracting_dims={1}, rhs_contracting_dims={0}\n  \
     %t = f32[5,4]{1,0} transpose(f32[4,5] %d), dimensions={1,0}\n  \
     %zero = f32[] constant(0)\n  \
     %sum = f32[4]{0} reduce(f32[4,3] %x, f32[] %zero), dimensions={1}, to_apply=%add\n  \
     ROOT %out = (f32[5,4], f32[4]) tuple(%t, %sum)\n}\n",
    "HloModule robust_b\n\nENTRY %main (x: f32[6,4]) -> f32[3,4] {\n  \
     %x = f32[6,4]{1,0} parameter(0)\n  \
     %s = f32[3,4]{1,0} slice(%x), slice={[0:6:2], [0:4]}\n  \
     %zero = f32[] constant(0)\n  \
     %zb = f32[3,4]{1,0} broadcast(%zero), dimensions={}\n  \
     %m = pred[3,4]{1,0} compare(%s, %zb), direction=GT\n  \
     %r = f32[3,4]{1,0} select(%m, %s, %zb)\n  \
     ROOT %f = f32[3,4]{1,0} reshape(%r)\n}\n",
];

/// Bytes a mutation may write: enough HLO structure to keep many mutants
/// parseable (the interesting ones), no way to grow a dimension past two
/// digits because replacements are length-preserving.
const ALPHABET: &[u8] = b"0123456789fspu%[]{}(),=:.-> abcdexyz";

fn mutate(rng: &mut Rng, text: &str) -> String {
    let mut bytes = text.as_bytes().to_vec();
    match rng.below(5) {
        // single-byte replacement
        0 | 1 => {
            let i = rng.below(bytes.len());
            bytes[i] = ALPHABET[rng.below(ALPHABET.len())];
        }
        // swap two bytes
        2 => {
            let (i, j) = (rng.below(bytes.len()), rng.below(bytes.len()));
            bytes.swap(i, j);
        }
        // drop or duplicate a whole line
        3 => {
            let mut lines: Vec<&str> = text.lines().collect();
            let i = rng.below(lines.len());
            if rng.below(2) == 0 {
                lines.remove(i);
            } else {
                lines.insert(i, lines[i]);
            }
            return lines.join("\n");
        }
        // truncate mid-stream
        _ => {
            let at = rng.below(bytes.len());
            bytes.truncate(at.max(1));
        }
    }
    // length-preserving byte edits can split a multi-byte char; the parser
    // must survive lossy text too
    String::from_utf8_lossy(&bytes).into_owned()
}

/// Every mutant must come out of parse → compile → verify as either a
/// clean success or a typed error — never a panic. Compile runs the
/// static plan verifier in test builds, so surviving mutants get their
/// plans proved sound; that is also asserted explicitly.
#[test]
fn malformed_hlo_yields_typed_errors_never_panics() {
    let client = PjRtClient::cpu().expect("client");
    let mut rng = Rng::new(0x5eed_cafe_f00d_0001);
    let (mut parsed, mut compiled) = (0usize, 0usize);
    for round in 0..400 {
        let base = CORPUS[round % CORPUS.len()];
        let mutant = mutate(&mut rng, base);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let proto = match HloModuleProto::from_text(&mutant) {
                Ok(proto) => proto,
                Err(_) => return (false, false),
            };
            match client.compile(&XlaComputation::from_proto(&proto)) {
                Ok(exe) => {
                    // a compiled mutant passed the verifier inside
                    // compile; re-verifying must agree
                    exe.verify().expect("compiled plan must re-verify clean");
                    (true, true)
                }
                Err(_) => (true, false),
            }
        }));
        match outcome {
            Ok((p, c)) => {
                parsed += usize::from(p);
                compiled += usize::from(c);
            }
            Err(_) => panic!("panic on round {round}; mutant was:\n{mutant}"),
        }
    }
    // the corpus must actually exercise the deep end of the pipeline, not
    // just bounce off the tokenizer
    assert!(parsed > 20, "only {parsed}/400 mutants parsed — mutations too destructive");
    assert!(compiled > 5, "only {compiled}/400 mutants compiled — corpus too brittle");
}

/// The same stream of mutants, replayed from the same seed, makes the
/// exact same decisions — the harness itself is deterministic.
#[test]
fn mutation_stream_is_deterministic() {
    let run = || {
        let mut rng = Rng::new(0x5eed_cafe_f00d_0001);
        (0..50)
            .map(|i| mutate(&mut rng, CORPUS[i % CORPUS.len()]))
            .collect::<Vec<_>>()
    };
    assert_eq!(run(), run());
}
