//! End-to-end check of the checked-in `surrogate_predict` HLO fixture:
//! the same linear-at-zero-weights property `rust/src/runtime/runtime.rs`
//! asserts through the full `Runtime`, here exercised at the crate
//! boundary (file → parse → compile → execute → untuple).

use std::path::Path;

use xla::{HloModuleProto, PjRtClient, XlaComputation};

const SUR_FEATS: usize = 72;
const SUR_HIDDEN: usize = 128;
const SUR_OUT: usize = 6;
const SUR_BATCH: usize = 256;

fn fixture(name: &str) -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name)
}

#[test]
fn surrogate_predict_fixture_is_linear_at_zero_weights() {
    let proto = HloModuleProto::from_text_file(fixture("surrogate_predict.hlo.txt"))
        .expect("fixture parses");
    let client = PjRtClient::cpu().unwrap();
    let exe = client
        .compile(&XlaComputation::from_proto(&proto))
        .expect("fixture compiles");

    let buf = |data: &[f32], dims: &[usize]| {
        client
            .buffer_from_host_buffer::<f32>(data, dims, None)
            .unwrap()
    };
    let z1 = vec![0.0f32; SUR_FEATS * SUR_HIDDEN];
    let zb1 = vec![0.0f32; SUR_HIDDEN];
    let z2 = vec![0.0f32; SUR_HIDDEN * SUR_HIDDEN];
    let zb2 = vec![0.0f32; SUR_HIDDEN];
    let z3 = vec![0.0f32; SUR_HIDDEN * SUR_OUT];
    let b3 = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
    let x = vec![0.5f32; SUR_BATCH * SUR_FEATS];
    let args = [
        buf(&z1, &[SUR_FEATS, SUR_HIDDEN]),
        buf(&zb1, &[SUR_HIDDEN]),
        buf(&z2, &[SUR_HIDDEN, SUR_HIDDEN]),
        buf(&zb2, &[SUR_HIDDEN]),
        buf(&z3, &[SUR_HIDDEN, SUR_OUT]),
        buf(&b3, &[SUR_OUT]),
        buf(&x, &[SUR_BATCH, SUR_FEATS]),
    ];
    let out = exe.execute_b(&args).expect("fixture executes");
    let leaves = out[0][0].to_literal_sync().unwrap().to_tuple().unwrap();
    assert_eq!(leaves.len(), 1, "surrogate_predict returns one output");
    let pred = leaves[0].to_vec::<f32>().unwrap();
    assert_eq!(pred.len(), SUR_BATCH * SUR_OUT);
    // all-zero weights → prediction == output bias everywhere
    for row in pred.chunks(SUR_OUT) {
        assert_eq!(row, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }
}

#[test]
fn surrogate_predict_fixture_responds_to_weights() {
    // one non-zero weight path: x[., 0] = 1, w1[0,0] = 1, w2[0,0] = 1,
    // w3[0, k] = k → pred[., k] = k (ReLU passes the positive activation)
    let proto =
        HloModuleProto::from_text_file(fixture("surrogate_predict.hlo.txt")).unwrap();
    let client = PjRtClient::cpu().unwrap();
    let exe = client
        .compile(&XlaComputation::from_proto(&proto))
        .unwrap();
    let buf = |data: &[f32], dims: &[usize]| {
        client
            .buffer_from_host_buffer::<f32>(data, dims, None)
            .unwrap()
    };
    let mut w1 = vec![0.0f32; SUR_FEATS * SUR_HIDDEN];
    w1[0] = 1.0;
    let mut w2 = vec![0.0f32; SUR_HIDDEN * SUR_HIDDEN];
    w2[0] = 1.0;
    let mut w3 = vec![0.0f32; SUR_HIDDEN * SUR_OUT];
    for k in 0..SUR_OUT {
        w3[k] = k as f32;
    }
    let zb = vec![0.0f32; SUR_HIDDEN];
    let zb3 = vec![0.0f32; SUR_OUT];
    let mut x = vec![0.0f32; SUR_BATCH * SUR_FEATS];
    for r in 0..SUR_BATCH {
        x[r * SUR_FEATS] = 1.0;
    }
    let args = [
        buf(&w1, &[SUR_FEATS, SUR_HIDDEN]),
        buf(&zb, &[SUR_HIDDEN]),
        buf(&w2, &[SUR_HIDDEN, SUR_HIDDEN]),
        buf(&zb, &[SUR_HIDDEN]),
        buf(&w3, &[SUR_HIDDEN, SUR_OUT]),
        buf(&zb3, &[SUR_OUT]),
        buf(&x, &[SUR_BATCH, SUR_FEATS]),
    ];
    let out = exe.execute_b(&args).unwrap();
    let pred = out[0][0]
        .to_literal_sync()
        .unwrap()
        .to_tuple()
        .unwrap()
        .remove(0)
        .to_vec::<f32>()
        .unwrap();
    for row in pred.chunks(SUR_OUT) {
        assert_eq!(row, &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
    }
}
