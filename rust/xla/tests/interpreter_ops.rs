//! Per-op unit tests for the HLO-text interpreter: parser round-trip +
//! numerics vs hand-computed expectations.

use xla::{HloModuleProto, PjRtClient, XlaComputation};

/// Parse, compile and execute a single-computation module against f32
/// arguments, returning the flat root value.
fn run(text: &str, args: &[(&[f32], &[usize])]) -> Vec<f32> {
    let proto = HloModuleProto::from_text(text).expect("parse");
    let client = PjRtClient::cpu().expect("client");
    let exe = client
        .compile(&XlaComputation::from_proto(&proto))
        .expect("compile");
    let buffers: Vec<xla::PjRtBuffer> = args
        .iter()
        .map(|(data, dims)| {
            client
                .buffer_from_host_buffer::<f32>(data, dims, None)
                .expect("buffer")
        })
        .collect();
    let out = exe.execute_b(&buffers).expect("execute");
    out[0][0]
        .to_literal_sync()
        .expect("literal")
        .to_vec::<f32>()
        .expect("to_vec")
}

fn entry(body: &str, params: &str, ret: &str) -> String {
    format!("HloModule t\n\nENTRY %main ({params}) -> {ret} {{\n{body}}}\n")
}

#[test]
fn elementwise_binary_ops() {
    for (op, expect) in [
        ("add", [5.0f32, -1.0]),
        ("subtract", [-1.0, 5.0]),
        ("multiply", [6.0, -6.0]),
        ("divide", [2.0 / 3.0, -2.0 / 3.0]),
        ("maximum", [3.0, 2.0]),
        ("minimum", [2.0, -3.0]),
    ] {
        let text = entry(
            &format!(
                "  %a = f32[2] parameter(0)\n  %b = f32[2] parameter(1)\n  \
                 ROOT %r = f32[2] {op}(%a, %b)\n"
            ),
            "a: f32[2], b: f32[2]",
            "f32[2]",
        );
        let out = run(&text, &[(&[2.0, 2.0], &[2]), (&[3.0, -3.0], &[2])]);
        assert_eq!(out, expect, "{op}");
    }
}

#[test]
fn unary_ops() {
    let text = entry(
        "  %a = f32[4] parameter(0)\n  %e = f32[4] exponential(%a)\n  \
         ROOT %l = f32[4] log(%e)\n",
        "a: f32[4]",
        "f32[4]",
    );
    let out = run(&text, &[(&[0.0, 1.0, -1.0, 2.5], &[4])]);
    for (o, e) in out.iter().zip([0.0f32, 1.0, -1.0, 2.5]) {
        assert!((o - e).abs() < 1e-6, "{o} vs {e}");
    }
    let text = entry(
        "  %a = f32[3] parameter(0)\n  %n = f32[3] negate(%a)\n  \
         ROOT %r = f32[3] abs(%n)\n",
        "a: f32[3]",
        "f32[3]",
    );
    assert_eq!(run(&text, &[(&[1.0, -2.0, 0.5], &[3])]), vec![1.0, 2.0, 0.5]);
    let text = entry(
        "  %a = f32[2] parameter(0)\n  ROOT %r = f32[2] rsqrt(%a)\n",
        "a: f32[2]",
        "f32[2]",
    );
    assert_eq!(run(&text, &[(&[4.0, 0.25], &[2])]), vec![0.5, 2.0]);
}

#[test]
fn compare_select_convert() {
    let text = entry(
        "  %a = f32[4] parameter(0)\n  %z = f32[] constant(0)\n  \
         %zb = f32[4] broadcast(%z), dimensions={}\n  \
         %m = pred[4] compare(%a, %zb), direction=GT\n  \
         %mf = f32[4] convert(%m)\n  \
         ROOT %r = f32[4] multiply(%mf, %a)\n",
        "a: f32[4]",
        "f32[4]",
    );
    // relu via compare+convert+multiply
    assert_eq!(
        run(&text, &[(&[1.5, -2.0, 0.0, 3.0], &[4])]),
        vec![1.5, 0.0, 0.0, 3.0]
    );
    let text = entry(
        "  %a = f32[4] parameter(0)\n  %b = f32[4] parameter(1)\n  \
         %m = pred[4] compare(%a, %b), direction=LE\n  \
         ROOT %r = f32[4] select(%m, %a, %b)\n",
        "a: f32[4], b: f32[4]",
        "f32[4]",
    );
    // elementwise min via select
    assert_eq!(
        run(
            &text,
            &[(&[1.0, 5.0, -1.0, 2.0], &[4]), (&[2.0, 4.0, -2.0, 2.0], &[4])]
        ),
        vec![1.0, 4.0, -2.0, 2.0]
    );
}

#[test]
fn broadcast_vector_along_rows_and_columns() {
    // dimensions={1}: operand indexes output dim 1 (a row vector copied
    // down the rows)
    let text = entry(
        "  %v = f32[3] parameter(0)\n  \
         ROOT %r = f32[2,3] broadcast(%v), dimensions={1}\n",
        "v: f32[3]",
        "f32[2,3]",
    );
    assert_eq!(
        run(&text, &[(&[1.0, 2.0, 3.0], &[3])]),
        vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]
    );
    // dimensions={0}: a column vector copied across the columns
    let text = entry(
        "  %v = f32[2] parameter(0)\n  \
         ROOT %r = f32[2,3] broadcast(%v), dimensions={0}\n",
        "v: f32[2]",
        "f32[2,3]",
    );
    assert_eq!(
        run(&text, &[(&[1.0, 2.0], &[2])]),
        vec![1.0, 1.0, 1.0, 2.0, 2.0, 2.0]
    );
}

#[test]
fn reshape_transpose_slice_concat_iota() {
    let text = entry(
        "  %a = f32[2,3] parameter(0)\n  \
         ROOT %t = f32[3,2] transpose(%a), dimensions={1,0}\n",
        "a: f32[2,3]",
        "f32[3,2]",
    );
    assert_eq!(
        run(&text, &[(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3])]),
        vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]
    );
    let text = entry(
        "  %a = f32[2,4] parameter(0)\n  \
         %s = f32[1,2] slice(%a), slice={[1:2], [1:3]}\n  \
         ROOT %r = f32[2] reshape(%s)\n",
        "a: f32[2,4]",
        "f32[2]",
    );
    assert_eq!(
        run(
            &text,
            &[(&[0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0], &[2, 4])]
        ),
        vec![5.0, 6.0]
    );
    let text = entry(
        "  %a = f32[1,2] parameter(0)\n  %b = f32[2,2] parameter(1)\n  \
         ROOT %c = f32[3,2] concatenate(%a, %b), dimensions={0}\n",
        "a: f32[1,2], b: f32[2,2]",
        "f32[3,2]",
    );
    assert_eq!(
        run(&text, &[(&[9.0, 8.0], &[1, 2]), (&[1.0, 2.0, 3.0, 4.0], &[2, 2])]),
        vec![9.0, 8.0, 1.0, 2.0, 3.0, 4.0]
    );
    let text = "HloModule t\n\nENTRY %main () -> f32[2,3] {\n  \
                ROOT %i = f32[2,3] iota(), iota_dimension=1\n}\n";
    assert_eq!(run(text, &[]), vec![0.0, 1.0, 2.0, 0.0, 1.0, 2.0]);
}

#[test]
fn strided_slice() {
    let text = entry(
        "  %a = f32[6] parameter(0)\n  \
         ROOT %s = f32[3] slice(%a), slice={[0:6:2]}\n",
        "a: f32[6]",
        "f32[3]",
    );
    assert_eq!(
        run(&text, &[(&[0.0, 1.0, 2.0, 3.0, 4.0, 5.0], &[6])]),
        vec![0.0, 2.0, 4.0]
    );
}

#[test]
fn dot_rank2_matmul() {
    // [[1,2],[3,4]] x [[5,6],[7,8]] = [[19,22],[43,50]]
    let text = entry(
        "  %a = f32[2,2] parameter(0)\n  %b = f32[2,2] parameter(1)\n  \
         ROOT %d = f32[2,2] dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n",
        "a: f32[2,2], b: f32[2,2]",
        "f32[2,2]",
    );
    assert_eq!(
        run(
            &text,
            &[(&[1.0, 2.0, 3.0, 4.0], &[2, 2]), (&[5.0, 6.0, 7.0, 8.0], &[2, 2])]
        ),
        vec![19.0, 22.0, 43.0, 50.0]
    );
}

#[test]
fn dot_transposed_contractions() {
    // contracting lhs dim 0 vs rhs dim 0: aᵀ·b — the gradient pattern
    let text = entry(
        "  %a = f32[2,3] parameter(0)\n  %b = f32[2,2] parameter(1)\n  \
         ROOT %d = f32[3,2] dot(%a, %b), lhs_contracting_dims={0}, rhs_contracting_dims={0}\n",
        "a: f32[2,3], b: f32[2,2]",
        "f32[3,2]",
    );
    let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // [[1,2,3],[4,5,6]]
    let b = [1.0f32, 0.0, 0.0, 1.0]; // identity
    assert_eq!(
        run(&text, &[(&a, &[2, 3]), (&b, &[2, 2])]),
        vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0] // aᵀ
    );
    // contracting lhs dim 1 vs rhs dim 1: a·bᵀ — the backprop-through-W
    // pattern
    let text = entry(
        "  %a = f32[2,3] parameter(0)\n  %b = f32[4,3] parameter(1)\n  \
         ROOT %d = f32[2,4] dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={1}\n",
        "a: f32[2,3], b: f32[4,3]",
        "f32[2,4]",
    );
    let a = [1.0f32, 0.0, 0.0, 0.0, 1.0, 0.0]; // rows e0, e1
    let b = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0, 11.0, 12.0];
    assert_eq!(
        run(&text, &[(&a, &[2, 3]), (&b, &[4, 3])]),
        vec![1.0, 4.0, 7.0, 10.0, 2.0, 5.0, 8.0, 11.0] // bᵀ's first two rows
    );
}

#[test]
fn dot_batched() {
    // batch dim 0, contract lhs{2} rhs{1}: two independent 1x2 · 2x1
    let text = entry(
        "  %a = f32[2,1,2] parameter(0)\n  %b = f32[2,2,1] parameter(1)\n  \
         ROOT %d = f32[2,1,1] dot(%a, %b), lhs_batch_dims={0}, rhs_batch_dims={0}, \
         lhs_contracting_dims={2}, rhs_contracting_dims={1}\n",
        "a: f32[2,1,2], b: f32[2,2,1]",
        "f32[2,1,1]",
    );
    let a = [1.0f32, 2.0, 3.0, 4.0];
    let b = [10.0f32, 20.0, 30.0, 40.0];
    // batch 0: [1,2]·[10,20] = 50; batch 1: [3,4]·[30,40] = 250
    assert_eq!(run(&text, &[(&a, &[2, 1, 2]), (&b, &[2, 2, 1])]), vec![50.0, 250.0]);
}

#[test]
fn reduce_add_and_max_over_rows_and_all() {
    let region = "%add_f32 (a: f32[], b: f32[]) -> f32[] {\n  \
                  %a = f32[] parameter(0)\n  %b = f32[] parameter(1)\n  \
                  ROOT %r = f32[] add(%a, %b)\n}\n\n\
                  %max_f32 (c: f32[], d: f32[]) -> f32[] {\n  \
                  %c = f32[] parameter(0)\n  %d = f32[] parameter(1)\n  \
                  ROOT %m = f32[] maximum(%c, %d)\n}\n\n";
    let text = format!(
        "HloModule t\n\n{region}ENTRY %main (a: f32[2,3]) -> f32[2] {{\n  \
         %a = f32[2,3] parameter(0)\n  %z = f32[] constant(0)\n  \
         ROOT %s = f32[2] reduce(%a, %z), dimensions={{1}}, to_apply=%add_f32\n}}\n"
    );
    let data = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
    assert_eq!(run(&text, &[(&data, &[2, 3])]), vec![6.0, 15.0]);
    let text = format!(
        "HloModule t\n\n{region}ENTRY %main (a: f32[2,3]) -> f32[3] {{\n  \
         %a = f32[2,3] parameter(0)\n  %n = f32[] constant(-inf)\n  \
         ROOT %m = f32[3] reduce(%a, %n), dimensions={{0}}, to_apply=%max_f32\n}}\n"
    );
    assert_eq!(run(&text, &[(&data, &[2, 3])]), vec![4.0, 5.0, 6.0]);
    let text = format!(
        "HloModule t\n\n{region}ENTRY %main (a: f32[2,3]) -> f32[] {{\n  \
         %a = f32[2,3] parameter(0)\n  %z = f32[] constant(0)\n  \
         ROOT %s = f32[] reduce(%a, %z), dimensions={{0,1}}, to_apply=%add_f32\n}}\n"
    );
    assert_eq!(run(&text, &[(&data, &[2, 3])]), vec![21.0]);
}

#[test]
fn reduce_nontrivial_region_falls_back_to_interpretation() {
    // region computes a + 2b — not a recognised fast path
    let text = "HloModule t\n\n\
                %weird (a: f32[], b: f32[]) -> f32[] {\n  \
                %a = f32[] parameter(0)\n  %b = f32[] parameter(1)\n  \
                %two = f32[] constant(2)\n  %bb = f32[] multiply(%two, %b)\n  \
                ROOT %r = f32[] add(%a, %bb)\n}\n\n\
                ENTRY %main (a: f32[3]) -> f32[] {\n  \
                %a = f32[3] parameter(0)\n  %z = f32[] constant(0)\n  \
                ROOT %s = f32[] reduce(%a, %z), dimensions={0}, to_apply=%weird\n}\n";
    // fold: ((0 + 2·1) + 2·2) + 2·3 = 12
    assert_eq!(run(text, &[(&[1.0, 2.0, 3.0], &[3])]), vec![12.0]);
}

#[test]
fn constants_scalar_vector_and_nested() {
    let text = "HloModule t\n\nENTRY %main () -> f32[2,2] {\n  \
                ROOT %c = f32[2,2] constant({ { 1, 2 }, { 3.5, -4 } })\n}\n";
    assert_eq!(run(text, &[]), vec![1.0, 2.0, 3.5, -4.0]);
    let text = "HloModule t\n\nENTRY %main () -> f32[3] {\n  \
                %c = f32[3] constant({1, -2, 0.25})\n  \
                %s = f32[] constant(2)\n  \
                ROOT %r = f32[3] multiply(%c, %s)\n}\n";
    assert_eq!(run(text, &[]), vec![2.0, -4.0, 0.5]);
}

#[test]
fn tuple_roundtrip_through_get_tuple_element() {
    let text = "HloModule t\n\nENTRY %main (a: f32[2], b: f32[3]) -> f32[3] {\n  \
                %a = f32[2] parameter(0)\n  %b = f32[3] parameter(1)\n  \
                %t = (f32[2], f32[3]) tuple(%a, %b)\n  \
                ROOT %g = f32[3] get-tuple-element(%t), index=1\n}\n";
    assert_eq!(
        run(text, &[(&[1.0, 2.0], &[2]), (&[7.0, 8.0, 9.0], &[3])]),
        vec![7.0, 8.0, 9.0]
    );
}

#[test]
fn tuple_root_untuples_into_leaves() {
    let text = "HloModule t\n\nENTRY %main (a: f32[2]) -> (f32[2], f32[]) {\n  \
                %a = f32[2] parameter(0)\n  %z = f32[] constant(41)\n  \
                %one = f32[] constant(1)\n  %s = f32[] add(%z, %one)\n  \
                ROOT %t = (f32[2], f32[]) tuple(%a, %s)\n}\n";
    let proto = HloModuleProto::from_text(text).unwrap();
    let client = PjRtClient::cpu().unwrap();
    let exe = client.compile(&XlaComputation::from_proto(&proto)).unwrap();
    let a = client
        .buffer_from_host_buffer::<f32>(&[5.0, 6.0], &[2], None)
        .unwrap();
    let out = exe.execute_b(&[a]).unwrap();
    let leaves = out[0][0].to_literal_sync().unwrap().to_tuple().unwrap();
    assert_eq!(leaves.len(), 2);
    assert_eq!(leaves[0].to_vec::<f32>().unwrap(), vec![5.0, 6.0]);
    assert_eq!(leaves[1].to_vec::<f32>().unwrap(), vec![42.0]);
}

#[test]
fn layouts_inline_shapes_and_metadata_are_tolerated() {
    // decoration an XLA as_hlo_text dump carries: layouts on shapes,
    // operand shape annotations, metadata attributes
    let text = "HloModule jit_f, entry_computation_layout={(f32[2,2]{1,0})->f32[2,2]{1,0}}\n\n\
                ENTRY %main.4 (Arg_0.1: f32[2,2]) -> f32[2,2] {\n  \
                %Arg_0.1 = f32[2,2]{1,0} parameter(0), metadata={op_name=\"args[0]\"}\n  \
                ROOT %multiply.3 = f32[2,2]{1,0} multiply(f32[2,2]{1,0} %Arg_0.1, f32[2,2]{1,0} %Arg_0.1), metadata={op_type=\"mul\" op_name=\"jit(f)/mul\" source_file=\"x.py\" source_line=1}\n}\n";
    assert_eq!(
        run(text, &[(&[1.0, 2.0, 3.0, 4.0], &[2, 2])]),
        vec![1.0, 4.0, 9.0, 16.0]
    );
}

#[test]
fn power_and_tanh() {
    let text = entry(
        "  %a = f32[2] parameter(0)\n  %e = f32[] constant(2)\n  \
         ROOT %p = f32[2] power(%a, %e)\n",
        "a: f32[2]",
        "f32[2]",
    );
    assert_eq!(run(&text, &[(&[3.0, -2.0], &[2])]), vec![9.0, 4.0]);
    let text = entry(
        "  %a = f32[1] parameter(0)\n  ROOT %t = f32[1] tanh(%a)\n",
        "a: f32[1]",
        "f32[1]",
    );
    let out = run(&text, &[(&[0.5], &[1])]);
    assert!((out[0] - 0.5f32.tanh()).abs() < 1e-6);
}

/// Compile and execute expecting failure; returns the error message.
/// Since the execution-plan refactor, shape/stride validation runs at
/// `compile` time; this helper accepts a clean failure from either phase
/// (never a panic) and returns its message.
fn run_err(text: &str, args: &[(&[f32], &[usize])]) -> String {
    let proto = HloModuleProto::from_text(text).expect("parse");
    let client = PjRtClient::cpu().expect("client");
    let exe = match client.compile(&XlaComputation::from_proto(&proto)) {
        Ok(exe) => exe,
        Err(e) => return e.to_string(),
    };
    let buffers: Vec<xla::PjRtBuffer> = args
        .iter()
        .map(|(data, dims)| {
            client
                .buffer_from_host_buffer::<f32>(data, dims, None)
                .expect("buffer")
        })
        .collect();
    exe.execute_b(&buffers)
        .expect_err("execution must fail, not panic")
        .to_string()
}

#[test]
fn zero_size_dimensions_broadcast_and_reshape() {
    // broadcasting an empty operand into an empty output is a no-op, not
    // a panic (an empty generation shard produces exactly these shapes)
    let text = entry(
        "  %a = f32[0] parameter(0)\n  \
         ROOT %b = f32[3,0] broadcast(%a), dimensions={1}\n",
        "a: f32[0]",
        "f32[3,0]",
    );
    assert_eq!(run(&text, &[(&[], &[0])]), Vec::<f32>::new());

    // reshape between equally-empty shapes
    let text = entry(
        "  %a = f32[2,0] parameter(0)\n  ROOT %r = f32[0,4] reshape(%a)\n",
        "a: f32[2,0]",
        "f32[0,4]",
    );
    assert_eq!(run(&text, &[(&[], &[2, 0])]), Vec::<f32>::new());
}

#[test]
fn zero_size_concatenate_contributes_nothing() {
    // an empty operand in the middle of a concat must not shift data
    let text = entry(
        "  %a = f32[2,1] parameter(0)\n  %e = f32[2,0] parameter(1)\n  \
         %b = f32[2,2] parameter(2)\n  \
         ROOT %c = f32[2,3] concatenate(%a, %e, %b), dimensions={1}\n",
        "a: f32[2,1], e: f32[2,0], b: f32[2,2]",
        "f32[2,3]",
    );
    assert_eq!(
        run(
            &text,
            &[
                (&[1.0, 2.0], &[2, 1]),
                (&[], &[2, 0]),
                (&[10.0, 11.0, 20.0, 21.0], &[2, 2]),
            ]
        ),
        vec![1.0, 10.0, 11.0, 2.0, 20.0, 21.0]
    );

    // all-empty concat along the concat dim yields the other operand
    let text = entry(
        "  %e = f32[0] parameter(0)\n  %b = f32[2] parameter(1)\n  \
         ROOT %c = f32[2] concatenate(%e, %b), dimensions={0}\n",
        "e: f32[0], b: f32[2]",
        "f32[2]",
    );
    assert_eq!(run(&text, &[(&[], &[0]), (&[5.0, 6.0], &[2])]), vec![5.0, 6.0]);
}

#[test]
fn single_element_reduce_folds_once() {
    let text = "HloModule t\n\n\
                %add (p0: f32[], p1: f32[]) -> f32[] {\n  \
                %p0 = f32[] parameter(0)\n  %p1 = f32[] parameter(1)\n  \
                ROOT %r = f32[] add(%p0, %p1)\n}\n\n\
                ENTRY %main (a: f32[1]) -> f32[] {\n  \
                %a = f32[1] parameter(0)\n  %z = f32[] constant(10)\n  \
                ROOT %s = f32[] reduce(%a, %z), dimensions={0}, to_apply=%add\n}\n";
    // init ⊕ the single element, exactly once
    assert_eq!(run(text, &[(&[32.0], &[1])]), vec![42.0]);

    // keeping a dimension of size one: reduce the singleton axis away
    let text = "HloModule t\n\n\
                %max (p0: f32[], p1: f32[]) -> f32[] {\n  \
                %p0 = f32[] parameter(0)\n  %p1 = f32[] parameter(1)\n  \
                ROOT %r = f32[] maximum(%p0, %p1)\n}\n\n\
                ENTRY %main (a: f32[1,3]) -> f32[3] {\n  \
                %a = f32[1,3] parameter(0)\n  %z = f32[] constant(-10)\n  \
                ROOT %s = f32[3] reduce(%a, %z), dimensions={0}, to_apply=%max\n}\n";
    assert_eq!(run(text, &[(&[3.0, -20.0, 7.0], &[1, 3])]), vec![3.0, -10.0, 7.0]);
}

#[test]
fn out_of_range_strided_slice_is_an_error_naming_the_op() {
    // limit beyond the dimension
    let text = entry(
        "  %a = f32[4] parameter(0)\n  \
         ROOT %sl = f32[7] slice(%a), slice={[2:9:1]}\n",
        "a: f32[4]",
        "f32[7]",
    );
    let err = run_err(&text, &[(&[1.0, 2.0, 3.0, 4.0], &[4])]);
    assert!(err.contains("%sl"), "error names the op: {err}");
    assert!(err.contains("out of bounds"), "{err}");

    // start beyond the limit
    let text = entry(
        "  %a = f32[4] parameter(0)\n  \
         ROOT %sl = f32[0] slice(%a), slice={[3:1:1]}\n",
        "a: f32[4]",
        "f32[0]",
    );
    let err = run_err(&text, &[(&[1.0, 2.0, 3.0, 4.0], &[4])]);
    assert!(err.contains("%sl"), "error names the op: {err}");

    // a declared output shape that disagrees with the produced extent
    let text = entry(
        "  %a = f32[6] parameter(0)\n  \
         ROOT %sl = f32[4] slice(%a), slice={[0:6:2]}\n",
        "a: f32[6]",
        "f32[4]",
    );
    let err = run_err(&text, &[(&[0.0; 6], &[6])]);
    assert!(err.contains("%sl"), "error names the op: {err}");

    // sanity: the in-range strided sibling still evaluates
    let text = entry(
        "  %a = f32[6] parameter(0)\n  \
         ROOT %sl = f32[3] slice(%a), slice={[0:6:2]}\n",
        "a: f32[6]",
        "f32[3]",
    );
    assert_eq!(
        run(&text, &[(&[0.0, 1.0, 2.0, 3.0, 4.0, 5.0], &[6])]),
        vec![0.0, 2.0, 4.0]
    );
}

#[test]
fn reduce_with_duplicate_dimensions_is_a_typed_error() {
    // used to build a double-counted offset table and panic with
    // index-out-of-bounds; must be a clean error naming the op
    let text = "HloModule t\n\n\
                %sum (p0: f32[], p1: f32[]) -> f32[] {\n  \
                %p0 = f32[] parameter(0)\n  %p1 = f32[] parameter(1)\n  \
                ROOT %s = f32[] add(%p0, %p1)\n}\n\n\
                ENTRY %main (a: f32[2,3]) -> f32[3] {\n  \
                %a = f32[2,3] parameter(0)\n  %z = f32[] constant(0)\n  \
                ROOT %r = f32[3] reduce(%a, %z), dimensions={0,0}, to_apply=%sum\n}\n";
    let err = run_err(text, &[(&[0.0; 6], &[2, 3])]);
    assert!(err.contains("%r"), "error names the op: {err}");
    assert!(err.contains("reduce") && err.contains("more than once"), "{err}");
}

#[test]
fn dot_with_duplicate_dimensions_is_a_typed_error() {
    let text = entry(
        "  %a = f32[2,2] parameter(0)\n  %b = f32[2,2] parameter(1)\n  \
         ROOT %d = f32[4] dot(%a, %b), lhs_contracting_dims={0,0}, \
         rhs_contracting_dims={0,1}\n",
        "a: f32[2,2], b: f32[2,2]",
        "f32[4]",
    );
    let err = run_err(&text, &[(&[0.0; 4], &[2, 2]), (&[0.0; 4], &[2, 2])]);
    assert!(err.contains("%d"), "error names the op: {err}");
    assert!(err.contains("dot") && err.contains("more than once"), "{err}");
}

#[test]
fn broadcast_dimensions_must_be_strictly_increasing() {
    // duplicate entries used to silently compute a wrong operand index
    let text = entry(
        "  %a = f32[2,2] parameter(0)\n  \
         ROOT %b = f32[2,2] broadcast(%a), dimensions={0,0}\n",
        "a: f32[2,2]",
        "f32[2,2]",
    );
    let err = run_err(&text, &[(&[1.0, 2.0, 3.0, 4.0], &[2, 2])]);
    assert!(err.contains("%b"), "error names the op: {err}");
    assert!(err.contains("strictly increasing"), "{err}");

    // permuted (transpose-like) mappings are rejected too — XLA requires
    // an explicit transpose for that
    let text = entry(
        "  %a = f32[2,3] parameter(0)\n  \
         ROOT %b = f32[3,2] broadcast(%a), dimensions={1,0}\n",
        "a: f32[2,3]",
        "f32[3,2]",
    );
    let err = run_err(&text, &[(&[0.0; 6], &[2, 3])]);
    assert!(err.contains("strictly increasing"), "{err}");
}

#[test]
fn duplicate_dim_validation_also_guards_the_reference_evaluator() {
    // the naive evaluator (the differential oracle) must reject the same
    // malformed modules instead of panicking
    let text = "HloModule t\n\n\
                %sum (p0: f32[], p1: f32[]) -> f32[] {\n  \
                %p0 = f32[] parameter(0)\n  %p1 = f32[] parameter(1)\n  \
                ROOT %s = f32[] add(%p0, %p1)\n}\n\n\
                ENTRY %main (a: f32[2,3]) -> f32[3] {\n  \
                %a = f32[2,3] parameter(0)\n  %z = f32[] constant(0)\n  \
                ROOT %r = f32[3] reduce(%a, %z), dimensions={0,0}, to_apply=%sum\n}\n";
    let module = xla::parser::parse_module(text).expect("parse");
    let arg = xla::interp::Value::Array(
        xla::interp::ArrayValue::new(
            xla::parser::Shape {
                dtype: xla::parser::DType::F32,
                dims: vec![2, 3],
            },
            vec![0.0; 6],
        )
        .unwrap(),
    );
    let err = xla::interp::evaluate(&module, module.entry, &[arg])
        .expect_err("must error, not panic");
    assert!(err.to_string().contains("more than once"), "{err}");
}
